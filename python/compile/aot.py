"""AOT lowering: JAX/Pallas (L2+L1) → HLO text artifacts for the rust
runtime.

HLO *text* is the interchange format, NOT a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (under --out-dir, default ../artifacts):
  spmv_{n}x{w}.hlo.txt      — one SpMV       (4 inputs, 1-tuple output)
  cg_{n}x{w}_i{it}.hlo.txt  — full CG scan   (4 inputs, 2-tuple output)
  manifest.txt              — one line per artifact: name n w [iters]

Run via `make artifacts`; python never runs on the request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# The AOT shape set. Row counts are multiples of BLOCK_ROWS (1024) so the
# Pallas grid divides evenly; widths cover 2-D (w=8) and 3-D (w=16) meshes.
SPMV_SHAPES = [(4096, 8), (16384, 8), (16384, 16), (65536, 8)]
CG_SHAPES = [(16384, 8, 64)]  # (n, w, iters)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spmv(n: int, w: int) -> str:
    # Donating x would let XLA alias the output buffer, but the rust
    # driver reuses inputs across calls, so no donation for spmv.
    # block_rows = n: whole-array Pallas tile for the CPU-interpret
    # artifact (the grid loop costs 12x on XLA-CPU; TPU lowering would
    # pass the VMEM-sized default instead — see model.spmv).
    fn = lambda values, cols, diag, x: model.spmv(values, cols, diag, x, block_rows=n)
    lowered = jax.jit(fn).lower(*model.spmv_shapes(n, w))
    return to_hlo_text(lowered)


def lower_cg(n: int, w: int, iters: int) -> str:
    fn = lambda values, cols, diag, b: model.cg_run(
        values, cols, diag, b, iters, block_rows=n
    )
    lowered = jax.jit(fn).lower(*model.cg_shapes(n, w))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the smallest spmv shape (CI smoke)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    spmv_shapes = SPMV_SHAPES[:1] if args.quick else SPMV_SHAPES
    for n, w in spmv_shapes:
        name = f"spmv_{n}x{w}"
        text = lower_spmv(n, w)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} {n} {w}")
        print(f"wrote {path} ({len(text)} chars)")
    if not args.quick:
        for n, w, iters in CG_SHAPES:
            name = f"cg_{n}x{w}_i{iters}"
            text = lower_cg(n, w, iters)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest.append(f"{name} {n} {w} {iters}")
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
