"""L2 — the JAX compute graph the rust coordinator executes via PJRT.

Two entry points, both calling the L1 Pallas kernel:

* :func:`spmv`: one shifted-Laplacian SpMV ``y = diag·x + A_ell·x`` —
  the per-block hot path of the distributed CG driver (rust runs one of
  these per PU per iteration, on that PU's padded row block).
* :func:`cg_run`: a whole conjugate-gradient solve as a single fused
  ``lax.scan`` — `iters` CG steps with no host round-trips, used by the
  end-to-end example for the single-executable baseline and by L2 perf
  measurements. Buffers are donated at lowering time (see aot.py) so XLA
  reuses the state in place.

Python never runs at request time: `aot.py` lowers these once to HLO
text; the rust runtime compiles and executes them through the PJRT C
API.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.spmv_pallas import spmv_ell


def spmv(values, cols, diag, x, block_rows=None):
    """Shifted-Laplacian SpMV: ``(diag(d) + ELL) @ x``.

    `block_rows` picks the Pallas tile height. On real TPUs the default
    (1024) keeps tiles inside VMEM; for the CPU-interpret artifacts the
    grid loop lowers to a serialized dynamic-slice `while`, so the AOT
    path uses one whole-array tile (block_rows = n) — measured 12x faster
    on XLA-CPU with identical numerics (EXPERIMENTS.md §Perf).
    """
    br = block_rows if block_rows is not None else 1024
    return diag * x + spmv_ell(values, cols, x, block_rows=br)


def cg_run(values, cols, diag, b, iters: int, block_rows=None):
    """`iters` steps of conjugate gradients from x0 = 0.

    Returns (x, residual_norms[iters]).
    """

    tiny = jnp.asarray(1e-30, b.dtype)

    def step(state, _):
        # Guarded divisions: a fixed-length scan keeps stepping after
        # convergence, where rs and p'Ap underflow to 0 (0/0 = NaN).
        x, r, p, rs = state
        ap = spmv(values, cols, diag, p, block_rows=block_rows)
        alpha = rs / jnp.maximum(jnp.dot(p, ap), tiny)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.maximum(rs, tiny)
        p = r + beta * p
        return (x, r, p, rs_new), jnp.sqrt(rs_new)

    x0 = jnp.zeros_like(b)
    init = (x0, b, b, jnp.dot(b, b))
    (x, _r, _p, _rs), norms = lax.scan(step, init, None, length=iters)
    return x, norms


def spmv_shapes(n: int, w: int):
    """Example-argument shapes for lowering `spmv`."""
    f = jax.ShapeDtypeStruct
    return (
        f((n, w), jnp.float32),   # values
        f((n, w), jnp.int32),     # cols
        f((n,), jnp.float32),     # diag
        f((n,), jnp.float32),     # x
    )


def cg_shapes(n: int, w: int):
    """Example-argument shapes for lowering `cg_run` (iters is static)."""
    f = jax.ShapeDtypeStruct
    return (
        f((n, w), jnp.float32),
        f((n, w), jnp.int32),
        f((n,), jnp.float32),
        f((n,), jnp.float32),     # b
    )
