"""Pure-jnp correctness oracles for the L1 kernel and the L2 CG model.

Everything here is deliberately naive: dense matrices and textbook CG.
pytest compares the Pallas kernel and the lowered artifacts against these.
"""

import jax.numpy as jnp


def spmv_ell_ref(values, cols, x):
    """Reference ELL SpMV: y[i] = sum_j values[i,j] * x[cols[i,j]]."""
    return jnp.sum(values * x[cols], axis=1)


def ell_to_dense(values, cols, n):
    """Expand an ELL matrix to dense (for small-shape cross-checks).

    Padding entries (value 0) contribute nothing regardless of their
    column index, matching the kernel's convention.
    """
    a = jnp.zeros((n, n), dtype=values.dtype)
    rows = jnp.arange(n)[:, None] * jnp.ones_like(cols)
    return a.at[rows.reshape(-1), cols.reshape(-1)].add(values.reshape(-1))


def spmv_dense_ref(values, cols, diag, x):
    """Full shifted-Laplacian SpMV via a dense matrix."""
    n = x.shape[0]
    a = ell_to_dense(values, cols, n) + jnp.diag(diag)
    return a @ x


def cg_ref(values, cols, diag, b, iters):
    """Textbook conjugate gradients on A = diag + ELL, fixed iterations.

    Returns (x, residual_norms) with residual_norms of length `iters`.
    """
    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = jnp.dot(r, r)
    norms = []
    tiny = jnp.asarray(1e-30, b.dtype)
    for _ in range(iters):
        ap = diag * p + spmv_ell_ref(values, cols, p)
        alpha = rs / jnp.maximum(jnp.dot(p, ap), tiny)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.maximum(rs, tiny)
        p = r + beta * p
        rs = rs_new
        norms.append(jnp.sqrt(rs_new))
    return x, jnp.stack(norms)
