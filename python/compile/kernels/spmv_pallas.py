"""L1 — Pallas blocked-ELL SpMV kernel.

The application hot-spot of the paper's benchmarks (SpMV inside CG,
§VI-a), written as a Pallas kernel with a TPU-shaped layout:

* **ELL format**: the shifted-Laplacian rows are stored as dense
  ``values[n, w]`` / ``cols[n, w]`` with zero-padding — mesh graphs have
  bounded degree, so the padding waste is small (w = 8 for 2-D meshes,
  16 for 3-D). Dense tiles are what the TPU's VPU (8×128 lanes) wants;
  this is the TPU analogue of a GPU warp-per-row CSR kernel (see
  DESIGN.md §Hardware-Adaptation).
* **BlockSpec schedule**: the grid walks row tiles of ``BLOCK_ROWS``;
  ``values``/``cols`` stream tile-by-tile through VMEM while ``x`` stays
  resident (the gather target must be fully addressable). With the
  largest AOT shape (n = 65536, f32) x occupies 256 KiB — comfortably
  inside the ~16 MiB VMEM budget; a values/cols tile is
  1024×8×4 B = 32 KiB each.
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
  Mosaic custom-calls, so the kernel is lowered to plain HLO. Real-TPU
  performance is *estimated* from the VMEM footprint in DESIGN.md; the
  interpret path provides the numerics for every test and artifact.

The diagonal is kept separate (``y = diag·x + ELL(values, cols)·x``):
the rank-1 diagonal product fuses into the surrounding XLA graph for
free and halves the ELL width needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 1024×8 f32 tiles = 32 KiB per operand in VMEM.
BLOCK_ROWS = 1024


def _spmv_ell_kernel(vals_ref, cols_ref, x_ref, o_ref):
    """One row-tile: o[i] = Σ_j vals[i, j] · x[cols[i, j]]."""
    vals = vals_ref[...]  # (bn, w)
    cols = cols_ref[...]  # (bn, w) int32
    x = x_ref[...]  # (n,) resident in VMEM
    o_ref[...] = jnp.sum(vals * x[cols], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def spmv_ell(values, cols, x, *, block_rows: int = BLOCK_ROWS):
    """ELL SpMV via the Pallas kernel (off-diagonal part only).

    Args:
      values: (n, w) float32 — padded row entries (0 in padding slots).
      cols:   (n, w) int32   — column of each entry (0 in padding slots;
              padding values are 0 so the gathered x contributes nothing).
      x:      (n,) float32.

    Returns: (n,) float32 — ``A_ell @ x``.
    """
    n, w = values.shape
    bn = min(block_rows, n)
    if n % bn != 0:
        # AOT shapes are multiples of BLOCK_ROWS; tests may use odd sizes.
        bn = _largest_divisor(n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        _spmv_ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, w), lambda i: (i, 0)),
            pl.BlockSpec((bn, w), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), values.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(values, cols, x)


def _largest_divisor(n: int, at_most: int) -> int:
    d = min(at_most, n)
    while n % d != 0:
        d -= 1
    return d


def vmem_footprint_bytes(n: int, w: int, block_rows: int = BLOCK_ROWS) -> dict:
    """Static VMEM budget estimate for DESIGN.md §Perf (no TPU here, so
    the schedule is validated by arithmetic, not wallclock)."""
    bn = min(block_rows, n)
    return {
        "values_tile": bn * w * 4,
        "cols_tile": bn * w * 4,
        "x_resident": n * 4,
        "out_tile": bn * 4,
        "total": bn * w * 8 + n * 4 + bn * 4,
    }
