#!/usr/bin/env python3
"""Render benchmark CSVs from results/ as ASCII charts.

The bench harness writes one CSV per paper table/figure; this renders
quick terminal views of them without any plotting dependency (the image
is offline). Examples:

    python python/analysis.py results/fig2a.csv --value rel_cut --group algo
    python python/analysis.py results/fig5.csv --value 'simCG_t/iter(ms)' --group algo
    python python/analysis.py results/table3.csv
"""

import argparse
import csv
import sys
from collections import defaultdict


def read_rows(path):
    with open(path, newline="") as f:
        return list(csv.DictReader(f))


def geomean(xs):
    import math
    xs = [x for x in xs if x > 0]
    if not xs:
        return float("nan")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def bar_chart(items, width=48):
    """items: list of (label, value). Renders horizontal bars."""
    if not items:
        return "(no data)"
    vmax = max(v for _, v in items) or 1.0
    lw = max(len(l) for l, _ in items)
    lines = []
    for label, v in items:
        n = int(round(width * v / vmax))
        lines.append(f"{label:<{lw}}  {'#' * n} {v:.3g}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("csv_path")
    ap.add_argument("--value", help="numeric column to aggregate")
    ap.add_argument("--group", help="column to group by (geomean per group)")
    ap.add_argument("--width", type=int, default=48)
    args = ap.parse_args()

    rows = read_rows(args.csv_path)
    if not rows:
        print("empty CSV", file=sys.stderr)
        return 1

    if not args.value or not args.group:
        # Plain aligned dump.
        cols = list(rows[0].keys())
        widths = {c: max(len(c), *(len(r[c]) for r in rows)) for c in cols}
        print("  ".join(f"{c:<{widths[c]}}" for c in cols))
        for r in rows:
            print("  ".join(f"{r[c]:<{widths[c]}}" for c in cols))
        return 0

    groups = defaultdict(list)
    for r in rows:
        try:
            groups[r[args.group]].append(float(r[args.value]))
        except (ValueError, KeyError):
            continue
    items = sorted(
        ((g, geomean(vs)) for g, vs in groups.items()), key=lambda kv: kv[1]
    )
    print(f"{args.csv_path}: geomean of {args.value} by {args.group}")
    print(bar_chart(items, args.width))
    return 0


if __name__ == "__main__":
    sys.exit(main())
