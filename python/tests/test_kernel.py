"""L1 kernel vs pure-jnp oracle — the core correctness signal.

The Pallas kernel runs under interpret=True (CPU PJRT cannot execute
Mosaic custom-calls); hypothesis sweeps shapes, widths and degree
distributions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ell_to_dense, spmv_dense_ref, spmv_ell_ref
from compile.kernels.spmv_pallas import spmv_ell, vmem_footprint_bytes


def random_ell(rng, n, w, frac_filled=0.7, dtype=np.float32):
    """Random padded ELL matrix: ~frac_filled of slots used."""
    values = rng.standard_normal((n, w)).astype(dtype)
    cols = rng.integers(0, n, size=(n, w)).astype(np.int32)
    mask = rng.random((n, w)) < frac_filled
    values = np.where(mask, values, 0.0).astype(dtype)
    cols = np.where(mask, cols, 0).astype(np.int32)
    return jnp.asarray(values), jnp.asarray(cols)


class TestKernelVsRef:
    @pytest.mark.parametrize("n,w", [(8, 2), (64, 4), (256, 8), (1000, 7), (2048, 16)])
    def test_matches_ref(self, n, w):
        rng = np.random.default_rng(n * 31 + w)
        values, cols = random_ell(rng, n, w)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        got = spmv_ell(values, cols, x)
        want = spmv_ell_ref(values, cols, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_matches_dense(self):
        n, w = 64, 4
        rng = np.random.default_rng(7)
        values, cols = random_ell(rng, n, w)
        diag = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        got = diag * x + spmv_ell(values, cols, x)
        want = spmv_dense_ref(values, cols, diag, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_padding_is_inert(self):
        # Fully padded rows must produce exactly 0.
        n, w = 32, 4
        values = jnp.zeros((n, w), jnp.float32)
        cols = jnp.zeros((n, w), jnp.int32)
        x = jnp.ones(n, jnp.float32) * 3.0
        got = spmv_ell(values, cols, x)
        np.testing.assert_array_equal(np.asarray(got), np.zeros(n, np.float32))

    def test_identity_rows(self):
        # One entry per row pointing at itself with value 1 → y = x.
        n, w = 128, 3
        values = jnp.zeros((n, w), jnp.float32).at[:, 0].set(1.0)
        cols = jnp.zeros((n, w), jnp.int32).at[:, 0].set(jnp.arange(n, dtype=jnp.int32))
        x = jnp.arange(n, dtype=jnp.float32)
        got = spmv_ell(values, cols, x)
        np.testing.assert_allclose(got, x, rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(4, 300),
        w=st.integers(1, 12),
        frac=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, w, frac, seed):
        rng = np.random.default_rng(seed)
        values, cols = random_ell(rng, n, w, frac_filled=frac)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        got = spmv_ell(values, cols, x)
        want = spmv_ell_ref(values, cols, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @settings(max_examples=8, deadline=None)
    @given(block=st.sampled_from([1, 2, 8, 64, 1024]))
    def test_block_size_invariance(self, block):
        # The grid decomposition must not change the numbers.
        n, w = 256, 6
        rng = np.random.default_rng(3)
        values, cols = random_ell(rng, n, w)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        got = spmv_ell(values, cols, x, block_rows=block)
        want = spmv_ell(values, cols, x, block_rows=n)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_ell_to_dense_roundtrip(self):
        n, w = 16, 3
        rng = np.random.default_rng(11)
        values, cols = random_ell(rng, n, w, frac_filled=1.0)
        dense = ell_to_dense(values, cols, n)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        np.testing.assert_allclose(
            dense @ x, spmv_ell_ref(values, cols, x), rtol=1e-4, atol=1e-4
        )


class TestVmemBudget:
    def test_largest_aot_shape_fits(self):
        # DESIGN.md §Hardware-Adaptation: tiles + resident x within VMEM.
        fp = vmem_footprint_bytes(65536, 8)
        assert fp["values_tile"] == 32 * 1024
        assert fp["x_resident"] == 256 * 1024
        assert fp["total"] < 16 * 1024 * 1024  # TPU VMEM budget
