"""Tests for the results-CSV analysis helper."""

import subprocess
import sys
import os

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import analysis


class TestHelpers:
    def test_geomean(self):
        assert abs(analysis.geomean([1.0, 4.0]) - 2.0) < 1e-12
        assert abs(analysis.geomean([2.0, 2.0]) - 2.0) < 1e-12

    def test_geomean_skips_nonpositive(self):
        assert abs(analysis.geomean([0.0, 4.0]) - 4.0) < 1e-12

    def test_bar_chart_shape(self):
        out = analysis.bar_chart([("a", 1.0), ("bb", 2.0)], width=10)
        lines = out.splitlines()
        assert len(lines) == 2
        assert lines[1].count("#") == 10  # max value gets full width
        assert lines[0].count("#") == 5


class TestCli:
    @pytest.fixture
    def sample_csv(self, tmp_path):
        p = tmp_path / "fig.csv"
        p.write_text(
            "topology,algo,rel_cut\n"
            "t1,geoKM,1.0\nt1,zSFC,1.4\nt2,geoKM,1.0\nt2,zSFC,1.2\n"
        )
        return p

    def test_grouped_chart(self, sample_csv):
        r = subprocess.run(
            [
                sys.executable,
                os.path.join(os.path.dirname(__file__), "..", "analysis.py"),
                str(sample_csv),
                "--value",
                "rel_cut",
                "--group",
                "algo",
            ],
            capture_output=True,
            text=True,
        )
        assert r.returncode == 0
        assert "geoKM" in r.stdout and "zSFC" in r.stdout
        # zSFC's bar longer than geoKM's.
        lines = {l.split()[0]: l.count("#") for l in r.stdout.splitlines() if "#" in l}
        assert lines["zSFC"] > lines["geoKM"]

    def test_plain_dump(self, sample_csv):
        r = subprocess.run(
            [
                sys.executable,
                os.path.join(os.path.dirname(__file__), "..", "analysis.py"),
                str(sample_csv),
            ],
            capture_output=True,
            text=True,
        )
        assert r.returncode == 0
        assert "topology" in r.stdout
