"""AOT lowering pipeline tests: manifest format, HLO-text properties,
and the interchange constraints the rust loader depends on."""

import os
import subprocess
import sys

import pytest

from compile import aot, model


class TestHloText:
    def test_spmv_text_is_parseable_hlo(self):
        text = aot.lower_spmv(4096, 8)
        # Structural properties the rust loader relies on.
        assert text.startswith("HloModule")
        assert "ROOT" in text
        # One tuple output (return_tuple=True).
        assert "tuple(" in text.replace(" ", "")

    def test_no_serialized_proto_artifacts(self):
        # The interchange is text; 64-bit-id protos would break
        # xla_extension 0.5.1 (see /opt/xla-example/README.md).
        text = aot.lower_spmv(4096, 8)
        assert not text.startswith(b"\x08".decode("latin1"))

    def test_shapes_embedded(self):
        text = aot.lower_spmv(4096, 8)
        assert "f32[4096,8]" in text
        assert "s32[4096,8]" in text
        assert "f32[4096]" in text

    def test_cg_contains_loop_and_both_outputs(self):
        text = aot.lower_cg(4096, 8, 16)
        assert "while" in text
        # Output tuple: x (n) and norms (iters).
        assert "f32[4096]" in text
        assert "f32[16]" in text

    def test_spmv_shape_set_is_pallas_compatible(self):
        # AOT row counts must divide by the kernel grid (whole-array tile
        # ⇒ always true, but keep the invariant if tiles return).
        for n, w in aot.SPMV_SHAPES:
            assert n >= 1 and w >= 1
            assert n % 1024 == 0, f"{n} not a BLOCK_ROWS multiple"


class TestManifest:
    def test_quick_run_writes_manifest(self, tmp_path):
        out = tmp_path / "arts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        manifest = (out / "manifest.txt").read_text().strip().splitlines()
        assert len(manifest) == 1
        name, n, w = manifest[0].split()
        assert name == f"spmv_{n}x{w}"
        assert (out / f"{name}.hlo.txt").exists()
        text = (out / f"{name}.hlo.txt").read_text()
        assert text.startswith("HloModule")
