"""L2 model tests: CG convergence on real Laplacians, scan vs reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import cg_ref


def grid_laplacian_ell(side, w=8, shift=0.05, dtype=np.float32):
    """Shifted Laplacian of a `side`x`side` grid graph in ELL form."""
    n = side * side
    values = np.zeros((n, w), dtype)
    cols = np.zeros((n, w), np.int32)
    diag = np.full(n, shift, dtype)
    slot = np.zeros(n, np.int64)
    def add(u, v):
        values[u, slot[u]] = -1.0
        cols[u, slot[u]] = v
        slot[u] += 1
        diag[u] += 1.0
    for j in range(side):
        for i in range(side):
            u = j * side + i
            if i + 1 < side:
                add(u, u + 1)
                add(u + 1, u)
            if j + 1 < side:
                add(u, u + side)
                add(u + side, u)
    return jnp.asarray(values), jnp.asarray(cols), jnp.asarray(diag)


class TestCg:
    def test_cg_converges_on_grid_laplacian(self):
        values, cols, diag = grid_laplacian_ell(12)
        n = diag.shape[0]
        rng = np.random.default_rng(5)
        b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        x, norms = model.cg_run(values, cols, diag, b, 200)
        # Residual must drop by orders of magnitude.
        assert float(norms[-1]) < 1e-3 * float(norms[0])
        # And Ax ≈ b.
        ax = model.spmv(values, cols, diag, x)
        np.testing.assert_allclose(np.asarray(ax), np.asarray(b), rtol=2e-2, atol=2e-2)

    def test_scan_matches_python_loop(self):
        values, cols, diag = grid_laplacian_ell(8)
        n = diag.shape[0]
        rng = np.random.default_rng(9)
        b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        x_scan, norms_scan = model.cg_run(values, cols, diag, b, 30)
        x_ref, norms_ref = cg_ref(values, cols, diag, b, 30)
        np.testing.assert_allclose(np.asarray(x_scan), np.asarray(x_ref), rtol=3e-3, atol=3e-3)
        np.testing.assert_allclose(
            np.asarray(norms_scan), np.asarray(norms_ref), rtol=3e-3, atol=3e-3
        )

    def test_residuals_monotone_early(self):
        # CG residual norms on an SPD system decrease (allowing f32 noise
        # at the tail).
        values, cols, diag = grid_laplacian_ell(10)
        n = diag.shape[0]
        b = jnp.ones(n, jnp.float32)
        _, norms = model.cg_run(values, cols, diag, b, 40)
        norms = np.asarray(norms)
        drops = (norms[1:] <= norms[:-1] * 1.5).mean()
        assert drops > 0.8, f"residuals not mostly decreasing: {norms[:10]}"


class TestLowering:
    def test_spmv_lowers_to_hlo_text(self):
        from compile.aot import lower_spmv
        text = lower_spmv(4096, 8)
        assert "HloModule" in text
        # No Mosaic custom-calls (interpret=True requirement).
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()

    def test_cg_lowers_with_scan(self):
        from compile.aot import lower_cg
        text = lower_cg(4096, 8, 8)
        assert "HloModule" in text
        assert "while" in text  # the scan loop survives lowering
