//! Partition quality metrics.
//!
//! - **edge cut** (Eq. (1)): weight of edges with endpoints in different
//!   blocks — the paper's primary quality metric;
//! - **communication volume**: per block i, the number of (boundary
//!   vertex, foreign block) pairs — the data block i must send during an
//!   SpMV halo exchange; the paper reports the *maximum* over blocks;
//! - **boundary vertices**: vertices with ≥1 neighbor in another block;
//! - **imbalance**: max_i (w(b_i) − tw(b_i))/tw(b_i) against the
//!   heterogeneous targets, and the LDHT objective max_i w(b_i)/c_s(p_i).

use super::Partition;
use crate::graph::Csr;

/// Computed quality metrics for one partition.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Total edge cut (edge-weight sum across blocks).
    pub cut: f64,
    /// Max over blocks of the outgoing communication volume.
    pub max_comm_volume: f64,
    /// Total communication volume (sum over blocks).
    pub total_comm_volume: f64,
    /// Number of boundary vertices.
    pub boundary_vertices: usize,
    /// Block weights.
    pub block_weights: Vec<f64>,
    /// Max relative overweight vs targets: max_i (w_i − tw_i)/tw_i (can be
    /// negative if all blocks are under target).
    pub imbalance: f64,
}

/// Compute all metrics in one CSR sweep. `targets` may be empty (then
/// imbalance is measured against uniform targets n/k).
pub fn metrics(g: &Csr, p: &Partition, targets: &[f64]) -> Metrics {
    debug_assert_eq!(p.assignment.len(), g.n());
    let k = p.k;
    let mut cut = 0.0;
    let mut send_vol = vec![0.0; k];
    let mut boundary = 0usize;
    // Scratch: last block seen per (vertex, foreign block) — use a small
    // per-vertex set since mesh degrees are tiny.
    let mut seen: Vec<u32> = Vec::with_capacity(16);
    for u in 0..g.n() {
        let bu = p.assignment[u];
        let mut is_boundary = false;
        seen.clear();
        for e in g.arc_range(u) {
            let v = g.adjncy[e] as usize;
            let bv = p.assignment[v];
            if bv != bu {
                is_boundary = true;
                if u < v {
                    cut += g.arc_weight(e);
                }
                if !seen.contains(&bv) {
                    seen.push(bv);
                    // u's value must reach block bv once.
                    send_vol[bu as usize] += g.vertex_weight(u);
                }
            }
        }
        if is_boundary {
            boundary += 1;
        }
    }
    let block_weights = p.block_weights(g);
    let uniform = g.total_vertex_weight() / k as f64;
    let imbalance = (0..k)
        .map(|i| {
            let tw = if targets.is_empty() { uniform } else { targets[i] };
            if tw > 0.0 {
                (block_weights[i] - tw) / tw
            } else if block_weights[i] > 0.0 {
                f64::INFINITY
            } else {
                0.0
            }
        })
        .fold(f64::NEG_INFINITY, f64::max);
    let max_comm_volume = send_vol.iter().copied().fold(0.0, f64::max);
    let total_comm_volume = send_vol.iter().sum();
    Metrics {
        cut,
        max_comm_volume,
        total_comm_volume,
        boundary_vertices: boundary,
        block_weights,
        imbalance,
    }
}

/// Epoch-to-epoch migration metrics of a repartitioning step: how much
/// application data must move when the assignment changes from `prev` to
/// `next` (the cost side of the dynamic-repartitioning trade-off; the
/// quality side is the per-epoch [`Metrics`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationMetrics {
    /// Total vertex weight that changed blocks.
    pub migrated_weight: f64,
    /// Number of vertices that changed blocks (= words shipped when each
    /// vertex carries one value, the unit `repart::execute_migration`
    /// prices through the `Comm` seam).
    pub migrated_vertices: usize,
    /// Total vertex weight of the graph (denominator for fractions).
    pub total_weight: f64,
}

impl MigrationMetrics {
    /// Migrated weight as a fraction of total weight (0 when empty).
    pub fn frac_weight(&self) -> f64 {
        if self.total_weight > 0.0 {
            self.migrated_weight / self.total_weight
        } else {
            0.0
        }
    }
}

/// Compare two assignments of the *same* vertex set under the current
/// epoch's vertex weights. Panics if either partition disagrees with the
/// graph on the vertex count.
pub fn migration(g: &Csr, prev: &Partition, next: &Partition) -> MigrationMetrics {
    assert_eq!(prev.n(), g.n(), "prev partition size != graph size");
    assert_eq!(next.n(), g.n(), "next partition size != graph size");
    let mut migrated_weight = 0.0;
    let mut migrated_vertices = 0usize;
    for u in 0..g.n() {
        if prev.assignment[u] != next.assignment[u] {
            migrated_weight += g.vertex_weight(u);
            migrated_vertices += 1;
        }
    }
    MigrationMetrics {
        migrated_weight,
        migrated_vertices,
        total_weight: g.total_vertex_weight(),
    }
}

impl Metrics {
    /// The LDHT objective (Eq. (2)): max_i w(b_i)/c_s(p_i).
    pub fn ldht_objective(&self, speeds: &[f64]) -> f64 {
        self.block_weights
            .iter()
            .zip(speeds)
            .map(|(&w, &s)| w / s)
            .fold(0.0, f64::max)
    }

    /// Memory-constraint violation (Eq. (3)): max_i w(b_i) − m_cap(p_i),
    /// clamped at 0 when satisfied.
    pub fn memory_violation(&self, mems: &[f64]) -> f64 {
        self.block_weights
            .iter()
            .zip(mems)
            .map(|(&w, &m)| (w - m).max(0.0))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// 3x2 grid graph:
    /// 0-1-2
    /// | | |
    /// 3-4-5
    fn grid3x2() -> Csr {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(3, 4);
        b.add_edge(4, 5);
        b.add_edge(0, 3);
        b.add_edge(1, 4);
        b.add_edge(2, 5);
        b.build()
    }

    #[test]
    fn cut_and_volume_vertical_split() {
        let g = grid3x2();
        // blocks {0,3} | {1,2,4,5}: cut edges 0-1, 3-4 → cut 2.
        let p = Partition::new(vec![0, 1, 1, 0, 1, 1], 2);
        let m = metrics(&g, &p, &[]);
        assert_eq!(m.cut, 2.0);
        // Boundary vertices: 0,1,3,4.
        assert_eq!(m.boundary_vertices, 4);
        // Volume: block0 sends {0→b1, 3→b1} = 2; block1 sends {1→b0, 4→b0} = 2.
        assert_eq!(m.max_comm_volume, 2.0);
        assert_eq!(m.total_comm_volume, 4.0);
    }

    #[test]
    fn zero_cut_single_block() {
        let g = grid3x2();
        let p = Partition::trivial(6);
        let m = metrics(&g, &p, &[]);
        assert_eq!(m.cut, 0.0);
        assert_eq!(m.max_comm_volume, 0.0);
        assert_eq!(m.boundary_vertices, 0);
    }

    #[test]
    fn volume_counts_multi_block_targets() {
        let g = grid3x2();
        // Vertex 4 neighbors blocks 0,1,2 when split {0,3},{1,4? no...
        // blocks: 0:{0,1,2}, 1:{3,4}, 2:{5}.
        let p = Partition::new(vec![0, 0, 0, 1, 1, 2], 3);
        let m = metrics(&g, &p, &[]);
        // cut edges: 0-3, 1-4, 2-5, 4-5 → 4.
        assert_eq!(m.cut, 4.0);
        // send volumes: b0: 0→1, 1→1, 2→2 = 3. b1: 3→0, 4→0, 4→2 = 3.
        // b2: 5→0, 5→1 = 2.
        assert_eq!(m.total_comm_volume, 8.0);
        assert_eq!(m.max_comm_volume, 3.0);
    }

    #[test]
    fn imbalance_vs_targets() {
        let g = grid3x2();
        let p = Partition::new(vec![0, 0, 0, 0, 1, 1], 2);
        // weights 4 and 2; targets 3 and 3 → imbalance (4-3)/3 = 1/3.
        let m = metrics(&g, &p, &[3.0, 3.0]);
        assert!((m.imbalance - 1.0 / 3.0).abs() < 1e-12);
        // Heterogeneous targets 4 and 2 → perfectly balanced (max rel = 0).
        let m2 = metrics(&g, &p, &[4.0, 2.0]);
        assert!(m2.imbalance.abs() < 1e-12);
    }

    #[test]
    fn ldht_objective_and_memory() {
        let g = grid3x2();
        let p = Partition::new(vec![0, 0, 0, 0, 1, 1], 2);
        let m = metrics(&g, &p, &[]);
        // weights 4, 2; speeds 2, 1 → max(2, 2) = 2.
        assert_eq!(m.ldht_objective(&[2.0, 1.0]), 2.0);
        assert_eq!(m.memory_violation(&[4.0, 2.0]), 0.0);
        assert_eq!(m.memory_violation(&[3.0, 2.0]), 1.0);
    }

    #[test]
    fn weighted_edges_in_cut() {
        let mut b = GraphBuilder::new(2);
        b.add_weighted_edge(0, 1, 2.5);
        let g = b.build();
        let p = Partition::new(vec![0, 1], 2);
        let m = metrics(&g, &p, &[]);
        assert_eq!(m.cut, 2.5);
    }

    // ----- hand-computed fixtures: path, star, 4-cycle ------------------

    /// Path 0-1-2-3-4 split {0,1} | {2,3,4}: exactly one cut edge (1-2),
    /// one boundary vertex per side, one unit of volume per side.
    #[test]
    fn path_metrics_hand_computed() {
        let mut b = GraphBuilder::new(5);
        for u in 0..4 {
            b.add_edge(u, u + 1);
        }
        let g = b.build();
        let p = Partition::new(vec![0, 0, 1, 1, 1], 2);
        let m = metrics(&g, &p, &[]);
        assert_eq!(m.cut, 1.0);
        assert_eq!(m.boundary_vertices, 2); // vertices 1 and 2
        // Block 0 sends vertex 1 to block 1; block 1 sends vertex 2 back.
        assert_eq!(m.max_comm_volume, 1.0);
        assert_eq!(m.total_comm_volume, 2.0);
        assert_eq!(m.block_weights, vec![2.0, 3.0]);
        // Uniform targets 2.5 each → imbalance (3 − 2.5)/2.5 = +0.2.
        assert!((m.imbalance - 0.2).abs() < 1e-12);
    }

    /// Star: center 0 with leaves 1..=4; center alone in block 0. The
    /// center is one boundary vertex but its value is sent to ONE foreign
    /// block once per (vertex, block) pair — volume counts pairs, not cut
    /// edges.
    #[test]
    fn star_metrics_hand_computed() {
        let mut b = GraphBuilder::new(5);
        for leaf in 1..5 {
            b.add_edge(0, leaf);
        }
        let g = b.build();
        // Leaves split across blocks 1 and 2 → center reaches 2 foreign
        // blocks.
        let p = Partition::new(vec![0, 1, 1, 2, 2], 3);
        let m = metrics(&g, &p, &[]);
        assert_eq!(m.cut, 4.0); // all four spokes cut
        assert_eq!(m.boundary_vertices, 5); // everyone touches a foreign block
        // Block 0 sends the center to blocks 1 and 2 → volume 2;
        // blocks 1/2 each send both leaves to block 0 → volume 2 each.
        assert_eq!(m.max_comm_volume, 2.0);
        assert_eq!(m.total_comm_volume, 6.0);
        // Imbalance sign convention: targets may exceed weights; the max
        // relative deviation can be negative only if ALL blocks are under
        // target, so with targets (2, 2, 2) → max = 0/2 = 0.
        let m2 = metrics(&g, &p, &[2.0, 2.0, 2.0]);
        assert!(m2.imbalance.abs() < 1e-12);
        // Overweight target set: every block under target → negative.
        let m3 = metrics(&g, &p, &[4.0, 4.0, 4.0]);
        assert!(m3.imbalance < 0.0, "imbalance {}", m3.imbalance);
    }

    /// 4-cycle 0-1-2-3-0 across 2 blocks {0,1} | {2,3}: two cut edges,
    /// every vertex boundary, each block sends both its vertices once.
    #[test]
    fn four_cycle_metrics_hand_computed() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 3);
        b.add_edge(3, 0);
        let g = b.build();
        let p = Partition::new(vec![0, 0, 1, 1], 2);
        let m = metrics(&g, &p, &[]);
        assert_eq!(m.cut, 2.0); // edges 1-2 and 3-0
        assert_eq!(m.boundary_vertices, 4);
        assert_eq!(m.max_comm_volume, 2.0);
        assert_eq!(m.total_comm_volume, 4.0);
        // Perfectly balanced against uniform targets.
        assert!(m.imbalance.abs() < 1e-12);
        // LDHT objective with speeds (2, 1): max(2/2, 2/1) = 2 — the slow
        // PU dominates even at equal weights.
        assert_eq!(m.ldht_objective(&[2.0, 1.0]), 2.0);
    }

    #[test]
    fn migration_counts_changed_vertices_and_weight() {
        let g = grid3x2();
        let a = Partition::new(vec![0, 0, 0, 1, 1, 1], 2);
        let b = Partition::new(vec![0, 0, 1, 1, 1, 1], 2);
        let m = migration(&g, &a, &b);
        assert_eq!(m.migrated_vertices, 1);
        assert_eq!(m.migrated_weight, 1.0);
        assert_eq!(m.total_weight, 6.0);
        assert!((m.frac_weight() - 1.0 / 6.0).abs() < 1e-12);
        // Identical partitions migrate nothing.
        let z = migration(&g, &a, &a);
        assert_eq!(z.migrated_vertices, 0);
        assert_eq!(z.migrated_weight, 0.0);
    }

    #[test]
    fn migration_respects_vertex_weights() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.set_vertex_weights(vec![5.0, 1.0, 2.0]);
        let g = b.build();
        let p = Partition::new(vec![0, 0, 1], 2);
        let q = Partition::new(vec![1, 0, 1], 2);
        let m = migration(&g, &p, &q);
        assert_eq!(m.migrated_vertices, 1);
        assert_eq!(m.migrated_weight, 5.0);
        assert_eq!(m.total_weight, 8.0);
    }

    /// Vertex weights scale communication volume (a heavy boundary vertex
    /// costs its weight per foreign block).
    #[test]
    fn weighted_vertices_scale_volume() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1);
        b.set_vertex_weights(vec![3.0, 1.0]);
        let g = b.build();
        let p = Partition::new(vec![0, 1], 2);
        let m = metrics(&g, &p, &[]);
        assert_eq!(m.cut, 1.0);
        // Block 0 ships weight-3 vertex 0; block 1 ships unit vertex 1.
        assert_eq!(m.max_comm_volume, 3.0);
        assert_eq!(m.total_comm_volume, 4.0);
    }
}
