//! Partitions and their quality metrics (paper §II, §VI-a).
//!
//! A [`Partition`] assigns each vertex to one of `k` blocks. Quality is
//! measured by edge cut, maximum/total communication volume, boundary
//! vertices, and imbalance against the heterogeneous target weights from
//! Algorithm 1.

mod metrics;

pub use metrics::{metrics, migration, Metrics, MigrationMetrics};

use crate::graph::Csr;

/// A k-way partition of a graph's vertex set.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Block id per vertex.
    pub assignment: Vec<u32>,
    /// Number of blocks.
    pub k: usize,
}

impl Partition {
    /// Partition from an explicit assignment (`assignment[u]` = block of `u`).
    pub fn new(assignment: Vec<u32>, k: usize) -> Partition {
        debug_assert!(assignment.iter().all(|&b| (b as usize) < k));
        Partition { assignment, k }
    }

    /// All vertices in block 0 (trivial partition).
    pub fn trivial(n: usize) -> Partition {
        Partition { assignment: vec![0; n], k: 1 }
    }

    #[inline]
    /// Block that vertex `u` belongs to.
    pub fn block_of(&self, u: usize) -> u32 {
        self.assignment[u]
    }

    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.assignment.len()
    }

    /// Weight of each block under the graph's vertex weights.
    pub fn block_weights(&self, g: &Csr) -> Vec<f64> {
        let mut w = vec![0.0; self.k];
        for u in 0..self.n() {
            w[self.assignment[u] as usize] += g.vertex_weight(u);
        }
        w
    }

    /// Number of vertices per block.
    pub fn block_sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.k];
        for &b in &self.assignment {
            s[b as usize] += 1;
        }
        s
    }

    /// Validity: every vertex assigned to a block < k, matching graph size.
    pub fn validate(&self, g: &Csr) -> Result<(), String> {
        if self.assignment.len() != g.n() {
            return Err(format!(
                "assignment length {} != n {}",
                self.assignment.len(),
                g.n()
            ));
        }
        for (u, &b) in self.assignment.iter().enumerate() {
            if b as usize >= self.k {
                return Err(format!("vertex {u} in block {b} >= k {}", self.k));
            }
        }
        Ok(())
    }

    /// Renumber blocks so that used block ids are contiguous 0..k'
    /// (some partitioners can leave a block empty on tiny inputs).
    pub fn compact(&mut self) {
        let mut map = vec![u32::MAX; self.k];
        let mut next = 0u32;
        for b in self.assignment.iter_mut() {
            if map[*b as usize] == u32::MAX {
                map[*b as usize] = next;
                next += 1;
            }
            *b = map[*b as usize];
        }
        self.k = next as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path4() -> Csr {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 3);
        b.build()
    }

    #[test]
    fn block_weights_and_sizes() {
        let g = path4();
        let p = Partition::new(vec![0, 0, 1, 1], 2);
        p.validate(&g).unwrap();
        assert_eq!(p.block_weights(&g), vec![2.0, 2.0]);
        assert_eq!(p.block_sizes(), vec![2, 2]);
    }

    #[test]
    fn validate_catches_bad_block() {
        let g = path4();
        let p = Partition { assignment: vec![0, 0, 5, 1], k: 2 };
        assert!(p.validate(&g).is_err());
    }

    #[test]
    fn compact_renumbers() {
        let mut p = Partition { assignment: vec![3, 3, 1, 1], k: 5 };
        p.compact();
        assert_eq!(p.k, 2);
        assert_eq!(p.assignment, vec![0, 0, 1, 1]);
    }
}
