//! `hetpart` CLI — leader entrypoint.
//!
//! Subcommands (see `hetpart help`):
//!   blocksizes  — run Algorithm 1 on a topology spec and print tw() values
//!   partition   — generate/load a graph, partition it, print metrics
//!   solve       — partition + distributed CG under the cluster simulator
//!   experiment  — run a named paper experiment grid (fig1..fig5, table3, table4)

fn main() {
    hetpart::coordinator::cli::main();
}
