//! `hetpart` CLI dispatch.
//!
//! ```text
//! hetpart blocksizes --k 96 --topo topo1 --num-fast 8 --fast-speed 16 --fast-mem 13.8
//! hetpart partition  --family rdg2d --n 16384 --algo geoKM --k 24 [--topo topo1 ...]
//!                    [--backend sim|threads --ranks N [--net flat|fattree|torus]]
//! hetpart compare    --family tri2d --n 10000 --k 24 [--topo ...]
//! hetpart solve      --family rdg2d --n 16384 --algo geoRef --k 96 [--pjrt] [--iters 100]
//!                    [--backend sim|threads] [--overlap on|off] [--cg classic|pipelined]
//!                    [--layout ell|sellcs] [--net flat|fattree|torus]
//! hetpart harness    --matrix smoke|paper-small|paper-full|dynamic|partdist|serve|apps|scale|sweep
//!                    [--overlap on|off] [--layout ell|sellcs] [--net flat|fattree|torus]
//!                    [--max-ranks N] [--out results/harness] [--workers N] [--verbose]
//! hetpart app        --app bfs|sssp|pagerank [--agg on|off] [--backend sim|threads]
//!                    [--ranks 4] [--net flat|fattree|torus] [--buffer-bytes 16384]
//!                    [--source 0] [--family tri2d --n 900 --seed 42]
//! hetpart serve      --duration 5 --arrival-rate 50 --seed 1
//!                    [--family tri2d --n 800 --k 8 --preset uniform --algo geoKM]
//!                    [--backend threads|sim] [--workers N] [--queue-cap 64]
//!                    [--cache-cap N] [--clients N] [--coalesce on|off]
//!                    [--batch on|off] [--shards N] [--out results/serve/summary.json]
//! hetpart repart     --family refined2d --n 2000 --k 8 --preset twospeed
//!                    --dynamic refine-front|speed-drift --epochs 6
//!                    --repart scratchRemap|diffusion|increKM
//!                    [--algo geoKM] [--backend sim|threads] [--overlap on|off] [--csv FILE]
//! hetpart version | help
//! ```

use crate::blocksizes::block_sizes;
use crate::coordinator::{run_one, RunResult};
use crate::gen::Family;
use crate::partitioners::ALL_NAMES;
use crate::topology::{topo1, topo2, topo3, Pu, Topo1Spec, Topo2Spec, Topo3Spec, Topology};
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::util::fmt_f64;

/// CLI entry point: dispatch on the first positional argument.
pub fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "blocksizes" => cmd_blocksizes(&args),
        "partition" => cmd_partition(&args),
        "compare" => cmd_compare(&args),
        "solve" => cmd_solve(&args),
        "experiment" => cmd_experiment(&args),
        "harness" => cmd_harness(&args),
        "repart" => cmd_repart(&args),
        "serve" => cmd_serve(&args),
        "app" => cmd_app(&args),
        "version" => {
            println!("hetpart {}", super::version());
            0
        }
        _ => {
            print_help();
            if cmd == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "hetpart {} — heterogeneous load distribution for sparse matrix/graph apps

USAGE: hetpart <subcommand> [--options]

SUBCOMMANDS
  blocksizes   run Algorithm 1 and print target block weights
  partition    generate a graph, partition with one algorithm, print metrics
               (--backend sim|threads --ranks N runs the *partitioner* on
                the virtual cluster — geoKM|zRCB|zMJ — bit-identical to
                the sequential run, reporting priced/measured partSecs)
  compare      run all {} partitioners on one instance (Table IV row)
  solve        partition + distributed CG under the cluster simulator
               (--backend sim|threads runs the virtual-cluster engine:
                sequential α-β-priced supersteps or thread-per-PU;
                --overlap on hides the halo exchange behind the interior
                SpMV through the nonblocking Comm path; --cg pipelined
                runs the single-reduction CG variant; --layout sellcs
                runs the SELL-C-σ SpMV fast path, bit-identical to ELL;
                --net fattree|torus prices messages by hop count instead
                of the flat α-β model — numerics are unchanged)
  experiment   run a paper experiment grid by name
               (table3|fig1|fig2a|fig2b|fig3|fig4|fig5|table4)
  harness      run a declarative scenario matrix in parallel and write
               CSV + JSON artifacts (--matrix smoke|paper-small|paper-full
               |dynamic|partdist|serve|apps|scale|sweep — partdist sweeps
               the distributed partitioners over backend/rank axes for the
               quality-vs-partition-time scatter; serve replays open-loop
               serving traces through the resident partition service;
               sweep steps one serving cell across ~6 offered rates so
               the saturation knee (goodput flattens, latP99 grows) is
               readable from one CSV, and snapshots per-rate goodput as
               BENCH_serve.json;
               apps sweeps the irregular kernels × aggregation × backend;
               scale prices 64–16384-rank virtual clusters, flat vs
               hierarchical collectives on fat-tree/torus networks,
               through the analytic collective model (--max-ranks N
               truncates the rank axis for smoke runs);
               --overlap on flips every scenario's overlap axis,
               --layout sellcs flips the SpMV-layout axis, --net flips
               every scenario's network model, --out DIR,
               --workers N, --verbose prints every run)
  repart       replay an adaptive multi-epoch workload and repartition it
               (--dynamic refine-front|speed-drift, --epochs E,
                --repart scratchRemap|diffusion|increKM, --preset
                uniform|twospeed|hier2x2|memsat, --algo <static baseline>,
                --backend sim|threads prices migration, --overlap on
                migrates through the nonblocking path, --csv FILE)
  serve        run the resident partition service against a synthetic
               open-loop request trace and report throughput, latency
               percentiles, and cache hit rate
               (--duration S --arrival-rate λ --seed S, --backend
                threads|sim — threads measures wall-clock latencies,
                sim replays in deterministic virtual time; --workers N,
                --queue-cap C bounds admission, --cache-cap N bounds the
                resident caches with LRU eviction, --clients N switches
                to a closed loop of N think-time-zero clients,
                --coalesce on|off gates single-flight build sharing,
                --batch on|off gates same-tenant solve batching,
                --shards N sizes the sharded caches, --out FILE writes
                the summary JSON)
  app          run one irregular graph kernel on the virtual cluster
               through the aggregating message layer
               (--app bfs|sssp|pagerank, --agg on|off switches bulk
                aggregation vs one exchange per superstep — results are
                bit-identical; --backend sim|threads, --ranks N,
                --buffer-bytes B sizes the per-destination flush buffers,
                --source V for the traversal kernels)
  version      print version

COMMON OPTIONS
  --family  rgg2d|rgg3d|rdg2d|tri2d|tet3d|refined2d   (default rdg2d)
  --n       approximate vertex count                  (default 10000)
  --k       number of PUs/blocks                      (default 24)
  --topo    homog|topo1|topo2|topo3                   (default topo1)
  --num-fast N  --fast-speed S  --fast-mem M          (topo1/topo2 specs)
  --slowdown X  --nodes N  --fast-nodes F             (topo3 specs)
  --algo    {}
  --epsilon ε   --seed S",
        super::version(),
        ALL_NAMES.len(),
        ALL_NAMES.join("|"),
    );
}

/// Parse the `--overlap on|off` axis (a bare `--overlap` counts as on).
/// `None` means an unrecognized value was passed.
fn overlap_from_args(args: &Args) -> Option<bool> {
    if args.flag("overlap") {
        return Some(true);
    }
    match args.get("overlap", "off".to_string()).to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Some(true),
        "off" | "false" | "0" => Some(false),
        _ => None,
    }
}

/// Parse the `--layout ell|sellcs` axis. `None` means an unrecognized
/// value was passed (defaults to ELL when the flag is absent).
fn layout_from_args(args: &Args) -> Option<crate::exec::SpmvLayout> {
    crate::exec::SpmvLayout::parse(&args.get("layout", "ell".to_string()))
}

/// Parse the `--net flat|fattree|torus` axis — the network model the
/// simulated backend prices point-to-point messages and collective
/// rounds with. `None` means an unrecognized value was passed (defaults
/// to the flat α-β model when the flag is absent).
fn net_from_args(args: &Args) -> Option<crate::exec::NetKind> {
    crate::exec::NetKind::parse(&args.get("net", "flat".to_string()))
}

/// Build the topology from CLI options.
pub fn topo_from_args(args: &Args, k: usize) -> Topology {
    let kind: String = args.get("topo", "topo1".to_string());
    let fast = Pu {
        speed: args.get("fast-speed", 4.0),
        memory: args.get("fast-mem", 5.2),
    };
    let num_fast = args.get("num-fast", (k / 12).max(1));
    match kind.as_str() {
        "homog" => Topology::homogeneous(k, 1.0, 2.0),
        "topo1" => topo1(Topo1Spec { k, num_fast, fast }),
        "topo2" => topo2(Topo2Spec { k, num_fast, fast }),
        "topo3" => {
            let nodes = args.get("nodes", 4usize);
            let fast_nodes = args.get("fast-nodes", 1usize);
            let slowdown = args.get("slowdown", 4.0);
            topo3(Topo3Spec {
                nodes,
                pus_per_node: k / nodes.max(1),
                fast_nodes,
                slowdown,
            })
        }
        other => {
            eprintln!("unknown --topo {other}");
            std::process::exit(2);
        }
    }
}

fn load_graph(args: &Args) -> (String, crate::graph::Csr) {
    if let Some(path) = args.opt::<String>("graph-file") {
        let p = std::path::PathBuf::from(&path);
        let g = crate::graph::io::read_metis(&p).unwrap_or_else(|e| {
            eprintln!("failed to read {path}: {e}");
            std::process::exit(2);
        });
        (path, g)
    } else {
        let fam: String = args.get("family", "rdg2d".to_string());
        let family = Family::parse(&fam).unwrap_or_else(|| {
            eprintln!("unknown --family {fam}");
            std::process::exit(2);
        });
        let n = args.get("n", 10_000usize);
        let seed = args.get("seed", 1u64);
        crate::coordinator::instance(family, n, seed)
    }
}

fn cmd_blocksizes(args: &Args) -> i32 {
    let k = args.get("k", 96usize);
    let topo = topo_from_args(args, k);
    let fill = args.get("fill", crate::blocksizes::TABLE3_FILL);
    let n = args.opt::<f64>("load").unwrap_or(fill * topo.total_memory());
    match block_sizes(n, &topo) {
        Ok(bs) => {
            println!(
                "topology {} | k={k} load={} C_s={} M_cap={}",
                topo.label,
                fmt_f64(n),
                fmt_f64(topo.total_speed()),
                fmt_f64(topo.total_memory())
            );
            let mut t = Table::new(vec!["pu", "speed", "memory", "tw", "saturated", "tw/speed"]);
            for i in 0..k.min(12) {
                t.row(vec![
                    i.to_string(),
                    fmt_f64(topo.pus[i].speed),
                    fmt_f64(topo.pus[i].memory),
                    fmt_f64(bs.tw[i]),
                    bs.saturated[i].to_string(),
                    fmt_f64(bs.tw[i] / topo.pus[i].speed),
                ]);
            }
            if k > 12 {
                println!("(first 12 of {k} PUs)");
            }
            print!("{}", t.to_text());
            println!(
                "max ratio (Eq.2 objective) = {} | tw(fast)/tw(slow) = {}",
                fmt_f64(bs.max_ratio),
                fmt_f64(bs.tw[0] / bs.tw[k - 1])
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    use crate::harness::{emit, experiments, BenchScale};
    let name = match args.positional.get(1) {
        Some(n) => n.clone(),
        None => {
            eprintln!("usage: hetpart experiment <table3|fig1|fig2a|fig2b|fig3|fig4|fig5|table4>");
            return 2;
        }
    };
    let scale = BenchScale::from_env();
    let t = match name.as_str() {
        "table3" => experiments::table3(),
        "fig1" => experiments::fig1(scale),
        "fig2a" => experiments::fig2(scale, 'a'),
        "fig2b" => experiments::fig2(scale, 'b'),
        "fig3" => experiments::fig3(scale),
        "fig4" => experiments::fig4(scale),
        "fig5" => experiments::fig5(scale),
        "table4" => experiments::table4(scale),
        other => {
            eprintln!("unknown experiment '{other}'");
            return 2;
        }
    };
    emit(&name, &format!("paper experiment {name}"), &t);
    0
}

/// `hetpart harness --matrix <name>`: run a scenario matrix over the job
/// queue and persist CSV + JSON artifacts (see EXPERIMENTS.md).
fn cmd_harness(args: &Args) -> i32 {
    use crate::harness::{run_matrix, runner, summarize, write_artifacts, MatrixKind};
    let name: String = args.get("matrix", "smoke".to_string());
    let Some(kind) = MatrixKind::parse(&name) else {
        eprintln!(
            "unknown --matrix {name} (expected smoke|paper-small|paper-full|dynamic|partdist|serve|apps|scale|sweep)"
        );
        return 2;
    };
    let workers = args.get("workers", crate::coordinator::default_workers());
    let out: String = args.get("out", "results/harness".to_string());
    let Some(overlap) = overlap_from_args(args) else {
        eprintln!("unknown --overlap value (expected on|off)");
        return 2;
    };
    let Some(layout) = layout_from_args(args) else {
        eprintln!("unknown --layout value (expected ell|sellcs)");
        return 2;
    };
    // --net overrides every scenario's network model; absent, scenarios
    // keep their own (the scale matrix carries per-cell nets).
    let net_override = match args.opt::<String>("net") {
        None => None,
        Some(v) => match crate::exec::NetKind::parse(&v) {
            Some(nk) => Some(nk),
            None => {
                eprintln!("unknown --net {v} (expected flat|fattree|torus)");
                return 2;
            }
        },
    };
    let mut scenarios = kind.scenarios();
    if overlap {
        for s in &mut scenarios {
            s.overlap = true;
        }
    }
    if layout != crate::exec::SpmvLayout::default() {
        for s in &mut scenarios {
            s.layout = layout;
        }
    }
    if let Some(nk) = net_override {
        for s in &mut scenarios {
            s.net = nk;
        }
    }
    // --max-ranks truncates the scale axis (CI smoke runs cap the
    // virtual rank count); scenarios off the axis are unaffected.
    let max_ranks = args.opt::<usize>("max-ranks");
    if let Some(mr) = max_ranks {
        scenarios.retain(|s| s.scale.map_or(true, |sp| sp.ranks <= mr));
    }
    // Axis-flipped runs get their own artifact directory (<matrix>-ov /
    // <matrix>-l<layout>), so the comparison EXPERIMENTS.md §4 describes
    // never overwrites the baseline run's runs.csv / summary.* it is
    // compared against.
    let mut matrix_label = kind.name().to_string();
    if overlap {
        matrix_label.push_str("-ov");
    }
    if layout != crate::exec::SpmvLayout::default() {
        matrix_label.push_str(&format!("-l{}", layout.name()));
    }
    if let Some(nk) = net_override {
        matrix_label.push_str(&format!("-net{}", nk.name()));
    }
    if let Some(mr) = max_ranks {
        matrix_label.push_str(&format!("-r{mr}"));
    }
    println!(
        "harness matrix '{}': {} scenarios over {} workers{}{}",
        kind.name(),
        scenarios.len(),
        workers,
        if overlap { " (overlap on)" } else { "" },
        if layout != crate::exec::SpmvLayout::default() {
            format!(" (layout {})", layout.name())
        } else {
            String::new()
        }
    );
    let (ok, failed) = run_matrix(&scenarios, workers);
    if args.flag("verbose") {
        print!("{}", runner::runs_table(&ok).to_text());
    }
    println!("\n=== per-partitioner summary ({} runs) ===", ok.len());
    print!("{}", runner::summary_table(&summarize(&ok)).to_text());
    for (id, e) in &failed {
        eprintln!("FAILED {id}: {e}");
    }
    match write_artifacts(&out, &matrix_label, &ok, &failed) {
        Ok(dir) => {
            println!(
                "[artifacts: {}/runs.csv, runs/<id>.json, summary.csv, summary.json]",
                dir.display()
            );
            // The sweep matrix additionally snapshots per-rate goodput as
            // a higher-is-better BENCH_serve.json, so bench_compare can
            // gate serving-throughput regressions in the right direction.
            if kind == MatrixKind::Sweep {
                let mut snap = crate::harness::bench_snapshot::BenchSnapshot::new("serve");
                for r in &ok {
                    if let Some(v) = &r.serve {
                        snap.push_rate(
                            &format!("goodput@{:.0}", v.offered_rate),
                            r.n,
                            v.goodput,
                        );
                    }
                }
                match snap.save(&dir) {
                    Ok(p) => println!("[bench snapshot: {}]", p.display()),
                    Err(e) => {
                        eprintln!("bench snapshot write failed: {e}");
                        return 1;
                    }
                }
            }
        }
        Err(e) => {
            eprintln!("artifact write failed: {e}");
            return 1;
        }
    }
    if !failed.is_empty() {
        eprintln!("{} of {} scenarios failed", failed.len(), scenarios.len());
        return 1;
    }
    0
}

/// `hetpart repart`: replay a dynamic trace (moving refinement front or
/// PU speed drift) and repartition every epoch, printing the per-epoch
/// quality/migration table (optionally also written as CSV).
fn cmd_repart(args: &Args) -> i32 {
    use crate::harness::TopoPreset;
    use crate::repart::{
        epoch_table, repartitioner_for_trace, run_trace, DynamicKind, EpochTrace, TraceOptions,
    };
    let (name, g) = load_graph(args);
    let k = args.get("k", 8usize);
    let preset_name: String = args.get("preset", "twospeed".to_string());
    let Some(preset) = TopoPreset::parse(&preset_name) else {
        eprintln!("unknown --preset {preset_name} (expected uniform|twospeed|hier2x2|memsat)");
        return 2;
    };
    if preset == TopoPreset::Hier && (k % 4 != 0 || k < 4) {
        eprintln!("--preset hier2x2 needs --k divisible by 4, got {k}");
        return 2;
    }
    let dyn_name: String = args.get("dynamic", "refine-front".to_string());
    let Some(kind) = DynamicKind::parse(&dyn_name) else {
        eprintln!("unknown --dynamic {dyn_name} (expected none|refine-front|speed-drift)");
        return 2;
    };
    let backend_name: String = args.get("backend", "sim".to_string());
    let Some(backend) = crate::exec::ExecBackend::parse(&backend_name) else {
        eprintln!("unknown --backend {backend_name} (expected sim|threads)");
        return 2;
    };
    let epochs = args.get("epochs", 6usize).max(1);
    let Some(nonblocking) = overlap_from_args(args) else {
        eprintln!("unknown --overlap value (expected on|off)");
        return 2;
    };
    // Seed default matches load_graph's (and the other subcommands'), so
    // one --seed value governs generation, partitioning and the trace.
    let opts = TraceOptions {
        scratch_algo: args.get("algo", "geoKM".to_string()),
        backend,
        nonblocking,
        epsilon: args.get("epsilon", 0.03),
        seed: args.get("seed", 1u64),
    };
    let rp_name: String = args.get("repart", "diffusion".to_string());
    let Some(rp) = repartitioner_for_trace(&rp_name, &opts.scratch_algo) else {
        eprintln!("unknown --repart {rp_name} (expected scratchRemap|diffusion|increKM)");
        return 2;
    };
    let trace = EpochTrace::new(&g, preset.build(k), kind, epochs, opts.seed);
    println!(
        "graph {name}: n={} m={} | preset {} k={k} | dynamic {} x{epochs} epochs | \
         repartitioner {} (scratch baseline {}) | backend {}{}",
        g.n(),
        g.m(),
        preset.name(),
        kind.name(),
        rp.name(),
        opts.scratch_algo,
        backend.name(),
        if opts.nonblocking { " (nonblocking migration)" } else { "" },
    );
    let res = match run_trace(&trace, rp.as_ref(), &opts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let t = epoch_table(&res);
    print!("{}", t.to_text());
    let naive = res.total_naive_migrated_weight();
    let worst = res.worst_obj_vs_scratch();
    println!(
        "totals: migrated weight {:.1} ({} words) vs naive scratch {:.1}{} | \
         worst obj/scratch {}",
        res.total_migrated_weight(),
        res.total_migration_volume(),
        naive,
        if naive > 0.0 {
            format!(" (ratio {:.3})", res.total_migrated_weight() / naive)
        } else {
            String::new()
        },
        if worst.is_finite() { format!("{worst:.4}") } else { "-".to_string() },
    );
    if let Some(path) = args.opt::<String>("csv") {
        let p = std::path::PathBuf::from(&path);
        if let Some(dir) = p.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        match std::fs::write(&p, t.to_csv()) {
            Ok(()) => println!("[saved {}]", p.display()),
            Err(e) => {
                eprintln!("csv write failed: {e}");
                return 1;
            }
        }
    }
    0
}

/// `hetpart serve`: run the resident partition service against a
/// deterministic synthetic open-loop trace (see `coordinator::serve`)
/// and report throughput, latency percentiles, and cache hit rate.
fn cmd_serve(args: &Args) -> i32 {
    use crate::coordinator::serve::{run_serve, ClientMode, ServeConfig, Tenant};
    use crate::harness::TopoPreset;
    let fam: String = args.get("family", "tri2d".to_string());
    let Some(family) = Family::parse(&fam) else {
        eprintln!("unknown --family {fam}");
        return 2;
    };
    let k = args.get("k", 8usize);
    let preset_name: String = args.get("preset", "uniform".to_string());
    let Some(preset) = TopoPreset::parse(&preset_name) else {
        eprintln!("unknown --preset {preset_name} (expected uniform|twospeed|hier2x2|memsat)");
        return 2;
    };
    if preset == TopoPreset::Hier && (k % 4 != 0 || k < 4) {
        eprintln!("--preset hier2x2 needs --k divisible by 4, got {k}");
        return 2;
    }
    let backend_name: String = args.get("backend", "threads".to_string());
    let Some(backend) = crate::exec::ExecBackend::parse(&backend_name) else {
        eprintln!("unknown --backend {backend_name} (expected sim|threads)");
        return 2;
    };
    let seed = args.get("seed", 1u64);
    let primary = Tenant {
        family,
        n: args.get("n", 800usize),
        graph_seed: seed,
        preset,
        k,
        algo: args.get("algo", "geoKM".to_string()),
        epsilon: args.get("epsilon", 0.03),
    };
    let mut cfg = ServeConfig::new(
        primary,
        args.get("duration", 5.0),
        args.get("arrival-rate", 50.0),
        seed,
        backend,
    );
    cfg.servers = args.get("workers", cfg.servers);
    cfg.queue_cap = args.get("queue-cap", cfg.queue_cap);
    // 0 (or absent) keeps the historical unbounded caches.
    let cache_cap = args.get("cache-cap", 0usize);
    cfg.cache_cap = if cache_cap == 0 { None } else { Some(cache_cap) };
    // Throughput knobs: --clients N switches to a closed loop of N
    // think-time-zero clients (0 = the default open-loop trace);
    // --coalesce/--batch (default on) gate single-flight build sharing
    // and same-tenant solve batching; --shards sizes the sharded caches.
    let clients = args.get("clients", 0usize);
    cfg.client_mode = if clients == 0 {
        ClientMode::Open
    } else {
        ClientMode::Closed { clients }
    };
    match args.get("coalesce", "on".to_string()).to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => cfg.coalesce = true,
        "off" | "false" | "0" => cfg.coalesce = false,
        v => {
            eprintln!("unknown --coalesce {v} (expected on|off)");
            return 2;
        }
    }
    match args.get("batch", "on".to_string()).to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => cfg.batch = true,
        "off" | "false" | "0" => cfg.batch = false,
        v => {
            eprintln!("unknown --batch {v} (expected on|off)");
            return 2;
        }
    }
    cfg.shards = args.get("shards", cfg.shards);
    if cfg.shards == 0 {
        eprintln!("--shards must be at least 1");
        return 2;
    }
    println!(
        "serve: {} tenants over {}_{} preset {} k={} | {} for {}s (seed {}) | \
         backend {} x{} workers, queue cap {} | coalesce {} batch {} shards {}",
        cfg.tenants.len(),
        cfg.tenants[0].family.name(),
        cfg.tenants[0].n,
        cfg.tenants[0].preset.name(),
        cfg.tenants[0].k,
        match cfg.client_mode {
            ClientMode::Open => format!("open loop λ={}/s", cfg.arrival_rate),
            ClientMode::Closed { clients } => format!("closed loop x{clients} clients"),
        },
        cfg.duration_secs,
        cfg.seed,
        backend.name(),
        cfg.servers,
        cfg.queue_cap,
        if cfg.coalesce { "on" } else { "off" },
        if cfg.batch { "on" } else { "off" },
        cfg.shards,
    );
    let rep = match run_serve(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    print!("{}", rep.table().to_text());
    println!(
        "throughput {:.1} req/s (goodput {:.1}/s) | p50 {:.3}ms p95 {:.3}ms p99 {:.3}ms | \
         cache hit rate {:.3} | {} builds, {} coalesced, {} batched | \
         {} warm starts (mean migrated frac {:.3})",
        rep.req_per_sec,
        rep.goodput,
        rep.latency_p50_ms,
        rep.latency_p95_ms,
        rep.latency_p99_ms,
        rep.cache_hit_rate,
        rep.builds,
        rep.coalesced,
        rep.batched,
        rep.warm_starts,
        rep.mean_migrated_frac,
    );
    let out: String = args.get("out", "results/serve/summary.json".to_string());
    let p = std::path::PathBuf::from(&out);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(dir);
        }
    }
    match std::fs::write(&p, rep.summary_json().render()) {
        Ok(()) => println!("[saved {}]", p.display()),
        Err(e) => {
            eprintln!("summary write failed: {e}");
            return 1;
        }
    }
    0
}

/// `hetpart app`: run one irregular graph kernel (`apps::by_name`) over
/// the generated instance on the virtual cluster, through the
/// aggregating (or direct) message layer, and print the cost/traffic
/// table plus the result digest.
fn cmd_app(args: &Args) -> i32 {
    use crate::apps::{by_name, run_app, AppConfig};
    use crate::exec::AggMode;
    let app_name: String = args.get("app", "bfs".to_string());
    let Some(kernel) = by_name(&app_name) else {
        eprintln!("unknown --app {app_name} (expected {})", crate::apps::APP_NAMES.join("|"));
        return 2;
    };
    let agg_name: String = args.get("agg", "on".to_string());
    let Some(mode) = AggMode::parse(&agg_name) else {
        eprintln!("unknown --agg {agg_name} (expected on|off)");
        return 2;
    };
    let backend_name: String = args.get("backend", "sim".to_string());
    let Some(backend) = crate::exec::ExecBackend::parse(&backend_name) else {
        eprintln!("unknown --backend {backend_name} (expected sim|threads)");
        return 2;
    };
    let Some(net) = net_from_args(args) else {
        eprintln!("unknown --net value (expected flat|fattree|torus)");
        return 2;
    };
    let (name, g) = load_graph(args);
    let ranks = args.get("ranks", 4usize);
    let mut cfg = AppConfig {
        backend,
        ranks,
        mode,
        net: net.model(ranks),
        source: args.get("source", 0usize),
        seed: args.get("seed", 1u64),
        ..AppConfig::default()
    };
    cfg.buffer_bytes = args.get("buffer-bytes", cfg.buffer_bytes);
    println!(
        "graph {name}: n={} m={} | app {} | {} messaging (buffer {} B) | backend {} x{} ranks",
        g.n(),
        g.m(),
        kernel.name(),
        cfg.mode.name(),
        cfg.buffer_bytes,
        backend.name(),
        cfg.ranks,
    );
    let (_, rep) = match run_app(&g, kernel.as_ref(), &cfg) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let mut t = Table::new(vec![
        "app", "backend", "aggMode", "ranks", "iters", "flushes", "aggBytes", "maxLinkBytes",
        "appSecs", "wall(s)",
    ]);
    t.row(vec![
        rep.app.clone(),
        rep.backend.to_string(),
        rep.mode.name().to_string(),
        rep.ranks.to_string(),
        rep.iterations.to_string(),
        rep.flushes.to_string(),
        rep.agg_bytes.to_string(),
        rep.max_link_bytes().to_string(),
        format!("{:.3e}", rep.app_secs()),
        format!("{:.3}", rep.wall_secs),
    ]);
    print!("{}", t.to_text());
    let bottleneck = (0..rep.ranks)
        .max_by(|&a, &b| {
            let fa = rep.compute_secs[a] + rep.comm_secs[a];
            let fb = rep.compute_secs[b] + rep.comm_secs[b];
            fa.partial_cmp(&fb).unwrap()
        })
        .unwrap_or(0);
    println!(
        "result check passed | digest {:016x} | bottleneck rank {} (compute {:.3e}s comm {:.3e}s)",
        rep.digest, bottleneck, rep.compute_secs[bottleneck], rep.comm_secs[bottleneck],
    );
    0
}

fn result_row(t: &mut Table, r: &RunResult) {
    t.row(vec![
        r.algo.clone(),
        fmt_f64(r.cut),
        fmt_f64(r.max_comm_volume),
        fmt_f64(r.imbalance),
        fmt_f64(r.ldht_objective),
        format!("{:.3}", r.time_partition),
    ]);
}

fn cmd_partition(args: &Args) -> i32 {
    let (name, g) = load_graph(args);
    let k = args.get("k", 24usize);
    let topo = topo_from_args(args, k);
    let algo: String = args.get("algo", "geoKM".to_string());
    let epsilon = args.get("epsilon", 0.03);
    let seed = args.get("seed", 1u64);
    let Some(net) = net_from_args(args) else {
        eprintln!("unknown --net value (expected flat|fattree|torus)");
        return 2;
    };
    println!("graph {name}: n={} m={} | topo {}", g.n(), g.m(), topo.label);
    // Distributed path: run the partitioner itself on the virtual
    // cluster (`--backend sim|threads --ranks N`) and report partSecs —
    // the partitioning-time axis of the paper's Tables IV–VI. The
    // partition is bit-identical to the sequential path below.
    if let Some(bs) = args.opt::<String>("backend") {
        let Some(backend) = crate::exec::ExecBackend::parse(&bs) else {
            eprintln!("unknown --backend {bs} (expected sim|threads)");
            return 2;
        };
        let ranks = args.get("ranks", 4usize);
        return match crate::coordinator::run_one_dist_net(
            &name, &g, &topo, &algo, epsilon, seed, backend, ranks, net.model(ranks),
        ) {
            Ok((r, _p, rep)) => {
                let mut t = Table::new(vec![
                    "algo", "backend", "ranks", "cut", "maxCommVol", "imbalance", "ldhtObj",
                    "partSecs", "wall(s)",
                ]);
                t.row(vec![
                    r.algo.clone(),
                    rep.backend.to_string(),
                    rep.ranks.to_string(),
                    fmt_f64(r.cut),
                    fmt_f64(r.max_comm_volume),
                    fmt_f64(r.imbalance),
                    fmt_f64(r.ldht_objective),
                    format!("{:.3e}", rep.part_secs()),
                    format!("{:.3}", rep.wall_secs),
                ]);
                print!("{}", t.to_text());
                println!(
                    "bottleneck rank {} (compute {:.3e}s comm {:.3e}s)",
                    rep.bottleneck_rank(),
                    rep.compute_secs[rep.bottleneck_rank()],
                    rep.comm_secs[rep.bottleneck_rank()],
                );
                0
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        };
    }
    // The sequential path prices no communication, so a non-flat network
    // would silently do nothing — refuse instead.
    if net != crate::exec::NetKind::Flat {
        eprintln!(
            "--net {} prices the distributed path: add --backend sim|threads --ranks N",
            net.name()
        );
        return 2;
    }
    match run_one(&name, &g, &topo, &algo, epsilon, seed) {
        Ok((r, _p)) => {
            let mut t = Table::new(vec!["algo", "cut", "maxCommVol", "imbalance", "ldhtObj", "time(s)"]);
            result_row(&mut t, &r);
            print!("{}", t.to_text());
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_compare(args: &Args) -> i32 {
    let (name, g) = load_graph(args);
    let k = args.get("k", 24usize);
    let topo = topo_from_args(args, k);
    let epsilon = args.get("epsilon", 0.03);
    let seed = args.get("seed", 1u64);
    println!("graph {name}: n={} m={} | topo {}", g.n(), g.m(), topo.label);
    let mut t = Table::new(vec!["algo", "cut", "maxCommVol", "imbalance", "ldhtObj", "time(s)"]);
    for algo in ALL_NAMES {
        match run_one(&name, &g, &topo, algo, epsilon, seed) {
            Ok((r, _)) => result_row(&mut t, &r),
            Err(e) => eprintln!("WARN {algo}: {e}"),
        }
    }
    print!("{}", t.to_text());
    0
}

fn cmd_solve(args: &Args) -> i32 {
    use crate::solver::cg::NativeBackend;
    use crate::solver::{ClusterSim, EllMatrix};
    let (name, g) = load_graph(args);
    let k = args.get("k", 24usize);
    let topo = topo_from_args(args, k);
    let algo: String = args.get("algo", "geoKM".to_string());
    let epsilon = args.get("epsilon", 0.03);
    let seed = args.get("seed", 1u64);
    let iters = args.get("iters", 100usize);
    let shift = args.get("shift", 0.05);
    println!("graph {name}: n={} m={} | topo {}", g.n(), g.m(), topo.label);
    let (r, part) = match run_one(&name, &g, &topo, &algo, epsilon, seed) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    // Engine execution options are validated regardless of the path
    // taken, so a typo'd value never silently runs something else.
    let Some(overlap) = overlap_from_args(args) else {
        eprintln!("unknown --overlap value (expected on|off)");
        return 2;
    };
    let cg_name: String = args.get("cg", "classic".to_string());
    let Some(variant) = crate::exec::CgVariant::parse(&cg_name) else {
        eprintln!("unknown --cg {cg_name} (expected classic|pipelined)");
        return 2;
    };
    let Some(layout) = layout_from_args(args) else {
        eprintln!("unknown --layout value (expected ell|sellcs)");
        return 2;
    };
    let Some(net) = net_from_args(args) else {
        eprintln!("unknown --net value (expected flat|fattree|torus)");
        return 2;
    };
    // Virtual-cluster engine path: thread-per-PU or sequential-sim
    // distributed CG behind the Comm seam, optionally with nonblocking
    // compute/communication overlap and the pipelined CG variant.
    if let Some(bs) = args.opt::<String>("backend") {
        let Some(backend) = crate::exec::ExecBackend::parse(&bs) else {
            eprintln!("unknown --backend {bs} (expected sim|threads)");
            return 2;
        };
        let opts =
            crate::exec::SolveOpts { overlap, variant, layout, net: net.model(k) };
        let (s, cg) = match crate::coordinator::run_solve_opts(
            &g, &part, &topo, backend, shift, iters, 1e-6, opts,
        ) {
            Ok(x) => x,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        let mut t = Table::new(vec![
            "algo", "backend", "cg", "overlap", "layout", "cut", "maxCommVol", "iters",
            "residual", "t/iter(s)", "commHidden(s)", "ovEff", "wall(s)",
        ]);
        t.row(vec![
            r.algo.clone(),
            s.backend.to_string(),
            variant.name().to_string(),
            if s.overlap { "on" } else { "off" }.to_string(),
            s.layout.to_string(),
            fmt_f64(r.cut),
            fmt_f64(r.max_comm_volume),
            cg.iterations.to_string(),
            format!("{:.2e}", s.final_residual),
            format!("{:.2e}", s.time_per_iter),
            format!("{:.2e}", s.comm_hidden_secs),
            format!("{:.4}", s.overlap_efficiency),
            format!("{:.3}", s.wall_secs),
        ]);
        print!("{}", t.to_text());
        println!("bottleneck PU {}", s.bottleneck_rank);
        return 0;
    }
    // The legacy ClusterSim path below knows nothing about overlap, CG
    // variants, or SpMV layouts — refuse rather than silently run a
    // blocking classic ELL solve the user did not ask for.
    if overlap
        || variant != crate::exec::CgVariant::Classic
        || layout != crate::exec::SpmvLayout::default()
        || net != crate::exec::NetKind::Flat
    {
        eprintln!(
            "--overlap on / --cg {} / --layout {} / --net {} require the \
             virtual-cluster engine: add --backend sim|threads",
            variant.name(),
            layout.name(),
            net.name()
        );
        return 2;
    }
    let ell = EllMatrix::from_graph(&g, shift);
    let mut sim = ClusterSim::default();
    sim.calibrate(&ell);
    let b = crate::coordinator::experiment::default_rhs(g.n());
    let use_pjrt = args.flag("pjrt");
    let (cg, rep) = if use_pjrt {
        match pjrt_cg(&g, &part, &topo, &ell, &sim, &b, iters) {
            Ok(x) => x,
            Err(e) => {
                eprintln!("pjrt path failed ({e}); falling back to native");
                let mut backend = NativeBackend { a: &ell };
                sim.run_cg(&g, &part, &topo, ell.w, &mut backend, &b, iters, 1e-6)
                    .unwrap()
            }
        }
    } else {
        let mut backend = NativeBackend { a: &ell };
        sim.run_cg(&g, &part, &topo, ell.w, &mut backend, &b, iters, 1e-6)
            .unwrap()
    };
    let mut t = Table::new(vec!["algo", "cut", "maxCommVol", "time_part(s)", "iters", "residual", "sim_t/iter(s)"]);
    t.row(vec![
        r.algo.clone(),
        fmt_f64(r.cut),
        fmt_f64(r.max_comm_volume),
        format!("{:.3}", r.time_partition),
        cg.iterations.to_string(),
        format!("{:.2e}", cg.residual_norms.last().copied().unwrap_or(0.0)),
        format!("{:.2e}", rep.time_per_iter),
    ]);
    print!("{}", t.to_text());
    println!(
        "bottleneck PU {}: compute {:.2e}s comm {:.2e}s",
        rep.bottleneck_pu, rep.bottleneck_compute, rep.bottleneck_comm
    );
    0
}

/// PJRT-backed CG for `solve --pjrt`: pad to the best-fit artifact.
fn pjrt_cg(
    g: &crate::graph::Csr,
    part: &crate::partition::Partition,
    topo: &Topology,
    ell: &crate::solver::EllMatrix,
    sim: &crate::solver::ClusterSim,
    b: &[f32],
    iters: usize,
) -> anyhow::Result<(crate::solver::CgResult, crate::solver::SimReport)> {
    use crate::runtime::{ArtifactSet, Runtime};
    use crate::solver::cg::PjrtBackend;
    let manifest = ArtifactSet::discover()?;
    let entry = manifest
        .best_spmv(ell.n, ell.w)
        .ok_or_else(|| anyhow::anyhow!("no spmv artifact fits n={} w={}", ell.n, ell.w))?;
    let rt = Runtime::cpu()?;
    let exec = rt.load_spmv(&manifest, entry)?;
    let padded = ell.pad_to(exec.n, exec.w)?;
    let mut bp = b.to_vec();
    bp.resize(exec.n, 0.0);
    let mut backend = PjrtBackend::new(&exec, &padded)?;
    let (mut cg, rep) = sim.run_cg(g, part, topo, ell.w, &mut backend, &bp, iters, 1e-6)?;
    cg.x.truncate(g.n());
    Ok((cg, rep))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn topo_from_args_variants() {
        let t = topo_from_args(&parse(&["--topo", "homog"]), 8);
        assert_eq!(t.k(), 8);
        let t = topo_from_args(&parse(&["--topo", "topo1", "--num-fast", "2", "--fast-speed", "8"]), 12);
        assert_eq!(t.pus.iter().filter(|p| p.speed == 8.0).count(), 2);
        let t = topo_from_args(&parse(&["--topo", "topo2", "--num-fast", "2"]), 12);
        assert_eq!(t.k(), 12);
        let t = topo_from_args(&parse(&["--topo", "topo3", "--nodes", "2", "--fast-nodes", "1"]), 8);
        assert_eq!(t.k(), 8);
        assert_eq!(t.root_children().len(), 2);
    }
}
