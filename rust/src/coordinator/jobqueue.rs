//! Leader/worker job execution over std::thread (the offline image has no
//! tokio; experiment grids are CPU-bound anyway, so a scoped thread pool
//! with a shared work queue is the right tool).
//!
//! The leader owns the job list; workers pull indices from a shared
//! atomic cursor and write results into their slot — no locks on the
//! result path, results come back in job order regardless of scheduling.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Run `jobs` through `f` on `workers` threads; results in job order.
/// Panics in `f` are propagated to the caller (fail fast, like the tests
/// that drive experiment grids want): the first panic poisons the queue,
/// so the other workers stop pulling new jobs instead of draining the
/// rest of the grid before the failure surfaces at scope join.
pub fn run_jobs<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return jobs.iter().map(|j| f(j)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if poisoned.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match catch_unwind(AssertUnwindSafe(|| f(&jobs[i]))) {
                    Ok(r) => *results[i].lock().unwrap() = Some(r),
                    Err(payload) => {
                        poisoned.store(true, Ordering::Relaxed);
                        resume_unwind(payload);
                    }
                }
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job missing result"))
        .collect()
}

/// Number of worker threads to use by default (leave one core for the
/// leader when possible): `max(1, available_parallelism - 1)`. The serve
/// loop's leader thread genuinely competes for a core — it paces the
/// arrival schedule and runs admission — so the pool must not claim every
/// core on multi-core machines.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer FIFO over a mutex + condvar:
/// the admission queue of the serve loop. `push` never blocks — it
/// *rejects* (returns `false`) when the queue is full or closed, which
/// is exactly the admission-control contract; `pop` blocks until an item
/// arrives or the queue is closed and drained. [`BoundedQueue::pop_group`]
/// additionally drains a run of consecutive matching items in one
/// critical section, the seam solve batching hangs off.
pub struct BoundedQueue<T> {
    cap: usize,
    inner: Mutex<QueueInner<T>>,
    ready: Condvar,
}

impl<T> BoundedQueue<T> {
    /// Queue bounded to `cap` items (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            cap: cap.max(1),
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    /// Try to enqueue `item`. Returns `false` — dropping the item — when
    /// the queue is at capacity or closed; never blocks the producer.
    pub fn push(&self, item: T) -> bool {
        let mut q = self.inner.lock().unwrap();
        if q.closed || q.items.len() >= self.cap {
            return false;
        }
        q.items.push_back(item);
        drop(q);
        self.ready.notify_one();
        true
    }

    /// Dequeue one item, blocking while the queue is open and empty.
    /// Returns `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some(x) = q.items.pop_front() {
                return Some(x);
            }
            if q.closed {
                return None;
            }
            q = self.ready.wait(q).unwrap();
        }
    }

    /// Dequeue one item plus the run of *consecutive* front items that
    /// `same(&group[0], next)` accepts, up to `max` total, all in one
    /// critical section. Blocks like [`BoundedQueue::pop`] for the first
    /// item; never blocks to grow the group (what is queued now is the
    /// batch). Returns `None` once closed and drained.
    pub fn pop_group<F>(&self, same: F, max: usize) -> Option<Vec<T>>
    where
        F: Fn(&T, &T) -> bool,
    {
        let max = max.max(1);
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some(first) = q.items.pop_front() {
                let mut group = vec![first];
                while group.len() < max {
                    let Some(next) = q.items.front() else { break };
                    if !same(&group[0], next) {
                        break;
                    }
                    let next = q.items.pop_front().expect("front just observed");
                    group.push(next);
                }
                return Some(group);
            }
            if q.closed {
                return None;
            }
            q = self.ready.wait(q).unwrap();
        }
    }

    /// Close the queue: pending items still drain, new pushes are
    /// rejected, and blocked consumers wake to observe the close.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Items currently queued (racy by nature; for tests and telemetry).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Whether the queue is currently empty (racy by nature).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_job_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = run_jobs(jobs, 4, |&j| j * j);
        assert_eq!(out, (0..100).map(|j| j * j).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = run_jobs(vec![1, 2, 3], 1, |&j| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<usize> = run_jobs(Vec::<usize>::new(), 4, |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_jobs(vec![7], 16, |&j| j);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn results_in_job_order_under_contention() {
        // Uneven job durations so completion order differs from job
        // order; results must still come back in job order.
        let jobs: Vec<usize> = (0..64).collect();
        let out = run_jobs(jobs, 8, |&j| {
            if j % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            j * 10
        });
        assert_eq!(out, (0..64).map(|j| j * 10).collect::<Vec<_>>());
    }

    #[test]
    fn workers_zero_clamps_to_one() {
        let out = run_jobs(vec![1, 2, 3], 0, |&j| j * 2);
        assert_eq!(out, vec![2, 4, 6]);
    }

    #[test]
    fn empty_jobs_with_zero_workers() {
        let out: Vec<usize> = run_jobs(Vec::<usize>::new(), 0, |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn panics_propagate_to_caller() {
        // A panicking job must fail the whole run_jobs call (fail fast),
        // not silently produce a partial result.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs((0..32).collect::<Vec<usize>>(), 4, |&j| {
                if j == 17 {
                    panic!("job 17 exploded");
                }
                j
            })
        }));
        assert!(result.is_err(), "panic in a worker must propagate");
    }

    #[test]
    fn panics_propagate_on_single_worker_path() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs(vec![1], 1, |_| -> usize { panic!("boom") })
        }));
        assert!(result.is_err());
    }

    #[test]
    fn poisoned_queue_stops_pulling_jobs_after_a_panic() {
        // Regression: before the poison flag, a panic only surfaced at
        // scope join, so the surviving workers drained the entire grid
        // (499 of 500 jobs here) before the caller saw the failure.
        let executed = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs((0..500).collect::<Vec<usize>>(), 4, |&j| {
                if j == 8 {
                    panic!("job 8 exploded");
                }
                executed.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(1));
            })
        }));
        assert!(result.is_err(), "panic in a worker must propagate");
        let done = executed.load(Ordering::Relaxed);
        assert!(
            done < 450,
            "workers drained {done} jobs after the panicking one instead of bailing early"
        );
    }

    #[test]
    fn default_workers_leaves_a_core_for_the_leader() {
        let workers = default_workers();
        assert!(workers >= 1);
        if let Ok(p) = std::thread::available_parallelism() {
            let p = p.get();
            assert!(workers <= p);
            if p >= 2 {
                assert_eq!(workers, p - 1, "doc promises max(1, parallelism - 1)");
            }
        }
    }

    #[test]
    fn all_jobs_execute_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let count = AtomicUsize::new(0);
        let _ = run_jobs((0..500).collect::<Vec<_>>(), 8, |_| {
            count.fetch_add(1, Ordering::Relaxed)
        });
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn bounded_queue_is_fifo() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            assert!(q.push(i));
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_rejects_past_capacity_and_after_close() {
        let q = BoundedQueue::new(2);
        assert!(q.push(1));
        assert!(q.push(2));
        assert!(!q.push(3), "push past cap must reject, not block");
        assert_eq!(q.pop(), Some(1));
        assert!(q.push(3), "a pop frees a slot");
        q.close();
        assert!(!q.push(4), "closed queue rejects new items");
        // Pending items still drain after close.
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_blocking_pop_wakes_on_push() {
        let q = BoundedQueue::new(4);
        std::thread::scope(|scope| {
            let consumer = scope.spawn(|| q.pop());
            // Give the consumer a moment to park, then feed it.
            std::thread::sleep(std::time::Duration::from_millis(5));
            assert!(q.push(42));
            assert_eq!(consumer.join().unwrap(), Some(42));
            let drained = scope.spawn(|| q.pop());
            std::thread::sleep(std::time::Duration::from_millis(5));
            q.close();
            assert_eq!(drained.join().unwrap(), None, "close wakes parked consumers");
        });
    }

    #[test]
    fn pop_group_drains_consecutive_matching_items() {
        let q = BoundedQueue::new(16);
        // Runs of equal parity: [2, 4, 6, 1, 3, 8].
        for x in [2, 4, 6, 1, 3, 8] {
            assert!(q.push(x));
        }
        let same_parity = |a: &i32, b: &i32| a % 2 == b % 2;
        assert_eq!(q.pop_group(same_parity, 8), Some(vec![2, 4, 6]));
        assert_eq!(q.pop_group(same_parity, 8), Some(vec![1, 3]));
        assert_eq!(q.pop_group(same_parity, 8), Some(vec![8]));
        q.close();
        assert_eq!(q.pop_group(same_parity, 8), None);
    }

    #[test]
    fn pop_group_respects_the_batch_cap() {
        let q = BoundedQueue::new(16);
        for x in 0..6 {
            assert!(q.push(x));
        }
        let any = |_: &i32, _: &i32| true;
        assert_eq!(q.pop_group(any, 4), Some(vec![0, 1, 2, 3]));
        assert_eq!(q.pop_group(any, 4), Some(vec![4, 5]));
        // A zero cap clamps to single-item groups instead of looping.
        assert!(q.push(9));
        assert_eq!(q.pop_group(any, 0), Some(vec![9]));
    }
}
