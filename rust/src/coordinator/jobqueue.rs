//! Leader/worker job execution over std::thread (the offline image has no
//! tokio; experiment grids are CPU-bound anyway, so a scoped thread pool
//! with a shared work queue is the right tool).
//!
//! The leader owns the job list; workers pull indices from a shared
//! atomic cursor and write results into their slot — no locks on the
//! result path, results come back in job order regardless of scheduling.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `jobs` through `f` on `workers` threads; results in job order.
/// Panics in `f` are propagated to the caller (fail fast, like the tests
/// that drive experiment grids want): the first panic poisons the queue,
/// so the other workers stop pulling new jobs instead of draining the
/// rest of the grid before the failure surfaces at scope join.
pub fn run_jobs<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return jobs.iter().map(|j| f(j)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if poisoned.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match catch_unwind(AssertUnwindSafe(|| f(&jobs[i]))) {
                    Ok(r) => *results[i].lock().unwrap() = Some(r),
                    Err(payload) => {
                        poisoned.store(true, Ordering::Relaxed);
                        resume_unwind(payload);
                    }
                }
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job missing result"))
        .collect()
}

/// Number of worker threads to use by default (leave one core for the
/// leader when possible): `max(1, available_parallelism - 1)`. The serve
/// loop's leader thread genuinely competes for a core — it paces the
/// arrival schedule and runs admission — so the pool must not claim every
/// core on multi-core machines.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_job_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = run_jobs(jobs, 4, |&j| j * j);
        assert_eq!(out, (0..100).map(|j| j * j).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = run_jobs(vec![1, 2, 3], 1, |&j| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<usize> = run_jobs(Vec::<usize>::new(), 4, |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_jobs(vec![7], 16, |&j| j);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn results_in_job_order_under_contention() {
        // Uneven job durations so completion order differs from job
        // order; results must still come back in job order.
        let jobs: Vec<usize> = (0..64).collect();
        let out = run_jobs(jobs, 8, |&j| {
            if j % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            j * 10
        });
        assert_eq!(out, (0..64).map(|j| j * 10).collect::<Vec<_>>());
    }

    #[test]
    fn workers_zero_clamps_to_one() {
        let out = run_jobs(vec![1, 2, 3], 0, |&j| j * 2);
        assert_eq!(out, vec![2, 4, 6]);
    }

    #[test]
    fn empty_jobs_with_zero_workers() {
        let out: Vec<usize> = run_jobs(Vec::<usize>::new(), 0, |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn panics_propagate_to_caller() {
        // A panicking job must fail the whole run_jobs call (fail fast),
        // not silently produce a partial result.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs((0..32).collect::<Vec<usize>>(), 4, |&j| {
                if j == 17 {
                    panic!("job 17 exploded");
                }
                j
            })
        }));
        assert!(result.is_err(), "panic in a worker must propagate");
    }

    #[test]
    fn panics_propagate_on_single_worker_path() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs(vec![1], 1, |_| -> usize { panic!("boom") })
        }));
        assert!(result.is_err());
    }

    #[test]
    fn poisoned_queue_stops_pulling_jobs_after_a_panic() {
        // Regression: before the poison flag, a panic only surfaced at
        // scope join, so the surviving workers drained the entire grid
        // (499 of 500 jobs here) before the caller saw the failure.
        let executed = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs((0..500).collect::<Vec<usize>>(), 4, |&j| {
                if j == 8 {
                    panic!("job 8 exploded");
                }
                executed.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(1));
            })
        }));
        assert!(result.is_err(), "panic in a worker must propagate");
        let done = executed.load(Ordering::Relaxed);
        assert!(
            done < 450,
            "workers drained {done} jobs after the panicking one instead of bailing early"
        );
    }

    #[test]
    fn default_workers_leaves_a_core_for_the_leader() {
        let workers = default_workers();
        assert!(workers >= 1);
        if let Ok(p) = std::thread::available_parallelism() {
            let p = p.get();
            assert!(workers <= p);
            if p >= 2 {
                assert_eq!(workers, p - 1, "doc promises max(1, parallelism - 1)");
            }
        }
    }

    #[test]
    fn all_jobs_execute_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let count = AtomicUsize::new(0);
        let _ = run_jobs((0..500).collect::<Vec<_>>(), 8, |_| {
            count.fetch_add(1, Ordering::Relaxed)
        });
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }
}
