//! Resident partition-as-a-service loop.
//!
//! The batch pipeline answers "how good is partitioner X on instance Y";
//! this module answers the *serving-system* question the north star asks:
//! what happens when partition/solve/repartition requests arrive as an
//! open-loop stream against a long-running coordinator. The pieces:
//!
//! - [`generate_trace`] — a deterministic synthetic traffic generator:
//!   Poisson arrivals (exponential inter-arrival gaps from the seeded
//!   [`Rng`]), a 3× burst phase mid-run, a zipf-lite tenant mix over the
//!   configured [`Tenant`] pool, and a partition/repartition/solve
//!   request mix. Same seed, same trace, bit for bit.
//! - [`PartitionService`] — the resident state: an instance-fingerprint →
//!   [`Partition`] cache (cached results are bit-identical to fresh
//!   runs — the partitioners are deterministic, the cache just skips
//!   recomputation), a per-instance [`EllMatrix`] cache so repeat solves
//!   skip the O(m) assembly, and per-tenant *current* partitions so a
//!   repeat tenant's repartition request warm-starts increKM
//!   ([`warm_start`]) from its previous blocks instead of re-seeding
//!   from scratch. The graph/matrix/partition caches are optionally
//!   *bounded* ([`ServeConfig::cache_cap`]) with least-recently-used
//!   eviction, surfaced as the [`ServeReport::evictions`] counter; an
//!   evicted entry is simply recomputed on the next request, so bounded
//!   responses stay bit-identical to unbounded ones (only hit rates and
//!   priced latencies move). Per-tenant *current* partitions are never
//!   evicted — dropping them would reseed warm-start chains and change
//!   repartition results.
//! - [`run_serve`] — the service loop on either engine backend:
//!   `sim` executes requests in *virtual time* against an analytic
//!   service-cost model (FCFS over `servers` virtual servers, bounded
//!   admission queue), so the whole [`ServeReport`] is deterministic;
//!   `threads` is the real resident loop — a leader thread paces the
//!   arrival schedule, admission rejects when the bounded queue is full,
//!   and worker threads measure wall-clock latencies. Both backends
//!   execute the *real* partition/solve/repartition work, so cache
//!   bit-identity holds everywhere; only the latency accounting differs.
//!
//! Throughput (req/s), latency percentiles (p50/p95/p99), and the cache
//! hit rate are first-class outputs ([`ServeReport::summary_json`],
//! [`ServeReport::table`]), surfaced by `hetpart serve` and the
//! harness's `--matrix serve` scenarios.

use crate::coordinator::experiment::{instance, run_one, run_solve_prepared};
use crate::exec::{ExecBackend, SolveOpts};
use crate::gen::refine::front_weights;
use crate::gen::Family;
use crate::graph::Csr;
use crate::harness::scenario::{alg1_targets, TopoPreset};
use crate::partition::{migration, Partition};
use crate::repart::warm_start;
use crate::solver::EllMatrix;
use crate::topology::Topology;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::util::stats::{mean, percentile};
use crate::util::table::Table;
use anyhow::{ensure, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Analytic service-cost model (virtual seconds) for the `sim` backend.
/// Priced, not measured, so the simulated serving run is deterministic:
/// a cache hit costs a lookup plus a response proportional to n; a cold
/// partition is priced per nonzero; a warm repartition is cheaper per
/// nonzero than a cold partition (the whole point of warm starts); a
/// solve is priced per nonzero per iteration.
const HIT_BASE_SECS: f64 = 50e-6;
const HIT_PER_ROW_SECS: f64 = 1e-9;
const PARTITION_PER_NNZ_SECS: f64 = 150e-9;
const REPART_PER_NNZ_SECS: f64 = 50e-9;
const SOLVE_PER_NNZ_ITER_SECS: f64 = 10e-9;

/// Repartition requests drift the vertex weights with `gen::refine`'s
/// moving front at this amplitude/band (the refinetrace shape).
const DRIFT_AMP: f64 = 6.0;
const DRIFT_BAND: f64 = 0.12;

/// Lloyd rounds / influence exponent for serve-layer warm starts (same
/// defaults as `repart::IncrementalGeoKM`).
const WARM_MAX_ITERS: usize = 12;
const WARM_GAMMA: f64 = 0.6;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over `bytes`, continuing from `h`. Hand-rolled rather than
/// `DefaultHasher` because cache fingerprints must be stable across Rust
/// versions and processes (they key artifacts and tests).
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One tenant of the service: a fully-specified partitioning instance
/// (graph family/size/seed × topology preset/k × algorithm/ε). Two
/// requests from the same tenant are the same problem, which is what the
/// fingerprint cache keys on.
#[derive(Debug, Clone, PartialEq)]
pub struct Tenant {
    /// Graph family to generate.
    pub family: Family,
    /// Approximate vertex count handed to the generator.
    pub n: usize,
    /// Generator seed (also the partitioning seed).
    pub graph_seed: u64,
    /// Topology preset.
    pub preset: TopoPreset,
    /// Number of PUs/blocks.
    pub k: usize,
    /// Partitioner name (see `partitioners::by_name`).
    pub algo: String,
    /// Imbalance tolerance ε.
    pub epsilon: f64,
}

impl Tenant {
    /// Stable instance fingerprint: the partition-cache key. Everything
    /// that determines the partition bit-for-bit is hashed; nothing else.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, self.family.name().as_bytes());
        h = fnv1a(h, &(self.n as u64).to_le_bytes());
        h = fnv1a(h, &self.graph_seed.to_le_bytes());
        h = fnv1a(h, self.preset.name().as_bytes());
        h = fnv1a(h, &(self.k as u64).to_le_bytes());
        h = fnv1a(h, self.algo.as_bytes());
        h = fnv1a(h, &self.epsilon.to_bits().to_le_bytes());
        h
    }

    /// Cache key for the generated graph (and its assembled matrix):
    /// tenants sharing (family, n, seed) share the instance.
    fn graph_key(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, self.family.name().as_bytes());
        h = fnv1a(h, &(self.n as u64).to_le_bytes());
        h = fnv1a(h, &self.graph_seed.to_le_bytes());
        h
    }

    /// The concrete topology this tenant partitions for.
    pub fn topology(&self) -> Topology {
        self.preset.build(self.k)
    }
}

/// What a request asks the service to do.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RequestKind {
    /// Partition the tenant's instance (cache-served when warm).
    Partition,
    /// Repartition under drifted vertex weights, warm-starting from the
    /// tenant's current blocks.
    Repartition,
    /// Run `iters` distributed-CG iterations on the (cached) partition.
    Solve {
        /// CG iterations to run.
        iters: usize,
    },
}

impl RequestKind {
    /// Kind name for records and tables.
    pub fn name(&self) -> &'static str {
        match self {
            RequestKind::Partition => "partition",
            RequestKind::Repartition => "repartition",
            RequestKind::Solve { .. } => "solve",
        }
    }
}

/// One request of the open-loop trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Sequence number (arrival order).
    pub id: usize,
    /// Arrival time in seconds from the start of the run.
    pub arrival: f64,
    /// Which tenant is asking.
    pub tenant: Tenant,
    /// What they ask for.
    pub kind: RequestKind,
    /// Front position t ∈ [0, 1) for repartition requests (0 otherwise);
    /// advances per tenant so consecutive repartitions drift coherently.
    pub drift: f64,
}

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Trace length in (virtual or wall) seconds.
    pub duration_secs: f64,
    /// Mean arrival rate λ in requests/second (tripled during the burst).
    pub arrival_rate: f64,
    /// Trace seed (tenant mix, arrival gaps, request kinds).
    pub seed: u64,
    /// Worker threads (`threads`) / virtual servers (`sim`).
    pub servers: usize,
    /// Admission bound: arrivals finding this many requests waiting are
    /// rejected, not enqueued — the loop must never build unbounded
    /// backlog or deadlock under overload.
    pub queue_cap: usize,
    /// `sim` = virtual-time deterministic serving; `threads` = real
    /// resident loop with measured latencies.
    pub backend: ExecBackend,
    /// Bound on each resident cache (graphs, matrices, partitions):
    /// `None` (the historical default) never evicts; `Some(cap)` evicts
    /// the least-recently-used entry past `cap`. Responses are
    /// bit-identical either way.
    pub cache_cap: Option<usize>,
    /// Tenant pool; index 0 is the primary (picked with probability 0.4,
    /// the rest uniformly).
    pub tenants: Vec<Tenant>,
}

impl ServeConfig {
    /// Config with the standard tenant pool: the primary tenant plus
    /// same-shaped variants over sibling mesh families (the repeat-tenant
    /// mix the cache and warm starts are measured on).
    pub fn new(
        primary: Tenant,
        duration_secs: f64,
        arrival_rate: f64,
        seed: u64,
        backend: ExecBackend,
    ) -> ServeConfig {
        let mut tenants = vec![primary.clone()];
        for family in [Family::Tri2d, Family::Rdg2d, Family::Refined2d] {
            if family != primary.family && tenants.len() < 3 {
                tenants.push(Tenant { family, ..primary.clone() });
            }
        }
        ServeConfig {
            duration_secs,
            arrival_rate,
            seed,
            servers: crate::coordinator::jobqueue::default_workers(),
            queue_cap: 64,
            backend,
            cache_cap: None,
            tenants,
        }
    }
}

/// Arrival-rate multiplier at `frac` ∈ [0, 1] of the run: a 3× burst
/// during the [40%, 55%) window, 1× elsewhere.
pub fn burst_multiplier(frac: f64) -> f64 {
    if (0.40..0.55).contains(&frac) {
        3.0
    } else {
        1.0
    }
}

/// Generate the open-loop request trace for a config. Deterministic:
/// the same config yields the same `Vec<Request>` bit for bit.
pub fn generate_trace(cfg: &ServeConfig) -> Vec<Request> {
    assert!(!cfg.tenants.is_empty(), "serve config has no tenants");
    let mut rng = Rng::new(cfg.seed);
    let mut drift_step: Vec<u64> = vec![0; cfg.tenants.len()];
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Thinned Poisson process: the burst window triples the rate.
        let rate = cfg.arrival_rate * burst_multiplier(t / cfg.duration_secs);
        let u = rng.f64();
        t += -(1.0 - u).ln() / rate;
        if t >= cfg.duration_secs {
            break;
        }
        let ti = if cfg.tenants.len() == 1 || rng.bool(0.4) {
            0
        } else {
            1 + rng.usize(cfg.tenants.len() - 1)
        };
        let r = rng.f64();
        let kind = if r < 0.55 {
            RequestKind::Partition
        } else if r < 0.80 {
            RequestKind::Repartition
        } else {
            RequestKind::Solve { iters: 4 + rng.usize(8) }
        };
        let drift = if kind == RequestKind::Repartition {
            drift_step[ti] += 1;
            (0.1 * drift_step[ti] as f64) % 1.0
        } else {
            0.0
        };
        out.push(Request {
            id: out.len(),
            arrival: t,
            tenant: cfg.tenants[ti].clone(),
            kind,
            drift,
        });
    }
    out
}

/// What happened to one handled request.
#[derive(Debug, Clone, Copy)]
pub struct Outcome {
    /// The tenant's partition was already cached.
    pub hit: bool,
    /// A warm-started repartition ran.
    pub warm: bool,
    /// Fraction of vertex weight the repartition migrated (0 otherwise).
    pub migrated_frac: f64,
    /// Virtual service seconds under the analytic cost model.
    pub service_secs: f64,
}

/// A tiny bounded map with least-recently-used eviction. Entries are
/// tagged with the service-wide access tick; inserting past the cap
/// drops the smallest-tick (stalest) entry. An unbounded map (`cap ==
/// None`) never evicts, matching the historical behaviour.
struct LruMap<V: Clone> {
    cap: Option<usize>,
    map: HashMap<u64, (u64, V)>,
}

impl<V: Clone> LruMap<V> {
    fn new(cap: Option<usize>) -> LruMap<V> {
        LruMap { cap, map: HashMap::new() }
    }

    /// Look up `key`, marking it most-recently used on a hit.
    fn touch(&mut self, key: u64, now: u64) -> Option<V> {
        self.map.get_mut(&key).map(|e| {
            e.0 = now;
            e.1.clone()
        })
    }

    /// Read without refreshing recency (test seam).
    fn peek(&self, key: u64) -> Option<&V> {
        self.map.get(&key).map(|e| &e.1)
    }

    /// First-insert-wins insert (racing workers compute identical
    /// values), then evict least-recently-used entries past the cap.
    /// Returns the surviving value and how many entries were evicted.
    /// The fresh entry carries the newest tick, so it is never the one
    /// evicted.
    fn insert(&mut self, key: u64, value: V, now: u64) -> (V, usize) {
        let e = self.map.entry(key).or_insert((now, value));
        e.0 = now;
        let v = e.1.clone();
        let mut evicted = 0;
        if let Some(cap) = self.cap {
            let cap = cap.max(1);
            while self.map.len() > cap {
                // O(len) scan: capped maps are small by construction.
                let oldest = self
                    .map
                    .iter()
                    .min_by_key(|(_, (tick, _))| *tick)
                    .map(|(k, _)| *k)
                    .expect("len > cap >= 1 implies non-empty");
                self.map.remove(&oldest);
                evicted += 1;
            }
        }
        (v, evicted)
    }
}

struct ServiceState {
    /// Monotone access counter driving LRU recency.
    tick: u64,
    /// Entries dropped across all bounded caches.
    evictions: usize,
    /// graph_key → (instance name, generated graph).
    graphs: LruMap<(String, Arc<Csr>)>,
    /// graph_key → assembled shifted-Laplacian ELL matrix (solve reuse).
    ells: LruMap<Arc<EllMatrix>>,
    /// fingerprint → cached partition (bit-identical to a fresh run).
    cache: LruMap<Arc<Partition>>,
    /// fingerprint → the tenant's *current* partition after repartitions
    /// (warm-start seed for the next repartition; starts at the cached
    /// base). Never bounded: evicting it would reseed warm-start chains
    /// and change repartition bits under a cap.
    current: HashMap<u64, Arc<Partition>>,
}

impl ServiceState {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }
}

/// The resident service: owns every cache and handles one request at a
/// time per calling worker. All state sits behind one mutex; the heavy
/// work (generation, partitioning, solving) runs *outside* the lock, so
/// workers only serialize on lookups and inserts. Two workers racing on
/// the same cold key may both compute — they produce identical results
/// (everything is deterministic), so first-insert-wins is safe.
pub struct PartitionService {
    state: Mutex<ServiceState>,
    /// Worker threads for the warm-start assignment step (1 under the
    /// threads backend — the serve workers already own the cores).
    warm_workers: usize,
}

impl PartitionService {
    /// Fresh service with empty, unbounded caches.
    pub fn new(warm_workers: usize) -> PartitionService {
        PartitionService::with_cache_cap(warm_workers, None)
    }

    /// Fresh service whose graph/matrix/partition caches are each
    /// bounded to `cache_cap` entries with LRU eviction (`None` never
    /// evicts). The per-tenant `current` partitions are always
    /// unbounded.
    pub fn with_cache_cap(
        warm_workers: usize,
        cache_cap: Option<usize>,
    ) -> PartitionService {
        PartitionService {
            state: Mutex::new(ServiceState {
                tick: 0,
                evictions: 0,
                graphs: LruMap::new(cache_cap),
                ells: LruMap::new(cache_cap),
                cache: LruMap::new(cache_cap),
                current: HashMap::new(),
            }),
            warm_workers: warm_workers.max(1),
        }
    }

    /// Entries dropped from the bounded caches so far (0 when unbounded).
    pub fn evictions(&self) -> usize {
        self.state.lock().unwrap().evictions
    }

    fn graph(&self, t: &Tenant) -> (String, Arc<Csr>) {
        let key = t.graph_key();
        {
            let mut st = self.state.lock().unwrap();
            let now = st.next_tick();
            if let Some(g) = st.graphs.touch(key, now) {
                return g;
            }
        }
        let (name, g) = instance(t.family, t.n, t.graph_seed);
        let entry = (name, Arc::new(g));
        let mut st = self.state.lock().unwrap();
        let now = st.next_tick();
        let (v, evicted) = st.graphs.insert(key, entry, now);
        st.evictions += evicted;
        v
    }

    fn ell(&self, key: u64, g: &Csr) -> Arc<EllMatrix> {
        {
            let mut st = self.state.lock().unwrap();
            let now = st.next_tick();
            if let Some(e) = st.ells.touch(key, now) {
                return e;
            }
        }
        let e = Arc::new(EllMatrix::from_graph(g, 0.05));
        let mut st = self.state.lock().unwrap();
        let now = st.next_tick();
        let (v, evicted) = st.ells.insert(key, e, now);
        st.evictions += evicted;
        v
    }

    /// The tenant's base partition: cached (hit) or computed through the
    /// exact same path a standalone run takes (`run_one`), then cached.
    fn base_partition(
        &self,
        t: &Tenant,
        name: &str,
        g: &Csr,
    ) -> Result<(Arc<Partition>, bool)> {
        let fp = t.fingerprint();
        {
            let mut st = self.state.lock().unwrap();
            let now = st.next_tick();
            if let Some(p) = st.cache.touch(fp, now) {
                return Ok((p, true));
            }
        }
        let topo = t.topology();
        let (_r, part) = run_one(name, g, &topo, &t.algo, t.epsilon, t.graph_seed)?;
        let part = Arc::new(part);
        let mut st = self.state.lock().unwrap();
        let now = st.next_tick();
        let (p, evicted) = st.cache.insert(fp, part, now);
        st.evictions += evicted;
        Ok((p, false))
    }

    /// The cached partition for a tenant, if any (test seam for the
    /// bit-identity pin). Does not refresh LRU recency.
    pub fn cached_partition(&self, t: &Tenant) -> Option<Arc<Partition>> {
        self.state.lock().unwrap().cache.peek(t.fingerprint()).cloned()
    }

    /// Handle one request (synchronously, on the calling thread).
    pub fn handle(&self, req: &Request) -> Result<Outcome> {
        let t = &req.tenant;
        let (name, g) = self.graph(t);
        match req.kind {
            RequestKind::Partition => {
                let (_p, hit) = self.base_partition(t, &name, &g)?;
                let service_secs = if hit {
                    HIT_BASE_SECS + g.n() as f64 * HIT_PER_ROW_SECS
                } else {
                    g.m() as f64 * PARTITION_PER_NNZ_SECS
                };
                Ok(Outcome { hit, warm: false, migrated_frac: 0.0, service_secs })
            }
            RequestKind::Solve { iters } => {
                let (p, hit) = self.base_partition(t, &name, &g)?;
                let ell = self.ell(t.graph_key(), &g);
                let topo = t.topology();
                run_solve_prepared(
                    &ell,
                    &p,
                    &topo,
                    ExecBackend::Sim,
                    iters,
                    0.0,
                    SolveOpts::default(),
                )?;
                let service_secs = iters as f64 * g.m() as f64 * SOLVE_PER_NNZ_ITER_SECS;
                Ok(Outcome { hit, warm: false, migrated_frac: 0.0, service_secs })
            }
            RequestKind::Repartition => {
                let (base, hit) = self.base_partition(t, &name, &g)?;
                if !g.has_coords() {
                    // No geometry, no front drift: serve the base.
                    let service_secs = HIT_BASE_SECS + g.n() as f64 * HIT_PER_ROW_SECS;
                    return Ok(Outcome { hit, warm: false, migrated_frac: 0.0, service_secs });
                }
                // Warm-start from the tenant's current blocks (cross-
                // request state — the lifted increKM seam), falling back
                // to the cached base on the tenant's first repartition.
                let prev = self
                    .state
                    .lock()
                    .unwrap()
                    .current
                    .get(&t.fingerprint())
                    .cloned()
                    .unwrap_or_else(|| base.clone());
                let mut drifted = (*g).clone();
                drifted.vwgt = front_weights(&drifted.coords, req.drift, DRIFT_AMP, DRIFT_BAND);
                let topo = t.topology();
                let (tw, _opt) = alg1_targets(&drifted, &topo)?;
                let next = Arc::new(warm_start(
                    &drifted,
                    &prev,
                    &tw,
                    t.epsilon,
                    WARM_MAX_ITERS,
                    WARM_GAMMA,
                    self.warm_workers,
                )?);
                let migrated_frac = migration(&drifted, &prev, &next).frac_weight();
                self.state.lock().unwrap().current.insert(t.fingerprint(), next);
                let service_secs = g.m() as f64 * REPART_PER_NNZ_SECS;
                Ok(Outcome { hit, warm: true, migrated_frac, service_secs })
            }
        }
    }
}

/// Per-request record of a serving run (one per offered request).
#[derive(Debug, Clone)]
pub struct ReqRecord {
    /// Request sequence number.
    pub id: usize,
    /// Request kind name.
    pub kind: &'static str,
    /// Tenant fingerprint.
    pub fingerprint: u64,
    /// Arrival-to-completion latency (virtual on `sim`, measured
    /// queue-to-completion on `threads`; 0 for rejected requests).
    pub latency_secs: f64,
    /// Cache hit.
    pub hit: bool,
    /// Warm-started repartition.
    pub warm: bool,
    /// Migrated weight fraction (repartitions only).
    pub migrated_frac: f64,
    /// Rejected at admission (queue full) — never executed.
    pub rejected: bool,
}

/// Aggregated results of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Backend that served the trace.
    pub backend: &'static str,
    /// Requests the generator offered.
    pub offered: usize,
    /// Requests executed to completion.
    pub completed: usize,
    /// Requests rejected at admission.
    pub rejected: usize,
    /// Completed requests whose partition was cache-served.
    pub hits: usize,
    /// Completed requests that computed a partition cold.
    pub misses: usize,
    /// Warm-started repartitions executed.
    pub warm_starts: usize,
    /// hits / completed (0 when nothing completed).
    pub cache_hit_rate: f64,
    /// completed / makespan.
    pub req_per_sec: f64,
    /// Median completion latency (ms).
    pub latency_p50_ms: f64,
    /// 95th-percentile completion latency (ms).
    pub latency_p95_ms: f64,
    /// 99th-percentile completion latency (ms).
    pub latency_p99_ms: f64,
    /// Mean completion latency (ms).
    pub latency_mean_ms: f64,
    /// Mean migrated-weight fraction over warm repartitions (0 if none).
    pub mean_migrated_frac: f64,
    /// End of the last completion (virtual or wall seconds).
    pub makespan_secs: f64,
    /// Cache entries the service evicted (0 when caches are unbounded).
    pub evictions: usize,
    /// Per-request records, in arrival order.
    pub records: Vec<ReqRecord>,
}

fn assemble_report(
    backend: &'static str,
    offered: usize,
    records: Vec<ReqRecord>,
    makespan_secs: f64,
    evictions: usize,
) -> ServeReport {
    let rejected = records.iter().filter(|r| r.rejected).count();
    let completed = records.len() - rejected;
    let hits = records.iter().filter(|r| !r.rejected && r.hit).count();
    let warm_starts = records.iter().filter(|r| r.warm).count();
    let lat: Vec<f64> =
        records.iter().filter(|r| !r.rejected).map(|r| r.latency_secs).collect();
    let pct = |p: f64| if lat.is_empty() { 0.0 } else { percentile(&lat, p) * 1e3 };
    let migs: Vec<f64> =
        records.iter().filter(|r| r.warm).map(|r| r.migrated_frac).collect();
    ServeReport {
        backend,
        offered,
        completed,
        rejected,
        hits,
        misses: completed - hits,
        warm_starts,
        cache_hit_rate: if completed > 0 { hits as f64 / completed as f64 } else { 0.0 },
        req_per_sec: if makespan_secs > 0.0 { completed as f64 / makespan_secs } else { 0.0 },
        latency_p50_ms: pct(50.0),
        latency_p95_ms: pct(95.0),
        latency_p99_ms: pct(99.0),
        latency_mean_ms: if lat.is_empty() { 0.0 } else { mean(&lat) * 1e3 },
        mean_migrated_frac: if migs.is_empty() { 0.0 } else { mean(&migs) },
        makespan_secs,
        evictions,
        records,
    }
}

impl ServeReport {
    /// Summary JSON (aggregates only — per-request records stay in
    /// memory). On the `sim` backend this document is bit-identical
    /// across runs of the same config.
    pub fn summary_json(&self) -> Json {
        obj(vec![
            ("backend", Json::Str(self.backend.to_string())),
            ("offered", Json::Num(self.offered as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("hits", Json::Num(self.hits as f64)),
            ("misses", Json::Num(self.misses as f64)),
            ("warm_starts", Json::Num(self.warm_starts as f64)),
            ("cache_hit_rate", Json::Num(self.cache_hit_rate)),
            ("req_per_sec", Json::Num(self.req_per_sec)),
            ("latency_p50_ms", Json::Num(self.latency_p50_ms)),
            ("latency_p95_ms", Json::Num(self.latency_p95_ms)),
            ("latency_p99_ms", Json::Num(self.latency_p99_ms)),
            ("latency_mean_ms", Json::Num(self.latency_mean_ms)),
            ("mean_migrated_frac", Json::Num(self.mean_migrated_frac)),
            ("makespan_secs", Json::Num(self.makespan_secs)),
            ("evictions", Json::Num(self.evictions as f64)),
        ])
    }

    /// One-row summary table for the CLI.
    pub fn table(&self) -> Table {
        let mut t = Table::new(vec![
            "backend", "offered", "completed", "rejected", "hits", "cacheHit", "warm",
            "evictions", "reqPerSec", "p50(ms)", "p95(ms)", "p99(ms)", "mean(ms)",
            "makespan(s)",
        ]);
        t.row(vec![
            self.backend.to_string(),
            self.offered.to_string(),
            self.completed.to_string(),
            self.rejected.to_string(),
            self.hits.to_string(),
            format!("{:.3}", self.cache_hit_rate),
            self.warm_starts.to_string(),
            self.evictions.to_string(),
            format!("{:.1}", self.req_per_sec),
            format!("{:.3}", self.latency_p50_ms),
            format!("{:.3}", self.latency_p95_ms),
            format!("{:.3}", self.latency_p99_ms),
            format!("{:.3}", self.latency_mean_ms),
            format!("{:.3}", self.makespan_secs),
        ]);
        t
    }
}

/// Run a full serving trace on the configured backend.
pub fn run_serve(cfg: &ServeConfig) -> Result<ServeReport> {
    ensure!(cfg.duration_secs > 0.0, "serve duration must be positive");
    ensure!(cfg.arrival_rate > 0.0, "serve arrival rate must be positive");
    ensure!(!cfg.tenants.is_empty(), "serve config has no tenants");
    ensure!(cfg.queue_cap >= 1, "serve queue capacity must be at least 1");
    let trace = generate_trace(cfg);
    match cfg.backend {
        ExecBackend::Sim => {
            let service = PartitionService::with_cache_cap(
                crate::coordinator::jobqueue::default_workers(),
                cfg.cache_cap,
            );
            run_serve_sim(cfg, &service, &trace)
        }
        ExecBackend::Threads => {
            // Serve workers own the cores; warm starts stay single-
            // threaded inside each worker (deterministic either way).
            let service = PartitionService::with_cache_cap(1, cfg.cache_cap);
            run_serve_threads(cfg, &service, &trace)
        }
    }
}

/// Virtual-time serving: FCFS over `servers` virtual servers, priced by
/// the analytic cost model. The real partition/solve work still executes
/// (so caches fill exactly as on `threads`); only the clock is virtual,
/// which makes the whole report deterministic.
fn run_serve_sim(
    cfg: &ServeConfig,
    service: &PartitionService,
    trace: &[Request],
) -> Result<ServeReport> {
    let servers = cfg.servers.max(1);
    let mut free_at = vec![0.0f64; servers];
    // Start times of admitted requests; entries > the current arrival are
    // still waiting (FCFS start times are nondecreasing, so a deque
    // drained from the front is exact).
    let mut started: VecDeque<f64> = VecDeque::new();
    let mut records = Vec::with_capacity(trace.len());
    let mut makespan = cfg.duration_secs;
    for req in trace {
        while started.front().is_some_and(|&s| s <= req.arrival) {
            started.pop_front();
        }
        if started.len() >= cfg.queue_cap {
            records.push(ReqRecord {
                id: req.id,
                kind: req.kind.name(),
                fingerprint: req.tenant.fingerprint(),
                latency_secs: 0.0,
                hit: false,
                warm: false,
                migrated_frac: 0.0,
                rejected: true,
            });
            continue;
        }
        let (si, soonest) = free_at
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let start = req.arrival.max(soonest);
        let out = service.handle(req)?;
        let finish = start + out.service_secs;
        free_at[si] = finish;
        started.push_back(start);
        makespan = makespan.max(finish);
        records.push(ReqRecord {
            id: req.id,
            kind: req.kind.name(),
            fingerprint: req.tenant.fingerprint(),
            latency_secs: finish - req.arrival,
            hit: out.hit,
            warm: out.warm,
            migrated_frac: out.migrated_frac,
            rejected: false,
        });
    }
    Ok(assemble_report("sim", trace.len(), records, makespan, service.evictions()))
}

/// Real-time serving: the leader paces the arrival schedule and runs
/// admission over a bounded condvar queue; `servers` workers pull,
/// execute, and measure wall-clock latencies.
fn run_serve_threads(
    cfg: &ServeConfig,
    service: &PartitionService,
    trace: &[Request],
) -> Result<ServeReport> {
    struct Queue {
        items: VecDeque<(usize, Instant)>,
        closed: bool,
    }
    let queue = Mutex::new(Queue { items: VecDeque::new(), closed: false });
    let ready = Condvar::new();
    let records: Mutex<Vec<ReqRecord>> = Mutex::new(Vec::with_capacity(trace.len()));
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..cfg.servers.max(1) {
            scope.spawn(|| loop {
                let item = {
                    let mut q = queue.lock().unwrap();
                    loop {
                        if let Some(x) = q.items.pop_front() {
                            break Some(x);
                        }
                        if q.closed {
                            break None;
                        }
                        q = ready.wait(q).unwrap();
                    }
                };
                let Some((i, enqueued)) = item else { break };
                let req = &trace[i];
                match service.handle(req) {
                    Ok(out) => records.lock().unwrap().push(ReqRecord {
                        id: req.id,
                        kind: req.kind.name(),
                        fingerprint: req.tenant.fingerprint(),
                        latency_secs: enqueued.elapsed().as_secs_f64(),
                        hit: out.hit,
                        warm: out.warm,
                        migrated_frac: out.migrated_frac,
                        rejected: false,
                    }),
                    Err(e) => errors
                        .lock()
                        .unwrap()
                        .push(format!("request {}: {e:#}", req.id)),
                }
            });
        }
        // Leader: pace the arrival schedule against the wall clock.
        for (i, req) in trace.iter().enumerate() {
            let target = Duration::from_secs_f64(req.arrival);
            let now = t0.elapsed();
            if target > now {
                std::thread::sleep(target - now);
            }
            let admitted = {
                let mut q = queue.lock().unwrap();
                if q.items.len() >= cfg.queue_cap {
                    false
                } else {
                    q.items.push_back((i, Instant::now()));
                    true
                }
            };
            if admitted {
                ready.notify_one();
            } else {
                records.lock().unwrap().push(ReqRecord {
                    id: req.id,
                    kind: req.kind.name(),
                    fingerprint: req.tenant.fingerprint(),
                    latency_secs: 0.0,
                    hit: false,
                    warm: false,
                    migrated_frac: 0.0,
                    rejected: true,
                });
            }
        }
        queue.lock().unwrap().closed = true;
        ready.notify_all();
    });
    let makespan = t0.elapsed().as_secs_f64();
    let errors = errors.into_inner().unwrap();
    ensure!(errors.is_empty(), "serve loop failures: {}", errors.join("; "));
    let mut records = records.into_inner().unwrap();
    records.sort_by_key(|r| r.id);
    Ok(assemble_report("threads", trace.len(), records, makespan, service.evictions()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_tenant() -> Tenant {
        Tenant {
            family: Family::Tri2d,
            n: 400,
            graph_seed: 7,
            preset: TopoPreset::Uniform,
            k: 4,
            algo: "geoKM".to_string(),
            epsilon: 0.05,
        }
    }

    fn tiny_config() -> ServeConfig {
        let mut cfg =
            ServeConfig::new(tiny_tenant(), 1.0, 40.0, 11, ExecBackend::Sim);
        cfg.servers = 2;
        cfg.queue_cap = 16;
        cfg
    }

    #[test]
    fn fingerprints_separate_tenants() {
        let a = tiny_tenant();
        assert_eq!(a.fingerprint(), tiny_tenant().fingerprint());
        let mut b = a.clone();
        b.algo = "zSFC".to_string();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = a.clone();
        c.epsilon = 0.03;
        assert_ne!(a.fingerprint(), c.fingerprint());
        let mut d = a.clone();
        d.n = 401;
        assert_ne!(a.fingerprint(), d.fingerprint());
        let mut e = a.clone();
        e.preset = TopoPreset::TwoSpeed;
        assert_ne!(a.fingerprint(), e.fingerprint());
        // Graph key ignores the partitioning knobs: b shares a's instance.
        assert_eq!(a.graph_key(), b.graph_key());
        assert_ne!(a.graph_key(), d.graph_key());
    }

    #[test]
    fn trace_is_deterministic_and_well_formed() {
        let cfg = tiny_config();
        let t1 = generate_trace(&cfg);
        let t2 = generate_trace(&cfg);
        assert_eq!(t1, t2, "same config must yield the same trace");
        assert!(!t1.is_empty());
        for (i, r) in t1.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(r.arrival < cfg.duration_secs);
            if i > 0 {
                assert!(r.arrival >= t1[i - 1].arrival, "arrivals out of order");
            }
            match r.kind {
                RequestKind::Repartition => assert!(r.drift > 0.0),
                _ => assert_eq!(r.drift, 0.0),
            }
        }
        // A different seed moves the trace.
        let mut other = cfg.clone();
        other.seed = 12;
        assert_ne!(generate_trace(&other), t1);
    }

    #[test]
    fn burst_phase_raises_the_arrival_density() {
        let mut cfg = tiny_config();
        cfg.duration_secs = 20.0;
        cfg.arrival_rate = 30.0;
        let trace = generate_trace(&cfg);
        let frac = |r: &Request| r.arrival / cfg.duration_secs;
        let in_burst =
            trace.iter().filter(|r| (0.40..0.55).contains(&frac(r))).count() as f64;
        let before_burst =
            trace.iter().filter(|r| (0.25..0.40).contains(&frac(r))).count() as f64;
        // Same-width windows; the burst triples λ, so even with Poisson
        // noise the burst window must clearly dominate.
        assert!(
            in_burst > 1.5 * before_burst,
            "burst {in_burst} vs before {before_burst}"
        );
        assert_eq!(burst_multiplier(0.45), 3.0);
        assert_eq!(burst_multiplier(0.2), 1.0);
        assert_eq!(burst_multiplier(0.60), 1.0);
    }

    #[test]
    fn sim_serving_fills_the_cache_and_reports() {
        let cfg = tiny_config();
        let rep = run_serve(&cfg).unwrap();
        assert_eq!(rep.backend, "sim");
        assert_eq!(rep.offered, generate_trace(&cfg).len());
        assert_eq!(rep.completed + rep.rejected, rep.offered);
        assert_eq!(rep.hits + rep.misses, rep.completed);
        assert!(rep.cache_hit_rate > 0.0, "repeat tenants must hit the cache");
        assert!(rep.req_per_sec > 0.0);
        assert!(rep.latency_p50_ms <= rep.latency_p95_ms);
        assert!(rep.latency_p95_ms <= rep.latency_p99_ms);
        assert_eq!(rep.records.len(), rep.offered);
        // The summary renders to valid JSON with the first-class columns.
        let back = Json::parse(&rep.summary_json().render()).unwrap();
        assert!(back.get("req_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.get("cache_hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.get("latency_p99_ms").is_some());
        assert_eq!(rep.table().rows.len(), 1);
    }

    #[test]
    fn report_percentiles_come_from_completed_requests_only() {
        let records = vec![
            ReqRecord {
                id: 0,
                kind: "partition",
                fingerprint: 1,
                latency_secs: 0.010,
                hit: false,
                warm: false,
                migrated_frac: 0.0,
                rejected: false,
            },
            ReqRecord {
                id: 1,
                kind: "partition",
                fingerprint: 1,
                latency_secs: 0.0,
                hit: false,
                warm: false,
                migrated_frac: 0.0,
                rejected: true,
            },
            ReqRecord {
                id: 2,
                kind: "partition",
                fingerprint: 1,
                latency_secs: 0.030,
                hit: true,
                warm: false,
                migrated_frac: 0.0,
                rejected: false,
            },
        ];
        let rep = assemble_report("sim", 3, records, 2.0, 0);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.hits, 1);
        assert_eq!(rep.misses, 1);
        assert_eq!(rep.cache_hit_rate, 0.5);
        assert_eq!(rep.req_per_sec, 1.0);
        // p50 of {10ms, 30ms} interpolates to 20ms — the rejected 0 never
        // drags the percentiles down.
        assert!((rep.latency_p50_ms - 20.0).abs() < 1e-9, "{}", rep.latency_p50_ms);
        assert!((rep.latency_mean_ms - 20.0).abs() < 1e-9);
        assert_eq!(rep.evictions, 0);
    }

    #[test]
    fn lru_cap_of_one_keeps_responses_bit_identical() {
        let a = tiny_tenant();
        let mut b = tiny_tenant();
        b.algo = "zSFC".to_string(); // shares a's graph, separate partition
        let req = |id: usize, tenant: &Tenant| Request {
            id,
            arrival: id as f64 * 0.01,
            tenant: tenant.clone(),
            kind: RequestKind::Partition,
            drift: 0.0,
        };
        let unbounded = PartitionService::new(1);
        let capped = PartitionService::with_cache_cap(1, Some(1));
        for svc in [&unbounded, &capped] {
            // A, B, A: under cap 1 the second A is recomputed after B
            // evicted it; under no cap it is a hit.
            svc.handle(&req(0, &a)).unwrap();
            svc.handle(&req(1, &b)).unwrap();
            let out = svc.handle(&req(2, &a)).unwrap();
            assert_eq!(out.hit, std::ptr::eq(svc, &unbounded));
        }
        assert_eq!(unbounded.evictions(), 0);
        // B evicted A's partition, then A's recompute evicted B's.
        assert!(capped.evictions() >= 2, "evictions {}", capped.evictions());
        // The recomputed partition carries exactly the bits the unbounded
        // cache held all along.
        let fresh = capped.cached_partition(&a).expect("a recomputed and cached");
        let kept = unbounded.cached_partition(&a).expect("a cached");
        assert_eq!(fresh.assignment, kept.assignment);
    }

    #[test]
    fn serving_under_a_cache_cap_changes_hits_not_results() {
        let base = tiny_config();
        let mut capped = tiny_config();
        capped.cache_cap = Some(1);
        let r1 = run_serve(&base).unwrap();
        let r2 = run_serve(&capped).unwrap();
        assert_eq!(r1.evictions, 0);
        assert!(r2.evictions > 0, "cap 1 with 3 tenants must evict");
        assert!(r2.hits < r1.hits, "evictions must cost cache hits");
        // Same offered trace, and every request resolves to the same
        // answer: only latency/hit bookkeeping may move.
        assert_eq!(r1.offered, r2.offered);
        assert_eq!(r1.rejected, 0);
        assert_eq!(r2.rejected, 0);
        for (x, y) in r1.records.iter().zip(&r2.records) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.warm, y.warm);
            assert_eq!(x.migrated_frac.to_bits(), y.migrated_frac.to_bits());
        }
    }
}
