//! Resident partition-as-a-service loop.
//!
//! The batch pipeline answers "how good is partitioner X on instance Y";
//! this module answers the *serving-system* question the north star asks:
//! what happens when partition/solve/repartition requests arrive as an
//! open-loop stream against a long-running coordinator. The pieces:
//!
//! - [`generate_trace`] — a deterministic synthetic traffic generator:
//!   Poisson arrivals (exponential inter-arrival gaps from the seeded
//!   [`Rng`]), a 3× burst phase mid-run, a zipf-lite tenant mix over the
//!   configured [`Tenant`] pool, and a partition/repartition/solve
//!   request mix. Same seed, same trace, bit for bit.
//! - [`PartitionService`] — the resident state: an instance-fingerprint →
//!   [`Partition`] cache (cached results are bit-identical to fresh
//!   runs — the partitioners are deterministic, the cache just skips
//!   recomputation), a per-instance [`EllMatrix`] cache so repeat solves
//!   skip the O(m) assembly, and per-tenant *current* partitions so a
//!   repeat tenant's repartition request warm-starts increKM
//!   ([`warm_start`]) from its previous blocks instead of re-seeding
//!   from scratch. The graph/matrix/partition caches are optionally
//!   *bounded* ([`ServeConfig::cache_cap`]) with least-recently-used
//!   eviction, surfaced as the [`ServeReport::evictions`] counter; an
//!   evicted entry is simply recomputed on the next request, so bounded
//!   responses stay bit-identical to unbounded ones (only hit rates and
//!   priced latencies move). Per-tenant *current* partitions are never
//!   evicted — dropping them would reseed warm-start chains and change
//!   repartition results.
//!
//!   Three throughput mechanisms keep the service scalable under the
//!   threads backend (all no-ops for the sequential `sim` loop, which is
//!   why sim reports stay bit-identical):
//!
//!   * **Sharded state** — each cache is split into fingerprint-hash
//!     shards behind independent mutexes ([`ServeConfig::shards`]), so
//!     workers serving unrelated tenants stop serializing on one lock.
//!     Recency ticks come from one shared atomic counter and eviction
//!     picks the globally stalest entry, so a single-threaded run is
//!     bit-identical to the historical one-mutex LRU at any shard count.
//!   * **Single-flight coalescing** — concurrent requests for one cold
//!     fingerprint share one build: the first becomes the leader, the
//!     rest park on a per-fingerprint condvar cell and receive the
//!     bit-identical [`Partition`] ([`ServeConfig::coalesce`]).
//!   * **Solve batching** — consecutive queued solves for one
//!     fingerprint drain as one batch over the prebuilt [`EllMatrix`]
//!     ([`run_solve_batch`]), amortizing calibration and workspace
//!     setup; per-request latencies are still recorded individually
//!     ([`ServeConfig::batch`]).
//! - [`run_serve`] — the service loop on either engine backend:
//!   `sim` executes requests in *virtual time* against an analytic
//!   service-cost model (FCFS over `servers` virtual servers, bounded
//!   admission queue), so the whole [`ServeReport`] is deterministic;
//!   `threads` is the real resident loop — a leader thread paces the
//!   arrival schedule, admission rejects when the bounded queue is full,
//!   and worker threads measure wall-clock latencies. Both backends
//!   execute the *real* partition/solve/repartition work, so cache
//!   bit-identity holds everywhere; only the latency accounting differs.
//!   [`ClientMode`] picks between the open-loop trace and a closed loop
//!   of think-time-zero clients (issue → wait → issue), the load shape a
//!   saturation sweep needs.
//!
//! Throughput (req/s and goodput), latency percentiles (p50/p95/p99),
//! build/coalesce counters, and the cache hit rate are first-class
//! outputs ([`ServeReport::summary_json`], [`ServeReport::table`]),
//! surfaced by `hetpart serve` and the harness's `--matrix serve` and
//! `--matrix sweep` scenarios.

use crate::coordinator::experiment::{instance, run_one, run_solve_batch, run_solve_prepared};
use crate::coordinator::jobqueue::BoundedQueue;
use crate::exec::{ExecBackend, SolveOpts};
use crate::gen::refine::front_weights;
use crate::gen::Family;
use crate::graph::Csr;
use crate::harness::scenario::{alg1_targets, TopoPreset};
use crate::partition::{migration, Partition};
use crate::repart::warm_start;
use crate::solver::EllMatrix;
use crate::topology::Topology;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::util::stats::{mean, percentile};
use crate::util::table::Table;
use anyhow::{anyhow, ensure, Result};
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Analytic service-cost model (virtual seconds) for the `sim` backend.
/// Priced, not measured, so the simulated serving run is deterministic:
/// a cache hit costs a lookup plus a response proportional to n; a cold
/// partition is priced per nonzero; a warm repartition is cheaper per
/// nonzero than a cold partition (the whole point of warm starts); a
/// solve is priced per nonzero per iteration.
const HIT_BASE_SECS: f64 = 50e-6;
const HIT_PER_ROW_SECS: f64 = 1e-9;
const PARTITION_PER_NNZ_SECS: f64 = 150e-9;
const REPART_PER_NNZ_SECS: f64 = 50e-9;
const SOLVE_PER_NNZ_ITER_SECS: f64 = 10e-9;

/// Repartition requests drift the vertex weights with `gen::refine`'s
/// moving front at this amplitude/band (the refinetrace shape).
const DRIFT_AMP: f64 = 6.0;
const DRIFT_BAND: f64 = 0.12;

/// Lloyd rounds / influence exponent for serve-layer warm starts (same
/// defaults as `repart::IncrementalGeoKM`).
const WARM_MAX_ITERS: usize = 12;
const WARM_GAMMA: f64 = 0.6;

/// Default shard count for the service caches: enough to spread a
/// handful of worker threads across independent locks without bloating
/// the eviction scan.
const DEFAULT_SHARDS: usize = 8;

/// Most solve requests one worker drains as a single batch. Small on
/// purpose: batching amortizes calibration/workspace setup, but an
/// unbounded batch would let one fingerprint monopolize a worker.
const SOLVE_BATCH_MAX: usize = 8;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over `bytes`, continuing from `h`. Hand-rolled rather than
/// `DefaultHasher` because cache fingerprints must be stable across Rust
/// versions and processes (they key artifacts and tests).
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One tenant of the service: a fully-specified partitioning instance
/// (graph family/size/seed × topology preset/k × algorithm/ε). Two
/// requests from the same tenant are the same problem, which is what the
/// fingerprint cache keys on.
#[derive(Debug, Clone, PartialEq)]
pub struct Tenant {
    /// Graph family to generate.
    pub family: Family,
    /// Approximate vertex count handed to the generator.
    pub n: usize,
    /// Generator seed (also the partitioning seed).
    pub graph_seed: u64,
    /// Topology preset.
    pub preset: TopoPreset,
    /// Number of PUs/blocks.
    pub k: usize,
    /// Partitioner name (see `partitioners::by_name`).
    pub algo: String,
    /// Imbalance tolerance ε.
    pub epsilon: f64,
}

impl Tenant {
    /// Stable instance fingerprint: the partition-cache key. Everything
    /// that determines the partition bit-for-bit is hashed; nothing else.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, self.family.name().as_bytes());
        h = fnv1a(h, &(self.n as u64).to_le_bytes());
        h = fnv1a(h, &self.graph_seed.to_le_bytes());
        h = fnv1a(h, self.preset.name().as_bytes());
        h = fnv1a(h, &(self.k as u64).to_le_bytes());
        h = fnv1a(h, self.algo.as_bytes());
        h = fnv1a(h, &self.epsilon.to_bits().to_le_bytes());
        h
    }

    /// Cache key for the generated graph (and its assembled matrix):
    /// tenants sharing (family, n, seed) share the instance.
    fn graph_key(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, self.family.name().as_bytes());
        h = fnv1a(h, &(self.n as u64).to_le_bytes());
        h = fnv1a(h, &self.graph_seed.to_le_bytes());
        h
    }

    /// The concrete topology this tenant partitions for.
    pub fn topology(&self) -> Topology {
        self.preset.build(self.k)
    }
}

/// What a request asks the service to do.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RequestKind {
    /// Partition the tenant's instance (cache-served when warm).
    Partition,
    /// Repartition under drifted vertex weights, warm-starting from the
    /// tenant's current blocks.
    Repartition,
    /// Run `iters` distributed-CG iterations on the (cached) partition.
    Solve {
        /// CG iterations to run.
        iters: usize,
    },
}

impl RequestKind {
    /// Kind name for records and tables.
    pub fn name(&self) -> &'static str {
        match self {
            RequestKind::Partition => "partition",
            RequestKind::Repartition => "repartition",
            RequestKind::Solve { .. } => "solve",
        }
    }
}

/// One request of the open-loop trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Sequence number (arrival order).
    pub id: usize,
    /// Arrival time in seconds from the start of the run.
    pub arrival: f64,
    /// Which tenant is asking.
    pub tenant: Tenant,
    /// What they ask for.
    pub kind: RequestKind,
    /// Front position t ∈ [0, 1) for repartition requests (0 otherwise);
    /// advances per tenant so consecutive repartitions drift coherently.
    pub drift: f64,
}

/// How load reaches the service.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClientMode {
    /// Open loop: a pre-generated Poisson trace arrives on schedule
    /// regardless of how the service keeps up (the overload shape).
    Open,
    /// Closed loop: `clients` think-time-zero clients each issue one
    /// request, wait for its completion, and immediately issue the next
    /// — offered load self-limits at the service's capacity, which is
    /// what a saturation sweep measures goodput against.
    Closed {
        /// Number of concurrent closed-loop clients (≥ 1).
        clients: usize,
    },
}

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Trace length in (virtual or wall) seconds.
    pub duration_secs: f64,
    /// Mean arrival rate λ in requests/second (tripled during the burst).
    /// Ignored by closed-loop clients, whose offered load is emergent.
    pub arrival_rate: f64,
    /// Trace seed (tenant mix, arrival gaps, request kinds).
    pub seed: u64,
    /// Worker threads (`threads`) / virtual servers (`sim`).
    pub servers: usize,
    /// Admission bound: arrivals finding this many requests waiting are
    /// rejected, not enqueued — the loop must never build unbounded
    /// backlog or deadlock under overload.
    pub queue_cap: usize,
    /// `sim` = virtual-time deterministic serving; `threads` = real
    /// resident loop with measured latencies.
    pub backend: ExecBackend,
    /// Bound on each resident cache (graphs, matrices, partitions):
    /// `None` (the historical default) never evicts; `Some(cap)` evicts
    /// the least-recently-used entry past `cap`. Responses are
    /// bit-identical either way.
    pub cache_cap: Option<usize>,
    /// Open-loop trace or closed-loop clients (default open).
    pub client_mode: ClientMode,
    /// Single-flight coalescing of concurrent identical cold requests
    /// (default on; off recovers the historical racing-builds behavior).
    pub coalesce: bool,
    /// Drain consecutive same-fingerprint solve requests as one batch on
    /// the threads backend (default on; sequential backends never see a
    /// batch, so sim is unaffected either way).
    pub batch: bool,
    /// Shard count for the service caches (≥ 1; 1 recovers the
    /// single-lock layout bit for bit).
    pub shards: usize,
    /// Tenant pool; index 0 is the primary (picked with probability 0.4,
    /// the rest uniformly).
    pub tenants: Vec<Tenant>,
}

impl ServeConfig {
    /// Config with the standard tenant pool: the primary tenant plus
    /// same-shaped variants over sibling mesh families (the repeat-tenant
    /// mix the cache and warm starts are measured on).
    pub fn new(
        primary: Tenant,
        duration_secs: f64,
        arrival_rate: f64,
        seed: u64,
        backend: ExecBackend,
    ) -> ServeConfig {
        let mut tenants = vec![primary.clone()];
        for family in [Family::Tri2d, Family::Rdg2d, Family::Refined2d] {
            if family != primary.family && tenants.len() < 3 {
                tenants.push(Tenant { family, ..primary.clone() });
            }
        }
        ServeConfig {
            duration_secs,
            arrival_rate,
            seed,
            servers: crate::coordinator::jobqueue::default_workers(),
            queue_cap: 64,
            backend,
            cache_cap: None,
            client_mode: ClientMode::Open,
            coalesce: true,
            batch: true,
            shards: DEFAULT_SHARDS,
            tenants,
        }
    }
}

/// Arrival-rate multiplier at `frac` ∈ [0, 1] of the run: a 3× burst
/// during the [40%, 55%) window, 1× elsewhere.
pub fn burst_multiplier(frac: f64) -> f64 {
    if (0.40..0.55).contains(&frac) {
        3.0
    } else {
        1.0
    }
}

/// Draw one request body (tenant index, kind, drift) from `rng`,
/// advancing `drift_step` for repartitions. Shared by the open-loop
/// trace generator and the closed-loop clients so both draw from the
/// same distribution with the exact same rng call order.
fn draw_request(
    rng: &mut Rng,
    drift_step: &mut [u64],
    tenants: &[Tenant],
) -> (usize, RequestKind, f64) {
    let ti = if tenants.len() == 1 || rng.bool(0.4) {
        0
    } else {
        1 + rng.usize(tenants.len() - 1)
    };
    let r = rng.f64();
    let kind = if r < 0.55 {
        RequestKind::Partition
    } else if r < 0.80 {
        RequestKind::Repartition
    } else {
        RequestKind::Solve { iters: 4 + rng.usize(8) }
    };
    let drift = if kind == RequestKind::Repartition {
        drift_step[ti] += 1;
        (0.1 * drift_step[ti] as f64) % 1.0
    } else {
        0.0
    };
    (ti, kind, drift)
}

/// Generate the open-loop request trace for a config. Deterministic:
/// the same config yields the same `Vec<Request>` bit for bit.
pub fn generate_trace(cfg: &ServeConfig) -> Vec<Request> {
    assert!(!cfg.tenants.is_empty(), "serve config has no tenants");
    let mut rng = Rng::new(cfg.seed);
    let mut drift_step: Vec<u64> = vec![0; cfg.tenants.len()];
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Thinned Poisson process: the burst window triples the rate.
        let rate = cfg.arrival_rate * burst_multiplier(t / cfg.duration_secs);
        let u = rng.f64();
        t += -(1.0 - u).ln() / rate;
        if t >= cfg.duration_secs {
            break;
        }
        let (ti, kind, drift) = draw_request(&mut rng, &mut drift_step, &cfg.tenants);
        out.push(Request {
            id: out.len(),
            arrival: t,
            tenant: cfg.tenants[ti].clone(),
            kind,
            drift,
        });
    }
    out
}

/// Per-client rng seed for closed-loop clients: decorrelated from the
/// trace seed and from each other by a golden-ratio stride.
fn client_seed(seed: u64, client: u64) -> u64 {
    seed ^ 0x9e3779b97f4a7c15u64.wrapping_mul(client + 1)
}

/// How a request's base partition was resolved.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Resolution {
    /// Served from the partition cache.
    Hit,
    /// This request computed the partition itself.
    Built,
    /// This request parked on another request's in-flight build and
    /// received the shared (bit-identical) result.
    Coalesced,
}

/// What happened to one handled request.
#[derive(Debug, Clone, Copy)]
pub struct Outcome {
    /// The tenant's partition was already cached.
    pub hit: bool,
    /// The partition came from another request's coalesced in-flight
    /// build (never set together with `hit`; a request that neither hit
    /// nor coalesced built the partition itself).
    pub coalesced: bool,
    /// A warm-started repartition ran.
    pub warm: bool,
    /// Fraction of vertex weight the repartition migrated (0 otherwise).
    pub migrated_frac: f64,
    /// Virtual service seconds under the analytic cost model (a
    /// coalesced resolution is priced like a hit: the waiter did no
    /// partitioning work of its own).
    pub service_secs: f64,
}

impl Outcome {
    fn from_resolution(res: Resolution, warm: bool, migrated_frac: f64, service_secs: f64) -> Outcome {
        Outcome {
            hit: res == Resolution::Hit,
            coalesced: res == Resolution::Coalesced,
            warm,
            migrated_frac,
            service_secs,
        }
    }
}

/// One cache shard: key → (recency tick, value).
type Shard<V> = Mutex<HashMap<u64, (u64, V)>>;

/// A bounded map with least-recently-used eviction, split into
/// fingerprint-hash shards behind independent mutexes so concurrent
/// workers touching unrelated keys never contend. Recency ticks come
/// from the service-wide atomic counter; the *cap and the eviction scan
/// are global* (the stalest entry across all shards goes first), so a
/// single-threaded run behaves bit-identically to the historical
/// one-mutex map at any shard count. Under concurrency the scan-then-
/// remove eviction is approximate LRU — an entry touched between the
/// scan and the removal can still be evicted — which only moves hit
/// rates, never response bits. An unbounded map (`cap == None`) never
/// evicts.
struct ShardedLru<V: Clone> {
    cap: Option<usize>,
    len: AtomicUsize,
    shards: Vec<Shard<V>>,
}

impl<V: Clone> ShardedLru<V> {
    fn new(cap: Option<usize>, shards: usize) -> ShardedLru<V> {
        ShardedLru {
            cap,
            len: AtomicUsize::new(0),
            shards: (0..shards.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    /// The shard owning `key` (upper key bits folded in so the FNV
    /// fingerprints spread even when shard counts divide low-bit cycles).
    fn shard(&self, key: u64) -> &Shard<V> {
        let folded = (key ^ (key >> 32)) as usize;
        &self.shards[folded % self.shards.len()]
    }

    /// Look up `key`, marking it most-recently used on a hit.
    fn touch(&self, key: u64, now: u64) -> Option<V> {
        self.shard(key).lock().unwrap().get_mut(&key).map(|e| {
            e.0 = now;
            e.1.clone()
        })
    }

    /// Read without refreshing recency (test seam; also the coalescing
    /// leader's double-check, which must not consume a recency tick so
    /// sequential runs keep the historical tick sequence).
    fn peek(&self, key: u64) -> Option<V> {
        self.shard(key).lock().unwrap().get(&key).map(|e| e.1.clone())
    }

    /// First-insert-wins insert (racing workers compute identical
    /// values), then evict least-recently-used entries past the cap.
    /// Returns the surviving value and how many entries were evicted.
    /// The fresh entry carries the newest tick, so it is never the one
    /// evicted.
    fn insert(&self, key: u64, value: V, now: u64) -> (V, usize) {
        let v = {
            let mut m = self.shard(key).lock().unwrap();
            match m.entry(key) {
                Entry::Occupied(mut e) => {
                    e.get_mut().0 = now;
                    e.get().1.clone()
                }
                Entry::Vacant(e) => {
                    self.len.fetch_add(1, Ordering::Relaxed);
                    e.insert((now, value)).1.clone()
                }
            }
        };
        (v, self.evict_past_cap())
    }

    /// Drop globally-stalest entries while the map exceeds its cap,
    /// locking one shard at a time (never two — no lock-order cycles).
    fn evict_past_cap(&self) -> usize {
        let Some(cap) = self.cap else { return 0 };
        let cap = cap.max(1);
        let mut evicted = 0;
        while self.len.load(Ordering::Relaxed) > cap {
            let mut oldest: Option<(usize, u64, u64)> = None;
            for (si, shard) in self.shards.iter().enumerate() {
                let m = shard.lock().unwrap();
                for (k, (tick, _)) in m.iter() {
                    if oldest.is_none_or(|(_, _, t)| *tick < t) {
                        oldest = Some((si, *k, *tick));
                    }
                }
            }
            let Some((si, key, _)) = oldest else { break };
            if self.shards[si].lock().unwrap().remove(&key).is_some() {
                self.len.fetch_sub(1, Ordering::Relaxed);
                evicted += 1;
            }
        }
        evicted
    }
}

/// Sharded overwrite map for the per-tenant *current* partitions:
/// unbounded (never evicted — see the module docs) and last-write-wins,
/// unlike the first-insert-wins LRU caches.
struct ShardedMap<V: Clone> {
    shards: Vec<Mutex<HashMap<u64, V>>>,
}

impl<V: Clone> ShardedMap<V> {
    fn new(shards: usize) -> ShardedMap<V> {
        ShardedMap {
            shards: (0..shards.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<HashMap<u64, V>> {
        let folded = (key ^ (key >> 32)) as usize;
        &self.shards[folded % self.shards.len()]
    }

    fn get(&self, key: u64) -> Option<V> {
        self.shard(key).lock().unwrap().get(&key).cloned()
    }

    fn set(&self, key: u64, value: V) {
        self.shard(key).lock().unwrap().insert(key, value);
    }
}

/// A per-fingerprint single-flight cell: the build leader publishes the
/// (bit-identical) result or an error string; followers park on the
/// condvar until the cell fills.
#[derive(Default)]
struct Inflight {
    done: Mutex<Option<std::result::Result<Arc<Partition>, String>>>,
    cv: Condvar,
}

/// The resident service: owns every cache and handles one request at a
/// time per calling worker. State is sharded per kind (graphs, ELL
/// matrices, partitions, per-tenant currents) and the heavy work
/// (generation, partitioning, solving) runs *outside* any lock, so
/// workers only serialize on same-shard lookups and inserts. With
/// coalescing on, workers racing on one cold fingerprint share a single
/// build; with it off they may all compute — either way they produce
/// identical results (everything is deterministic), so first-insert-wins
/// is safe.
pub struct PartitionService {
    /// Monotone access counter driving LRU recency, shared by all caches
    /// so a sequential run's tick sequence matches the historical
    /// single-lock service exactly.
    tick: AtomicU64,
    /// Entries dropped across all bounded caches.
    evictions: AtomicUsize,
    /// Cold partition builds actually executed (the coalescing win is
    /// measured as a drop in this counter at equal completions).
    builds: AtomicUsize,
    /// Share in-flight builds of one fingerprint (single-flight).
    coalesce: bool,
    /// graph_key → (instance name, generated graph).
    graphs: ShardedLru<(String, Arc<Csr>)>,
    /// graph_key → assembled shifted-Laplacian ELL matrix (solve reuse).
    ells: ShardedLru<Arc<EllMatrix>>,
    /// fingerprint → cached partition (bit-identical to a fresh run).
    cache: ShardedLru<Arc<Partition>>,
    /// fingerprint → the tenant's *current* partition after repartitions
    /// (warm-start seed for the next repartition; starts at the cached
    /// base). Never bounded: evicting it would reseed warm-start chains
    /// and change repartition bits under a cap.
    current: ShardedMap<Arc<Partition>>,
    /// fingerprint → in-flight build cell (present only while a build
    /// runs; removed before the leader returns).
    inflight: Mutex<HashMap<u64, Arc<Inflight>>>,
    /// Worker threads for the warm-start assignment step (1 under the
    /// threads backend — the serve workers already own the cores).
    warm_workers: usize,
}

impl PartitionService {
    /// Fresh service with empty, unbounded caches and default sharding.
    pub fn new(warm_workers: usize) -> PartitionService {
        PartitionService::with_cache_cap(warm_workers, None)
    }

    /// Fresh service whose graph/matrix/partition caches are each
    /// bounded to `cache_cap` entries with LRU eviction (`None` never
    /// evicts). The per-tenant `current` partitions are always
    /// unbounded.
    pub fn with_cache_cap(
        warm_workers: usize,
        cache_cap: Option<usize>,
    ) -> PartitionService {
        PartitionService::with_opts(warm_workers, cache_cap, true, DEFAULT_SHARDS)
    }

    /// Fully-configured service: cache bound, single-flight coalescing
    /// toggle, and cache shard count (`1` recovers the single-lock
    /// layout bit for bit).
    pub fn with_opts(
        warm_workers: usize,
        cache_cap: Option<usize>,
        coalesce: bool,
        shards: usize,
    ) -> PartitionService {
        let shards = shards.max(1);
        PartitionService {
            tick: AtomicU64::new(0),
            evictions: AtomicUsize::new(0),
            builds: AtomicUsize::new(0),
            coalesce,
            graphs: ShardedLru::new(cache_cap, shards),
            ells: ShardedLru::new(cache_cap, shards),
            cache: ShardedLru::new(cache_cap, shards),
            current: ShardedMap::new(shards),
            inflight: Mutex::new(HashMap::new()),
            warm_workers: warm_workers.max(1),
        }
    }

    /// Entries dropped from the bounded caches so far (0 when unbounded).
    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Cold partition builds executed so far. With coalescing on, N
    /// concurrent requests for one cold fingerprint move this by exactly
    /// 1; with it off, by up to N.
    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn graph(&self, t: &Tenant) -> (String, Arc<Csr>) {
        let key = t.graph_key();
        let now = self.next_tick();
        if let Some(g) = self.graphs.touch(key, now) {
            return g;
        }
        let (name, g) = instance(t.family, t.n, t.graph_seed);
        let entry = (name, Arc::new(g));
        let now = self.next_tick();
        let (v, evicted) = self.graphs.insert(key, entry, now);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        v
    }

    fn ell(&self, key: u64, g: &Csr) -> Arc<EllMatrix> {
        let now = self.next_tick();
        if let Some(e) = self.ells.touch(key, now) {
            return e;
        }
        let e = Arc::new(EllMatrix::from_graph(g, 0.05));
        let now = self.next_tick();
        let (v, evicted) = self.ells.insert(key, e, now);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        v
    }

    /// Compute the tenant's partition cold through the exact path a
    /// standalone run takes (`run_one`) and insert it (first-insert-wins).
    fn build_base(&self, t: &Tenant, name: &str, g: &Csr, fp: u64) -> Result<Arc<Partition>> {
        let topo = t.topology();
        let (_r, part) = run_one(name, g, &topo, &t.algo, t.epsilon, t.graph_seed)?;
        self.builds.fetch_add(1, Ordering::Relaxed);
        let now = self.next_tick();
        let (p, evicted) = self.cache.insert(fp, Arc::new(part), now);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        Ok(p)
    }

    /// The tenant's base partition: cached (hit), computed (built), or —
    /// when another request is already building the same fingerprint —
    /// received from that build (coalesced).
    fn base_partition(
        &self,
        t: &Tenant,
        name: &str,
        g: &Csr,
    ) -> Result<(Arc<Partition>, Resolution)> {
        let fp = t.fingerprint();
        let now = self.next_tick();
        if let Some(p) = self.cache.touch(fp, now) {
            return Ok((p, Resolution::Hit));
        }
        if !self.coalesce {
            return self.build_base(t, name, g, fp).map(|p| (p, Resolution::Built));
        }
        // Single flight: first-comer registers the in-flight cell and
        // leads the build; everyone else parks on it.
        let (cell, leader) = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.entry(fp) {
                Entry::Occupied(e) => (e.get().clone(), false),
                Entry::Vacant(e) => {
                    let cell = Arc::new(Inflight::default());
                    e.insert(cell.clone());
                    (cell, true)
                }
            }
        };
        if !leader {
            let mut done = cell.done.lock().unwrap();
            while done.is_none() {
                done = cell.cv.wait(done).unwrap();
            }
            return match done.as_ref().expect("loop exits only when filled") {
                Ok(p) => Ok((p.clone(), Resolution::Coalesced)),
                Err(e) => Err(anyhow!("coalesced build failed: {e}")),
            };
        }
        // Leader: double-check the cache first — a previous leader may
        // have filled it between our miss and our registration. `peek`
        // on purpose: no recency tick, so a sequential run's tick
        // sequence (and therefore its LRU evictions) is bit-identical to
        // the pre-coalescing service.
        let result = match self.cache.peek(fp) {
            Some(p) => Ok((p, Resolution::Hit)),
            None => self.build_base(t, name, g, fp).map(|p| (p, Resolution::Built)),
        };
        // Publish before deregistering — even on error, or followers
        // would park forever.
        let publish = match &result {
            Ok((p, _)) => Ok(p.clone()),
            Err(e) => Err(format!("{e:#}")),
        };
        *cell.done.lock().unwrap() = Some(publish);
        cell.cv.notify_all();
        self.inflight.lock().unwrap().remove(&fp);
        result
    }

    /// The cached partition for a tenant, if any (test seam for the
    /// bit-identity pin). Does not refresh LRU recency.
    pub fn cached_partition(&self, t: &Tenant) -> Option<Arc<Partition>> {
        self.cache.peek(t.fingerprint())
    }

    /// Handle one request (synchronously, on the calling thread).
    pub fn handle(&self, req: &Request) -> Result<Outcome> {
        let t = &req.tenant;
        let (name, g) = self.graph(t);
        match req.kind {
            RequestKind::Partition => {
                let (_p, res) = self.base_partition(t, &name, &g)?;
                let service_secs = if res == Resolution::Built {
                    g.m() as f64 * PARTITION_PER_NNZ_SECS
                } else {
                    HIT_BASE_SECS + g.n() as f64 * HIT_PER_ROW_SECS
                };
                Ok(Outcome::from_resolution(res, false, 0.0, service_secs))
            }
            RequestKind::Solve { iters } => {
                let (p, res) = self.base_partition(t, &name, &g)?;
                let ell = self.ell(t.graph_key(), &g);
                let topo = t.topology();
                run_solve_prepared(
                    &ell,
                    &p,
                    &topo,
                    ExecBackend::Sim,
                    iters,
                    0.0,
                    SolveOpts::default(),
                )?;
                let service_secs = iters as f64 * g.m() as f64 * SOLVE_PER_NNZ_ITER_SECS;
                Ok(Outcome::from_resolution(res, false, 0.0, service_secs))
            }
            RequestKind::Repartition => {
                let (base, res) = self.base_partition(t, &name, &g)?;
                if !g.has_coords() {
                    // No geometry, no front drift: serve the base.
                    let service_secs = HIT_BASE_SECS + g.n() as f64 * HIT_PER_ROW_SECS;
                    return Ok(Outcome::from_resolution(res, false, 0.0, service_secs));
                }
                // Warm-start from the tenant's current blocks (cross-
                // request state — the lifted increKM seam), falling back
                // to the cached base on the tenant's first repartition.
                let prev = self.current.get(t.fingerprint()).unwrap_or_else(|| base.clone());
                let mut drifted = (*g).clone();
                drifted.vwgt = front_weights(&drifted.coords, req.drift, DRIFT_AMP, DRIFT_BAND);
                let topo = t.topology();
                let (tw, _opt) = alg1_targets(&drifted, &topo)?;
                let next = Arc::new(warm_start(
                    &drifted,
                    &prev,
                    &tw,
                    t.epsilon,
                    WARM_MAX_ITERS,
                    WARM_GAMMA,
                    self.warm_workers,
                )?);
                let migrated_frac = migration(&drifted, &prev, &next).frac_weight();
                self.current.set(t.fingerprint(), next);
                let service_secs = g.m() as f64 * REPART_PER_NNZ_SECS;
                Ok(Outcome::from_resolution(res, true, migrated_frac, service_secs))
            }
        }
    }

    /// Handle a batch of solve requests sharing one fingerprint: the
    /// graph, base partition, and ELL matrix resolve once, and the CG
    /// runs share one calibrated cluster model ([`run_solve_batch`]) —
    /// amortizing the per-request setup a sequence of individual
    /// [`PartitionService::handle`] calls would repeat. Outcomes line up
    /// with `reqs`: the first carries the batch's real resolution, the
    /// rest are hits by construction (exactly what serving them
    /// individually right after the first would report). Numerics are
    /// bitwise identical to individual serving.
    pub fn handle_solve_batch(&self, reqs: &[&Request]) -> Result<Vec<Outcome>> {
        ensure!(!reqs.is_empty(), "empty solve batch");
        let t = &reqs[0].tenant;
        let fp = t.fingerprint();
        let mut iters = Vec::with_capacity(reqs.len());
        for r in reqs {
            ensure!(
                r.tenant.fingerprint() == fp,
                "solve batch mixes fingerprints (request {})",
                r.id
            );
            match r.kind {
                RequestKind::Solve { iters: it } => iters.push(it),
                _ => anyhow::bail!("solve batch got a {} request ({})", r.kind.name(), r.id),
            }
        }
        let (name, g) = self.graph(t);
        let (p, res) = self.base_partition(t, &name, &g)?;
        let ell = self.ell(t.graph_key(), &g);
        let topo = t.topology();
        run_solve_batch(&ell, &p, &topo, ExecBackend::Sim, &iters, 0.0, SolveOpts::default())?;
        Ok(iters
            .iter()
            .enumerate()
            .map(|(i, &it)| {
                let service_secs = it as f64 * g.m() as f64 * SOLVE_PER_NNZ_ITER_SECS;
                if i == 0 {
                    Outcome::from_resolution(res, false, 0.0, service_secs)
                } else {
                    Outcome::from_resolution(Resolution::Hit, false, 0.0, service_secs)
                }
            })
            .collect())
    }
}

/// Per-request record of a serving run (one per offered request).
#[derive(Debug, Clone)]
pub struct ReqRecord {
    /// Request sequence number.
    pub id: usize,
    /// Request kind name.
    pub kind: &'static str,
    /// Tenant fingerprint.
    pub fingerprint: u64,
    /// Arrival-to-completion latency (virtual on `sim`, measured
    /// queue-to-completion on `threads`; 0 for rejected requests).
    pub latency_secs: f64,
    /// Cache hit.
    pub hit: bool,
    /// Received a coalesced in-flight build.
    pub coalesced: bool,
    /// Served as the trailing member of a solve batch.
    pub batched: bool,
    /// Warm-started repartition.
    pub warm: bool,
    /// Migrated weight fraction (repartitions only).
    pub migrated_frac: f64,
    /// Rejected at admission (queue full) — never executed.
    pub rejected: bool,
}

impl ReqRecord {
    /// Completed-request record from a request and its outcome.
    fn completed(req: &Request, out: &Outcome, latency_secs: f64, batched: bool) -> ReqRecord {
        ReqRecord {
            id: req.id,
            kind: req.kind.name(),
            fingerprint: req.tenant.fingerprint(),
            latency_secs,
            hit: out.hit,
            coalesced: out.coalesced,
            batched,
            warm: out.warm,
            migrated_frac: out.migrated_frac,
            rejected: false,
        }
    }

    /// Admission-rejection record for a request.
    fn rejected(req: &Request) -> ReqRecord {
        ReqRecord {
            id: req.id,
            kind: req.kind.name(),
            fingerprint: req.tenant.fingerprint(),
            latency_secs: 0.0,
            hit: false,
            coalesced: false,
            batched: false,
            warm: false,
            migrated_frac: 0.0,
            rejected: true,
        }
    }
}

/// Aggregated results of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Backend that served the trace.
    pub backend: &'static str,
    /// Requests the generator offered.
    pub offered: usize,
    /// Requests executed to completion.
    pub completed: usize,
    /// Requests rejected at admission.
    pub rejected: usize,
    /// Completed requests whose partition was cache-served.
    pub hits: usize,
    /// Completed requests that computed a partition cold.
    pub misses: usize,
    /// Warm-started repartitions executed.
    pub warm_starts: usize,
    /// hits / completed (0 when nothing completed).
    pub cache_hit_rate: f64,
    /// completed / makespan.
    pub req_per_sec: f64,
    /// Median completion latency (ms).
    pub latency_p50_ms: f64,
    /// 95th-percentile completion latency (ms).
    pub latency_p95_ms: f64,
    /// 99th-percentile completion latency (ms).
    pub latency_p99_ms: f64,
    /// Mean completion latency (ms).
    pub latency_mean_ms: f64,
    /// Mean migrated-weight fraction over warm repartitions (0 if none).
    pub mean_migrated_frac: f64,
    /// End of the last completion (virtual or wall seconds).
    pub makespan_secs: f64,
    /// Cache entries the service evicted (0 when caches are unbounded).
    pub evictions: usize,
    /// Completed requests that built their partition themselves
    /// (`builds + coalesced + hits == completed`).
    pub builds: usize,
    /// Completed requests that received a coalesced in-flight build.
    pub coalesced: usize,
    /// Completed solve requests served as trailing batch members.
    pub batched: usize,
    /// Closed-loop client count (0 for open-loop runs).
    pub clients: usize,
    /// Offered load in requests/second: the configured λ for open-loop
    /// runs, the realized issue rate for closed-loop runs.
    pub offered_rate: f64,
    /// Completions per second of *trace time* (completed / duration) —
    /// the sweep's y-axis. Unlike `req_per_sec` it does not shrink when
    /// a straggling completion stretches the makespan.
    pub goodput: f64,
    /// Per-request records, in arrival order.
    pub records: Vec<ReqRecord>,
}

#[allow(clippy::too_many_arguments)]
fn assemble_report(
    backend: &'static str,
    offered: usize,
    records: Vec<ReqRecord>,
    makespan_secs: f64,
    evictions: usize,
    duration_secs: f64,
    offered_rate: f64,
    clients: usize,
) -> ServeReport {
    let rejected = records.iter().filter(|r| r.rejected).count();
    let completed = records.len() - rejected;
    let hits = records.iter().filter(|r| !r.rejected && r.hit).count();
    let coalesced = records.iter().filter(|r| !r.rejected && r.coalesced).count();
    let batched = records.iter().filter(|r| !r.rejected && r.batched).count();
    let warm_starts = records.iter().filter(|r| r.warm).count();
    let lat: Vec<f64> =
        records.iter().filter(|r| !r.rejected).map(|r| r.latency_secs).collect();
    let pct = |p: f64| if lat.is_empty() { 0.0 } else { percentile(&lat, p) * 1e3 };
    let migs: Vec<f64> =
        records.iter().filter(|r| r.warm).map(|r| r.migrated_frac).collect();
    ServeReport {
        backend,
        offered,
        completed,
        rejected,
        hits,
        misses: completed - hits,
        warm_starts,
        cache_hit_rate: if completed > 0 { hits as f64 / completed as f64 } else { 0.0 },
        req_per_sec: if makespan_secs > 0.0 { completed as f64 / makespan_secs } else { 0.0 },
        latency_p50_ms: pct(50.0),
        latency_p95_ms: pct(95.0),
        latency_p99_ms: pct(99.0),
        latency_mean_ms: if lat.is_empty() { 0.0 } else { mean(&lat) * 1e3 },
        mean_migrated_frac: if migs.is_empty() { 0.0 } else { mean(&migs) },
        makespan_secs,
        evictions,
        builds: completed - hits - coalesced,
        coalesced,
        batched,
        clients,
        offered_rate,
        goodput: if duration_secs > 0.0 { completed as f64 / duration_secs } else { 0.0 },
        records,
    }
}

impl ServeReport {
    /// Summary JSON (aggregates only — per-request records stay in
    /// memory). On the `sim` backend this document is bit-identical
    /// across runs of the same config. The historical keys keep their
    /// exact order; the throughput-pass keys (`builds`…`goodput`) are
    /// appended after them.
    pub fn summary_json(&self) -> Json {
        obj(vec![
            ("backend", Json::Str(self.backend.to_string())),
            ("offered", Json::Num(self.offered as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("hits", Json::Num(self.hits as f64)),
            ("misses", Json::Num(self.misses as f64)),
            ("warm_starts", Json::Num(self.warm_starts as f64)),
            ("cache_hit_rate", Json::Num(self.cache_hit_rate)),
            ("req_per_sec", Json::Num(self.req_per_sec)),
            ("latency_p50_ms", Json::Num(self.latency_p50_ms)),
            ("latency_p95_ms", Json::Num(self.latency_p95_ms)),
            ("latency_p99_ms", Json::Num(self.latency_p99_ms)),
            ("latency_mean_ms", Json::Num(self.latency_mean_ms)),
            ("mean_migrated_frac", Json::Num(self.mean_migrated_frac)),
            ("makespan_secs", Json::Num(self.makespan_secs)),
            ("evictions", Json::Num(self.evictions as f64)),
            ("builds", Json::Num(self.builds as f64)),
            ("coalesced", Json::Num(self.coalesced as f64)),
            ("batched", Json::Num(self.batched as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("offered_rate", Json::Num(self.offered_rate)),
            ("goodput", Json::Num(self.goodput)),
        ])
    }

    /// One-row summary table for the CLI.
    pub fn table(&self) -> Table {
        let mut t = Table::new(vec![
            "backend", "offered", "completed", "rejected", "hits", "builds", "coalesced",
            "cacheHit", "warm", "evictions", "reqPerSec", "goodput", "p50(ms)", "p95(ms)",
            "p99(ms)", "mean(ms)", "makespan(s)",
        ]);
        t.row(vec![
            self.backend.to_string(),
            self.offered.to_string(),
            self.completed.to_string(),
            self.rejected.to_string(),
            self.hits.to_string(),
            self.builds.to_string(),
            self.coalesced.to_string(),
            format!("{:.3}", self.cache_hit_rate),
            self.warm_starts.to_string(),
            self.evictions.to_string(),
            format!("{:.1}", self.req_per_sec),
            format!("{:.1}", self.goodput),
            format!("{:.3}", self.latency_p50_ms),
            format!("{:.3}", self.latency_p95_ms),
            format!("{:.3}", self.latency_p99_ms),
            format!("{:.3}", self.latency_mean_ms),
            format!("{:.3}", self.makespan_secs),
        ]);
        t
    }
}

/// Run a full serving trace on the configured backend.
pub fn run_serve(cfg: &ServeConfig) -> Result<ServeReport> {
    ensure!(cfg.duration_secs > 0.0, "serve duration must be positive");
    ensure!(cfg.arrival_rate > 0.0, "serve arrival rate must be positive");
    ensure!(!cfg.tenants.is_empty(), "serve config has no tenants");
    ensure!(cfg.queue_cap >= 1, "serve queue capacity must be at least 1");
    ensure!(cfg.shards >= 1, "serve cache shard count must be at least 1");
    if let ClientMode::Closed { clients } = cfg.client_mode {
        ensure!(clients >= 1, "closed-loop serving needs at least one client");
    }
    let service = match cfg.backend {
        ExecBackend::Sim => PartitionService::with_opts(
            crate::coordinator::jobqueue::default_workers(),
            cfg.cache_cap,
            cfg.coalesce,
            cfg.shards,
        ),
        // Serve workers own the cores; warm starts stay single-
        // threaded inside each worker (deterministic either way).
        ExecBackend::Threads => {
            PartitionService::with_opts(1, cfg.cache_cap, cfg.coalesce, cfg.shards)
        }
    };
    match (cfg.backend, cfg.client_mode) {
        (ExecBackend::Sim, ClientMode::Open) => {
            run_serve_sim(cfg, &service, &generate_trace(cfg))
        }
        (ExecBackend::Sim, ClientMode::Closed { clients }) => {
            run_serve_sim_closed(cfg, &service, clients)
        }
        (ExecBackend::Threads, ClientMode::Open) => {
            run_serve_threads(cfg, &service, &generate_trace(cfg))
        }
        (ExecBackend::Threads, ClientMode::Closed { clients }) => {
            run_serve_threads_closed(cfg, &service, clients)
        }
    }
}

/// Virtual-time serving: FCFS over `servers` virtual servers, priced by
/// the analytic cost model. The real partition/solve work still executes
/// (so caches fill exactly as on `threads`); only the clock is virtual,
/// which makes the whole report deterministic.
fn run_serve_sim(
    cfg: &ServeConfig,
    service: &PartitionService,
    trace: &[Request],
) -> Result<ServeReport> {
    let servers = cfg.servers.max(1);
    let mut free_at = vec![0.0f64; servers];
    // Start times of admitted requests; entries > the current arrival are
    // still waiting (FCFS start times are nondecreasing, so a deque
    // drained from the front is exact).
    let mut started: VecDeque<f64> = VecDeque::new();
    let mut records = Vec::with_capacity(trace.len());
    let mut makespan = cfg.duration_secs;
    for req in trace {
        while started.front().is_some_and(|&s| s <= req.arrival) {
            started.pop_front();
        }
        if started.len() >= cfg.queue_cap {
            records.push(ReqRecord::rejected(req));
            continue;
        }
        let (si, soonest) = free_at
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let start = req.arrival.max(soonest);
        let out = service.handle(req)?;
        let finish = start + out.service_secs;
        free_at[si] = finish;
        started.push_back(start);
        makespan = makespan.max(finish);
        records.push(ReqRecord::completed(req, &out, finish - req.arrival, false));
    }
    Ok(assemble_report(
        "sim",
        trace.len(),
        records,
        makespan,
        service.evictions(),
        cfg.duration_secs,
        cfg.arrival_rate,
        0,
    ))
}

/// Virtual-time closed-loop serving: `clients` think-time-zero clients
/// each issue, wait for completion, and immediately issue again, over
/// the same FCFS virtual servers. Each client draws requests from its
/// own decorrelated rng ([`client_seed`]), so the run is deterministic.
/// Closed loops never reject: at most `clients` requests are ever
/// outstanding, and queue pressure surfaces as completion latency.
fn run_serve_sim_closed(
    cfg: &ServeConfig,
    service: &PartitionService,
    clients: usize,
) -> Result<ServeReport> {
    let servers = cfg.servers.max(1);
    let mut free_at = vec![0.0f64; servers];
    let mut ready = vec![0.0f64; clients];
    let mut rngs: Vec<Rng> =
        (0..clients).map(|c| Rng::new(client_seed(cfg.seed, c as u64))).collect();
    let mut drift_step = vec![vec![0u64; cfg.tenants.len()]; clients];
    let mut records = Vec::new();
    let mut makespan = cfg.duration_secs;
    let mut seq = 0usize;
    loop {
        // Next client to act: smallest ready time, lowest index on ties
        // — a deterministic event order.
        let (ci, issue_at) = ready
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        if issue_at >= cfg.duration_secs {
            break;
        }
        let (ti, kind, drift) =
            draw_request(&mut rngs[ci], &mut drift_step[ci], &cfg.tenants);
        let req = Request {
            id: seq,
            arrival: issue_at,
            tenant: cfg.tenants[ti].clone(),
            kind,
            drift,
        };
        seq += 1;
        let (si, soonest) = free_at
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let start = issue_at.max(soonest);
        let out = service.handle(&req)?;
        let finish = start + out.service_secs;
        free_at[si] = finish;
        ready[ci] = finish;
        makespan = makespan.max(finish);
        records.push(ReqRecord::completed(&req, &out, finish - issue_at, false));
    }
    let offered = records.len();
    Ok(assemble_report(
        "sim",
        offered,
        records,
        makespan,
        service.evictions(),
        cfg.duration_secs,
        offered as f64 / cfg.duration_secs,
        clients,
    ))
}

/// Do `a` and `b` form one solve batch (both solves, same fingerprint)?
fn same_solve_batch(a: &Request, b: &Request) -> bool {
    matches!(a.kind, RequestKind::Solve { .. })
        && matches!(b.kind, RequestKind::Solve { .. })
        && a.tenant.fingerprint() == b.tenant.fingerprint()
}

/// Real-time serving: the leader paces the arrival schedule and runs
/// admission over a bounded condvar queue ([`BoundedQueue`]); `servers`
/// workers pull, execute, and measure wall-clock latencies. With
/// batching on, a worker drains consecutive same-fingerprint solves
/// behind the queue head as one [`PartitionService::handle_solve_batch`]
/// call, still recording each request's own latency.
fn run_serve_threads(
    cfg: &ServeConfig,
    service: &PartitionService,
    trace: &[Request],
) -> Result<ServeReport> {
    let queue: BoundedQueue<(usize, Instant)> = BoundedQueue::new(cfg.queue_cap);
    let records: Mutex<Vec<ReqRecord>> = Mutex::new(Vec::with_capacity(trace.len()));
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..cfg.servers.max(1) {
            scope.spawn(|| loop {
                let group = if cfg.batch {
                    queue.pop_group(
                        |&(a, _), &(b, _)| same_solve_batch(&trace[a], &trace[b]),
                        SOLVE_BATCH_MAX,
                    )
                } else {
                    queue.pop().map(|item| vec![item])
                };
                let Some(group) = group else { break };
                if group.len() > 1 {
                    let reqs: Vec<&Request> = group.iter().map(|&(i, _)| &trace[i]).collect();
                    match service.handle_solve_batch(&reqs) {
                        Ok(outs) => {
                            let mut recs = records.lock().unwrap();
                            for (gi, (&(i, enqueued), out)) in
                                group.iter().zip(&outs).enumerate()
                            {
                                recs.push(ReqRecord::completed(
                                    &trace[i],
                                    out,
                                    enqueued.elapsed().as_secs_f64(),
                                    gi > 0,
                                ));
                            }
                        }
                        Err(e) => {
                            let ids: Vec<String> =
                                group.iter().map(|&(i, _)| trace[i].id.to_string()).collect();
                            errors
                                .lock()
                                .unwrap()
                                .push(format!("solve batch [{}]: {e:#}", ids.join(",")));
                        }
                    }
                } else {
                    let (i, enqueued) = group[0];
                    let req = &trace[i];
                    match service.handle(req) {
                        Ok(out) => records.lock().unwrap().push(ReqRecord::completed(
                            req,
                            &out,
                            enqueued.elapsed().as_secs_f64(),
                            false,
                        )),
                        Err(e) => errors
                            .lock()
                            .unwrap()
                            .push(format!("request {}: {e:#}", req.id)),
                    }
                }
            });
        }
        // Leader: pace the arrival schedule against the wall clock.
        for (i, req) in trace.iter().enumerate() {
            let target = Duration::from_secs_f64(req.arrival);
            let now = t0.elapsed();
            if target > now {
                std::thread::sleep(target - now);
            }
            if !queue.push((i, Instant::now())) {
                records.lock().unwrap().push(ReqRecord::rejected(req));
            }
        }
        queue.close();
    });
    let makespan = t0.elapsed().as_secs_f64();
    let errors = errors.into_inner().unwrap();
    ensure!(errors.is_empty(), "serve loop failures: {}", errors.join("; "));
    let mut records = records.into_inner().unwrap();
    records.sort_by_key(|r| r.id);
    Ok(assemble_report(
        "threads",
        trace.len(),
        records,
        makespan,
        service.evictions(),
        cfg.duration_secs,
        cfg.arrival_rate,
        0,
    ))
}

/// Real-time closed-loop serving: `clients` threads each issue a
/// request, call the service directly (no admission queue — at most one
/// outstanding request per client, so nothing to bound), and issue the
/// next as soon as the previous completes. Request ids interleave client
/// index and per-client sequence so records stay unique and sortable.
fn run_serve_threads_closed(
    cfg: &ServeConfig,
    service: &PartitionService,
    clients: usize,
) -> Result<ServeReport> {
    let records: Mutex<Vec<ReqRecord>> = Mutex::new(Vec::new());
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let duration = Duration::from_secs_f64(cfg.duration_secs);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let records = &records;
            let errors = &errors;
            scope.spawn(move || {
                let mut rng = Rng::new(client_seed(cfg.seed, c as u64));
                let mut drift_step = vec![0u64; cfg.tenants.len()];
                let mut seq = 0usize;
                while t0.elapsed() < duration {
                    let (ti, kind, drift) =
                        draw_request(&mut rng, &mut drift_step, &cfg.tenants);
                    let req = Request {
                        id: c * 1_000_000 + seq,
                        arrival: t0.elapsed().as_secs_f64(),
                        tenant: cfg.tenants[ti].clone(),
                        kind,
                        drift,
                    };
                    seq += 1;
                    let issued = Instant::now();
                    match service.handle(&req) {
                        Ok(out) => records.lock().unwrap().push(ReqRecord::completed(
                            &req,
                            &out,
                            issued.elapsed().as_secs_f64(),
                            false,
                        )),
                        Err(e) => errors
                            .lock()
                            .unwrap()
                            .push(format!("client {c} request {}: {e:#}", req.id)),
                    }
                }
            });
        }
    });
    let makespan = t0.elapsed().as_secs_f64();
    let errors = errors.into_inner().unwrap();
    ensure!(errors.is_empty(), "serve loop failures: {}", errors.join("; "));
    let mut records = records.into_inner().unwrap();
    records.sort_by_key(|r| r.id);
    let offered = records.len();
    Ok(assemble_report(
        "threads",
        offered,
        records,
        makespan,
        service.evictions(),
        cfg.duration_secs,
        offered as f64 / cfg.duration_secs,
        clients,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Barrier;

    fn tiny_tenant() -> Tenant {
        Tenant {
            family: Family::Tri2d,
            n: 400,
            graph_seed: 7,
            preset: TopoPreset::Uniform,
            k: 4,
            algo: "geoKM".to_string(),
            epsilon: 0.05,
        }
    }

    fn tiny_config() -> ServeConfig {
        let mut cfg =
            ServeConfig::new(tiny_tenant(), 1.0, 40.0, 11, ExecBackend::Sim);
        cfg.servers = 2;
        cfg.queue_cap = 16;
        cfg
    }

    #[test]
    fn fingerprints_separate_tenants() {
        let a = tiny_tenant();
        assert_eq!(a.fingerprint(), tiny_tenant().fingerprint());
        let mut b = a.clone();
        b.algo = "zSFC".to_string();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = a.clone();
        c.epsilon = 0.03;
        assert_ne!(a.fingerprint(), c.fingerprint());
        let mut d = a.clone();
        d.n = 401;
        assert_ne!(a.fingerprint(), d.fingerprint());
        let mut e = a.clone();
        e.preset = TopoPreset::TwoSpeed;
        assert_ne!(a.fingerprint(), e.fingerprint());
        // Graph key ignores the partitioning knobs: b shares a's instance.
        assert_eq!(a.graph_key(), b.graph_key());
        assert_ne!(a.graph_key(), d.graph_key());
    }

    #[test]
    fn trace_is_deterministic_and_well_formed() {
        let cfg = tiny_config();
        let t1 = generate_trace(&cfg);
        let t2 = generate_trace(&cfg);
        assert_eq!(t1, t2, "same config must yield the same trace");
        assert!(!t1.is_empty());
        for (i, r) in t1.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(r.arrival < cfg.duration_secs);
            if i > 0 {
                assert!(r.arrival >= t1[i - 1].arrival, "arrivals out of order");
            }
            match r.kind {
                RequestKind::Repartition => assert!(r.drift > 0.0),
                _ => assert_eq!(r.drift, 0.0),
            }
        }
        // A different seed moves the trace.
        let mut other = cfg.clone();
        other.seed = 12;
        assert_ne!(generate_trace(&other), t1);
    }

    #[test]
    fn burst_phase_raises_the_arrival_density() {
        let mut cfg = tiny_config();
        cfg.duration_secs = 20.0;
        cfg.arrival_rate = 30.0;
        let trace = generate_trace(&cfg);
        let frac = |r: &Request| r.arrival / cfg.duration_secs;
        let in_burst =
            trace.iter().filter(|r| (0.40..0.55).contains(&frac(r))).count() as f64;
        let before_burst =
            trace.iter().filter(|r| (0.25..0.40).contains(&frac(r))).count() as f64;
        // Same-width windows; the burst triples λ, so even with Poisson
        // noise the burst window must clearly dominate.
        assert!(
            in_burst > 1.5 * before_burst,
            "burst {in_burst} vs before {before_burst}"
        );
        assert_eq!(burst_multiplier(0.45), 3.0);
        assert_eq!(burst_multiplier(0.2), 1.0);
        assert_eq!(burst_multiplier(0.60), 1.0);
    }

    #[test]
    fn sim_serving_fills_the_cache_and_reports() {
        let cfg = tiny_config();
        let rep = run_serve(&cfg).unwrap();
        assert_eq!(rep.backend, "sim");
        assert_eq!(rep.offered, generate_trace(&cfg).len());
        assert_eq!(rep.completed + rep.rejected, rep.offered);
        assert_eq!(rep.hits + rep.misses, rep.completed);
        // The accounting invariant the coalescing counters must keep.
        assert_eq!(rep.builds + rep.coalesced + rep.hits, rep.completed);
        // The sequential sim loop never has two requests in flight, so
        // nothing can coalesce or batch there.
        assert_eq!(rep.coalesced, 0);
        assert_eq!(rep.batched, 0);
        assert_eq!(rep.clients, 0, "open loop reports no clients");
        assert_eq!(rep.offered_rate, cfg.arrival_rate);
        assert!(rep.goodput > 0.0);
        assert!(rep.cache_hit_rate > 0.0, "repeat tenants must hit the cache");
        assert!(rep.req_per_sec > 0.0);
        assert!(rep.latency_p50_ms <= rep.latency_p95_ms);
        assert!(rep.latency_p95_ms <= rep.latency_p99_ms);
        assert_eq!(rep.records.len(), rep.offered);
        // The summary renders to valid JSON with the first-class columns.
        let back = Json::parse(&rep.summary_json().render()).unwrap();
        assert!(back.get("req_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.get("cache_hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.get("latency_p99_ms").is_some());
        assert!(back.get("goodput").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.get("builds").is_some());
        assert!(back.get("coalesced").is_some());
        assert_eq!(rep.table().rows.len(), 1);
    }

    #[test]
    fn report_percentiles_come_from_completed_requests_only() {
        let records = vec![
            ReqRecord {
                id: 0,
                kind: "partition",
                fingerprint: 1,
                latency_secs: 0.010,
                hit: false,
                coalesced: false,
                batched: false,
                warm: false,
                migrated_frac: 0.0,
                rejected: false,
            },
            ReqRecord {
                id: 1,
                kind: "partition",
                fingerprint: 1,
                latency_secs: 0.0,
                hit: false,
                coalesced: false,
                batched: false,
                warm: false,
                migrated_frac: 0.0,
                rejected: true,
            },
            ReqRecord {
                id: 2,
                kind: "partition",
                fingerprint: 1,
                latency_secs: 0.030,
                hit: true,
                coalesced: false,
                batched: false,
                warm: false,
                migrated_frac: 0.0,
                rejected: false,
            },
        ];
        let rep = assemble_report("sim", 3, records, 2.0, 0, 2.0, 1.5, 0);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.hits, 1);
        assert_eq!(rep.misses, 1);
        assert_eq!(rep.builds, 1, "the non-hit completion built its partition");
        assert_eq!(rep.coalesced, 0);
        assert_eq!(rep.batched, 0);
        assert_eq!(rep.cache_hit_rate, 0.5);
        assert_eq!(rep.req_per_sec, 1.0);
        assert_eq!(rep.goodput, 1.0, "completed 2 over duration 2");
        assert_eq!(rep.offered_rate, 1.5);
        // p50 of {10ms, 30ms} interpolates to 20ms — the rejected 0 never
        // drags the percentiles down.
        assert!((rep.latency_p50_ms - 20.0).abs() < 1e-9, "{}", rep.latency_p50_ms);
        assert!((rep.latency_mean_ms - 20.0).abs() < 1e-9);
        assert_eq!(rep.evictions, 0);
    }

    #[test]
    fn lru_cap_of_one_keeps_responses_bit_identical() {
        let a = tiny_tenant();
        let mut b = tiny_tenant();
        b.algo = "zSFC".to_string(); // shares a's graph, separate partition
        let req = |id: usize, tenant: &Tenant| Request {
            id,
            arrival: id as f64 * 0.01,
            tenant: tenant.clone(),
            kind: RequestKind::Partition,
            drift: 0.0,
        };
        let unbounded = PartitionService::new(1);
        let capped = PartitionService::with_cache_cap(1, Some(1));
        for svc in [&unbounded, &capped] {
            // A, B, A: under cap 1 the second A is recomputed after B
            // evicted it; under no cap it is a hit.
            svc.handle(&req(0, &a)).unwrap();
            svc.handle(&req(1, &b)).unwrap();
            let out = svc.handle(&req(2, &a)).unwrap();
            assert_eq!(out.hit, std::ptr::eq(svc, &unbounded));
        }
        assert_eq!(unbounded.evictions(), 0);
        // B evicted A's partition, then A's recompute evicted B's.
        assert!(capped.evictions() >= 2, "evictions {}", capped.evictions());
        // The recomputed partition carries exactly the bits the unbounded
        // cache held all along.
        let fresh = capped.cached_partition(&a).expect("a recomputed and cached");
        let kept = unbounded.cached_partition(&a).expect("a cached");
        assert_eq!(fresh.assignment, kept.assignment);
    }

    #[test]
    fn serving_under_a_cache_cap_changes_hits_not_results() {
        let base = tiny_config();
        let mut capped = tiny_config();
        capped.cache_cap = Some(1);
        let r1 = run_serve(&base).unwrap();
        let r2 = run_serve(&capped).unwrap();
        assert_eq!(r1.evictions, 0);
        assert!(r2.evictions > 0, "cap 1 with 3 tenants must evict");
        assert!(r2.hits < r1.hits, "evictions must cost cache hits");
        // Same offered trace, and every request resolves to the same
        // answer: only latency/hit bookkeeping may move.
        assert_eq!(r1.offered, r2.offered);
        assert_eq!(r1.rejected, 0);
        assert_eq!(r2.rejected, 0);
        for (x, y) in r1.records.iter().zip(&r2.records) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.warm, y.warm);
            assert_eq!(x.migrated_frac.to_bits(), y.migrated_frac.to_bits());
        }
    }

    #[test]
    fn sharded_and_single_lock_caches_serve_identical_bits() {
        // Same capped sim run at 1 shard (the historical single-lock
        // layout) and 8 shards: recency ticks come from one shared
        // counter and eviction picks the global minimum, so the entire
        // summary — hits, evictions, priced latencies — is bit-identical.
        let mut one = tiny_config();
        one.cache_cap = Some(1);
        one.shards = 1;
        let mut eight = tiny_config();
        eight.cache_cap = Some(1);
        eight.shards = 8;
        let a = run_serve(&one).unwrap();
        let b = run_serve(&eight).unwrap();
        assert!(a.evictions > 0, "cap 1 must evict in this trace");
        assert_eq!(
            a.summary_json().render(),
            b.summary_json().render(),
            "shard count must not change sequential serving bits"
        );
    }

    #[test]
    fn single_flight_coalesces_concurrent_cold_requests_into_one_build() {
        let t = tiny_tenant();
        let service = PartitionService::new(1);
        let n = 8;
        let barrier = Barrier::new(n);
        let results: Vec<(Arc<Partition>, Resolution)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let (service, t, barrier) = (&service, &t, &barrier);
                    scope.spawn(move || {
                        let (name, g) = service.graph(t);
                        barrier.wait();
                        service.base_partition(t, &name, &g).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(service.builds(), 1, "single flight must build exactly once");
        let built = results.iter().filter(|(_, r)| *r == Resolution::Built).count();
        assert_eq!(built, 1, "exactly one request is the leader");
        // Every response carries the same bits (and in fact the same Arc).
        for (p, _) in &results {
            assert_eq!(p.assignment, results[0].0.assignment);
        }
    }

    #[test]
    fn coalescing_off_lets_concurrent_cold_requests_race() {
        let t = tiny_tenant();
        let service = PartitionService::with_opts(1, None, false, DEFAULT_SHARDS);
        let n = 8;
        let barrier = Barrier::new(n);
        let results: Vec<Arc<Partition>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let (service, t, barrier) = (&service, &t, &barrier);
                    scope.spawn(move || {
                        let (name, g) = service.graph(t);
                        barrier.wait();
                        service.base_partition(t, &name, &g).unwrap().0
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // The cache-check window is microseconds against a multi-
        // millisecond build, so the barrier makes duplicate builds all
        // but certain — and first-insert-wins keeps responses identical.
        assert!(
            service.builds() >= 2,
            "expected racing duplicate builds, got {}",
            service.builds()
        );
        for p in &results {
            assert_eq!(p.assignment, results[0].assignment);
        }
    }

    #[test]
    fn duplicate_heavy_threads_trace_builds_strictly_less_with_coalescing() {
        // A duplicate-heavy burst: every request is the same fingerprint
        // (100% repeats), all arriving at t=0 against 4 workers. With
        // coalescing the whole burst shares one build; without it the
        // workers race cold and duplicate work.
        let t = tiny_tenant();
        let trace: Vec<Request> = (0..16)
            .map(|id| Request {
                id,
                arrival: 0.0,
                tenant: t.clone(),
                kind: RequestKind::Partition,
                drift: 0.0,
            })
            .collect();
        let mut cfg = ServeConfig::new(tiny_tenant(), 1.0, 50.0, 1, ExecBackend::Threads);
        cfg.servers = 4;
        cfg.queue_cap = 64;
        let on = PartitionService::with_opts(1, None, true, DEFAULT_SHARDS);
        let rep_on = run_serve_threads(&cfg, &on, &trace).unwrap();
        let off = PartitionService::with_opts(1, None, false, DEFAULT_SHARDS);
        let rep_off = run_serve_threads(&cfg, &off, &trace).unwrap();
        assert_eq!(rep_on.completed, trace.len());
        assert_eq!(rep_off.completed, trace.len());
        assert_eq!(on.builds(), 1, "coalescing must collapse the burst to one build");
        assert!(
            on.builds() < off.builds(),
            "coalescing on built {} times, off {} — expected strictly fewer",
            on.builds(),
            off.builds()
        );
        // Reported builds match the service counter on both sides.
        assert_eq!(rep_on.builds, on.builds());
        assert_eq!(rep_off.builds, off.builds());
        assert_eq!(rep_on.builds + rep_on.coalesced + rep_on.hits, rep_on.completed);
        // And the served partitions are bit-identical across both modes.
        let a = on.cached_partition(&t).unwrap();
        let b = off.cached_partition(&t).unwrap();
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn batched_solves_report_like_individually_served_solves() {
        let t = tiny_tenant();
        let reqs: Vec<Request> = [5usize, 9, 6]
            .iter()
            .enumerate()
            .map(|(id, &iters)| Request {
                id,
                arrival: 0.0,
                tenant: t.clone(),
                kind: RequestKind::Solve { iters },
                drift: 0.0,
            })
            .collect();
        let batched_svc = PartitionService::new(1);
        let refs: Vec<&Request> = reqs.iter().collect();
        let batch = batched_svc.handle_solve_batch(&refs).unwrap();
        let individual_svc = PartitionService::new(1);
        let individual: Vec<Outcome> =
            reqs.iter().map(|r| individual_svc.handle(r).unwrap()).collect();
        assert_eq!(batch.len(), individual.len());
        for (b, i) in batch.iter().zip(&individual) {
            assert_eq!(b.hit, i.hit, "batch hit accounting must match individual serving");
            assert_eq!(b.service_secs.to_bits(), i.service_secs.to_bits());
        }
        // One shared build either way, and identical cached bits.
        assert_eq!(batched_svc.builds(), 1);
        assert_eq!(individual_svc.builds(), 1);
        assert_eq!(
            batched_svc.cached_partition(&t).unwrap().assignment,
            individual_svc.cached_partition(&t).unwrap().assignment
        );
        // Mixed batches are rejected loudly.
        let mut bad = reqs.clone();
        bad[1].kind = RequestKind::Partition;
        let bad_refs: Vec<&Request> = bad.iter().collect();
        assert!(batched_svc.handle_solve_batch(&bad_refs).is_err());
    }

    #[test]
    fn closed_loop_sim_is_deterministic_and_never_rejects() {
        let mut cfg = tiny_config();
        cfg.client_mode = ClientMode::Closed { clients: 3 };
        let a = run_serve(&cfg).unwrap();
        let b = run_serve(&cfg).unwrap();
        assert_eq!(
            a.summary_json().render(),
            b.summary_json().render(),
            "closed-loop sim must be bit-identical across runs"
        );
        assert!(a.completed > 0, "clients issued nothing");
        assert_eq!(a.rejected, 0, "closed loops self-limit and never reject");
        assert_eq!(a.clients, 3);
        assert!(a.goodput > 0.0);
        assert!(a.offered_rate > 0.0);
        // More clients push at least as much load through the servers.
        let mut more = cfg.clone();
        more.client_mode = ClientMode::Closed { clients: 6 };
        let c = run_serve(&more).unwrap();
        assert!(
            c.offered >= a.offered,
            "6 clients offered {} vs 3 clients {}",
            c.offered,
            a.offered
        );
    }
}
