//! L3 coordinator: the leader process — CLI, experiment grids, and the
//! worker pool that runs them.

pub mod cli;
pub mod experiment;
pub mod jobqueue;
pub mod serve;

pub use experiment::{
    default_rhs, instance, relative_to, run_one, run_one_dist, run_one_dist_net, run_solve,
    run_solve_batch, run_solve_opts, run_solve_prepared, Grid, RunResult, SolveResult,
};
pub use jobqueue::{default_workers, run_jobs, BoundedQueue};
pub use serve::{
    generate_trace, run_serve, ClientMode, PartitionService, Request, RequestKind, ServeConfig,
    ServeReport, Tenant,
};

/// Crate version (used by the CLI banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
