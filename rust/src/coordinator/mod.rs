//! L3 coordinator: the leader process — CLI, experiment grids, and the
//! worker pool that runs them.

pub mod cli;
pub mod experiment;
pub mod jobqueue;

pub use experiment::{
    default_rhs, instance, relative_to, run_one, run_one_dist, run_solve, run_solve_opts, Grid,
    RunResult, SolveResult,
};
pub use jobqueue::{default_workers, run_jobs};

/// Crate version (used by the CLI banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
