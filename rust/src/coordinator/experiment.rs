//! Experiment grids: the unit of work the paper's figures/tables are made
//! of — run a set of partitioners on (graph, topology) pairs, collect
//! quality metrics and timings.

use crate::blocksizes::block_sizes;
use crate::exec::{CostModel, DistPartReport, ExecBackend, NetModel, SolveOpts, VirtualCluster};
use crate::gen::Family;
use crate::graph::Csr;
use crate::partition::{metrics, Metrics, Partition};
use crate::partitioners::{by_name, Ctx};
use crate::solver::{CgResult, ClusterSim, EllMatrix};
use crate::topology::Topology;
use crate::util::timer::timed;
use anyhow::{anyhow, Context, Result};

/// One measured (graph, topology, algorithm) cell.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Instance name (family + size).
    pub graph_name: String,
    /// Topology label.
    pub topo_label: String,
    /// Partitioner name.
    pub algo: String,
    /// Edge cut of the partition.
    pub cut: f64,
    /// Largest per-block communication volume.
    pub max_comm_volume: f64,
    /// Total communication volume over all blocks.
    pub total_comm_volume: f64,
    /// Relative imbalance vs the Algorithm-1 targets.
    pub imbalance: f64,
    /// Partitioning seconds.
    pub time_partition: f64,
    /// Number of blocks/PUs.
    pub k: usize,
    /// LDHT objective max_i w(b_i)/c_s(p_i) under the topology's speeds.
    pub ldht_objective: f64,
    /// The Algorithm-1 optimum for this (graph, topology): the smallest
    /// achievable value of the objective above (Theorem 1). Computed from
    /// the same scaled topology as the partitioner's targets, so
    /// `ldht_objective / ldht_optimum` is a well-defined quality ratio.
    pub ldht_optimum: f64,
}

/// Run one partitioner on one instance; targets come from Algorithm 1.
pub fn run_one(
    graph_name: &str,
    g: &Csr,
    topo: &Topology,
    algo: &str,
    epsilon: f64,
    seed: u64,
) -> Result<(RunResult, Partition)> {
    // The topology's memory units are the paper's normalized specs
    // ("slow = 2, fast = 13.8"); attach them to this graph by rescaling
    // so the load fills TABLE3_FILL of total memory (the calibration
    // that reproduces Table III — saturation patterns are preserved).
    let load = g.total_vertex_weight();
    let scaled = topo.scaled_for_load(load, crate::blocksizes::TABLE3_FILL);
    let bs = block_sizes(load, &scaled)
        .with_context(|| format!("block sizes for {}", topo.label))?;
    let partitioner = by_name(algo).ok_or_else(|| anyhow!("unknown partitioner {algo}"))?;
    // Hand partitioners the *scaled* topology so hierarchical algorithms
    // can re-run Algorithm 1 on subtrees feasibly.
    let ctx = Ctx { graph: g, targets: &bs.tw, topo: &scaled, epsilon, seed };
    let (part, secs) = timed(|| partitioner.partition(&ctx));
    let part = part?;
    part.validate(g).map_err(|e| anyhow!("{algo}: {e}"))?;
    Ok((
        assemble_result(graph_name, g, topo, algo, &bs.tw, bs.max_ratio, &part, secs),
        part,
    ))
}

/// Quality metrics + timing → one [`RunResult`] row (shared by the
/// sequential and distributed partitioning paths, so both report through
/// the same columns).
#[allow(clippy::too_many_arguments)]
fn assemble_result(
    graph_name: &str,
    g: &Csr,
    topo: &Topology,
    algo: &str,
    targets: &[f64],
    ldht_optimum: f64,
    part: &Partition,
    time_partition: f64,
) -> RunResult {
    let m: Metrics = metrics(g, part, targets);
    let speeds: Vec<f64> = topo.pus.iter().map(|p| p.speed).collect();
    RunResult {
        graph_name: graph_name.to_string(),
        topo_label: topo.label.clone(),
        algo: algo.to_string(),
        cut: m.cut,
        max_comm_volume: m.max_comm_volume,
        total_comm_volume: m.total_comm_volume,
        imbalance: m.imbalance,
        time_partition,
        k: topo.k(),
        ldht_objective: m.ldht_objective(&speeds),
        ldht_optimum,
    }
}

/// [`run_one`] with the partitioner executed *on the virtual cluster*:
/// the same Algorithm-1 targets and quality metrics, but the partition
/// is computed by the distributed implementation of `algo`
/// (`partitioners::dist`) over `ranks` rank threads through the chosen
/// `Comm` transport. Returns the usual quality row (whose
/// `time_partition` is the measured leader wall-clock), the partition —
/// bit-identical to the sequential `run_one`'s — and the per-rank
/// [`DistPartReport`] carrying `partSecs` (α-β priced on `sim`,
/// measured on `threads`).
#[allow(clippy::too_many_arguments)]
pub fn run_one_dist(
    graph_name: &str,
    g: &Csr,
    topo: &Topology,
    algo: &str,
    epsilon: f64,
    seed: u64,
    backend: ExecBackend,
    ranks: usize,
) -> Result<(RunResult, Partition, DistPartReport)> {
    run_one_dist_net(graph_name, g, topo, algo, epsilon, seed, backend, ranks, NetModel::FlatAlphaBeta)
}

/// [`run_one_dist`] with an explicit network model for the priced
/// backend (the `--net` CLI/harness axis). `FlatAlphaBeta` reproduces
/// the legacy charges exactly.
#[allow(clippy::too_many_arguments)]
pub fn run_one_dist_net(
    graph_name: &str,
    g: &Csr,
    topo: &Topology,
    algo: &str,
    epsilon: f64,
    seed: u64,
    backend: ExecBackend,
    ranks: usize,
    net: NetModel,
) -> Result<(RunResult, Partition, DistPartReport)> {
    let load = g.total_vertex_weight();
    let scaled = topo.scaled_for_load(load, crate::blocksizes::TABLE3_FILL);
    let bs = block_sizes(load, &scaled)
        .with_context(|| format!("block sizes for {}", topo.label))?;
    let (out, secs) = timed(|| {
        VirtualCluster::partition_dist_net(
            g,
            &bs.tw,
            epsilon,
            seed,
            algo,
            backend,
            ranks,
            CostModel::default(),
            net,
        )
    });
    let (part, report) = out.with_context(|| format!("distributed {algo} on {graph_name}"))?;
    part.validate(g).map_err(|e| anyhow!("{algo}: {e}"))?;
    Ok((
        assemble_result(graph_name, g, topo, algo, &bs.tw, bs.max_ratio, &part, secs),
        part,
        report,
    ))
}

/// One distributed-solve cell through the virtual-cluster engine.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Which engine backend ran (`sim` or `threads`).
    pub backend: &'static str,
    /// CG iterations executed.
    pub iterations: usize,
    /// ‖r‖ after the final iteration.
    pub final_residual: f32,
    /// Bottleneck (compute + comm) seconds per iteration.
    pub time_per_iter: f64,
    /// Rank whose compute + comm bounds the run.
    pub bottleneck_rank: usize,
    /// Leader wall-clock for the whole solve.
    pub wall_secs: f64,
    /// Whether the halo exchange overlapped the interior SpMV.
    pub overlap: bool,
    /// Total priced communication seconds hidden behind overlapped
    /// compute, summed over ranks (0 for blocking or `threads` runs).
    pub comm_hidden_secs: f64,
    /// Hidden / (hidden + exposed) priced communication — the harness's
    /// overlap-efficiency column (0 when nothing was hidden).
    pub overlap_efficiency: f64,
    /// Which SpMV storage layout the rank kernels ran on (`"ell"` /
    /// `"sellcs"`, see `solver::sell`).
    pub layout: &'static str,
}

/// The right-hand side every solve driver uses, so `hetpart solve` with
/// and without `--backend`, the example, and `run_solve` all solve the
/// same system and their residuals stay comparable.
pub fn default_rhs(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 23) as f32 - 11.0) / 7.0).collect()
}

/// Run distributed CG for a partition through the virtual-cluster
/// engine (blocking exchange, classic CG — see [`run_solve_opts`]).
/// The simulator is calibrated on the assembled matrix, so the `sim`
/// backend prices iterations with measured kernel speed while the
/// `threads` backend measures thread-per-PU execution for real.
pub fn run_solve(
    g: &Csr,
    part: &Partition,
    topo: &Topology,
    backend: ExecBackend,
    shift: f64,
    max_iters: usize,
    tol: f32,
) -> Result<(SolveResult, CgResult)> {
    run_solve_opts(g, part, topo, backend, shift, max_iters, tol, SolveOpts::default())
}

/// [`run_solve`] with explicit execution options: compute/communication
/// overlap through the nonblocking `Comm` path and/or the pipelined
/// single-reduction CG variant. The returned [`SolveResult`] carries the
/// overlap-efficiency accounting the harness surfaces.
#[allow(clippy::too_many_arguments)]
pub fn run_solve_opts(
    g: &Csr,
    part: &Partition,
    topo: &Topology,
    backend: ExecBackend,
    shift: f64,
    max_iters: usize,
    tol: f32,
    opts: SolveOpts,
) -> Result<(SolveResult, CgResult)> {
    let ell = EllMatrix::from_graph(g, shift);
    run_solve_prepared(&ell, part, topo, backend, max_iters, tol, opts)
}

/// [`run_solve_opts`] for a matrix that is already assembled: the solve
/// entry point for callers that hold many solves against the same
/// instance (the serve loop caches one [`EllMatrix`] per graph and skips
/// the O(m) assembly on every repeat solve).
#[allow(clippy::too_many_arguments)]
pub fn run_solve_prepared(
    ell: &EllMatrix,
    part: &Partition,
    topo: &Topology,
    backend: ExecBackend,
    max_iters: usize,
    tol: f32,
    opts: SolveOpts,
) -> Result<(SolveResult, CgResult)> {
    let mut sim = ClusterSim::default();
    sim.calibrate(ell);
    let b = default_rhs(ell.n);
    let (cg, rep) =
        sim.run_cg_virtual_opts(ell, part, topo, backend, &b, max_iters, tol, opts)?;
    Ok((
        SolveResult {
            backend: rep.backend,
            iterations: cg.iterations,
            final_residual: cg.residual_norms.last().copied().unwrap_or(0.0),
            time_per_iter: rep.time_per_iter(),
            bottleneck_rank: rep.bottleneck_rank(),
            wall_secs: rep.wall_secs,
            overlap: opts.overlap,
            comm_hidden_secs: rep.comm_hidden_total(),
            overlap_efficiency: rep.overlap_efficiency(),
            layout: opts.layout.name(),
        },
        cg,
    ))
}

/// [`run_solve_prepared`] for a *batch* of solves against one prepared
/// instance: the cluster model calibrates once and the right-hand side
/// assembles once, then each entry of `iters` runs as its own CG solve.
/// The serve loop drains consecutive same-tenant solve requests through
/// this to amortize per-request setup. Numerics are bitwise identical to
/// calling [`run_solve_prepared`] once per entry: `run_cg_virtual_opts`
/// builds a fresh virtual cluster per call, and calibration only affects
/// priced timings, never the iteration arithmetic.
#[allow(clippy::too_many_arguments)]
pub fn run_solve_batch(
    ell: &EllMatrix,
    part: &Partition,
    topo: &Topology,
    backend: ExecBackend,
    iters: &[usize],
    tol: f32,
    opts: SolveOpts,
) -> Result<Vec<(SolveResult, CgResult)>> {
    let mut sim = ClusterSim::default();
    sim.calibrate(ell);
    let b = default_rhs(ell.n);
    let mut out = Vec::with_capacity(iters.len());
    for &max_iters in iters {
        let (cg, rep) =
            sim.run_cg_virtual_opts(ell, part, topo, backend, &b, max_iters, tol, opts)?;
        out.push((
            SolveResult {
                backend: rep.backend,
                iterations: cg.iterations,
                final_residual: cg.residual_norms.last().copied().unwrap_or(0.0),
                time_per_iter: rep.time_per_iter(),
                bottleneck_rank: rep.bottleneck_rank(),
                wall_secs: rep.wall_secs,
                overlap: opts.overlap,
                comm_hidden_secs: rep.comm_hidden_total(),
                overlap_efficiency: rep.overlap_efficiency(),
                layout: opts.layout.name(),
            },
            cg,
        ));
    }
    Ok(out)
}

/// A grid: instances × topologies × algorithms.
pub struct Grid {
    /// Named instances to partition.
    pub graphs: Vec<(String, Csr)>,
    /// Topologies to run each instance on.
    pub topologies: Vec<Topology>,
    /// Partitioner names (see `partitioners::by_name`).
    pub algos: Vec<String>,
    /// Imbalance tolerance ε.
    pub epsilon: f64,
    /// Seed shared by all cells.
    pub seed: u64,
}

impl Grid {
    /// Run the full grid (sequentially — partitioners are themselves the
    /// unit of measurement, so no concurrent timing noise). Note geoKM's
    /// assignment step is itself multi-threaded by default; construct
    /// `GeoKMeans { workers: Some(1), .. }` where strict single-core
    /// timing comparability against the other algorithms is required.
    pub fn run(&self) -> Vec<RunResult> {
        let mut out = Vec::new();
        for (name, g) in &self.graphs {
            for topo in &self.topologies {
                for algo in &self.algos {
                    match run_one(name, g, topo, algo, self.epsilon, self.seed) {
                        Ok((r, _)) => out.push(r),
                        Err(e) => eprintln!("WARN {algo} on {name}/{}: {e}", topo.label),
                    }
                }
            }
        }
        out
    }
}

/// Generate a named instance: `family_logn`, e.g. `rdg_2d` at n=2^14.
pub fn instance(family: Family, n: usize, seed: u64) -> (String, Csr) {
    let g = family.generate(n, seed);
    (format!("{}_{}", family.name(), g.n()), g)
}

/// Results → normalized values relative to a baseline algorithm, as the
/// paper plots (Figs. 2–4: "values are relative to balanced k-means").
pub fn relative_to(
    results: &[RunResult],
    baseline: &str,
    get: impl Fn(&RunResult) -> f64,
) -> Vec<(String, String, String, f64)> {
    let mut out = Vec::new();
    for r in results {
        let base = results.iter().find(|b| {
            b.graph_name == r.graph_name && b.topo_label == r.topo_label && b.algo == baseline
        });
        if let Some(base) = base {
            let denom = get(base);
            if denom > 0.0 {
                out.push((
                    r.graph_name.clone(),
                    r.topo_label.clone(),
                    r.algo.clone(),
                    get(r) / denom,
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{topo1, Pu, Topo1Spec};

    #[test]
    fn run_one_produces_metrics() {
        let (name, g) = instance(Family::Tri2d, 900, 1);
        let topo = topo1(Topo1Spec {
            k: 6,
            num_fast: 1,
            fast: Pu { speed: 4.0, memory: 8.5 },
        });
        let (r, p) = run_one(&name, &g, &topo, "zSFC", 0.05, 1).unwrap();
        assert!(r.cut > 0.0);
        assert!(r.time_partition >= 0.0);
        assert_eq!(p.k, 6);
        // The fast PU's block really is bigger.
        let sizes = p.block_sizes();
        assert!(sizes[0] > sizes[5], "{sizes:?}");
    }

    #[test]
    fn run_solve_both_backends_agree() {
        let (name, g) = instance(Family::Tri2d, 900, 1);
        let topo = topo1(Topo1Spec {
            k: 4,
            num_fast: 1,
            fast: Pu { speed: 4.0, memory: 8.5 },
        });
        let (_, p) = run_one(&name, &g, &topo, "geoKM", 0.05, 1).unwrap();
        let (s_sim, cg_sim) =
            run_solve(&g, &p, &topo, ExecBackend::Sim, 0.05, 60, 1e-5).unwrap();
        let (s_thr, cg_thr) =
            run_solve(&g, &p, &topo, ExecBackend::Threads, 0.05, 60, 1e-5).unwrap();
        assert_eq!(s_sim.backend, "sim");
        assert_eq!(s_thr.backend, "threads");
        assert_eq!(s_sim.layout, "ell");
        assert_eq!(cg_sim.residual_norms, cg_thr.residual_norms);
        assert!(s_sim.final_residual < 1e-2);
        assert!(s_sim.time_per_iter > 0.0);
        assert!(s_thr.time_per_iter > 0.0);
        assert!(s_sim.bottleneck_rank < 4);
    }

    #[test]
    fn unknown_algo_is_error() {
        let (name, g) = instance(Family::Tri2d, 100, 1);
        let topo = Topology::homogeneous(2, 1.0, 1e9);
        assert!(run_one(&name, &g, &topo, "bogus", 0.05, 1).is_err());
    }

    #[test]
    fn run_one_dist_matches_sequential_quality() {
        let (name, g) = instance(Family::Tri2d, 900, 1);
        let topo = Topology::homogeneous(4, 1.0, 1e9);
        let (seq, p_seq) = run_one(&name, &g, &topo, "zRCB", 0.05, 1).unwrap();
        let (r, p, rep) =
            run_one_dist(&name, &g, &topo, "zRCB", 0.05, 1, ExecBackend::Sim, 2).unwrap();
        assert_eq!(p.assignment, p_seq.assignment, "distributed zRCB diverged");
        assert_eq!(r.cut, seq.cut);
        assert_eq!(r.max_comm_volume, seq.max_comm_volume);
        assert_eq!(r.ldht_objective, seq.ldht_objective);
        assert_eq!(rep.ranks, 2);
        assert!(rep.part_secs() > 0.0);
        // Algorithms without a distributed implementation are a clean error.
        let err = run_one_dist(&name, &g, &topo, "pmGraph", 0.05, 1, ExecBackend::Sim, 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("distributed"), "{err}");
    }

    #[test]
    fn grid_runs_all_cells() {
        let grid = Grid {
            graphs: vec![instance(Family::Tri2d, 400, 1)],
            topologies: vec![
                Topology::homogeneous(4, 1.0, 1e9),
                topo1(Topo1Spec { k: 4, num_fast: 1, fast: Pu { speed: 8.0, memory: 1e9 } }),
            ],
            algos: vec!["zSFC".into(), "zRCB".into()],
            epsilon: 0.05,
            seed: 1,
        };
        let rs = grid.run();
        assert_eq!(rs.len(), 4);
    }

    #[test]
    fn run_solve_batch_is_bitwise_identical_to_individual_solves() {
        let (name, g) = instance(Family::Tri2d, 400, 1);
        let topo = Topology::homogeneous(4, 1.0, 1e9);
        let (_, p) = run_one(&name, &g, &topo, "geoKM", 0.05, 1).unwrap();
        let ell = EllMatrix::from_graph(&g, 0.05);
        let iters = [5usize, 9, 6];
        let batch = run_solve_batch(
            &ell,
            &p,
            &topo,
            ExecBackend::Sim,
            &iters,
            0.0,
            SolveOpts::default(),
        )
        .unwrap();
        assert_eq!(batch.len(), iters.len());
        for (&it, (s, cg)) in iters.iter().zip(&batch) {
            let (s1, cg1) = run_solve_prepared(
                &ell,
                &p,
                &topo,
                ExecBackend::Sim,
                it,
                0.0,
                SolveOpts::default(),
            )
            .unwrap();
            // Sharing one calibrated cluster model across the batch must
            // not move a single bit of the CG arithmetic.
            assert_eq!(cg.iterations, cg1.iterations);
            assert_eq!(
                cg.residual_norms.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                cg1.residual_norms.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(
                cg.x.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                cg1.x.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(s.iterations, s1.iterations);
            assert_eq!(s.final_residual.to_bits(), s1.final_residual.to_bits());
        }
    }

    #[test]
    fn relative_normalization() {
        let grid = Grid {
            graphs: vec![instance(Family::Tri2d, 400, 2)],
            topologies: vec![Topology::homogeneous(4, 1.0, 1e9)],
            algos: vec!["geoKM".into(), "zSFC".into()],
            epsilon: 0.05,
            seed: 1,
        };
        let rs = grid.run();
        let rel = relative_to(&rs, "geoKM", |r| r.cut);
        let km = rel.iter().find(|(_, _, a, _)| a == "geoKM").unwrap();
        assert!((km.3 - 1.0).abs() < 1e-12);
        assert_eq!(rel.len(), 2);
    }
}
