//! Block → PU **mapping** (paper §I requirement (iii) and §III-c).
//!
//! Classic graph partitioning ignores *which* PU gets which block; when
//! PUs communicate at different speeds (hierarchical clusters: cores on
//! one node talk faster than across nodes), an explicit mapping step
//! assigns communicating blocks to nearby PUs. The paper's hierarchical
//! k-means gets this "for free" (§V); this module provides the explicit
//! counterpart used to *measure* that benefit:
//!
//! - [`CommCost`]: PU-pair distance matrix from the topology tree (hop
//!   count to the lowest common ancestor, the standard tree metric);
//! - [`mapping_cost`]: Σ over quotient edges of volume × PU distance —
//!   the objective from Hoefler & Snir's mapping literature [19];
//! - [`identity_mapping`], [`greedy_mapping`], [`refine_mapping`]:
//!   construction heuristics + pairwise-swap local search.
//!
//! Because LDHT blocks have *unequal* targets, a mapping must respect PU
//! capability: block i was sized by Algorithm 1 for PU i, so only blocks
//! with (nearly) equal targets may swap — mappings here permute within
//! *speed classes* only.

use crate::graph::QuotientGraph;
use crate::topology::{Topology, TreeNode};

/// Pairwise PU communication distances from the topology tree.
#[derive(Debug, Clone)]
pub struct CommCost {
    /// Number of PUs.
    pub k: usize,
    /// Row-major k×k hop distances (0 on the diagonal).
    pub dist: Vec<f64>,
}

impl CommCost {
    /// Tree distance: hops from each PU to the LCA and back. Flat
    /// topologies give uniform distance 2 between distinct PUs.
    pub fn from_topology(topo: &Topology) -> CommCost {
        let k = topo.k();
        // Path from root to each leaf.
        let mut paths: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut stack: Vec<(usize, Vec<usize>)> = vec![(topo.root, vec![topo.root])];
        while let Some((node, path)) = stack.pop() {
            match &topo.nodes[node] {
                TreeNode::Leaf { pu } => paths[*pu] = path,
                TreeNode::Inner { children } => {
                    for &c in children {
                        let mut p = path.clone();
                        p.push(c);
                        stack.push((c, p));
                    }
                }
            }
        }
        let mut dist = vec![0.0; k * k];
        for a in 0..k {
            for b in (a + 1)..k {
                // Depth of the lowest common ancestor.
                let common = paths[a]
                    .iter()
                    .zip(&paths[b])
                    .take_while(|(x, y)| x == y)
                    .count();
                let d = (paths[a].len() - common) + (paths[b].len() - common);
                dist[a * k + b] = d as f64;
                dist[b * k + a] = d as f64;
            }
        }
        CommCost { k, dist }
    }

    #[inline]
    /// Distance between PUs `a` and `b` (0 on the diagonal).
    pub fn d(&self, a: usize, b: usize) -> f64 {
        self.dist[a * self.k + b]
    }
}

/// Mapping objective: Σ_{quotient edges (i,j)} vol(i,j) · dist(π(i), π(j)).
pub fn mapping_cost(q: &QuotientGraph, cost: &CommCost, pi: &[u32]) -> f64 {
    q.edges()
        .iter()
        .map(|&(i, j, vol)| vol * cost.d(pi[i as usize] as usize, pi[j as usize] as usize))
        .sum()
}

/// The *bottleneck* (max-congested-link) mapping objective from
/// Langguth, Schlag & Schulz (arXiv:2001.09645): instead of summing
/// volume × distance over all edges, report the traffic on the single
/// most-loaded link — the quantity that actually bounds iteration time
/// on a real fabric.
///
/// Links are derived from the topology's node grouping
/// ([`Topology::node_groups`]): traffic between blocks mapped to
/// different nodes loads that ordered *node pair*'s fabric link; traffic
/// between distinct PUs of one node loads their ordered intra-node PU
/// link. Quotient-edge volumes count in both directions (halo exchanges
/// are symmetric), matching how `AggComm` records its per-(src,dst)
/// `link_bytes` matrix — on a flat topology (singleton node groups) this
/// is exactly the max ordered PU-pair volume, i.e. the apps layer's
/// `maxLinkBytes` computed from volumes (cross-checked in
/// `tests/scale.rs`).
pub fn bottleneck_volume(q: &QuotientGraph, topo: &Topology, pi: &[u32]) -> f64 {
    let vols = q
        .edges()
        .iter()
        .flat_map(|&(i, j, vol)| {
            let (a, b) = (pi[i as usize] as usize, pi[j as usize] as usize);
            [(a, b, vol), (b, a, vol)]
        })
        .collect::<Vec<_>>();
    bottleneck_over_links(&vols, topo)
}

/// [`bottleneck_volume`] computed from a measured per-(src,dst) byte
/// matrix (the `link_bytes` an `AggComm` application run records)
/// instead of quotient-edge volumes. `links[s][d]` is bytes from rank
/// `s` to rank `d`; `pi` maps ranks to PUs. Returns bytes on the
/// most-congested link.
pub fn bottleneck_from_links(links: &[Vec<usize>], topo: &Topology, pi: &[u32]) -> f64 {
    let mut vols = Vec::new();
    for (s, row) in links.iter().enumerate() {
        for (d, &bytes) in row.iter().enumerate() {
            if s != d && bytes > 0 {
                vols.push((pi[s] as usize, pi[d] as usize, bytes as f64));
            }
        }
    }
    bottleneck_over_links(&vols, topo)
}

/// Shared accumulator: fold directed (src PU, dst PU, volume) traffic
/// onto the topology's links and return the max. Inter-node traffic
/// accumulates per ordered node pair (the shared fabric link); traffic
/// between distinct PUs of one node accumulates per ordered PU pair.
fn bottleneck_over_links(vols: &[(usize, usize, f64)], topo: &Topology) -> f64 {
    let k = topo.k();
    let groups = topo.node_groups();
    let mut node_of = vec![0usize; k];
    for (n, g) in groups.iter().enumerate() {
        for &pu in g {
            node_of[pu] = n;
        }
    }
    let nodes = groups.len();
    let mut inter = std::collections::HashMap::<(usize, usize), f64>::new();
    let mut intra = std::collections::HashMap::<(usize, usize), f64>::new();
    let mut best = 0.0f64;
    for &(a, b, vol) in vols {
        if a == b {
            continue;
        }
        let (na, nb) = (node_of[a], node_of[b]);
        let loaded = if na != nb {
            debug_assert!(na < nodes && nb < nodes);
            let e = inter.entry((na, nb)).or_insert(0.0);
            *e += vol;
            *e
        } else {
            let e = intra.entry((a, b)).or_insert(0.0);
            *e += vol;
            *e
        };
        best = best.max(loaded);
    }
    best
}

/// Speed classes: blocks may only map to PUs of (nearly) the same speed,
/// because Algorithm 1 sized block i for PU i's capability. Public so the
/// repartitioning subsystem's scratch-remap step shares the exact same
/// class boundaries as the static mapping heuristics.
pub fn speed_classes(topo: &Topology) -> Vec<Vec<u32>> {
    let mut classes: Vec<(f64, Vec<u32>)> = Vec::new();
    for (i, pu) in topo.pus.iter().enumerate() {
        match classes
            .iter_mut()
            .find(|(s, _)| (*s - pu.speed).abs() < 1e-9 * s.max(1.0))
        {
            Some((_, l)) => l.push(i as u32),
            None => classes.push((pu.speed, vec![i as u32])),
        }
    }
    classes.into_iter().map(|(_, l)| l).collect()
}

/// Identity mapping (block i → PU i) — the implicit mapping every
/// partitioner in the study produces.
pub fn identity_mapping(k: usize) -> Vec<u32> {
    (0..k as u32).collect()
}

/// Greedy construction: place the heaviest-communicating blocks first,
/// each at the PU (within its speed class) minimizing cost against the
/// already-placed blocks.
pub fn greedy_mapping(q: &QuotientGraph, cost: &CommCost, topo: &Topology) -> Vec<u32> {
    let k = q.k;
    // Block order: total incident volume, descending.
    let mut vol = vec![0.0; k];
    for (i, j, v) in q.edges() {
        vol[i as usize] += v;
        vol[j as usize] += v;
    }
    let mut order: Vec<u32> = (0..k as u32).collect();
    order.sort_by(|&a, &b| vol[b as usize].partial_cmp(&vol[a as usize]).unwrap());
    // PU pools per speed class; block i must draw from the class of PU i.
    let classes = speed_classes(topo);
    let class_of_pu = {
        let mut m = vec![0usize; k];
        for (ci, c) in classes.iter().enumerate() {
            for &p in c {
                m[p as usize] = ci;
            }
        }
        m
    };
    let mut free: Vec<Vec<u32>> = classes.clone();
    let mut pi = vec![u32::MAX; k];
    for &b in &order {
        let ci = class_of_pu[b as usize];
        // Cost of placing b at candidate PU p against placed neighbors.
        let mut best: Option<(f64, usize)> = None; // (cost, index in free[ci])
        for (fi, &p) in free[ci].iter().enumerate() {
            let mut c = 0.0;
            for &(nb, v) in &q.adj[b as usize] {
                let placed = pi[nb as usize];
                if placed != u32::MAX {
                    c += v * cost.d(p as usize, placed as usize);
                }
            }
            if best.map(|(bc, _)| c < bc).unwrap_or(true) {
                best = Some((c, fi));
            }
        }
        let (_, fi) = best.expect("speed class exhausted");
        pi[b as usize] = free[ci].swap_remove(fi);
    }
    pi
}

/// Pairwise-swap local search within speed classes. Returns the improved
/// mapping and its cost.
pub fn refine_mapping(
    q: &QuotientGraph,
    cost: &CommCost,
    topo: &Topology,
    mut pi: Vec<u32>,
    max_rounds: usize,
) -> (Vec<u32>, f64) {
    let classes = speed_classes(topo);
    let mut cur = mapping_cost(q, cost, &pi);
    for _ in 0..max_rounds {
        let mut improved = false;
        for class in &classes {
            for x in 0..class.len() {
                for y in (x + 1)..class.len() {
                    let (a, b) = (class[x] as usize, class[y] as usize);
                    pi.swap(a, b);
                    let c = mapping_cost(q, cost, &pi);
                    if c + 1e-12 < cur {
                        cur = c;
                        improved = true;
                    } else {
                        pi.swap(a, b); // revert
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }
    (pi, cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::partitioners::{by_name, Ctx};
    use crate::topology::{Pu, Topology};

    fn hier_topo(nodes: usize, per: usize) -> Topology {
        Topology::hierarchical(
            &[nodes, per],
            |_| Pu { speed: 1.0, memory: 2.0 },
            "map-test",
        )
    }

    #[test]
    fn tree_distances() {
        let t = hier_topo(2, 2); // PUs 0,1 on node A; 2,3 on node B
        let c = CommCost::from_topology(&t);
        assert_eq!(c.d(0, 0), 0.0);
        assert_eq!(c.d(0, 1), 2.0); // same node
        assert_eq!(c.d(0, 2), 4.0); // across nodes
        assert_eq!(c.d(1, 3), 4.0);
    }

    #[test]
    fn flat_distances_uniform() {
        let t = Topology::homogeneous(4, 1.0, 2.0);
        let c = CommCost::from_topology(&t);
        for a in 0..4 {
            for b in 0..4 {
                let want = if a == b { 0.0 } else { 2.0 };
                assert_eq!(c.d(a, b), want);
            }
        }
    }

    fn quotient_for(k: usize) -> (crate::graph::Csr, QuotientGraph, Vec<u32>) {
        let g = mesh_2d_tri(20, 20, 7);
        let topo = Topology::homogeneous(k, 1.0, 2.0);
        let targets = vec![g.n() as f64 / k as f64; k];
        let ctx = Ctx { graph: &g, targets: &targets, topo: &topo, epsilon: 0.05, seed: 1 };
        let p = by_name("geoKM").unwrap().partition(&ctx).unwrap();
        let q = QuotientGraph::build(&g, &p.assignment, k);
        (g, q, p.assignment)
    }

    #[test]
    fn greedy_beats_worst_case_and_refine_monotone() {
        let (_g, q, _) = quotient_for(8);
        let topo = hier_topo(2, 4);
        let cost = CommCost::from_topology(&topo);
        let id = identity_mapping(8);
        let id_cost = mapping_cost(&q, &cost, &id);
        let greedy = greedy_mapping(&q, &cost, &topo);
        let greedy_cost = mapping_cost(&q, &cost, &greedy);
        // Refinement is monotone from any start; from the identity start
        // it can therefore never end above the identity cost.
        let (refined_g, cost_g) = refine_mapping(&q, &cost, &topo, greedy.clone(), 10);
        let (_refined_i, cost_i) = refine_mapping(&q, &cost, &topo, id.clone(), 10);
        // Valid permutation.
        let mut sorted = refined_g.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
        // Monotone improvements.
        assert!(cost_g <= greedy_cost + 1e-9);
        assert!(cost_i <= id_cost + 1e-9);
        // The better of the two starts defines the mapping we'd ship.
        assert!(cost_g.min(cost_i) <= id_cost + 1e-9);
    }

    #[test]
    fn mapping_respects_speed_classes() {
        // 2 fast + 6 slow PUs: fast blocks must stay on fast PUs.
        let mut pus = vec![Pu { speed: 8.0, memory: 8.5 }; 2];
        pus.extend(vec![Pu { speed: 1.0, memory: 2.0 }; 6]);
        let topo = Topology::flat(pus, "mixed");
        let (_g, q, _) = quotient_for(8);
        let cost = CommCost::from_topology(&topo);
        let pi = greedy_mapping(&q, &cost, &topo);
        // Blocks 0,1 (sized for fast PUs) must map to PUs {0,1}.
        let mut fast: Vec<u32> = vec![pi[0], pi[1]];
        fast.sort_unstable();
        assert_eq!(fast, vec![0, 1]);
    }

    /// Quotient-graph literal from symmetric (i, j, vol) edges.
    fn quotient_from_edges(k: usize, edges: &[(u32, u32, f64)]) -> QuotientGraph {
        let mut adj: Vec<Vec<(u32, f64)>> = vec![Vec::new(); k];
        for &(i, j, v) in edges {
            adj[i as usize].push((j, v));
            adj[j as usize].push((i, v));
        }
        for l in adj.iter_mut() {
            l.sort_by_key(|&(j, _)| j);
        }
        QuotientGraph { k, adj: adj.clone(), cut: adj }
    }

    #[test]
    fn bottleneck_volume_star_is_heaviest_spoke() {
        // Star: center block 0 talks to 1, 2, 3 with volumes 5, 7, 3.
        // On a flat topology every PU is its own node, so each spoke is
        // its own link: the bottleneck is the heaviest spoke.
        let q = quotient_from_edges(4, &[(0, 1, 5.0), (0, 2, 7.0), (0, 3, 3.0)]);
        let topo = Topology::homogeneous(4, 1.0, 2.0);
        assert_eq!(bottleneck_volume(&q, &topo, &identity_mapping(4)), 7.0);
    }

    #[test]
    fn bottleneck_volume_ring_is_heaviest_edge() {
        let q = quotient_from_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0), (0, 3, 4.0)],
        );
        let topo = Topology::homogeneous(4, 1.0, 2.0);
        assert_eq!(bottleneck_volume(&q, &topo, &identity_mapping(4)), 4.0);
    }

    #[test]
    fn bottleneck_volume_accumulates_on_the_node_link() {
        // 2 nodes × 2 PUs. Intra edge (0,1) carries 5; inter edges
        // (0,2) = 3 and (1,3) = 4 *share* the node0→node1 fabric link,
        // so the bottleneck is their sum 7 — larger than any single
        // edge. Total-volume objectives cannot see this.
        let q = quotient_from_edges(4, &[(0, 1, 5.0), (0, 2, 3.0), (1, 3, 4.0)]);
        let topo = hier_topo(2, 2);
        assert_eq!(bottleneck_volume(&q, &topo, &identity_mapping(4)), 7.0);
        // A mapping that swaps blocks 1 and 2 across nodes moves edge
        // (0,1) onto the fabric too: link load becomes 5 + 3 = 8
        // outbound... and the (1,3) edge turns intra. Recompute by hand:
        // node0 now hosts blocks {0, 2}, node1 hosts {1, 3}.
        //   (0,1): inter, 5   (0,2): intra PU link, 3   (1,3): intra, 4
        let pi = vec![0, 2, 1, 3];
        assert_eq!(bottleneck_volume(&q, &topo, &pi), 5.0);
    }

    #[test]
    fn bottleneck_from_links_matches_volume_on_flat_topology() {
        // A measured byte matrix on a flat topology: the bottleneck is
        // simply the max ordered-pair entry (what `maxLinkBytes`
        // reports).
        let links = vec![
            vec![0usize, 10, 0, 2],
            vec![9, 0, 1, 0],
            vec![0, 3, 0, 12],
            vec![2, 0, 11, 0],
        ];
        let topo = Topology::homogeneous(4, 1.0, 2.0);
        let max_entry = links.iter().flatten().copied().max().unwrap() as f64;
        assert_eq!(bottleneck_from_links(&links, &topo, &identity_mapping(4)), max_entry);
    }

    #[test]
    fn hierarchical_mapping_improves_on_random_quotient_placement() {
        // On a 2-node hierarchy, a good mapping keeps geometric neighbor
        // blocks on one node; cost must drop vs a deliberately scrambled
        // permutation.
        let (_g, q, _) = quotient_for(8);
        let topo = hier_topo(2, 4);
        let cost = CommCost::from_topology(&topo);
        let scrambled: Vec<u32> = vec![0, 4, 1, 5, 2, 6, 3, 7];
        let (refined, rc) = refine_mapping(&q, &cost, &topo, scrambled.clone(), 10);
        assert!(rc <= mapping_cost(&q, &cost, &scrambled));
        let mut sorted = refined;
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
    }
}
