//! Miniature property-based testing framework (offline replacement for
//! `proptest`).
//!
//! A property is a closure over a value drawn from a [`Gen`]erator; the
//! runner draws `cases` random values, and on failure greedily *shrinks*
//! the counterexample before reporting it. Used throughout the test suite
//! for invariants: Algorithm-1 optimality, partition validity, refinement
//! monotonicity, Hilbert-curve bijectivity, …
//!
//! ```no_run
//! // (no_run: doctest executables lack the xla rpath in this image)
//! use hetpart::prop::{check, gens};
//! check("reverse twice is identity", 200, 0xC0FFEE, gens::vec_usize(0..50, 0..100), |v| {
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     if w == *v { Ok(()) } else { Err("mismatch".into()) }
//! });
//! ```

use crate::util::rng::Rng;

/// A random-value generator plus a shrinking strategy.
pub trait Gen {
    /// The type of values this generator produces.
    type Value: std::fmt::Debug + Clone;
    /// Draw a random value.
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Propose strictly "smaller" candidate values (may be empty).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run a property over `cases` random inputs. Panics with the (shrunk)
/// counterexample on failure. `seed` makes runs reproducible.
pub fn check<G: Gen>(
    name: &str,
    cases: usize,
    seed: u64,
    gen: G,
    prop: impl Fn(&G::Value) -> PropResult,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // Greedy shrink: repeatedly take the first shrink candidate
            // that still fails, up to a bounded number of rounds.
            let mut cur = v;
            let mut cur_msg = msg;
            'outer: for _ in 0..1000 {
                for cand in gen.shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  \
                 counterexample: {cur:?}\n  error: {cur_msg}"
            );
        }
    }
}

/// Ready-made generators.
pub mod gens {
    use super::Gen;
    use crate::util::rng::Rng;
    use std::ops::Range;

    /// Uniform usize in a range.
    pub struct UsizeGen(pub Range<usize>);
    impl Gen for UsizeGen {
        type Value = usize;
        fn generate(&self, rng: &mut Rng) -> usize {
            self.0.start + rng.usize(self.0.end - self.0.start)
        }
        fn shrink(&self, v: &usize) -> Vec<usize> {
            let mut out = Vec::new();
            if *v > self.0.start {
                out.push(self.0.start);
                out.push(self.0.start + (*v - self.0.start) / 2);
                out.push(*v - 1);
            }
            out.dedup();
            out
        }
    }

    /// Generator for a `usize` drawn uniformly from `r`.
    pub fn usize_in(r: Range<usize>) -> UsizeGen {
        UsizeGen(r)
    }

    /// Uniform f64 in a range.
    pub struct F64Gen(pub Range<f64>);
    impl Gen for F64Gen {
        type Value = f64;
        fn generate(&self, rng: &mut Rng) -> f64 {
            rng.f64_range(self.0.start, self.0.end)
        }
        fn shrink(&self, v: &f64) -> Vec<f64> {
            let mid = 0.5 * (self.0.start + *v);
            if (mid - *v).abs() > 1e-9 {
                vec![self.0.start, mid]
            } else {
                vec![]
            }
        }
    }

    /// Generator for an `f64` drawn uniformly from `r`.
    pub fn f64_in(r: Range<f64>) -> F64Gen {
        F64Gen(r)
    }

    /// Vec of usize with random length.
    pub struct VecUsizeGen {
        /// Length range of the generated vector.
        pub len: Range<usize>,
        /// Range each element is drawn from.
        pub elem: Range<usize>,
    }
    impl Gen for VecUsizeGen {
        type Value = Vec<usize>;
        fn generate(&self, rng: &mut Rng) -> Vec<usize> {
            let n = self.len.start + rng.usize((self.len.end - self.len.start).max(1));
            (0..n)
                .map(|_| self.elem.start + rng.usize((self.elem.end - self.elem.start).max(1)))
                .collect()
        }
        fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
            let mut out = Vec::new();
            if v.len() > self.len.start {
                // Halve, drop-first, drop-last.
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[1..].to_vec());
                out.push(v[..v.len() - 1].to_vec());
            }
            // Element-wise shrink toward range start.
            for i in 0..v.len() {
                if v[i] > self.elem.start {
                    let mut w = v.clone();
                    w[i] = self.elem.start;
                    out.push(w);
                }
            }
            out.retain(|w| w.len() >= self.len.start);
            out
        }
    }

    /// Generator for `Vec<usize>` with the given length/element ranges.
    pub fn vec_usize(len: Range<usize>, elem: Range<usize>) -> VecUsizeGen {
        VecUsizeGen { len, elem }
    }

    /// Vec of f64 with random length.
    pub struct VecF64Gen {
        /// Length range of the generated vector.
        pub len: Range<usize>,
        /// Range each element is drawn from.
        pub elem: Range<f64>,
    }
    impl Gen for VecF64Gen {
        type Value = Vec<f64>;
        fn generate(&self, rng: &mut Rng) -> Vec<f64> {
            let n = self.len.start + rng.usize((self.len.end - self.len.start).max(1));
            (0..n)
                .map(|_| rng.f64_range(self.elem.start, self.elem.end))
                .collect()
        }
        fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
            if v.len() > self.len.start {
                vec![v[..v.len() / 2].to_vec(), v[1..].to_vec()]
            } else {
                vec![]
            }
        }
    }

    /// Generator for `Vec<f64>` with the given length/element ranges.
    pub fn vec_f64(len: Range<usize>, elem: Range<f64>) -> VecF64Gen {
        VecF64Gen { len, elem }
    }

    /// Pair of independent generators.
    pub struct PairGen<A, B>(pub A, pub B);
    impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
        type Value = (A::Value, B::Value);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> = self
                .0
                .shrink(&v.0)
                .into_iter()
                .map(|a| (a, v.1.clone()))
                .collect();
            out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
            out
        }
    }

    /// Generator combining two generators into a pair.
    pub fn pair<A: Gen, B: Gen>(a: A, b: B) -> PairGen<A, B> {
        PairGen(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("usize < bound", 200, 1, gens::usize_in(0..100), |&v| {
            if v < 100 {
                Ok(())
            } else {
                Err(format!("{v} >= 100"))
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let caught = std::panic::catch_unwind(|| {
            check("find >= 10", 500, 2, gens::usize_in(0..100), |&v| {
                if v < 10 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let msg = match caught {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("property should have failed"),
        };
        // Shrinker should land on the minimal counterexample 10.
        assert!(msg.contains("counterexample: 10"), "{msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        check(
            "vec bounds",
            100,
            3,
            gens::vec_usize(2..10, 5..9),
            |v| {
                if v.len() >= 2 && v.len() < 10 && v.iter().all(|&x| (5..9).contains(&x)) {
                    Ok(())
                } else {
                    Err(format!("{v:?}"))
                }
            },
        );
    }

    #[test]
    fn pair_gen_shrinks_both_sides() {
        let g = gens::pair(gens::usize_in(0..10), gens::usize_in(0..10));
        let shr = g.shrink(&(5, 7));
        assert!(shr.iter().any(|&(a, b)| a < 5 && b == 7));
        assert!(shr.iter().any(|&(a, b)| a == 5 && b < 7));
    }
}
