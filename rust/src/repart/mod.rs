//! Dynamic repartitioning for adaptive workloads.
//!
//! The paper's hardest instance (refinetrace, §IV) comes from *adaptive*
//! FEM: the mesh refines near a moving front, so any static partition
//! decays epoch by epoch. This module turns the one-shot pipeline into a
//! multi-epoch system:
//!
//! - [`trace`] — [`EpochTrace`] replays adaptive workloads: per-epoch
//!   load weights following `gen::refine`'s moving front, or per-PU
//!   speed drift;
//! - [`Repartitioner`] — one trait, three strategies:
//!   - [`ScratchRemap`] re-runs a static partitioner from
//!     `partitioners::by_name`, then remaps the new blocks onto PUs
//!     within Algorithm-1 speed classes to minimize migration volume
//!     (greedy bipartite matching on block overlap, with
//!     [`mapping::CommCost`](crate::mapping::CommCost) breaking ties
//!     toward communication-friendly placements);
//!   - [`Diffusion`] shifts boundary vertices on the quotient graph from
//!     overloaded toward underloaded PUs, respecting the heterogeneous
//!     `(1+ε)·tw(b_i)` capacities;
//!   - [`IncrementalGeoKM`] warm-starts balanced k-means from the
//!     previous epoch's centers;
//! - [`migrate`] — the epoch-to-epoch data movement expressed as an
//!   [`ExchangePlan`](crate::exec::ExchangePlan) and *executed* through
//!   the `exec::Comm` seam, so both the `sim` and `threads` backends
//!   price it;
//! - [`driver`] — [`run_trace`] runs a repartitioner over a trace,
//!   recording per-epoch quality (cut, LDHT objective vs the from-scratch
//!   baseline) and migration (weight, volume, priced seconds).
//!
//! Quality/migration trade-off targeted here (and pinned by
//! `tests/repart.rs`): per-epoch LDHT objective within 1.15× of a
//! from-scratch repartition while moving well under 35% of the weight a
//! naive scratch repartition (fresh labels every epoch) would move.

pub mod diffusion;
pub mod driver;
pub mod increkm;
pub mod migrate;
pub mod scratch;
pub mod trace;

pub use diffusion::Diffusion;
pub use driver::{epoch_table, run_trace, EpochRecord, TraceOptions, TraceResult};
pub use increkm::{warm_start, warm_start_centers, IncrementalGeoKM};
pub use migrate::{
    execute_migration, execute_migration_opts, migration_plan, MigrationPlan, MigrationReport,
};
pub use scratch::ScratchRemap;
pub use trace::{DynamicKind, Epoch, EpochTrace};

use crate::graph::Csr;
use crate::partition::Partition;
use crate::topology::Topology;
use anyhow::Result;

/// Everything a repartitioner may use for one epoch step. The previous
/// partition's block ids are PU ids (block i ran on PU i last epoch), so
/// "minimizing migration" and "mapping blocks to PUs" are the same
/// question.
pub struct EpochCtx<'a> {
    /// Current epoch's graph (same vertex set as last epoch, vertex
    /// weights updated to the new load).
    pub graph: &'a Csr,
    /// Previous epoch's partition (block i ↔ PU i).
    pub prev: &'a Partition,
    /// Algorithm-1 target block weights for the current epoch.
    pub targets: &'a [f64],
    /// Current epoch's (load-scaled) topology.
    pub topo: &'a Topology,
    /// Imbalance tolerance ε.
    pub epsilon: f64,
    /// RNG seed (repartitioners are deterministic given the seed).
    pub seed: u64,
    /// Optimization hint: the trace driver's already-computed from-scratch
    /// partition of this epoch, tagged with the static algorithm that
    /// produced it. A repartitioner about to run the *same* deterministic
    /// algorithm on the same inputs may reuse it instead of recomputing.
    pub scratch: Option<(&'a str, &'a Partition)>,
}

impl<'a> EpochCtx<'a> {
    /// Number of blocks (= number of targets).
    pub fn k(&self) -> usize {
        self.targets.len()
    }
}

/// A dynamic repartitioning strategy: produce the next epoch's partition
/// from the previous one under the current load.
pub trait Repartitioner {
    /// Strategy name as used by [`repartitioner_by_name`].
    fn name(&self) -> &'static str;
    /// Produce the next epoch's partition from `ctx.prev`.
    fn repartition(&self, ctx: &EpochCtx) -> Result<Partition>;
}

/// Look up a repartitioner by name (case-insensitive, hyphens optional).
pub fn repartitioner_by_name(name: &str) -> Option<Box<dyn Repartitioner>> {
    let norm: String = name
        .chars()
        .filter(|c| *c != '-' && *c != '_')
        .collect::<String>()
        .to_ascii_lowercase();
    Some(match norm.as_str() {
        "scratchremap" | "scratch" => Box::new(ScratchRemap::default()),
        "diffusion" | "diffusive" => Box::new(Diffusion::default()),
        "increkm" | "incrementalgeokm" => Box::new(IncrementalGeoKM::default()),
        _ => return None,
    })
}

/// Like [`repartitioner_by_name`], but with scratch-remap bound to the
/// same static algorithm the trace driver uses for its from-scratch
/// baseline — the binding that makes `obj/scratch ≈ 1` structural for
/// scratch-remap (comparing a geoKM remap against a zSFC baseline would
/// silently break that guarantee).
pub fn repartitioner_for_trace(name: &str, scratch_algo: &str) -> Option<Box<dyn Repartitioner>> {
    let rp = repartitioner_by_name(name)?;
    if rp.name() == "scratchRemap" {
        return Some(Box::new(ScratchRemap { algo: scratch_algo.to_string() }));
    }
    Some(rp)
}

/// The three repartitioners, in registry order.
pub const REPART_NAMES: [&str; 3] = ["scratchRemap", "diffusion", "increKM"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in REPART_NAMES {
            let r = repartitioner_by_name(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(r.name(), name, "registry returned a different strategy");
            for variant in [name.to_lowercase(), name.to_uppercase()] {
                assert!(
                    repartitioner_by_name(&variant).is_some(),
                    "casing {variant} missing"
                );
            }
        }
        assert!(repartitioner_by_name("scratch-remap").is_some());
        assert!(repartitioner_by_name("incremental-geoKM").is_some());
        assert!(repartitioner_by_name("nope").is_none());
    }

    #[test]
    fn for_trace_resolves_and_rejects() {
        for name in REPART_NAMES {
            let rp = repartitioner_for_trace(name, "zSFC").unwrap();
            assert_eq!(rp.name(), name);
        }
        assert!(repartitioner_for_trace("nope", "geoKM").is_none());
    }
}
