//! The epoch loop: run a repartitioner over an [`EpochTrace`], measuring
//! per-epoch quality *and* migration, with a from-scratch baseline
//! alongside.
//!
//! For every epoch e ≥ 1 the driver computes:
//! - the repartitioner's next partition and its quality metrics (cut,
//!   max communication volume, imbalance, LDHT objective vs the
//!   Algorithm-1 optimum for this epoch's load);
//! - the *from-scratch* baseline: a fresh static partition of the same
//!   epoch, whose objective anchors the quality ratio and whose labels,
//!   taken naively, define the migration a repartition-oblivious system
//!   would pay;
//! - the actual migration, executed through the `exec::Comm` seam
//!   ([`super::execute_migration`]) so the chosen backend prices it.

use super::migrate::{execute_migration_opts, migration_plan};
use super::trace::EpochTrace;
use super::Repartitioner;
use crate::blocksizes::{block_sizes, TABLE3_FILL};
use crate::exec::ExecBackend;
use crate::partition::{metrics, migration, Partition};
use crate::partitioners::by_name;
use crate::repart::EpochCtx;
use crate::util::table::Table;
use crate::util::timer::Timer;
use anyhow::{anyhow, Context, Result};

/// Driver knobs.
pub struct TraceOptions {
    /// Static partitioner used for the epoch-0 partition and the
    /// from-scratch baseline.
    pub scratch_algo: String,
    /// Transport that executes (and prices) the migration.
    pub backend: ExecBackend,
    /// Drive the migration through the nonblocking `Comm` path (one
    /// aggregated isend per destination; identical volumes and delivered
    /// state, pinned by `migrate`'s tests). `hetpart repart --overlap on`.
    pub nonblocking: bool,
    /// Imbalance tolerance ε handed to every (re)partitioner.
    pub epsilon: f64,
    /// Seed for the trace and all partitioners (runs are deterministic).
    pub seed: u64,
}

impl Default for TraceOptions {
    fn default() -> Self {
        TraceOptions {
            scratch_algo: "geoKM".to_string(),
            backend: ExecBackend::Sim,
            nonblocking: false,
            epsilon: 0.03,
            seed: 42,
        }
    }
}

/// Everything measured at one epoch.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    /// Epoch index (0 = initial static partition).
    pub epoch: usize,
    /// Vertices this epoch.
    pub n: usize,
    /// Total vertex weight this epoch.
    pub load: f64,
    /// Edge cut of the partition.
    pub cut: f64,
    /// Largest per-block communication volume.
    pub max_comm_volume: f64,
    /// Total communication volume over all blocks.
    pub total_comm_volume: f64,
    /// Relative imbalance vs this epoch's targets.
    pub imbalance: f64,
    /// Achieved LDHT objective `max_i w(b_i)/c_s(p_i)`.
    pub ldht_objective: f64,
    /// Algorithm-1 optimum for this epoch's (load, topology).
    pub ldht_optimum: f64,
    /// From-scratch baseline's LDHT objective this epoch.
    pub scratch_objective: f64,
    /// Vertex weight the repartitioner moved (0 at epoch 0).
    pub migrated_weight: f64,
    /// Vertices that changed blocks.
    pub migrated_vertices: usize,
    /// Words shipped through the `Comm` transport (one per moved vertex).
    pub migration_volume: usize,
    /// Slowest rank's migration seconds under the chosen backend.
    pub migration_secs: f64,
    /// Weight a naive scratch repartition (fresh labels, no remap) would
    /// have moved this epoch.
    pub naive_migrated_weight: f64,
    /// Repartitioning seconds this epoch.
    pub time_repartition: f64,
}

impl EpochRecord {
    /// Quality ratio vs the from-scratch baseline (≤ 1.15 is the
    /// subsystem's acceptance bar).
    pub fn obj_vs_scratch(&self) -> f64 {
        if self.scratch_objective > 0.0 {
            self.ldht_objective / self.scratch_objective
        } else {
            f64::NAN
        }
    }
}

/// A completed trace run.
#[derive(Debug, Clone)]
pub struct TraceResult {
    /// Repartitioner that produced this trace.
    pub repartitioner: String,
    /// Transport that executed the migrations (`sim`/`threads`).
    pub backend: &'static str,
    /// One record per epoch (epoch 0 = initial static partition, zero
    /// migration by definition).
    pub records: Vec<EpochRecord>,
}

impl TraceResult {
    /// Total weight migrated across all epochs.
    pub fn total_migrated_weight(&self) -> f64 {
        self.records.iter().map(|r| r.migrated_weight).sum()
    }

    /// Total weight a naive scratch repartition would have migrated.
    pub fn total_naive_migrated_weight(&self) -> f64 {
        self.records.iter().map(|r| r.naive_migrated_weight).sum()
    }

    /// Total words shipped through the transport.
    pub fn total_migration_volume(&self) -> usize {
        self.records.iter().map(|r| r.migration_volume).sum()
    }

    /// Worst per-epoch quality ratio vs from-scratch (epochs ≥ 1; NaN
    /// for a single-epoch trace, which has no repartitioned epochs).
    pub fn worst_obj_vs_scratch(&self) -> f64 {
        let worst = self
            .records
            .iter()
            .skip(1)
            .map(|r| r.obj_vs_scratch())
            .fold(f64::NEG_INFINITY, f64::max);
        if worst.is_finite() {
            worst
        } else {
            f64::NAN
        }
    }
}

/// Algorithm-1 targets for an epoch: scale the topology's normalized
/// memory to the epoch load (the `run_one` calibration) and solve.
fn epoch_targets(
    g: &crate::graph::Csr,
    topo: &crate::topology::Topology,
) -> Result<(crate::topology::Topology, Vec<f64>, f64)> {
    let load = g.total_vertex_weight();
    let scaled = topo.scaled_for_load(load, TABLE3_FILL);
    let bs = block_sizes(load, &scaled)
        .with_context(|| format!("Algorithm 1 on {}", topo.label))?;
    Ok((scaled, bs.tw, bs.max_ratio))
}

/// Run `rp` over the trace. Deterministic given the trace and options.
pub fn run_trace(
    trace: &EpochTrace,
    rp: &dyn Repartitioner,
    opts: &TraceOptions,
) -> Result<TraceResult> {
    let scratch = by_name(&opts.scratch_algo)
        .ok_or_else(|| anyhow!("unknown partitioner {}", opts.scratch_algo))?;
    // A front is a geometric object: a coordinate-less graph (e.g. a
    // METIS file) would silently degenerate to a static trace.
    anyhow::ensure!(
        trace.kind != crate::repart::DynamicKind::RefineFront || trace.base.has_coords(),
        "refine-front traces need vertex coordinates"
    );
    let mut records = Vec::with_capacity(trace.epochs);

    // Epoch 0: everyone starts from the same static partition.
    let e0 = trace.epoch(0);
    let (scaled0, tw0, opt0) = epoch_targets(&e0.graph, &e0.topo)?;
    let timer = Timer::start();
    let initial = scratch.partition(&crate::partitioners::Ctx {
        graph: &e0.graph,
        targets: &tw0,
        topo: &scaled0,
        epsilon: opts.epsilon,
        seed: opts.seed,
    })?;
    let t0_secs = timer.secs();
    initial.validate(&e0.graph).map_err(anyhow::Error::msg)?;
    let speeds0: Vec<f64> = scaled0.pus.iter().map(|p| p.speed).collect();
    let m0 = metrics(&e0.graph, &initial, &tw0);
    records.push(EpochRecord {
        epoch: 0,
        n: e0.graph.n(),
        load: e0.graph.total_vertex_weight(),
        cut: m0.cut,
        max_comm_volume: m0.max_comm_volume,
        total_comm_volume: m0.total_comm_volume,
        imbalance: m0.imbalance,
        ldht_objective: m0.ldht_objective(&speeds0),
        ldht_optimum: opt0,
        scratch_objective: m0.ldht_objective(&speeds0),
        migrated_weight: 0.0,
        migrated_vertices: 0,
        migration_volume: 0,
        migration_secs: 0.0,
        naive_migrated_weight: 0.0,
        time_repartition: t0_secs,
    });

    let mut prev_ours = initial.clone();
    let mut prev_naive = initial;
    for e in 1..trace.epochs {
        let ep = trace.epoch(e);
        let (scaled, tw, opt) = epoch_targets(&ep.graph, &ep.topo)?;
        let speeds: Vec<f64> = scaled.pus.iter().map(|p| p.speed).collect();

        // From-scratch baseline: fresh labels, no relation to last epoch.
        let fresh = scratch.partition(&crate::partitioners::Ctx {
            graph: &ep.graph,
            targets: &tw,
            topo: &scaled,
            epsilon: opts.epsilon,
            seed: opts.seed,
        })?;
        fresh.validate(&ep.graph).map_err(anyhow::Error::msg)?;
        let scratch_obj = metrics(&ep.graph, &fresh, &tw).ldht_objective(&speeds);
        let naive_mig = migration(&ep.graph, &prev_naive, &fresh);

        // The repartitioner under test.
        let timer = Timer::start();
        let part = rp
            .repartition(&EpochCtx {
                graph: &ep.graph,
                prev: &prev_ours,
                targets: &tw,
                topo: &scaled,
                epsilon: opts.epsilon,
                seed: opts.seed,
                scratch: Some((opts.scratch_algo.as_str(), &fresh)),
            })
            .with_context(|| format!("{} at epoch {e}", rp.name()))?;
        let rep_secs = timer.secs();
        part.validate(&ep.graph).map_err(anyhow::Error::msg)?;

        // Execute the actual data migration through the Comm seam (the
        // payload is one state word per vertex; values are the global
        // vertex ids so delivery is verifiable).
        let mig = migration(&ep.graph, &prev_ours, &part);
        let mp = migration_plan(&prev_ours, &part)?;
        let values: Vec<f32> = (0..ep.graph.n()).map(|u| u as f32).collect();
        let (delivered, mig_report) =
            execute_migration_opts(&mp, opts.backend, &values, opts.nonblocking)?;
        debug_assert_eq!(delivered, values, "migration corrupted the payload");
        debug_assert_eq!(mig_report.moved_words, mig.migrated_vertices);

        let m = metrics(&ep.graph, &part, &tw);
        records.push(EpochRecord {
            epoch: e,
            n: ep.graph.n(),
            load: ep.graph.total_vertex_weight(),
            cut: m.cut,
            max_comm_volume: m.max_comm_volume,
            total_comm_volume: m.total_comm_volume,
            imbalance: m.imbalance,
            ldht_objective: m.ldht_objective(&speeds),
            ldht_optimum: opt,
            scratch_objective: scratch_obj,
            migrated_weight: mig.migrated_weight,
            migrated_vertices: mig.migrated_vertices,
            migration_volume: mig_report.moved_words,
            migration_secs: mig_report.max_rank_secs(),
            naive_migrated_weight: naive_mig.migrated_weight,
            time_repartition: rep_secs,
        });
        prev_ours = part;
        prev_naive = fresh;
    }
    Ok(TraceResult {
        repartitioner: rp.name().to_string(),
        backend: opts.backend.name(),
        records,
    })
}

/// Per-epoch table (printed by `hetpart repart` and the example).
pub fn epoch_table(res: &TraceResult) -> Table {
    let mut t = Table::new(vec![
        "epoch", "n", "load", "cut", "maxCommVol", "imbalance", "ldhtObj", "ldhtOpt",
        "obj/scratch", "migWeight", "migW/naive", "migWords", "migSecs", "tRepart(s)",
    ]);
    for r in &res.records {
        let ratio = r.obj_vs_scratch();
        let mig_vs_naive = if r.naive_migrated_weight > 0.0 {
            format!("{:.3}", r.migrated_weight / r.naive_migrated_weight)
        } else {
            "-".to_string()
        };
        t.row(vec![
            r.epoch.to_string(),
            r.n.to_string(),
            format!("{:.1}", r.load),
            format!("{:.1}", r.cut),
            format!("{:.1}", r.max_comm_volume),
            format!("{:+.4}", r.imbalance),
            format!("{:.4}", r.ldht_objective),
            format!("{:.4}", r.ldht_optimum),
            if ratio.is_finite() { format!("{ratio:.4}") } else { "-".to_string() },
            format!("{:.1}", r.migrated_weight),
            mig_vs_naive,
            r.migration_volume.to_string(),
            format!("{:.3e}", r.migration_secs),
            format!("{:.4}", r.time_repartition),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::refined_mesh_2d;
    use crate::repart::trace::DynamicKind;
    use crate::repart::Diffusion;
    use crate::topology::Topology;

    #[test]
    fn trace_run_produces_one_record_per_epoch() {
        let g = refined_mesh_2d(1200, 5);
        let topo = Topology::homogeneous(6, 1.0, 2.0);
        let trace = EpochTrace::new(&g, topo, DynamicKind::RefineFront, 4, 5);
        let res = run_trace(&trace, &Diffusion::default(), &TraceOptions::default()).unwrap();
        assert_eq!(res.records.len(), 4);
        assert_eq!(res.repartitioner, "diffusion");
        assert_eq!(res.backend, "sim");
        assert_eq!(res.records[0].migrated_vertices, 0);
        for (e, r) in res.records.iter().enumerate() {
            assert_eq!(r.epoch, e);
            assert!(r.cut > 0.0, "epoch {e}: zero cut");
            assert!(r.ldht_objective > 0.0);
            assert!(r.ldht_optimum > 0.0);
            assert!(r.load > 0.0);
        }
        // Something must migrate on a moving-front trace.
        assert!(res.total_migrated_weight() > 0.0);
        assert!(res.total_migration_volume() > 0);
        // The table renders one row per record.
        assert_eq!(epoch_table(&res).rows.len(), 4);
    }

    #[test]
    fn trace_run_is_deterministic() {
        let g = refined_mesh_2d(900, 6);
        let topo = Topology::homogeneous(4, 1.0, 2.0);
        let trace = EpochTrace::new(&g, topo, DynamicKind::RefineFront, 3, 6);
        let a = run_trace(&trace, &Diffusion::default(), &TraceOptions::default()).unwrap();
        let b = run_trace(&trace, &Diffusion::default(), &TraceOptions::default()).unwrap();
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.cut, y.cut);
            assert_eq!(x.migrated_weight, y.migrated_weight);
            assert_eq!(x.migration_volume, y.migration_volume);
            assert_eq!(x.ldht_objective, y.ldht_objective);
        }
    }
}
