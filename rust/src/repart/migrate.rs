//! Epoch-to-epoch data migration, executed through the `exec::Comm` seam.
//!
//! When the assignment changes from `prev` to `next`, every vertex whose
//! block changed must ship its state from the old PU to the new one.
//! That movement is expressed as an ordinary
//! [`ExchangePlan`](crate::exec::ExchangePlan) — rank o's "owned" vector
//! holds the values of the vertices it had last epoch, its segments send
//! the departing values into the receivers' inboxes — and then *executed*
//! by either transport: [`SimComm`] prices it with the α-β model,
//! [`ThreadComm`] measures real scatter/copy/barrier time under one OS
//! thread per PU. Both transports run the same plan, so the migration
//! *volume* (words shipped per rank) is identical by construction — the
//! invariant `tests/repart.rs` pins.

use crate::exec::{Comm, CostModel, ExchangePlan, ExecBackend, SendSegment, SimComm, ThreadComm};
use crate::partition::Partition;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// A migration expressed as an exchange plan plus the vertex layout the
/// plan's local indices refer to.
pub struct MigrationPlan {
    /// The exchange pattern of the migration (rank = PU).
    pub plan: Arc<ExchangePlan>,
    /// Global vertex ids owned by each rank under `prev` (ascending; the
    /// plan's `src` indices point into these lists).
    pub own: Vec<Vec<u32>>,
    /// Global vertex ids arriving at each rank (ascending; the plan's
    /// `dst` slots point into these lists).
    pub arrivals: Vec<Vec<u32>>,
}

impl MigrationPlan {
    /// Total words shipped (one value per moved vertex).
    pub fn total_words(&self) -> usize {
        self.arrivals.iter().map(|a| a.len()).sum()
    }
}

/// Build the migration plan for the assignment change `prev` → `next`.
pub fn migration_plan(prev: &Partition, next: &Partition) -> Result<MigrationPlan> {
    ensure!(prev.n() == next.n(), "partition sizes differ: {} vs {}", prev.n(), next.n());
    ensure!(prev.k == next.k, "partition k differ: {} vs {}", prev.k, next.k);
    let k = prev.k;
    let n = prev.n();
    // Ownership under the previous epoch (ascending global ids).
    let mut own: Vec<Vec<u32>> = vec![Vec::new(); k];
    for u in 0..n {
        own[prev.assignment[u] as usize].push(u as u32);
    }
    // Arrivals per receiving rank (ascending global ids, because u runs
    // ascending) — the inbox layout.
    let mut arrivals: Vec<Vec<u32>> = vec![Vec::new(); k];
    for u in 0..n {
        let (pb, nb) = (prev.assignment[u], next.assignment[u]);
        if pb != nb {
            arrivals[nb as usize].push(u as u32);
        }
    }
    // Segments: for each sender, group departing vertices by receiver.
    let mut sends: Vec<Vec<SendSegment>> = Vec::with_capacity(k);
    for o in 0..k {
        let mut segs: Vec<SendSegment> = Vec::new();
        for (li, &g) in own[o].iter().enumerate() {
            let r = next.assignment[g as usize];
            if r as usize == o {
                continue;
            }
            let dst = arrivals[r as usize]
                .binary_search(&g)
                .expect("moved vertex missing from arrivals") as u32;
            match segs.iter_mut().find(|s| s.to == r) {
                Some(s) => {
                    s.src.push(li as u32);
                    s.dst.push(dst);
                }
                None => segs.push(SendSegment {
                    to: r,
                    src: vec![li as u32],
                    dst: vec![dst],
                }),
            }
        }
        segs.sort_by_key(|s| s.to);
        sends.push(segs);
    }
    let plan = ExchangePlan {
        ghost_len: arrivals.iter().map(|a| a.len()).collect(),
        own_len: own.iter().map(|o| o.len()).collect(),
        sends,
    };
    Ok(MigrationPlan { plan: Arc::new(plan), own, arrivals })
}

/// Cost/volume report of one executed migration.
#[derive(Debug, Clone)]
pub struct MigrationReport {
    /// Which transport executed it (`sim` or `threads`).
    pub backend: &'static str,
    /// Total words shipped across all ranks.
    pub moved_words: usize,
    /// Words sent per rank.
    pub per_rank_send_words: Vec<usize>,
    /// Communication seconds per rank: α-β priced (`sim`) or measured
    /// scatter/copy/barrier (`threads`).
    pub per_rank_secs: Vec<f64>,
}

impl MigrationReport {
    /// The makespan contribution: slowest rank's migration seconds.
    pub fn max_rank_secs(&self) -> f64 {
        self.per_rank_secs.iter().copied().fold(0.0, f64::max)
    }
}

/// Execute the migration of `values` (one f32 per vertex, e.g. the
/// solver state) through the chosen transport's **blocking** path.
/// Returns the post-migration global vector — moved entries really
/// traveled through the transport — and the cost report. See
/// [`execute_migration_opts`] for the nonblocking path.
pub fn execute_migration(
    mp: &MigrationPlan,
    backend: ExecBackend,
    values: &[f32],
) -> Result<(Vec<f32>, MigrationReport)> {
    execute_migration_opts(mp, backend, values, false)
}

/// Execute the migration through either `Comm` path.
///
/// With `nonblocking`, the plan runs through the isend/irecv/wait
/// primitives: `ThreadComm` puts the payload into each receiver's inbox
/// with **one aggregated write + notification per destination rank**
/// (no barrier, no allocation), and
/// `SimComm` prices the exchange at `wait` (no compute is overlapped
/// during a pure migration, so priced seconds equal the blocking path —
/// pinned by a test, as are the per-rank word volumes, which are
/// identical across paths and backends by construction).
pub fn execute_migration_opts(
    mp: &MigrationPlan,
    backend: ExecBackend,
    values: &[f32],
    nonblocking: bool,
) -> Result<(Vec<f32>, MigrationReport)> {
    let k = mp.plan.k();
    ensure!(
        values.len() == mp.own.iter().map(|o| o.len()).sum::<usize>(),
        "values length {} != vertex count",
        values.len()
    );
    let mut delivered = values.to_vec();
    let (secs, label): (Vec<f64>, &'static str) = match backend {
        ExecBackend::Sim => {
            let comm = SimComm::new(mp.plan.clone(), CostModel::default());
            for rank in 0..k {
                let owned: Vec<f32> =
                    mp.own[rank].iter().map(|&g| values[g as usize]).collect();
                if nonblocking {
                    let _ = comm.irecv_halo(rank);
                    comm.isend_halo(rank, &owned);
                } else {
                    comm.post_halo(rank, &owned);
                }
            }
            for rank in 0..k {
                if nonblocking {
                    comm.wait_all(rank);
                }
                let mut inbox = vec![0.0f32; mp.plan.ghost_len[rank]];
                comm.recv_halo(rank, &mut inbox);
                for (slot, &g) in mp.arrivals[rank].iter().enumerate() {
                    delivered[g as usize] = inbox[slot];
                }
            }
            (comm.comm_secs(), comm.label())
        }
        ExecBackend::Threads => {
            let comm = ThreadComm::new(mp.plan.clone());
            let inboxes: Vec<(usize, Vec<f32>)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..k)
                    .map(|rank| {
                        let comm = &comm;
                        let mp = &mp;
                        scope.spawn(move || {
                            let owned: Vec<f32> =
                                mp.own[rank].iter().map(|&g| values[g as usize]).collect();
                            if nonblocking {
                                let rq = comm.irecv_halo(rank);
                                comm.isend_halo(rank, &owned);
                                comm.wait(rank, rq);
                            } else {
                                comm.post_halo(rank, &owned);
                                comm.sync(rank);
                            }
                            let mut inbox = vec![0.0f32; mp.plan.ghost_len[rank]];
                            comm.recv_halo(rank, &mut inbox);
                            (rank, inbox)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (rank, inbox) in inboxes {
                for (slot, &g) in mp.arrivals[rank].iter().enumerate() {
                    delivered[g as usize] = inbox[slot];
                }
            }
            (comm.comm_secs(), comm.label())
        }
    };
    let per_rank_send_words: Vec<usize> =
        (0..k).map(|r| mp.plan.send_volume(r)).collect();
    let report = MigrationReport {
        backend: label,
        moved_words: per_rank_send_words.iter().sum(),
        per_rank_send_words,
        per_rank_secs: secs,
    };
    Ok((delivered, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn partitions() -> (Partition, Partition) {
        // 10 vertices over 3 ranks; vertices 2, 5, 9 move.
        let prev = Partition::new(vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 2], 3);
        let next = Partition::new(vec![0, 0, 1, 1, 1, 2, 2, 2, 2, 0], 3);
        (prev, next)
    }

    #[test]
    fn plan_shape_matches_moves() {
        let (prev, next) = partitions();
        let mp = migration_plan(&prev, &next).unwrap();
        assert_eq!(mp.total_words(), 3);
        assert_eq!(mp.plan.k(), 3);
        // Vertex 2 leaves rank 0 for rank 1; 5 leaves 1 for 2; 9 leaves 2
        // for 0.
        assert_eq!(mp.arrivals[0], vec![9]);
        assert_eq!(mp.arrivals[1], vec![2]);
        assert_eq!(mp.arrivals[2], vec![5]);
        assert_eq!(mp.plan.send_volume(0), 1);
        assert_eq!(mp.plan.send_volume(1), 1);
        assert_eq!(mp.plan.send_volume(2), 1);
        // src indices are local to the sender's own list.
        assert_eq!(mp.plan.sends[0][0].src, vec![2]); // vertex 2 is own[0][2]
        assert_eq!(mp.plan.sends[2][0].src, vec![3]); // vertex 9 is own[2][3]
    }

    #[test]
    fn both_backends_deliver_identical_values_and_volumes() {
        let (prev, next) = partitions();
        let mp = migration_plan(&prev, &next).unwrap();
        let values: Vec<f32> = (0..10).map(|u| 100.0 + u as f32).collect();
        let (d_sim, r_sim) = execute_migration(&mp, ExecBackend::Sim, &values).unwrap();
        let (d_thr, r_thr) = execute_migration(&mp, ExecBackend::Threads, &values).unwrap();
        assert_eq!(d_sim, values, "payload values must be preserved");
        assert_eq!(d_sim, d_thr, "backends delivered different states");
        assert_eq!(r_sim.per_rank_send_words, r_thr.per_rank_send_words);
        assert_eq!(r_sim.moved_words, 3);
        assert_eq!(r_sim.backend, "sim");
        assert_eq!(r_thr.backend, "threads");
        assert!(r_sim.max_rank_secs() > 0.0, "sim migration must be priced");
    }

    #[test]
    fn nonblocking_path_delivers_identical_values_volumes_and_price() {
        let (prev, next) = partitions();
        let mp = migration_plan(&prev, &next).unwrap();
        let values: Vec<f32> = (0..10).map(|u| 100.0 + u as f32).collect();
        let (d_bl, r_bl) = execute_migration_opts(&mp, ExecBackend::Sim, &values, false).unwrap();
        let (d_nb, r_nb) = execute_migration_opts(&mp, ExecBackend::Sim, &values, true).unwrap();
        assert_eq!(d_bl, d_nb, "paths delivered different states");
        assert_eq!(r_bl.per_rank_send_words, r_nb.per_rank_send_words);
        // A pure migration overlaps no compute, so the priced seconds of
        // the nonblocking path equal the blocking ones exactly.
        for (a, b) in r_bl.per_rank_secs.iter().zip(&r_nb.per_rank_secs) {
            assert!((a - b).abs() < 1e-15, "sim price changed: {a} vs {b}");
        }
        // The threads transport agrees on values and per-rank volumes
        // (one aggregated write + notification per destination).
        let (d_thr, r_thr) =
            execute_migration_opts(&mp, ExecBackend::Threads, &values, true).unwrap();
        assert_eq!(d_thr, d_nb);
        assert_eq!(r_thr.per_rank_send_words, r_nb.per_rank_send_words);
        for rank in 0..3 {
            assert_eq!(r_thr.per_rank_send_words[rank], mp.plan.send_volume(rank));
        }
    }

    #[test]
    fn identity_migration_is_empty() {
        let p = Partition::new(vec![0, 1, 0, 1], 2);
        let mp = migration_plan(&p, &p).unwrap();
        assert_eq!(mp.total_words(), 0);
        let values = vec![1.0f32; 4];
        let (d, rep) = execute_migration(&mp, ExecBackend::Sim, &values).unwrap();
        assert_eq!(d, values);
        assert_eq!(rep.moved_words, 0);
    }

    #[test]
    fn mismatched_partitions_rejected() {
        let a = Partition::new(vec![0, 1], 2);
        let b = Partition::new(vec![0, 1, 1], 2);
        assert!(migration_plan(&a, &b).is_err());
        let c = Partition::new(vec![0, 1], 3);
        assert!(migration_plan(&a, &c).is_err());
    }
}
