//! Scratch-remap repartitioning: re-run a static partitioner from
//! scratch, then relabel the fresh blocks onto PUs so migration is small.
//!
//! A from-scratch partition gives the best quality the static algorithm
//! can offer for the new load, but its block *labels* carry no relation
//! to where the data currently lives — naively adopting them migrates
//! almost everything. Because Algorithm 1 sizes block i for PU i, blocks
//! may be relabeled freely *within speed classes* (equal-speed PUs have
//! equal targets) without changing the LDHT objective at all. Scratch-
//! remap exploits exactly that freedom: greedy bipartite matching of new
//! blocks to PUs on the weight overlap with the previous assignment,
//! with [`CommCost`] distances breaking ties toward placements that keep
//! communicating blocks near each other, followed by a pairwise-swap
//! pass and a guarantee that the result never overlaps less than the
//! identity labeling (so migration is never worse than naive scratch).

use super::{EpochCtx, Repartitioner};
use crate::graph::QuotientGraph;
use crate::mapping::{speed_classes, CommCost};
use crate::partition::Partition;
use crate::partitioners::{by_name, Ctx};
use anyhow::{anyhow, ensure, Result};

/// Scratch-remap repartitioner: re-run a static algorithm from
/// scratch, then relabel the fresh blocks onto PUs within speed classes
/// to minimize migration (objective bit-identical to from-scratch).
pub struct ScratchRemap {
    /// Static partitioner to run from scratch each epoch.
    pub algo: String,
}

impl Default for ScratchRemap {
    fn default() -> Self {
        ScratchRemap { algo: "geoKM".to_string() }
    }
}

impl Repartitioner for ScratchRemap {
    fn name(&self) -> &'static str {
        "scratchRemap"
    }

    fn repartition(&self, ctx: &EpochCtx) -> Result<Partition> {
        let k = ctx.k();
        ensure!(ctx.prev.k == k, "prev partition k={} vs targets {}", ctx.prev.k, k);
        // Reuse the driver's from-scratch partition when it ran the same
        // (deterministic) algorithm — partitioning dominates the per-epoch
        // cost and recomputing it would yield the identical result.
        let fresh_owned;
        let fresh: &Partition = match ctx.scratch {
            Some((algo, p)) if algo.eq_ignore_ascii_case(&self.algo) => p,
            _ => {
                let partitioner = by_name(&self.algo)
                    .ok_or_else(|| anyhow!("unknown partitioner {}", self.algo))?;
                fresh_owned = partitioner.partition(&Ctx {
                    graph: ctx.graph,
                    targets: ctx.targets,
                    topo: ctx.topo,
                    epsilon: ctx.epsilon,
                    seed: ctx.seed,
                })?;
                &fresh_owned
            }
        };
        ensure!(fresh.k == k, "{} produced k={} blocks, expected {k}", self.algo, fresh.k);
        let pi = remap_for_overlap(ctx.graph, ctx.prev, fresh, ctx.topo);
        let assignment: Vec<u32> =
            fresh.assignment.iter().map(|&b| pi[b as usize]).collect();
        Ok(Partition::new(assignment, k))
    }
}

/// Overlap matrix: `overlap[b][p]` = vertex weight assigned to fresh
/// block `b` that previously lived on PU `p` (weight that does NOT
/// migrate if `b` is placed on `p`).
fn overlap_matrix(
    g: &crate::graph::Csr,
    prev: &Partition,
    fresh: &Partition,
    k: usize,
) -> Vec<Vec<f64>> {
    let mut overlap = vec![vec![0.0f64; k]; k];
    for u in 0..g.n() {
        overlap[fresh.assignment[u] as usize][prev.assignment[u] as usize] +=
            g.vertex_weight(u);
    }
    overlap
}

/// Choose a block→PU relabeling `pi` (a permutation within speed
/// classes) maximizing the total kept weight Σ_b overlap[b][pi[b]].
///
/// Greedy construction in descending block-mass order with CommCost
/// tie-breaks, floored at the identity labeling, then a pairwise-swap
/// hill climb — deterministic throughout.
pub fn remap_for_overlap(
    g: &crate::graph::Csr,
    prev: &Partition,
    fresh: &Partition,
    topo: &crate::topology::Topology,
) -> Vec<u32> {
    let k = fresh.k;
    let overlap = overlap_matrix(g, prev, fresh, k);
    let classes = speed_classes(topo);
    let class_of: Vec<usize> = {
        let mut m = vec![0usize; k];
        for (ci, c) in classes.iter().enumerate() {
            for &p in c {
                m[p as usize] = ci;
            }
        }
        m
    };
    // Tie-break data: quotient graph of the fresh partition + tree
    // distances, so equal-overlap choices prefer communication-friendly
    // placements (the mapping objective).
    let q = QuotientGraph::build(g, &fresh.assignment, k);
    let cost = CommCost::from_topology(topo);

    // Greedy: heaviest fresh blocks first (stable tie-break by id).
    let mass: Vec<f64> = (0..k).map(|b| overlap[b].iter().sum()).collect();
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| {
        mass[b].partial_cmp(&mass[a]).unwrap().then(a.cmp(&b))
    });
    let mut free: Vec<Vec<u32>> = classes.clone();
    let mut pi = vec![u32::MAX; k];
    for &b in &order {
        let ci = class_of[b];
        let mut best: Option<(f64, f64, usize)> = None; // (overlap, -commcost, idx)
        for (fi, &p) in free[ci].iter().enumerate() {
            let ov = overlap[b][p as usize];
            // Mapping cost of placing b at p against already-placed
            // quotient neighbors (lower is better).
            let mut cc = 0.0;
            for &(nb, vol) in &q.adj[b] {
                let placed = pi[nb as usize];
                if placed != u32::MAX {
                    cc += vol * cost.d(p as usize, placed as usize);
                }
            }
            let better = match best {
                None => true,
                Some((bov, bcc, _)) => ov > bov + 1e-12 || ((ov - bov).abs() <= 1e-12 && -cc > bcc + 1e-12),
            };
            if better {
                best = Some((ov, -cc, fi));
            }
        }
        let (_, _, fi) = best.expect("speed class exhausted");
        pi[b] = free[ci].swap_remove(fi);
    }

    // Floor at the identity labeling (always class-valid): never overlap
    // less than naive scratch would keep.
    let total = |pi: &[u32]| -> f64 {
        (0..k).map(|b| overlap[b][pi[b] as usize]).sum()
    };
    let identity: Vec<u32> = (0..k as u32).collect();
    if total(&identity) > total(&pi) {
        pi = identity;
    }

    // Pairwise-swap hill climb within classes on total overlap.
    let mut cur = total(&pi);
    for _round in 0..k.max(4) {
        let mut improved = false;
        for class in &classes {
            for x in 0..class.len() {
                for y in (x + 1)..class.len() {
                    let (a, b) = (class[x] as usize, class[y] as usize);
                    pi.swap(a, b);
                    let c = total(&pi);
                    if c > cur + 1e-12 {
                        cur = c;
                        improved = true;
                    } else {
                        pi.swap(a, b); // revert
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }
    pi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::partition::{metrics, migration};
    use crate::topology::{topo1, Pu, Topo1Spec};

    fn setup() -> (crate::graph::Csr, crate::topology::Topology, Vec<f64>) {
        let g = mesh_2d_tri(24, 24, 3);
        let topo = topo1(Topo1Spec {
            k: 6,
            num_fast: 2,
            fast: Pu { speed: 4.0, memory: 1e9 },
        });
        // Simple proportional targets (memory unconstrained).
        let total_speed: f64 = topo.pus.iter().map(|p| p.speed).sum();
        let targets: Vec<f64> = topo
            .pus
            .iter()
            .map(|p| g.total_vertex_weight() * p.speed / total_speed)
            .collect();
        (g, topo, targets)
    }

    #[test]
    fn remap_is_class_respecting_permutation() {
        let (g, topo, targets) = setup();
        let ctx = Ctx { graph: &g, targets: &targets, topo: &topo, epsilon: 0.05, seed: 1 };
        let prev = by_name("geoKM").unwrap().partition(&ctx).unwrap();
        let fresh = by_name("zSFC").unwrap().partition(&ctx).unwrap();
        let pi = remap_for_overlap(&g, &prev, &fresh, &topo);
        let mut sorted = pi.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..6).collect::<Vec<u32>>(), "not a permutation");
        for (b, &p) in pi.iter().enumerate() {
            assert_eq!(
                topo.pus[b].speed, topo.pus[p as usize].speed,
                "block {b} crossed speed class to PU {p}"
            );
        }
    }

    #[test]
    fn remap_never_migrates_more_than_identity() {
        let (g, topo, targets) = setup();
        let ctx = Ctx { graph: &g, targets: &targets, topo: &topo, epsilon: 0.05, seed: 1 };
        let prev = by_name("geoKM").unwrap().partition(&ctx).unwrap();
        let fresh = by_name("zRCB").unwrap().partition(&ctx).unwrap();
        let pi = remap_for_overlap(&g, &prev, &fresh, &topo);
        let remapped = Partition::new(
            fresh.assignment.iter().map(|&b| pi[b as usize]).collect(),
            6,
        );
        let naive = migration(&g, &prev, &fresh).migrated_weight;
        let ours = migration(&g, &prev, &remapped).migrated_weight;
        assert!(ours <= naive + 1e-9, "remap migrated {ours} > naive {naive}");
    }

    #[test]
    fn remap_preserves_ldht_objective() {
        // Relabeling within equal-speed classes permutes equal targets, so
        // the block-weight multiset per speed is unchanged and the LDHT
        // objective is bit-identical to the fresh partition's.
        let (g, topo, targets) = setup();
        let speeds: Vec<f64> = topo.pus.iter().map(|p| p.speed).collect();
        let ctx = Ctx { graph: &g, targets: &targets, topo: &topo, epsilon: 0.05, seed: 1 };
        let prev = by_name("zSFC").unwrap().partition(&ctx).unwrap();
        let rp = ScratchRemap::default();
        let ectx = EpochCtx {
            graph: &g,
            prev: &prev,
            targets: &targets,
            topo: &topo,
            epsilon: 0.05,
            seed: 1,
            scratch: None,
        };
        let ours = rp.repartition(&ectx).unwrap();
        ours.validate(&g).unwrap();
        let fresh = by_name("geoKM").unwrap().partition(&ctx).unwrap();
        let obj_ours = metrics(&g, &ours, &targets).ldht_objective(&speeds);
        let obj_fresh = metrics(&g, &fresh, &targets).ldht_objective(&speeds);
        assert!(
            (obj_ours - obj_fresh).abs() < 1e-9,
            "remap changed the objective: {obj_ours} vs {obj_fresh}"
        );
    }
}
