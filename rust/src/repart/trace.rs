//! Adaptive-workload traces: the per-epoch inputs a repartitioner reacts
//! to.
//!
//! Two drivers of change, matching the paper's motivation (§IV) and the
//! dynamic scenario axis of the harness:
//!
//! - **refine-front**: the vertex set stays fixed but per-vertex load
//!   weights follow `gen::refine`'s moving circular front (each vertex's
//!   weight models the number of refined FEM elements it carries this
//!   epoch) — the "refinetrace" character without losing the vertex
//!   correspondence migration accounting needs;
//! - **speed-drift**: the graph stays fixed but PU speeds drift
//!   multiplicatively epoch to epoch (co-scheduled jobs, thermal
//!   throttling), so Algorithm-1 targets move under the partition.

use crate::gen::refine::{front_weights, FRONT_BAND};
use crate::graph::Csr;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Which quantity changes between epochs (the harness `dynamic` axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DynamicKind {
    /// Static workload (the degenerate single-epoch case).
    None,
    /// Vertex weights follow a moving refinement front.
    RefineFront,
    /// PU speeds drift over epochs.
    SpeedDrift,
}

impl DynamicKind {
    /// Canonical kind name (the harness's `dynamic` column).
    pub fn name(&self) -> &'static str {
        match self {
            DynamicKind::None => "none",
            DynamicKind::RefineFront => "refine-front",
            DynamicKind::SpeedDrift => "speed-drift",
        }
    }

    /// Parse a kind name as written on the CLI.
    pub fn parse(s: &str) -> Option<DynamicKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "none" | "static" => DynamicKind::None,
            "refine-front" | "refinefront" | "refine_front" | "front" => {
                DynamicKind::RefineFront
            }
            "speed-drift" | "speeddrift" | "speed_drift" | "drift" => DynamicKind::SpeedDrift,
            _ => return None,
        })
    }
}

/// All dynamic kinds, in registry order.
pub const ALL_DYNAMICS: [DynamicKind; 3] = [
    DynamicKind::None,
    DynamicKind::RefineFront,
    DynamicKind::SpeedDrift,
];

/// One epoch's concrete inputs.
pub struct Epoch {
    /// The epoch graph: base structure with this epoch's vertex weights.
    pub graph: Csr,
    /// The epoch topology (speeds drifted for [`DynamicKind::SpeedDrift`]).
    pub topo: Topology,
}

/// A replayable multi-epoch workload over a fixed base graph.
pub struct EpochTrace<'a> {
    /// Base graph (vertex weights ignored; each epoch sets its own).
    pub base: &'a Csr,
    /// Base topology (unscaled preset specs; the driver load-scales).
    pub topo: Topology,
    /// Which change driver the trace replays.
    pub kind: DynamicKind,
    /// Number of epochs (≥ 1; epoch 0 is the initial static partition).
    pub epochs: usize,
    /// Seed the trace (and its speed walk) derives from.
    pub seed: u64,
    /// Refine-front weight amplitude (peak extra weight on the front).
    pub amp: f64,
    /// Refine-front band width.
    pub band: f64,
    /// Speed-drift step: per epoch each PU's speed multiplies by a factor
    /// in [1/(1+drift), 1+drift], clamped to ×4 / ÷4 of the original.
    pub drift: f64,
}

impl<'a> EpochTrace<'a> {
    /// A trace with the default front/drift magnitudes.
    pub fn new(
        base: &'a Csr,
        topo: Topology,
        kind: DynamicKind,
        epochs: usize,
        seed: u64,
    ) -> EpochTrace<'a> {
        assert!(epochs >= 1, "a trace needs at least one epoch");
        EpochTrace {
            base,
            topo,
            kind,
            epochs,
            seed,
            amp: 6.0,
            band: 1.5 * FRONT_BAND,
            drift: 0.35,
        }
    }

    /// Front sweep parameter for epoch `e`: 0 at epoch 0, advancing by
    /// `1/epochs` per epoch, so the last epoch sits at `(epochs−1)/epochs`
    /// — strictly below 1, because `front_center` wraps at t = 1 and a
    /// final epoch at exactly 1.0 would teleport the front back to the
    /// start instead of finishing the sweep.
    pub fn sweep_t(&self, e: usize) -> f64 {
        e as f64 / self.epochs as f64
    }

    /// Materialize epoch `e` (0-based, `e < epochs`). Deterministic:
    /// epoch e is the same whether reached by iterating or directly.
    pub fn epoch(&self, e: usize) -> Epoch {
        assert!(e < self.epochs, "epoch {e} out of range (epochs {})", self.epochs);
        let mut graph = self.base.clone();
        let mut topo = self.topo.clone();
        match self.kind {
            DynamicKind::None => {
                // Static: the base graph's own weights, unchanged.
            }
            DynamicKind::RefineFront => {
                // The front *defines* the epoch load profile (any base
                // weights are replaced, not scaled).
                graph.vwgt = front_weights(&graph.coords, self.sweep_t(e), self.amp, self.band);
            }
            DynamicKind::SpeedDrift => {
                // Weights stay whatever the base graph carries; only the
                // PU speeds move.
                // Replay the multiplicative walk up to epoch e so that
                // epoch e is independent of how it was reached.
                let mut rng = Rng::new(self.seed ^ 0x5eed_d21f_7a11_0b5e);
                let original: Vec<f64> = topo.pus.iter().map(|p| p.speed).collect();
                let mut factors = vec![1.0f64; topo.k()];
                for _ in 0..e {
                    for f in factors.iter_mut() {
                        let step = 1.0 + self.drift * (2.0 * rng.f64() - 1.0);
                        *f = (*f * step).clamp(0.25, 4.0);
                    }
                }
                for (pu, (&orig, &f)) in
                    topo.pus.iter_mut().zip(original.iter().zip(&factors))
                {
                    pu.speed = orig * f;
                }
            }
        }
        Epoch { graph, topo }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::refined_mesh_2d;
    use crate::topology::Topology;

    fn base() -> Csr {
        refined_mesh_2d(1200, 7)
    }

    #[test]
    fn dynamic_kind_names_round_trip() {
        for k in ALL_DYNAMICS {
            assert_eq!(DynamicKind::parse(k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(DynamicKind::parse("refinefront"), Some(DynamicKind::RefineFront));
        assert!(DynamicKind::parse("bogus").is_none());
    }

    #[test]
    fn refine_front_weights_move_with_epochs() {
        let g = base();
        let topo = Topology::homogeneous(4, 1.0, 2.0);
        let trace = EpochTrace::new(&g, topo, DynamicKind::RefineFront, 5, 42);
        // The sweep is monotone and never wraps: the last epoch's front
        // must sit strictly before t = 1 (a wrap would teleport the load
        // back to the epoch-0 position).
        for e in 1..5 {
            assert!(trace.sweep_t(e) > trace.sweep_t(e - 1));
        }
        assert!(trace.sweep_t(4) < 1.0);
        let e0 = trace.epoch(0);
        let e4 = trace.epoch(4);
        assert_eq!(e0.graph.n(), g.n());
        assert_eq!(e0.graph.vwgt.len(), g.n());
        // The weight profile must actually change across the sweep.
        let diff: f64 = e0
            .graph
            .vwgt
            .iter()
            .zip(&e4.graph.vwgt)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1.0, "front weights did not move (diff {diff})");
        // Speeds untouched on a refine-front trace.
        assert_eq!(
            e4.topo.pus.iter().map(|p| p.speed).collect::<Vec<_>>(),
            trace.topo.pus.iter().map(|p| p.speed).collect::<Vec<_>>()
        );
    }

    #[test]
    fn speed_drift_changes_speeds_deterministically() {
        let g = base();
        let topo = Topology::homogeneous(6, 1.0, 2.0);
        let trace = EpochTrace::new(&g, topo, DynamicKind::SpeedDrift, 4, 9);
        let a = trace.epoch(3);
        let b = trace.epoch(3);
        let sa: Vec<f64> = a.topo.pus.iter().map(|p| p.speed).collect();
        let sb: Vec<f64> = b.topo.pus.iter().map(|p| p.speed).collect();
        assert_eq!(sa, sb, "epoch materialization not deterministic");
        assert!(sa.iter().any(|&s| (s - 1.0).abs() > 1e-6), "no drift: {sa:?}");
        assert!(sa.iter().all(|&s| (0.25..=4.0).contains(&s)), "clamp: {sa:?}");
        // Weights stay unit on a drift trace.
        assert!(a.graph.vwgt.is_empty());
        // Epoch 0 is the undrifted topology.
        let e0 = trace.epoch(0);
        assert!(e0.topo.pus.iter().all(|p| p.speed == 1.0));
    }

    #[test]
    fn none_kind_is_static() {
        let g = base();
        let topo = Topology::homogeneous(4, 1.0, 2.0);
        let trace = EpochTrace::new(&g, topo, DynamicKind::None, 3, 1);
        let e2 = trace.epoch(2);
        assert!(e2.graph.vwgt.is_empty());
        assert!(e2.topo.pus.iter().all(|p| p.speed == 1.0));
    }
}
