//! Incremental balanced k-means: warm-start `geoKM` from the previous
//! epoch's block centers.
//!
//! A from-scratch `geoKM` re-seeds along the Hilbert curve, so its block
//! *labels* bear no relation to the previous epoch and migration is
//! dominated by label churn. Warm-starting the influence-k-means core
//! ([`lloyd_from_centers`]) from the previous blocks' weighted centroids
//! keeps label ↔ region identity by construction: clusters track the
//! load front instead of being reinvented, and only the vertices the
//! front actually pushed across a cluster boundary migrate.

use super::{EpochCtx, Repartitioner};
use crate::geometry::Point;
use crate::partition::Partition;
use crate::partitioners::geokm::lloyd_from_centers;
use anyhow::{ensure, Result};

/// Incremental geoKM: warm-start balanced k-means from the previous
/// epoch's centroids, so labels keep their region identity.
pub struct IncrementalGeoKM {
    /// Lloyd rounds per epoch (fewer than scratch geoKM's 40 — the warm
    /// start is already close).
    pub max_iters: usize,
    /// Influence exponent γ (as `GeoKMeans`).
    pub gamma: f64,
}

impl Default for IncrementalGeoKM {
    fn default() -> Self {
        IncrementalGeoKM { max_iters: 12, gamma: 0.6 }
    }
}

impl Repartitioner for IncrementalGeoKM {
    fn name(&self) -> &'static str {
        "increKM"
    }

    fn repartition(&self, ctx: &EpochCtx) -> Result<Partition> {
        let g = ctx.graph;
        let k = ctx.k();
        ensure!(g.has_coords(), "increKM requires vertex coordinates");
        ensure!(ctx.prev.k == k, "prev partition k={} vs targets {}", ctx.prev.k, k);
        ensure!(ctx.prev.n() == g.n(), "prev partition size != graph size");
        if k == 1 {
            return Ok(Partition::trivial(g.n()));
        }
        // Previous blocks' centroids under the *current* weights.
        let dim = g.coords[0].dim;
        let mut sums = vec![Point::zero(dim); k];
        let mut wsum = vec![0.0f64; k];
        for u in 0..g.n() {
            let b = ctx.prev.assignment[u] as usize;
            let w = g.vertex_weight(u);
            sums[b] = sums[b].add(&g.coords[u].scale(w));
            wsum[b] += w;
        }
        let centers: Vec<Point> = (0..k)
            .map(|i| {
                if wsum[i] > 0.0 {
                    sums[i].scale(1.0 / wsum[i])
                } else {
                    // Empty previous block: park its center on a vertex so
                    // it can win territory again.
                    g.coords[i % g.n()]
                }
            })
            .collect();
        // The extracted core is bit-identical for any worker count, so
        // use the same parallel assignment step GeoKMeans does.
        let assignment = lloyd_from_centers(
            g,
            centers,
            ctx.targets,
            ctx.epsilon,
            self.max_iters,
            self.gamma,
            crate::coordinator::jobqueue::default_workers(),
        );
        Ok(Partition::new(assignment, k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::refine::front_weights;
    use crate::gen::refined_mesh_2d;
    use crate::partition::{metrics, migration};
    use crate::partitioners::{by_name, Ctx};
    use crate::topology::Topology;

    #[test]
    fn warm_start_tracks_the_front_with_less_migration_than_fresh_labels() {
        let mut g0 = refined_mesh_2d(1500, 13);
        let mut g1 = g0.clone();
        g0.vwgt = front_weights(&g0.coords, 0.2, 6.0, 0.12);
        g1.vwgt = front_weights(&g1.coords, 0.5, 6.0, 0.12);
        let k = 6;
        let topo = Topology::homogeneous(k, 1.0, 1e9);
        let t0: Vec<f64> = vec![g0.total_vertex_weight() / k as f64; k];
        let prev = by_name("geoKM")
            .unwrap()
            .partition(&Ctx { graph: &g0, targets: &t0, topo: &topo, epsilon: 0.03, seed: 1 })
            .unwrap();
        let t1: Vec<f64> = vec![g1.total_vertex_weight() / k as f64; k];
        let ectx = EpochCtx {
            graph: &g1,
            prev: &prev,
            targets: &t1,
            topo: &topo,
            epsilon: 0.03,
            seed: 1,
            scratch: None,
        };
        let ours = IncrementalGeoKM::default().repartition(&ectx).unwrap();
        ours.validate(&g1).unwrap();
        // Meets the ε bound (the shared strict rebalance guarantees it).
        let m = metrics(&g1, &ours, &t1);
        assert!(m.imbalance <= 0.031, "imbalance {}", m.imbalance);
        // Determinism.
        let again = IncrementalGeoKM::default().repartition(&ectx).unwrap();
        assert_eq!(ours.assignment, again.assignment);
        // Migration is recorded sanely.
        let mig = migration(&g1, &prev, &ours);
        assert!(mig.frac_weight() < 0.9, "warm start moved almost everything");
    }
}
