//! Incremental balanced k-means: warm-start `geoKM` from the previous
//! epoch's block centers.
//!
//! A from-scratch `geoKM` re-seeds along the Hilbert curve, so its block
//! *labels* bear no relation to the previous epoch and migration is
//! dominated by label churn. Warm-starting the influence-k-means core
//! ([`lloyd_from_centers`]) from the previous blocks' weighted centroids
//! keeps label ↔ region identity by construction: clusters track the
//! load front instead of being reinvented, and only the vertices the
//! front actually pushed across a cluster boundary migrate.

use super::{EpochCtx, Repartitioner};
use crate::geometry::Point;
use crate::graph::Csr;
use crate::partition::Partition;
use crate::partitioners::geokm::lloyd_from_centers;
use anyhow::{ensure, Result};

/// Incremental geoKM: warm-start balanced k-means from the previous
/// epoch's centroids, so labels keep their region identity.
pub struct IncrementalGeoKM {
    /// Lloyd rounds per epoch (fewer than scratch geoKM's 40 — the warm
    /// start is already close).
    pub max_iters: usize,
    /// Influence exponent γ (as `GeoKMeans`).
    pub gamma: f64,
}

impl Default for IncrementalGeoKM {
    fn default() -> Self {
        IncrementalGeoKM { max_iters: 12, gamma: 0.6 }
    }
}

impl Repartitioner for IncrementalGeoKM {
    fn name(&self) -> &'static str {
        "increKM"
    }

    fn repartition(&self, ctx: &EpochCtx) -> Result<Partition> {
        warm_start(
            ctx.graph,
            ctx.prev,
            ctx.targets,
            ctx.epsilon,
            self.max_iters,
            self.gamma,
            crate::coordinator::jobqueue::default_workers(),
        )
    }
}

/// Previous blocks' weighted centroids under the *current* weights.
///
/// An empty previous block has no centroid; it is re-seeded
/// deterministically on the vertex farthest (squared Euclidean) from all
/// surviving centers and earlier re-seeds — a farthest-point sweep in
/// block-id order, ties broken toward the lower vertex id. Re-seeded
/// centers are therefore pairwise distinct whenever the graph has enough
/// distinct coordinates, so Lloyd assignment ties can never decide block
/// identity between two resurrected blocks (the old `coords[i % n]`
/// parking collided on duplicate points).
pub fn warm_start_centers(g: &Csr, prev: &Partition, k: usize) -> Vec<Point> {
    let dim = g.coords[0].dim;
    let mut sums = vec![Point::zero(dim); k];
    let mut wsum = vec![0.0f64; k];
    for u in 0..g.n() {
        let b = prev.assignment[u] as usize;
        let w = g.vertex_weight(u);
        sums[b] = sums[b].add(&g.coords[u].scale(w));
        wsum[b] += w;
    }
    let mut centers: Vec<Option<Point>> = (0..k)
        .map(|i| (wsum[i] > 0.0).then(|| sums[i].scale(1.0 / wsum[i])))
        .collect();
    for b in 0..k {
        if centers[b].is_some() {
            continue;
        }
        let placed: Vec<Point> = centers.iter().flatten().copied().collect();
        let mut best = (f64::NEG_INFINITY, 0usize);
        for u in 0..g.n() {
            let d = placed
                .iter()
                .map(|c| c.dist2(&g.coords[u]))
                .fold(f64::INFINITY, f64::min);
            if d > best.0 {
                best = (d, u);
            }
        }
        centers[b] = Some(g.coords[best.1]);
    }
    centers.into_iter().map(|c| c.expect("all centers placed")).collect()
}

/// Warm-start balanced k-means from a previous partition: the seam shared
/// by the per-trace [`IncrementalGeoKM`] and the serve-layer cache
/// (`coordinator::serve`), so a repeat tenant with drifted weights
/// warm-starts from its cached blocks instead of re-seeding from scratch.
/// Deterministic for a given `(graph, prev)` pair at any worker count.
pub fn warm_start(
    g: &Csr,
    prev: &Partition,
    targets: &[f64],
    epsilon: f64,
    max_iters: usize,
    gamma: f64,
    workers: usize,
) -> Result<Partition> {
    let k = targets.len();
    ensure!(g.has_coords(), "increKM requires vertex coordinates");
    ensure!(prev.k == k, "prev partition k={} vs targets {}", prev.k, k);
    ensure!(prev.n() == g.n(), "prev partition size != graph size");
    if k == 1 {
        return Ok(Partition::trivial(g.n()));
    }
    let centers = warm_start_centers(g, prev, k);
    // The extracted core is bit-identical for any worker count, so use
    // the same parallel assignment step GeoKMeans does.
    let assignment = lloyd_from_centers(g, centers, targets, epsilon, max_iters, gamma, workers);
    Ok(Partition::new(assignment, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::refine::front_weights;
    use crate::gen::refined_mesh_2d;
    use crate::partition::{metrics, migration};
    use crate::partitioners::{by_name, Ctx};
    use crate::topology::Topology;

    #[test]
    fn warm_start_tracks_the_front_with_less_migration_than_fresh_labels() {
        let mut g0 = refined_mesh_2d(1500, 13);
        let mut g1 = g0.clone();
        g0.vwgt = front_weights(&g0.coords, 0.2, 6.0, 0.12);
        g1.vwgt = front_weights(&g1.coords, 0.5, 6.0, 0.12);
        let k = 6;
        let topo = Topology::homogeneous(k, 1.0, 1e9);
        let t0: Vec<f64> = vec![g0.total_vertex_weight() / k as f64; k];
        let prev = by_name("geoKM")
            .unwrap()
            .partition(&Ctx { graph: &g0, targets: &t0, topo: &topo, epsilon: 0.03, seed: 1 })
            .unwrap();
        let t1: Vec<f64> = vec![g1.total_vertex_weight() / k as f64; k];
        let ectx = EpochCtx {
            graph: &g1,
            prev: &prev,
            targets: &t1,
            topo: &topo,
            epsilon: 0.03,
            seed: 1,
            scratch: None,
        };
        let ours = IncrementalGeoKM::default().repartition(&ectx).unwrap();
        ours.validate(&g1).unwrap();
        // Meets the ε bound (the shared strict rebalance guarantees it).
        let m = metrics(&g1, &ours, &t1);
        assert!(m.imbalance <= 0.031, "imbalance {}", m.imbalance);
        // Determinism.
        let again = IncrementalGeoKM::default().repartition(&ectx).unwrap();
        assert_eq!(ours.assignment, again.assignment);
        // Migration is recorded sanely.
        let mig = migration(&g1, &prev, &ours);
        assert!(mig.frac_weight() < 0.9, "warm start moved almost everything");
    }

    #[test]
    fn empty_blocks_reseed_on_distinct_vertices() {
        // Regression: the old code parked an empty block i's center on
        // g.coords[i % n], so two empty blocks whose parking vertices
        // share coordinates collided on the same point and Lloyd ties
        // then decided block identity. Build exactly that instance: a
        // graph whose vertices 2 and 3 are coincident, with blocks 2 and
        // 3 both emptied in the previous partition.
        let mut g = refined_mesh_2d(600, 5);
        g.coords[3] = g.coords[2];
        let k = 4;
        // Previous partition uses blocks 0 and 1 only (split by vertex
        // index); blocks 2 and 3 are empty.
        let assignment: Vec<u32> =
            (0..g.n()).map(|u| if u < g.n() / 2 { 0 } else { 1 }).collect();
        let prev = crate::partition::Partition::new(assignment, k);
        let centers = warm_start_centers(&g, &prev, k);
        assert_eq!(centers.len(), k);
        for i in 0..k {
            for j in (i + 1)..k {
                assert!(
                    centers[i].dist2(&centers[j]) > 0.0,
                    "centers {i} and {j} collided at {:?}",
                    centers[i]
                );
            }
        }
        // The full warm start stays valid and deterministic on this
        // instance (both resurrected blocks compete from distinct seeds).
        let targets: Vec<f64> = vec![g.total_vertex_weight() / k as f64; k];
        let p1 = warm_start(&g, &prev, &targets, 0.05, 12, 0.6, 2).unwrap();
        p1.validate(&g).unwrap();
        let p2 = warm_start(&g, &prev, &targets, 0.05, 12, 0.6, 4).unwrap();
        assert_eq!(p1.assignment, p2.assignment, "worker count changed the result");
    }

    #[test]
    fn warm_start_seam_matches_the_repartitioner() {
        // The lifted seam must produce exactly what IncrementalGeoKM
        // produces through EpochCtx — the serve cache layer relies on it.
        let mut g = refined_mesh_2d(900, 3);
        g.vwgt = front_weights(&g.coords, 0.3, 6.0, 0.12);
        let k = 5;
        let topo = Topology::homogeneous(k, 1.0, 1e9);
        let targets: Vec<f64> = vec![g.total_vertex_weight() / k as f64; k];
        let prev = by_name("geoKM")
            .unwrap()
            .partition(&Ctx { graph: &g, targets: &targets, topo: &topo, epsilon: 0.03, seed: 2 })
            .unwrap();
        let mut g2 = g.clone();
        g2.vwgt = front_weights(&g2.coords, 0.6, 6.0, 0.12);
        let ectx = EpochCtx {
            graph: &g2,
            prev: &prev,
            targets: &targets,
            topo: &topo,
            epsilon: 0.03,
            seed: 2,
            scratch: None,
        };
        let rp = IncrementalGeoKM::default();
        let via_trait = rp.repartition(&ectx).unwrap();
        let via_seam = warm_start(
            &g2,
            &prev,
            &targets,
            0.03,
            rp.max_iters,
            rp.gamma,
            crate::coordinator::jobqueue::default_workers(),
        )
        .unwrap();
        assert_eq!(via_trait.assignment, via_seam.assignment);
    }
}
