//! Diffusive rebalancing on the quotient graph.
//!
//! Keeps the previous epoch's partition and repairs it in place: boundary
//! vertices flow from overloaded blocks toward underloaded quotient
//! neighbors, respecting the heterogeneous capacities `(1+ε)·tw(b_i)`.
//! Each move is chosen by cut gain (external arcs to the receiver minus
//! internal arcs), so the repaired partition stays locally compact. When
//! no admissible boundary move remains but some block is still over its
//! capacity (a load spike far from any underloaded neighbor), a bounded
//! fallback pass teleports the lightest surplus vertices directly — that
//! guarantees the ε bound whenever it is satisfiable, which is what
//! bounds the LDHT objective at `(1+ε)·`optimum regardless of how far
//! the load moved.
//!
//! Migration is inherently small: only the surplus weight (plus the
//! little the gain heuristic shuffles along the way) ever moves, in
//! contrast to a from-scratch repartition that relabels freely.

use super::{EpochCtx, Repartitioner};
use crate::partition::Partition;
use anyhow::{ensure, Result};

/// Diffusive repartitioner: boundary vertices flow on the quotient
/// graph from overloaded toward underloaded blocks under the
/// heterogeneous `(1+ε)·tw` caps.
pub struct Diffusion {
    /// Maximum diffusion rounds before the fallback pass.
    pub max_rounds: usize,
}

impl Default for Diffusion {
    fn default() -> Self {
        Diffusion { max_rounds: 48 }
    }
}

impl Repartitioner for Diffusion {
    fn name(&self) -> &'static str {
        "diffusion"
    }

    fn repartition(&self, ctx: &EpochCtx) -> Result<Partition> {
        let g = ctx.graph;
        let k = ctx.k();
        ensure!(ctx.prev.k == k, "prev partition k={} vs targets {}", ctx.prev.k, k);
        ensure!(ctx.prev.n() == g.n(), "prev partition size != graph size");
        let mut assignment = ctx.prev.assignment.clone();
        let caps: Vec<f64> = ctx.targets.iter().map(|t| t * (1.0 + ctx.epsilon)).collect();
        let mut weights = vec![0.0f64; k];
        for u in 0..g.n() {
            weights[assignment[u] as usize] += g.vertex_weight(u);
        }

        for _round in 0..self.max_rounds {
            if !(0..k).any(|i| weights[i] > caps[i]) {
                break;
            }
            let mut moved = false;
            // One sweep: every vertex of an overloaded block may hop to
            // the best admissible neighbor block. Sequential in vertex
            // order with in-flight weight updates — deterministic.
            for u in 0..g.n() {
                let b = assignment[u] as usize;
                if weights[b] <= caps[b] {
                    continue;
                }
                let wu = g.vertex_weight(u);
                let load_b = weights[b] / ctx.targets[b].max(1e-300);
                // Arc weight from u into each candidate block.
                let mut to_b = 0.0f64;
                let mut cands: Vec<(u32, f64)> = Vec::new(); // (block, arc weight)
                for e in g.arc_range(u) {
                    let bv = assignment[g.adjncy[e] as usize];
                    if bv as usize == b {
                        to_b += g.arc_weight(e);
                    } else {
                        match cands.iter_mut().find(|(j, _)| *j == bv) {
                            Some((_, w)) => *w += g.arc_weight(e),
                            None => cands.push((bv, g.arc_weight(e))),
                        }
                    }
                }
                // Best admissible receiver: fits under cap, strictly less
                // loaded than the sender, max cut gain (ties: lower id).
                let mut best: Option<(f64, u32)> = None;
                for &(j, wj) in &cands {
                    let ju = j as usize;
                    if weights[ju] + wu > caps[ju] {
                        continue;
                    }
                    let load_j = weights[ju] / ctx.targets[ju].max(1e-300);
                    if load_j >= load_b {
                        continue;
                    }
                    let gain = wj - to_b;
                    let better = match best {
                        None => true,
                        Some((bg, bj)) => gain > bg + 1e-12 || ((gain - bg).abs() <= 1e-12 && j < bj),
                    };
                    if better {
                        best = Some((gain, j));
                    }
                }
                if let Some((_, j)) = best {
                    assignment[u] = j;
                    weights[b] -= wu;
                    weights[j as usize] += wu;
                    moved = true;
                }
            }
            if !moved {
                break;
            }
        }

        // Fallback: teleport the lightest surplus vertices of any block
        // still over its capacity into the most underloaded block that
        // fits — guarantees the ε bound when it is satisfiable at all.
        for b in 0..k {
            if weights[b] <= caps[b] {
                continue;
            }
            let mut mine: Vec<u32> = (0..g.n() as u32)
                .filter(|&u| assignment[u as usize] == b as u32)
                .collect();
            mine.sort_by(|&x, &y| {
                g.vertex_weight(x as usize)
                    .partial_cmp(&g.vertex_weight(y as usize))
                    .unwrap()
                    .then(x.cmp(&y))
            });
            for &u in &mine {
                if weights[b] <= caps[b] {
                    break;
                }
                let wu = g.vertex_weight(u as usize);
                // Most headroom relative to target, must fit.
                let mut best: Option<(f64, usize)> = None;
                for j in 0..k {
                    if j == b || weights[j] + wu > caps[j] {
                        continue;
                    }
                    let load_j = weights[j] / ctx.targets[j].max(1e-300);
                    if best.map(|(bl, _)| load_j < bl).unwrap_or(true) {
                        best = Some((load_j, j));
                    }
                }
                let Some((_, j)) = best else { break };
                assignment[u as usize] = j as u32;
                weights[b] -= wu;
                weights[j] += wu;
            }
        }

        Ok(Partition::new(assignment, k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::refine::front_weights;
    use crate::gen::refined_mesh_2d;
    use crate::partition::{metrics, migration};
    use crate::partitioners::{by_name, Ctx};
    use crate::topology::Topology;

    /// A weighted epoch pair: partition under epoch-0 weights, then ask
    /// diffusion to repair under shifted weights.
    fn epoch_pair() -> (crate::graph::Csr, crate::graph::Csr, Partition, Vec<f64>) {
        let mut g0 = refined_mesh_2d(1500, 11);
        let mut g1 = g0.clone();
        g0.vwgt = front_weights(&g0.coords, 0.0, 6.0, 0.12);
        g1.vwgt = front_weights(&g1.coords, 0.6, 6.0, 0.12);
        let k = 6;
        let topo = Topology::homogeneous(k, 1.0, 1e9);
        let targets0: Vec<f64> = vec![g0.total_vertex_weight() / k as f64; k];
        let ctx = Ctx { graph: &g0, targets: &targets0, topo: &topo, epsilon: 0.03, seed: 1 };
        let prev = by_name("geoKM").unwrap().partition(&ctx).unwrap();
        let targets1: Vec<f64> = vec![g1.total_vertex_weight() / k as f64; k];
        (g0, g1, prev, targets1)
    }

    #[test]
    fn diffusion_restores_the_epsilon_bound() {
        let (_g0, g1, prev, targets) = epoch_pair();
        let topo = Topology::homogeneous(6, 1.0, 1e9);
        let ectx = EpochCtx {
            graph: &g1,
            prev: &prev,
            targets: &targets,
            topo: &topo,
            epsilon: 0.03,
            seed: 1,
            scratch: None,
        };
        // The stale partition violates the new targets...
        let before = metrics(&g1, &prev, &targets);
        assert!(before.imbalance > 0.03, "trace too tame: {}", before.imbalance);
        // ...and diffusion repairs it within ε.
        let next = Diffusion::default().repartition(&ectx).unwrap();
        next.validate(&g1).unwrap();
        let after = metrics(&g1, &next, &targets);
        assert!(
            after.imbalance <= 0.03 + 1e-9,
            "diffusion left imbalance {}",
            after.imbalance
        );
    }

    #[test]
    fn diffusion_moves_little_and_is_deterministic() {
        let (_g0, g1, prev, targets) = epoch_pair();
        let topo = Topology::homogeneous(6, 1.0, 1e9);
        let ectx = EpochCtx {
            graph: &g1,
            prev: &prev,
            targets: &targets,
            topo: &topo,
            epsilon: 0.03,
            seed: 1,
            scratch: None,
        };
        let a = Diffusion::default().repartition(&ectx).unwrap();
        let b = Diffusion::default().repartition(&ectx).unwrap();
        assert_eq!(a.assignment, b.assignment, "diffusion not deterministic");
        // Migration stays a modest fraction of the total weight (it only
        // moves surplus, not whole blocks).
        let m = migration(&g1, &prev, &a);
        assert!(
            m.frac_weight() < 0.5,
            "diffusion moved {}% of the weight",
            m.frac_weight() * 100.0
        );
        assert!(m.migrated_vertices > 0, "nothing moved at all");
    }

    #[test]
    fn already_balanced_input_is_untouched() {
        let (g0, _g1, prev, _t) = epoch_pair();
        // Same weights as the epoch the partition was built for: every
        // block is already within ε, so diffusion must be the identity.
        let k = 6;
        let targets: Vec<f64> = vec![g0.total_vertex_weight() / k as f64; k];
        let topo = Topology::homogeneous(k, 1.0, 1e9);
        let ectx = EpochCtx {
            graph: &g0,
            prev: &prev,
            targets: &targets,
            topo: &topo,
            epsilon: 0.03,
            seed: 1,
            scratch: None,
        };
        let next = Diffusion::default().repartition(&ectx).unwrap();
        assert_eq!(next.assignment, prev.assignment);
    }
}
