//! Plain-text / CSV / markdown table writer for benchmark reports.
//!
//! Every benchmark prints a human-readable aligned table to stdout and can
//! persist the same rows as CSV under `results/` so figures can be re-drawn
//! from the raw data.

use std::fmt::Write as _;
use std::path::Path;

/// A rectangular table of strings with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Column headers, in display order.
    pub header: Vec<String>,
    /// Table rows (each as long as `header`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header length).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .zip(w)
                .map(|(c, &w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &w));
        let _ = writeln!(
            out,
            "{}",
            w.iter().map(|&w| "-".repeat(w)).collect::<Vec<_>>().join("  ")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &w));
        }
        out
    }

    /// CSV rendering (naive quoting: fields containing commas are quoted).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(esc).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// GitHub-flavored markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    /// Write CSV to `results/<name>.csv` (creating the directory).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(vec!["algo", "cut", "time"]);
        t.row(vec!["geoKM", "43428", "1.96"]);
        t.row(vec!["zSFC", "96465", "0.04"]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("algo"));
        assert!(lines[2].contains("geoKM"));
    }

    #[test]
    fn csv_format() {
        let csv = sample().to_csv();
        assert!(csv.starts_with("algo,cut,time\n"));
        assert!(csv.contains("zSFC,96465,0.04"));
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x,y"]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn markdown_format() {
        let md = sample().to_markdown();
        assert!(md.starts_with("| algo | cut | time |"));
        assert!(md.contains("|---|---|---|"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
