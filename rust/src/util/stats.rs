//! Descriptive statistics used by the benchmark harness and report tables.
//!
//! The paper aggregates per-graph values with the *geometric mean* relative
//! to the balanced-k-means baseline (Figs. 2–4); those helpers live here.

/// Arithmetic mean. Returns NaN on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean. All inputs must be > 0; returns NaN on empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies and sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// p-th percentile (0..=100), linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Min and max of a non-empty slice.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn geomean_known() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 2.0, 2.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_le_mean() {
        // AM-GM inequality on random-ish positive values.
        let xs = [0.5, 1.5, 2.5, 7.0, 0.1];
        assert!(geomean(&xs) <= mean(&xs));
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn min_max_works() {
        assert_eq!(min_max(&[3.0, -1.0, 7.0]), (-1.0, 7.0));
    }

    #[test]
    fn empty_inputs_nan() {
        assert!(mean(&[]).is_nan());
        assert!(median(&[]).is_nan());
        assert!(geomean(&[]).is_nan());
    }
}
