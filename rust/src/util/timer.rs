//! Timing helpers for benchmarks and coarse profiling.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a wall-clock timer.
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed time since [`Timer::start`].
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Restart and return the previous elapsed seconds.
    pub fn lap(&mut self) -> f64 {
        let e = self.secs();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Accumulating named phase timer for coarse profiling of multi-phase
/// algorithms (e.g. coarsen / initial / refine in the multilevel code).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, f64)>,
}

impl PhaseTimer {
    /// Fresh phase timer with no recorded phases.
    pub fn new() -> PhaseTimer {
        PhaseTimer::default()
    }

    /// Add `secs` to the named phase (creating it if new).
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(p) = self.phases.iter_mut().find(|(n, _)| n == name) {
            p.1 += secs;
        } else {
            self.phases.push((name.to_string(), secs));
        }
    }

    /// Run and time a closure under the named phase.
    pub fn run<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (r, s) = timed(f);
        self.add(name, s);
        r
    }

    /// Total seconds over all recorded phases.
    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    /// Recorded `(name, seconds)` phases, in order.
    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }

    /// One-line summary, e.g. `coarsen=1.23s refine=0.45s (total 1.68s)`.
    pub fn summary(&self) -> String {
        let body: Vec<String> = self
            .phases
            .iter()
            .map(|(n, s)| format!("{n}={s:.3}s"))
            .collect();
        format!("{} (total {:.3}s)", body.join(" "), self.total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn timed_returns_result() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("a", 1.0);
        pt.add("b", 2.0);
        pt.add("a", 0.5);
        assert_eq!(pt.phases().len(), 2);
        assert!((pt.total() - 3.5).abs() < 1e-12);
        assert!(pt.summary().contains("a=1.500s"));
    }

    #[test]
    fn phase_timer_run() {
        let mut pt = PhaseTimer::new();
        let v = pt.run("work", || 7);
        assert_eq!(v, 7);
        assert_eq!(pt.phases().len(), 1);
    }
}
