//! Minimal command-line argument parser (offline replacement for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments. Typed getters parse on demand and report readable errors.

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order of appearance.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options (last occurrence wins).
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is the bare flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Get a typed option value, or `default` if absent. Panics with a
    /// readable message on parse failure (CLI surface, not library code).
    pub fn get<T: FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => default,
            Some(s) => match s.parse() {
                Ok(v) => v,
                Err(e) => panic!("--{name} {s}: {e}"),
            },
        }
    }

    /// Get an optional typed option value.
    pub fn opt<T: FromStr>(&self, name: &str) -> Option<T>
    where
        T::Err: std::fmt::Display,
    {
        self.options.get(name).map(|s| match s.parse() {
            Ok(v) => v,
            Err(e) => panic!("--{name} {s}: {e}"),
        })
    }

    /// Get a comma-separated list option, e.g. `--ks 24,48,96`.
    pub fn list<T: FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| match p.trim().parse() {
                    Ok(v) => v,
                    Err(e) => panic!("--{name} element {p}: {e}"),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["partition", "--k", "96", "--algo=geokm", "--verbose"]);
        assert_eq!(a.positional, vec!["partition"]);
        assert_eq!(a.get::<usize>("k", 4), 96);
        assert_eq!(a.options.get("algo").unwrap(), "geokm");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get::<usize>("k", 4), 4);
        assert_eq!(a.get::<f64>("eps", 0.03), 0.03);
        assert!(a.opt::<usize>("k").is_none());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--fast", "--k", "8"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get::<usize>("k", 0), 8);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--ks", "24,48, 96"]);
        assert_eq!(a.list::<usize>("ks", &[1]), vec![24, 48, 96]);
        assert_eq!(a.list::<usize>("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn last_occurrence_wins() {
        let a = parse(&["--k", "1", "--k", "2"]);
        assert_eq!(a.get::<usize>("k", 0), 2);
    }

    #[test]
    fn negative_number_value() {
        // A value starting with '-' but not '--' is consumed as a value.
        let a = parse(&["--shift", "-0.5"]);
        assert_eq!(a.get::<f64>("shift", 0.0), -0.5);
    }
}
