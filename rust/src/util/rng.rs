//! Deterministic pseudo-random number generation.
//!
//! The offline image has no `rand` crate, so we implement SplitMix64 (for
//! seeding) and xoshiro256** (the workhorse generator, Blackman & Vigna).
//! Both are tiny, fast, and good enough for graph generation, k-means
//! seeding, and property testing. All experiments take explicit seeds so
//! every benchmark row is reproducible.

/// SplitMix64 step: used to expand a single `u64` seed into a full
/// xoshiro256** state, and as a standalone mixing function.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // use 128-bit multiply for unbiased-enough mapping.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform u64 in [lo, hi] inclusive.
    #[inline]
    pub fn u64_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        lo + ((self.next_u64() as u128 * (span as u128 + 1)) >> 64) as u64
    }

    /// true with probability p.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (m <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        // Partial Fisher–Yates on an index array; fine for our sizes.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.usize(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }
}
