//! Minimal JSON reader/writer (offline replacement for `serde_json`).
//!
//! The harness persists scenario results and golden baselines as JSON;
//! this module provides the small value model both sides share. Objects
//! preserve insertion order so written files diff cleanly. Non-finite
//! numbers serialize as `null` (JSON has no inf/NaN).

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (all numbers are f64, as in JavaScript).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (ordered key/value pairs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document (the whole string must be one value).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value pairs, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(kv) => {
                if kv.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{txt}' at byte {start}: {e}"))
    }

    /// Parse the 4 hex digits of a `\uXXXX` escape; `self.i` must point
    /// at the `u` and ends on the last hex digit.
    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 >= self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
            .map_err(|_| format!("non-hex bytes in \\u escape at byte {}", self.i))?;
        let code = u32::from_str_radix(hex, 16)
            .map_err(|e| format!("bad \\u escape '{hex}': {e}"))?;
        self.i += 4;
        Ok(code)
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let code = self.hex4()?;
                            let scalar = if (0xD800..=0xDBFF).contains(&code) {
                                // High surrogate: must pair with a \uDC00-
                                // \uDFFF escape immediately following.
                                if self.b.get(self.i + 1) != Some(&b'\\')
                                    || self.b.get(self.i + 2) != Some(&b'u')
                                {
                                    return Err(format!(
                                        "lone high surrogate \\u{code:04x} at byte {}",
                                        self.i
                                    ));
                                }
                                self.i += 2;
                                let low = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&low) {
                                    return Err(format!(
                                        "\\u{code:04x} not followed by a low surrogate"
                                    ));
                                }
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                            } else {
                                code
                            };
                            out.push(char::from_u32(scalar).ok_or_else(|| {
                                format!("\\u escape U+{scalar:04X} is not a scalar value")
                            })?);
                        }
                        other => {
                            return Err(format!(
                                "bad escape {:?} at byte {}",
                                other.map(|b| b as char),
                                self.i
                            ))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| format!("invalid utf-8 in string: {e}"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((key, v));
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (txt, want) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.5e2", Json::Num(-350.0)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(Json::parse(txt).unwrap(), want, "{txt}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = obj(vec![
            ("name", Json::Str("smoke".into())),
            ("bootstrap", Json::Bool(false)),
            ("runs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
            (
                "tol",
                obj(vec![("cut", Json::Num(0.02)), ("vol", Json::Num(0.05))]),
            ),
        ]);
        let txt = v.render();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back, v);
        // Order preserved.
        let keys: Vec<&str> = back.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["name", "bootstrap", "runs", "tol"]);
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te".into());
        let txt = v.render();
        assert_eq!(Json::parse(txt.trim()).unwrap(), v);
        let parsed = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(parsed.as_str().unwrap(), "Aé");
    }

    #[test]
    fn surrogate_pairs() {
        // 😀 is U+1F600 = \ud83d\ude00.
        let parsed = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(parsed.as_str().unwrap(), "\u{1F600}");
        // Lone or malformed surrogates are errors, not replacement chars.
        assert!(Json::parse("\"\\ud83d\"").is_err());
        assert!(Json::parse("\"\\ud83dx\"").is_err());
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err());
        assert!(Json::parse("\"\\ude00\"").is_err());
    }

    #[test]
    fn getters() {
        let v = Json::parse(r#"{"a": 1, "b": {"c": [true, "x"]}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        let arr = v.get("b").unwrap().get("c").unwrap().as_arr().unwrap();
        assert!(arr[0].as_bool().unwrap());
        assert_eq!(arr[1].as_str().unwrap(), "x");
        assert!(v.get("missing").is_none());
        assert!(Json::Null.get("a").is_none());
    }

    #[test]
    fn non_finite_serializes_null() {
        assert_eq!(Json::Num(f64::INFINITY).render().trim(), "null");
        assert_eq!(Json::Num(f64::NAN).render().trim(), "null");
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        let v = Json::parse(" {\n \"k\" : [ 1 , 2 ] \n} ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }
}
