//! Small self-contained utilities.
//!
//! This offline build has no access to `rand`, `clap`, `criterion`, or
//! `serde`, so the equivalents live here: a counter-based PRNG
//! ([`rng::Rng`]), a CLI argument parser ([`cli::Args`]), timing helpers
//! ([`timer`]), descriptive statistics ([`stats`]), a plain-text table
//! writer ([`table`]), and a minimal JSON reader/writer ([`json`]).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

/// Format a float compactly: integers without decimals, small values with
/// enough precision to be useful in report tables.
pub fn fmt_f64(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let a = v.abs();
    if a >= 1000.0 || (v.fract() == 0.0 && a < 1e15) {
        format!("{:.0}", v)
    } else if a >= 10.0 {
        format!("{:.2}", v)
    } else if a >= 0.01 || a == 0.0 {
        format!("{:.3}", v)
    } else {
        format!("{:.2e}", v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_f64_ranges() {
        assert_eq!(fmt_f64(12345.6), "12346");
        assert_eq!(fmt_f64(42.0), "42");
        assert_eq!(fmt_f64(12.345), "12.35");
        assert_eq!(fmt_f64(0.5), "0.500");
        assert_eq!(fmt_f64(0.0001), "1.00e-4");
        assert_eq!(fmt_f64(0.0), "0"); // integral branch wins

    }
}
