//! Algorithm 1: calculate target block sizes for the LDHT problem.

use crate::topology::Topology;
use anyhow::{bail, Result};

/// Result of Algorithm 1.
#[derive(Debug, Clone)]
pub struct BlockSizes {
    /// Target weight per PU, in the original PU order (`tw(b_i)`).
    pub tw: Vec<f64>,
    /// Which PUs ended saturated (assigned their full memory capacity).
    pub saturated: Vec<bool>,
    /// The achieved objective `max_i tw(b_i)/c_s(p_i)`.
    pub max_ratio: f64,
}

impl BlockSizes {
    /// Total assigned load (= n when feasible).
    pub fn total(&self) -> f64 {
        self.tw.iter().sum()
    }

    /// tw(fast)/tw(slow) style ratio between two PU indices (Table III's
    /// last column).
    pub fn ratio(&self, fast: usize, slow: usize) -> f64 {
        self.tw[fast] / self.tw[slow]
    }
}

/// Feasibility: the load must fit in total memory, and (for a meaningful
/// LDHT instance) at least one PU must end non-saturated.
pub fn check_feasible(n: f64, topo: &Topology) -> Result<()> {
    if n <= 0.0 {
        bail!("load must be positive, got {n}");
    }
    let mcap = topo.total_memory();
    if n > mcap {
        bail!("infeasible: load {n} exceeds total memory {mcap}");
    }
    if topo.pus.iter().any(|p| p.speed <= 0.0 || p.memory <= 0.0) {
        bail!("PU speeds and memories must be positive");
    }
    Ok(())
}

/// **Algorithm 1** (paper §IV). Computes the optimal `tw(b_i)` for load
/// `n` on `topo`, in `O(k log k)`.
///
/// PUs are visited by decreasing `c_s/m_cap`; each receives either its
/// proportional share of the *remaining* load or its full memory,
/// whichever is smaller. The result minimizes
/// `max_i tw(b_i)/c_s(p_i)` subject to `tw(b_i) ≤ m_cap(p_i)` —
/// provably optimal (paper Theorem 1, re-proved by this crate's
/// property tests). Errors when the instance is infeasible
/// ([`check_feasible`]): non-positive load/speeds/memories, or a load
/// exceeding total memory.
pub fn block_sizes(n: f64, topo: &Topology) -> Result<BlockSizes> {
    check_feasible(n, topo)?;
    let k = topo.k();
    // Line 1: sort PUs by decreasing c_s/m_cap.
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| {
        let ra = topo.pus[a].speed / topo.pus[a].memory;
        let rb = topo.pus[b].speed / topo.pus[b].memory;
        rb.partial_cmp(&ra).unwrap()
    });
    // Lines 2–3: joint load and joint speed.
    let mut j_load = n;
    let mut j_speed = topo.total_speed();
    let mut tw = vec![0.0; k];
    let mut saturated = vec![false; k];
    // Lines 4–12: greedy assignment in sorted order.
    for &i in &order {
        let pu = &topo.pus[i];
        let des_w = pu.speed * j_load / j_speed; // Line 5
        if des_w > pu.memory {
            tw[i] = pu.memory; // Line 7: saturated
            saturated[i] = true;
        } else {
            tw[i] = des_w; // Line 10: non-saturated
        }
        j_load -= tw[i]; // Line 11
        j_speed -= pu.speed; // Line 12
    }
    let max_ratio = (0..k)
        .map(|i| tw[i] / topo.pus[i].speed)
        .fold(0.0, f64::max);
    Ok(BlockSizes { tw, saturated, max_ratio })
}

/// Algorithm 1 applied to PU *subsets* (for hierarchical partitioning):
/// each subset is treated as one aggregate PU (speed/memory summed, the
/// paper's recursive inner-node accumulation), and the returned targets
/// are per subset.
pub fn block_sizes_for_subsets(
    n: f64,
    topo: &Topology,
    subsets: &[Vec<usize>],
) -> Result<Vec<f64>> {
    use crate::topology::Pu;
    let agg: Vec<Pu> = subsets
        .iter()
        .map(|s| {
            s.iter().fold(Pu { speed: 0.0, memory: 0.0 }, |acc, &i| Pu {
                speed: acc.speed + topo.pus[i].speed,
                memory: acc.memory + topo.pus[i].memory,
            })
        })
        .collect();
    let agg_topo = Topology::flat(agg, "subsets");
    Ok(block_sizes(n, &agg_topo)?.tw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, gens, Gen};
    use crate::topology::{topo1, topo2, Pu, Topo1Spec, Topo2Spec, Topology, TABLE3_STEPS};
    use crate::util::rng::Rng;

    fn topo_from(pus: Vec<Pu>) -> Topology {
        Topology::flat(pus, "test")
    }

    #[test]
    fn homogeneous_is_uniform() {
        let t = Topology::homogeneous(4, 1.0, 100.0);
        let bs = block_sizes(100.0, &t).unwrap();
        for &w in &bs.tw {
            assert!((w - 25.0).abs() < 1e-9);
        }
        assert!(bs.saturated.iter().all(|&s| !s));
        assert!((bs.total() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn unconstrained_is_speed_proportional() {
        // Eq. (4): tw*(b_i) = n * c_s(p_i) / C_s when memory is ample.
        let t = topo_from(vec![
            Pu { speed: 3.0, memory: 1e9 },
            Pu { speed: 1.0, memory: 1e9 },
        ]);
        let bs = block_sizes(100.0, &t).unwrap();
        assert!((bs.tw[0] - 75.0).abs() < 1e-9);
        assert!((bs.tw[1] - 25.0).abs() < 1e-9);
    }

    #[test]
    fn saturation_spills_to_others() {
        // Fast PU would want 75 but only has memory 50; the rest goes to
        // the slow PU.
        let t = topo_from(vec![
            Pu { speed: 3.0, memory: 50.0 },
            Pu { speed: 1.0, memory: 1e9 },
        ]);
        let bs = block_sizes(100.0, &t).unwrap();
        assert_eq!(bs.tw[0], 50.0);
        assert!(bs.saturated[0]);
        assert!((bs.tw[1] - 50.0).abs() < 1e-9);
        assert!(!bs.saturated[1]);
        assert!((bs.total() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn exact_fit_boundary() {
        // desW == m_cap exactly → non-saturated branch (not >).
        let t = topo_from(vec![
            Pu { speed: 1.0, memory: 50.0 },
            Pu { speed: 1.0, memory: 50.0 },
        ]);
        let bs = block_sizes(100.0, &t).unwrap();
        assert_eq!(bs.tw, vec![50.0, 50.0]);
        assert!(bs.saturated.iter().all(|&s| !s));
    }

    #[test]
    fn infeasible_rejected() {
        let t = topo_from(vec![Pu { speed: 1.0, memory: 10.0 }]);
        assert!(block_sizes(11.0, &t).is_err());
        assert!(block_sizes(-5.0, &t).is_err());
    }

    #[test]
    fn table3_ratios_reproduced() {
        // Reproduce Table III's last column: tw(fast)/tw(slow) for
        // |F| = k/12 and k/6 at k = 96. Paper values: 1–1, 2–2, 3.2–3.5,
        // 5.5–6.1, 9.4–11.5 (approximate). The paper's ratios are
        // consistent with the load filling ≈84% of total system memory
        // (back-solved from the step-5 row; all ten values then agree
        // within a few percent), so that is our calibration.
        let paper = [
            (1.0, 1.0),
            (2.0, 2.0),
            (3.2, 3.5),
            (5.5, 6.1),
            (9.4, 11.5),
        ];
        let k = 96;
        for (step, &(lo, hi)) in TABLE3_STEPS.iter().zip(paper.iter()) {
            let fast = Pu { speed: step.0, memory: step.1 };
            for (num_fast, expect) in [(k / 12, lo), (k / 6, hi)] {
                let t = topo1(Topo1Spec { k, num_fast, fast });
                let n = crate::blocksizes::TABLE3_FILL * t.total_memory();
                let bs = block_sizes(n, &t).unwrap();
                let ratio = bs.ratio(0, k - 1);
                assert!(
                    (ratio - expect).abs() / expect < 0.1,
                    "step {step:?} f{num_fast}: ratio {ratio:.2} vs paper {expect}"
                );
            }
        }
    }

    #[test]
    fn topo2_order_fast_s1_s2() {
        // In TOPO2, tw(F) ≥ tw(S1) ≥ tw(S2).
        let fast = Pu { speed: 16.0, memory: 13.8 };
        let t = topo2(Topo2Spec { k: 48, num_fast: 8, fast });
        let bs = block_sizes(48.0, &t).unwrap();
        assert!(bs.tw[0] >= bs.tw[8] - 1e-9);
        assert!(bs.tw[8] >= bs.tw[47] - 1e-9);
    }

    // ---------- property tests ----------

    /// Random feasible LDHT instance generator.
    struct InstanceGen;
    impl Gen for InstanceGen {
        type Value = (f64, Vec<(f64, f64)>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let k = 1 + rng.usize(12);
            let pus: Vec<(f64, f64)> = (0..k)
                .map(|_| {
                    (
                        0.1 + 10.0 * rng.f64(),
                        0.1 + 10.0 * rng.f64(),
                    )
                })
                .collect();
            let mcap: f64 = pus.iter().map(|p| p.1).sum();
            // Load at 5–95% of total memory to stay feasible.
            let n = mcap * (0.05 + 0.9 * rng.f64());
            (n, pus)
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let (n, pus) = v;
            let mut out = Vec::new();
            if pus.len() > 1 {
                out.push((n * 0.5, pus[..pus.len() / 2].to_vec()));
                out.push((n * 0.5, pus[1..].to_vec()));
            }
            out
        }
    }

    fn make(v: &(f64, Vec<(f64, f64)>)) -> (f64, Topology) {
        let pus = v.1.iter().map(|&(s, m)| Pu { speed: s, memory: m }).collect();
        (v.0, topo_from(pus))
    }

    #[test]
    fn prop_conservation_and_constraints() {
        check("alg1 conserves load & respects memory", 300, 0xA161, InstanceGen, |v| {
            let (n, t) = make(v);
            let bs = match block_sizes(n, &t) {
                Ok(b) => b,
                Err(_) => return Ok(()), // shrunk instance became infeasible
            };
            if (bs.total() - n).abs() > 1e-6 * n.max(1.0) {
                return Err(format!("total {} != n {}", bs.total(), n));
            }
            for (i, &w) in bs.tw.iter().enumerate() {
                if w > t.pus[i].memory + 1e-9 {
                    return Err(format!("tw[{i}]={w} > mcap={}", t.pus[i].memory));
                }
                if w < -1e-12 {
                    return Err(format!("negative tw[{i}]={w}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_lemma1_saturated_prefix() {
        // Lemma 1: in the sorted-by-c_s/m_cap order, all saturated PUs
        // precede all non-saturated ones.
        check("lemma 1: saturated prefix", 300, 0x1E44A, InstanceGen, |v| {
            let (n, t) = make(v);
            let bs = match block_sizes(n, &t) {
                Ok(b) => b,
                Err(_) => return Ok(()),
            };
            let mut order: Vec<usize> = (0..t.k()).collect();
            order.sort_by(|&a, &b| {
                let ra = t.pus[a].speed / t.pus[a].memory;
                let rb = t.pus[b].speed / t.pus[b].memory;
                rb.partial_cmp(&ra).unwrap()
            });
            let mut seen_nonsat = false;
            for &i in &order {
                if bs.saturated[i] && seen_nonsat {
                    return Err(format!("saturated PU {i} after non-saturated"));
                }
                if !bs.saturated[i] {
                    seen_nonsat = true;
                }
            }
            Ok(())
        });
    }

    /// Water-filling oracle: binary-search the optimal objective value
    /// r* = max tw_i/c_s_i; for a given r the max assignable load is
    /// Σ min(r·c_s_i, m_cap_i). The optimal r* is the smallest r with
    /// assignable(r) ≥ n. Independent of Algorithm 1's greedy order.
    fn oracle_max_ratio(n: f64, pus: &[(f64, f64)]) -> f64 {
        let assignable =
            |r: f64| -> f64 { pus.iter().map(|&(s, m)| (r * s).min(m)).sum() };
        let mut lo = 0.0;
        // Grow hi until assignable(hi) >= n (feasible instances converge
        // since assignable(r) -> M_cap >= n as r -> inf).
        let mut hi = 1.0;
        while assignable(hi) < n && hi < 1e18 {
            hi *= 2.0;
        }
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if assignable(mid) >= n {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }

    #[test]
    fn prop_theorem1_optimality() {
        // Theorem 1: Algorithm 1's max ratio equals the water-filling
        // optimum.
        check("theorem 1: optimal objective", 300, 0x7E03, InstanceGen, |v| {
            let (n, t) = make(v);
            let bs = match block_sizes(n, &t) {
                Ok(b) => b,
                Err(_) => return Ok(()),
            };
            let opt = oracle_max_ratio(n, &v.1);
            if (bs.max_ratio - opt).abs() > 1e-6 * opt.max(1e-9) {
                return Err(format!("greedy {} vs oracle {}", bs.max_ratio, opt));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_non_saturated_equal_ratio() {
        // All non-saturated PUs finish with equal tw/c_s (the proof's
        // proportionality invariant).
        check("non-saturated PUs share one ratio", 300, 0x50A7, InstanceGen, |v| {
            let (n, t) = make(v);
            let bs = match block_sizes(n, &t) {
                Ok(b) => b,
                Err(_) => return Ok(()),
            };
            let ratios: Vec<f64> = (0..t.k())
                .filter(|&i| !bs.saturated[i])
                .map(|i| bs.tw[i] / t.pus[i].speed)
                .collect();
            if let (Some(&first), true) = (ratios.first(), ratios.len() > 1) {
                for &r in &ratios {
                    if (r - first).abs() > 1e-6 * first.max(1e-9) {
                        return Err(format!("ratios differ: {ratios:?}"));
                    }
                }
            }
            Ok(())
        });
    }

    // ----- edge cases: feasibility boundary, degenerate topologies -----

    /// Exactly filling total memory is feasible (every PU saturated);
    /// one epsilon more is not.
    #[test]
    fn all_saturated_boundary_and_infeasibility() {
        let t = topo_from(vec![
            Pu { speed: 4.0, memory: 30.0 },
            Pu { speed: 1.0, memory: 10.0 },
        ]);
        // n == M_cap: every PU gets its full memory.
        let bs = block_sizes(40.0, &t).unwrap();
        assert_eq!(bs.tw, vec![30.0, 10.0]);
        assert!((bs.total() - 40.0).abs() < 1e-9);
        // The faster-per-memory PU is saturated; the last PU ends exactly
        // full through the non-saturated branch (desW == remaining == mem).
        assert!(bs.saturated[0]);
        // Past the boundary: infeasible.
        let err = block_sizes(40.0 + 1e-6, &t).unwrap_err().to_string();
        assert!(err.contains("infeasible"), "{err}");
    }

    /// Single PU: it takes the whole load (when it fits).
    #[test]
    fn single_pu_takes_everything() {
        let t = topo_from(vec![Pu { speed: 3.0, memory: 50.0 }]);
        let bs = block_sizes(20.0, &t).unwrap();
        assert_eq!(bs.tw, vec![20.0]);
        assert!(!bs.saturated[0]);
        assert!((bs.max_ratio - 20.0 / 3.0).abs() < 1e-12);
        assert!(block_sizes(50.1, &t).is_err());
    }

    /// Zero or negative speeds/memories are rejected up front — Algorithm
    /// 1 divides by both.
    #[test]
    fn zero_speed_or_memory_rejected() {
        let zero_speed = topo_from(vec![
            Pu { speed: 0.0, memory: 10.0 },
            Pu { speed: 1.0, memory: 10.0 },
        ]);
        let err = block_sizes(5.0, &zero_speed).unwrap_err().to_string();
        assert!(err.contains("positive"), "{err}");
        let zero_mem = topo_from(vec![Pu { speed: 1.0, memory: 0.0 }]);
        assert!(block_sizes(0.0, &zero_mem).is_err()); // load must be > 0 too
        let neg = topo_from(vec![Pu { speed: -1.0, memory: 10.0 }]);
        assert!(block_sizes(5.0, &neg).is_err());
    }

    /// The paper's 2-PU intuition behind Table III: one fast PU at step 5
    /// (speed 16, memory 13.8) next to one slow PU (speed 1, memory 2).
    /// At 95% memory fill the fast PU saturates at 13.8 and the slow PU
    /// absorbs the remainder, pinning tw(fast)/tw(slow) = 13.8/1.21
    /// ≈ 11.4 — the memory cap, not the 16× speed ratio, sets the split.
    #[test]
    fn two_pu_fast_slow_ratio_table3_example() {
        let t = topo_from(vec![
            Pu { speed: 16.0, memory: 13.8 },
            Pu { speed: 1.0, memory: 2.0 },
        ]);
        let n = 0.95 * t.total_memory(); // 15.01
        let bs = block_sizes(n, &t).unwrap();
        assert!(bs.saturated[0], "fast PU must saturate at 95% fill");
        assert!(!bs.saturated[1]);
        assert!((bs.tw[0] - 13.8).abs() < 1e-12);
        assert!((bs.tw[1] - (n - 13.8)).abs() < 1e-9);
        let ratio = bs.ratio(0, 1);
        assert!((ratio - 13.8 / (n - 13.8)).abs() < 1e-9);
        assert!((ratio - 11.4).abs() < 0.01, "ratio {ratio}");
        // Unconstrained contrast: with ample memory the split is the pure
        // 16× speed ratio (Eq. (4)).
        let ample = topo_from(vec![
            Pu { speed: 16.0, memory: 1e9 },
            Pu { speed: 1.0, memory: 1e9 },
        ]);
        let bs = block_sizes(n, &ample).unwrap();
        assert!((bs.ratio(0, 1) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn subsets_aggregate() {
        let t = topo_from(vec![
            Pu { speed: 2.0, memory: 100.0 },
            Pu { speed: 2.0, memory: 100.0 },
            Pu { speed: 4.0, memory: 100.0 },
        ]);
        let tws =
            block_sizes_for_subsets(80.0, &t, &[vec![0, 1], vec![2]]).unwrap();
        assert!((tws[0] - 40.0).abs() < 1e-9);
        assert!((tws[1] - 40.0).abs() < 1e-9);
    }

    #[test]
    fn alg1_is_fast_for_large_k() {
        // O(k log k): 100k PUs in well under a second.
        let mut rng = Rng::new(1);
        let pus: Vec<Pu> = (0..100_000)
            .map(|_| Pu { speed: 0.5 + rng.f64(), memory: 1.0 + rng.f64() })
            .collect();
        let t = topo_from(pus);
        let (_bs, secs) = crate::util::timer::timed(|| block_sizes(50_000.0, &t).unwrap());
        assert!(secs < 1.0, "took {secs}s");
    }

    #[test]
    fn prop_usage_in_docs_compiles() {
        // Exercise the doc-style gens API so it keeps compiling.
        check("vec gen sanity", 50, 1, gens::vec_usize(1..5, 0..10), |v| {
            if v.is_empty() {
                Err("empty".into())
            } else {
                Ok(())
            }
        });
    }
}
