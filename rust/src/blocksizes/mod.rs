//! Optimal target block sizes for the LDHT problem — **Algorithm 1** of
//! the paper (§IV).
//!
//! Given the application load `n = |V|` and a heterogeneous topology,
//! compute target weights `tw(b_i)` that minimize
//! `max_i tw(b_i)/c_s(p_i)` subject to `tw(b_i) ≤ m_cap(p_i)` —
//! provably optimal (paper Theorem 1) in `O(k log k)`:
//! sort PUs by decreasing `c_s/m_cap`, then greedily assign each PU
//! either its proportional share of the *remaining* load or its full
//! memory, whichever is smaller.

mod alg1;

/// Calibration for Table III: the paper's tw(fast)/tw(slow) ratios are
/// consistent with the application load filling ≈84% of total system
/// memory (back-solved from the step-5 row; all ten table values then
/// agree within a few percent).
pub const TABLE3_FILL: f64 = 0.84;

pub use alg1::{block_sizes, block_sizes_for_subsets, check_feasible, BlockSizes};
