//! Push-style PageRank over row strips.
//!
//! A fixed 20 damped power iterations (`d = 0.85`): each vertex pushes
//! `d·rank(u)/deg(u)` along every out-edge as a
//! `[dest_gid, src_gid, contribution]` record. Floating-point addition
//! is not associative, so the receiver does NOT fold records in arrival
//! order — it sorts every iteration's records by `(dest, src)` and folds
//! in that canonical order, which makes the scores bit-identical to a
//! sequential sweep that visits sources in ascending id order (the
//! checker exploits exactly this: it recomputes the reference and
//! demands `|Δrank|₁ = 0`). Dangling mass is deliberately **not**
//! redistributed: doing so would need a rank-order `Sum` allreduce whose
//! association varies with the rank count. Scores therefore sum to
//! `≤ 1`, short by the leaked dangling/damping mass.

use super::{AppCtx, AppKernel, AppOutput, RankRun};
use crate::exec::{AggComm, Comm};
use crate::graph::Csr;
use anyhow::{ensure, Result};

/// Damping factor.
pub const DAMPING: f64 = 0.85;
/// Fixed iteration count (no convergence test: identical schedule on
/// every rank count by construction).
pub const ITERS: usize = 20;

/// Push-style damped PageRank with canonical-order folding.
pub struct PageRank;

impl AppKernel for PageRank {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn rec_words(&self) -> usize {
        3
    }

    fn run_rank(&self, ctx: &AppCtx, _comm: &dyn Comm, agg: &mut AggComm) -> Result<RankRun> {
        let n_local = ctx.strip.n_local();
        let n = ctx.n_global as f64;
        let base = (1.0 - DAMPING) / n;
        let mut rank = vec![1.0 / n; n_local];
        let mut ops = 0.0f64;
        let mut incoming: Vec<(usize, u32, f64)> = Vec::new();
        for _ in 0..ITERS {
            for u in 0..n_local {
                let lo = ctx.strip.xadj[u];
                let hi = ctx.strip.xadj[u + 1];
                if hi == lo {
                    continue; // dangling: its mass leaks (see module docs)
                }
                let u_gid = (ctx.strip.row_lo + u) as f64;
                let c = DAMPING * rank[u] / (hi - lo) as f64;
                ops += (hi - lo) as f64;
                for &v in &ctx.strip.adjncy[lo..hi] {
                    agg.push(ctx.owner(v as usize), &[v as f64, u_gid, c]);
                }
            }
            incoming.clear();
            for part in &agg.drain() {
                for rec in part.chunks_exact(3) {
                    incoming.push((ctx.local(rec[0] as usize), rec[1] as u32, rec[2]));
                }
            }
            // Canonical fold order: by (dest, source id) — per dest this
            // is ascending global source order, matching the sequential
            // reference bit for bit.
            incoming.sort_by_key(|&(lv, src, _)| (lv, src));
            ops += incoming.len() as f64;
            for r in rank.iter_mut() {
                *r = base;
            }
            for &(lv, _, c) in &incoming {
                rank[lv] += c;
            }
        }
        Ok(RankRun { primary: rank, aux: Vec::new(), modeled_ops: ops, iterations: ITERS })
    }

    fn check(&self, g: &Csr, _source: usize, out: &AppOutput) -> Result<()> {
        ensure!(out.primary.len() == g.n() && out.aux.is_empty());
        let n = g.n() as f64;
        let base = (1.0 - DAMPING) / n;
        // Sequential reference with the same canonical fold order:
        // sources visited in ascending id, so each target accumulates
        // its contributions in ascending source order.
        let mut rank = vec![1.0 / n; g.n()];
        for _ in 0..ITERS {
            let mut next = vec![base; g.n()];
            for u in 0..g.n() {
                let deg = g.degree(u);
                if deg == 0 {
                    continue;
                }
                let c = DAMPING * rank[u] / deg as f64;
                for &v in g.neighbors(u) {
                    next[v as usize] += c;
                }
            }
            rank = next;
        }
        let l1: f64 = out.primary.iter().zip(&rank).map(|(a, b)| (a - b).abs()).sum();
        ensure!(l1 == 0.0, "|Δrank|₁ = {l1:e} against the sequential reference");
        let total: f64 = out.primary.iter().sum();
        ensure!(total <= 1.0 + 1e-9, "scores sum to {total} > 1");
        ensure!(out.primary.iter().all(|&r| r > 0.0), "scores must stay positive");
        Ok(())
    }
}
