//! Irregular graph-application kernels on the virtual cluster.
//!
//! The paper evaluates partitions only through mesh-style CG/SpMV, but
//! heterogeneous distributions really show their quality under
//! *irregular* communication (WindGP judges heterogeneous partitions on
//! real graph workloads; Langguth–Schlag–Schulz argue the binding
//! metric is the most-congested link, not total volume). This module
//! supplies that workload axis: three iterative kernels over the
//! row-distributed [`GraphStrip`] layout, batching their per-edge
//! messages through the aggregating transport
//! ([`AggComm`](crate::exec::AggComm)) so the harness can compare
//! aggregated against direct message traffic on both engine backends.
//!
//! Registered kernels, resolved by [`by_name`]:
//!
//! | `bfs` | frontier (level-synchronous) BFS: levels + min-parent tree |
//! | `sssp` | delta-stepping SSSP: bucketed relaxations, light/heavy phases |
//! | `pagerank` | push-style PageRank: 20 damped power iterations |
//!
//! # The bit-identity contract
//!
//! Every kernel's assembled output is **bit-identical** across
//! aggregation modes, both backends, and every rank count (pinned by
//! `tests/apps.rs`). The mechanisms: supersteps are globally
//! synchronized (round counts agreed by collective), so the *set* of
//! messages generated per superstep is a function of global state only;
//! message application is order-independent (min-folds for BFS parents
//! and SSSP relaxations) or canonically ordered (PageRank contributions
//! fold per target in ascending source id); and [`AggComm`] delivers
//! per-source records in push order regardless of mode or buffer size.
//!
//! [`AggComm`]: crate::exec::AggComm

pub mod bfs;
pub mod pagerank;
pub mod sssp;

pub use bfs::Bfs;
pub use pagerank::PageRank;
pub use sssp::DeltaSssp;

use crate::exec::{
    AggComm, AggMode, Comm, CostModel, ExchangePlan, ExecBackend, NetModel, SimComm, ThreadComm,
};
use crate::graph::Csr;
use crate::partitioners::dist::GraphStrip;
use crate::util::timer::Timer;
use anyhow::{anyhow, ensure, Context, Result};
use std::sync::{Arc, Mutex};

/// Kernel names in the module table's order (the registry of record,
/// kept in lockstep by `module_table_matches_registry`).
pub const APP_NAMES: [&str; 3] = ["bfs", "sssp", "pagerank"];

/// Resolve a kernel by its registered name.
pub fn by_name(name: &str) -> Option<Box<dyn AppKernel>> {
    match name {
        "bfs" => Some(Box::new(Bfs)),
        "sssp" => Some(Box::new(DeltaSssp)),
        "pagerank" => Some(Box::new(PageRank)),
        _ => None,
    }
}

/// Deterministic symmetric edge weight in `[1, 2)` for SSSP (and its
/// checker): the CSR carries unit weights, so weighted-path kernels
/// derive weights from the endpoint ids via a splitmix64 finalizer.
/// Same (u, v) → same weight on every rank, backend, and process.
pub fn edge_weight(u: u32, v: u32) -> f64 {
    let (a, b) = if u < v { (u, v) } else { (v, u) };
    let mut h = ((a as u64) << 32) | b as u64;
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^= h >> 31;
    1.0 + (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Everything one rank of an application kernel may use.
pub struct AppCtx<'a> {
    /// This rank.
    pub rank: usize,
    /// Total rank count.
    pub ranks: usize,
    /// Global vertex count.
    pub n_global: usize,
    /// The rank's row strip (columns stay global ids).
    pub strip: GraphStrip,
    /// Global row offsets of every strip (length `ranks + 1`), for owner
    /// lookups.
    pub row_starts: &'a [usize],
    /// Source vertex for traversal kernels (global id).
    pub source: usize,
    /// Seed (deterministic kernels ignore it).
    pub seed: u64,
}

impl AppCtx<'_> {
    /// Rank owning global vertex `v`.
    #[inline]
    pub fn owner(&self, v: usize) -> usize {
        self.row_starts.partition_point(|&s| s <= v) - 1
    }

    /// Local index of a globally-owned vertex of this rank.
    #[inline]
    pub fn local(&self, v: usize) -> usize {
        v - self.strip.row_lo
    }
}

/// One rank's kernel result.
pub struct RankRun {
    /// Primary per-vertex values for the rank's rows (BFS levels, SSSP
    /// tentative distances, PageRank scores).
    pub primary: Vec<f64>,
    /// Auxiliary per-vertex values (BFS parents; empty otherwise).
    pub aux: Vec<f64>,
    /// Deterministic operation count (the priced backend converts it to
    /// modeled compute seconds, `modeled_ops · t_flop`).
    pub modeled_ops: f64,
    /// Supersteps executed (identical on every rank by construction).
    pub iterations: usize,
}

/// Assembled global result of one application run.
#[derive(Debug, Clone, PartialEq)]
pub struct AppOutput {
    /// Primary per-vertex values, concatenated in global vertex order.
    pub primary: Vec<f64>,
    /// Auxiliary per-vertex values (empty when the kernel has none).
    pub aux: Vec<f64>,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

impl AppOutput {
    /// FNV-1a fingerprint over the raw bits of both value arrays — the
    /// checkable result digest the harness and tests pin. Bitwise equal
    /// outputs (the contract) have equal digests across processes.
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for v in self.primary.iter().chain(&self.aux) {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        }
        h
    }
}

/// One application kernel behind the common seam: a per-rank BSP body
/// plus a sequential validity check over the assembled output.
pub trait AppKernel: Sync {
    /// Registered kernel name.
    fn name(&self) -> &'static str;
    /// Words per [`AggComm`](crate::exec::AggComm) record this kernel
    /// pushes.
    fn rec_words(&self) -> usize;
    /// Run one rank's share. Must issue the same collective sequence on
    /// every rank (the rendezvous contract); error paths must be
    /// replicated decisions.
    fn run_rank(&self, ctx: &AppCtx, comm: &dyn Comm, agg: &mut AggComm) -> Result<RankRun>;
    /// Validate the assembled output against the full graph (BFS parent
    /// validity, SSSP triangle inequality, PageRank residual).
    fn check(&self, g: &Csr, source: usize, out: &AppOutput) -> Result<()>;
}

/// Cut `g` into `ranks` contiguous near-equal row strips. Unlike
/// `partitioners::dist::build_strips`, application strips need neither
/// coordinates nor accumulation-segment alignment, so any
/// `1 ≤ ranks ≤ n` works.
pub fn app_strips(g: &Csr, ranks: usize) -> Result<Vec<GraphStrip>> {
    ensure!(ranks >= 1, "need at least one rank");
    ensure!(ranks <= g.n(), "more ranks ({ranks}) than vertices ({})", g.n());
    let n = g.n();
    let mut strips = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let row_lo = r * n / ranks;
        let row_hi = (r + 1) * n / ranks;
        let arc_lo = g.xadj[row_lo];
        let xadj: Vec<usize> =
            g.xadj[row_lo..=row_hi].iter().map(|&x| x - arc_lo).collect();
        let adjncy = g.adjncy[arc_lo..g.xadj[row_hi]].to_vec();
        let vwgt =
            if g.vwgt.is_empty() { Vec::new() } else { g.vwgt[row_lo..row_hi].to_vec() };
        let coords = if g.coords.is_empty() {
            Vec::new()
        } else {
            g.coords[row_lo..row_hi].to_vec()
        };
        strips.push(GraphStrip {
            row_lo,
            row_hi,
            seg_lo: 0,
            seg_hi: 0,
            xadj,
            adjncy,
            vwgt,
            coords,
        });
    }
    Ok(strips)
}

/// Configuration of one application run.
#[derive(Debug, Clone)]
pub struct AppConfig {
    /// Engine backend (`sim` priced / `threads` measured).
    pub backend: ExecBackend,
    /// Rank count.
    pub ranks: usize,
    /// Aggregation mode of the message layer.
    pub mode: AggMode,
    /// Flush capacity per destination in aggregated mode.
    pub buffer_bytes: usize,
    /// α-β cost model for the priced backend.
    pub cost: CostModel,
    /// Network model the priced backend charges messages with (the
    /// `--net` axis); `FlatAlphaBeta` keeps the legacy charges
    /// bit-exact, and the measured backend ignores it.
    pub net: NetModel,
    /// Source vertex for traversal kernels.
    pub source: usize,
    /// Seed handed to the kernel context.
    pub seed: u64,
}

impl Default for AppConfig {
    fn default() -> AppConfig {
        AppConfig {
            backend: ExecBackend::Sim,
            ranks: 4,
            mode: AggMode::Agg,
            buffer_bytes: 16 * 1024,
            cost: CostModel::default(),
            net: NetModel::FlatAlphaBeta,
            source: 0,
            seed: 1,
        }
    }
}

/// Per-rank cost and traffic breakdown of one application run.
#[derive(Debug, Clone)]
pub struct AppReport {
    /// Kernel name.
    pub app: String,
    /// Which transport ran (`"sim"` / `"threads"`).
    pub backend: &'static str,
    /// Rank count.
    pub ranks: usize,
    /// Aggregation mode.
    pub mode: AggMode,
    /// Supersteps the kernel executed.
    pub iterations: usize,
    /// Exchange rounds (`alltoallv` flushes) — identical on every rank,
    /// reported once.
    pub flushes: usize,
    /// Total bytes shipped through the aggregation layer (off-rank only,
    /// summed over ranks).
    pub agg_bytes: usize,
    /// Bytes shipped per ordered (source, destination) rank pair; the
    /// diagonal is zero. `max` over entries is the bottleneck-link
    /// metric `maxLinkBytes`.
    pub link_bytes: Vec<Vec<usize>>,
    /// Per-rank compute seconds: modeled (`sim`) or measured (`threads`).
    pub compute_secs: Vec<f64>,
    /// Per-rank communication seconds: α-β priced (`sim`) or measured
    /// rendezvous (`threads`).
    pub comm_secs: Vec<f64>,
    /// Result digest ([`AppOutput::digest`]).
    pub digest: u64,
    /// Leader wall-clock for the whole run.
    pub wall_secs: f64,
}

impl AppReport {
    /// The bottleneck-link metric: bytes over the most-congested ordered
    /// rank pair (Langguth–Schlag–Schulz's binding quantity, reported
    /// next to cut/LDHT by the harness).
    pub fn max_link_bytes(&self) -> usize {
        self.link_bytes
            .iter()
            .flat_map(|row| row.iter().copied())
            .max()
            .unwrap_or(0)
    }

    /// Per-rank exposed communication seconds. The kernels issue no
    /// nonblocking overlap, so every priced/measured communication
    /// second is exposed: this is `comm_secs` by another name, kept as a
    /// seam for a future overlapped path.
    pub fn exposed_secs(&self) -> Vec<f64> {
        self.comm_secs.clone()
    }

    /// Application makespan: the slowest rank's compute + communication.
    pub fn app_secs(&self) -> f64 {
        (0..self.ranks)
            .map(|r| self.compute_secs[r] + self.comm_secs[r])
            .fold(0.0f64, f64::max)
    }
}

/// Run `kernel` over `cfg.ranks` virtual-cluster ranks and assemble the
/// global output. Mirrors `exec::run_dist_partition`: one OS thread per
/// rank on both backends, differing only in costing; the assembled
/// output is validated by the kernel's [`AppKernel::check`] before
/// returning.
pub fn run_app(g: &Csr, kernel: &dyn AppKernel, cfg: &AppConfig) -> Result<(AppOutput, AppReport)> {
    ensure!(g.n() >= 1, "empty graph");
    ensure!(cfg.source < g.n(), "source {} out of range (n={})", cfg.source, g.n());
    let ranks = cfg.ranks;
    let wall = Timer::start();
    let strips = app_strips(g, ranks)?;
    let row_starts: Vec<usize> =
        strips.iter().map(|s| s.row_lo).chain([g.n()]).collect();
    let plan = Arc::new(ExchangePlan::collectives_only(ranks));
    let comm: Box<dyn Comm> = match cfg.backend {
        ExecBackend::Sim => Box::new(SimComm::with_net(plan, cfg.cost, cfg.net, None)),
        ExecBackend::Threads => Box::new(ThreadComm::new(plan)),
    };
    let comm = &*comm;
    type RankRet = Result<(RankRun, crate::exec::AggStats, f64)>;
    let slots: Vec<Mutex<Option<RankRet>>> = (0..ranks).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for (rank, (strip, slot)) in strips.into_iter().zip(&slots).enumerate() {
            let row_starts = &row_starts;
            scope.spawn(move || {
                let ctx = AppCtx {
                    rank,
                    ranks,
                    n_global: g.n(),
                    strip,
                    row_starts,
                    source: cfg.source,
                    seed: cfg.seed,
                };
                let t = Timer::start();
                let run = || -> RankRet {
                    let mut agg = AggComm::new(
                        comm,
                        rank,
                        cfg.mode,
                        kernel.rec_words(),
                        cfg.buffer_bytes,
                    );
                    let out = kernel
                        .run_rank(&ctx, comm, &mut agg)
                        .with_context(|| format!("rank {rank}"))?;
                    ensure!(
                        out.primary.len() == ctx.strip.n_local(),
                        "rank {rank}: primary values have wrong length"
                    );
                    Ok((out, agg.stats().clone(), t.secs()))
                };
                *slot.lock().unwrap() = Some(run());
            });
        }
    });
    let mut primary = Vec::with_capacity(g.n());
    let mut aux = Vec::new();
    let mut link_bytes = vec![vec![0usize; ranks]; ranks];
    let mut modeled_ops = vec![0.0f64; ranks];
    let mut elapsed = vec![0.0f64; ranks];
    let mut flushes = 0usize;
    let mut iterations = 0usize;
    for (rank, slot) in slots.into_iter().enumerate() {
        let (run, stats, secs) = slot
            .into_inner()
            .unwrap()
            .ok_or_else(|| anyhow!("rank {rank} produced no result"))??;
        primary.extend_from_slice(&run.primary);
        aux.extend_from_slice(&run.aux);
        link_bytes[rank].copy_from_slice(&stats.bytes_to);
        modeled_ops[rank] = run.modeled_ops;
        elapsed[rank] = secs;
        flushes = flushes.max(stats.flushes);
        iterations = iterations.max(run.iterations);
    }
    let comm_secs = comm.comm_secs();
    let compute_secs: Vec<f64> = match cfg.backend {
        ExecBackend::Sim => modeled_ops.iter().map(|&ops| ops * cfg.cost.t_flop).collect(),
        ExecBackend::Threads => (0..ranks)
            .map(|r| (elapsed[r] - comm_secs[r]).max(0.0))
            .collect(),
    };
    let agg_bytes = link_bytes.iter().flatten().sum();
    let out = AppOutput { primary, aux };
    kernel
        .check(g, cfg.source, &out)
        .with_context(|| format!("{} result check", kernel.name()))?;
    let report = AppReport {
        app: kernel.name().to_string(),
        backend: comm.label(),
        ranks,
        mode: cfg.mode,
        iterations,
        flushes,
        agg_bytes,
        link_bytes,
        compute_secs,
        comm_secs,
        digest: out.digest(),
        wall_secs: wall.secs(),
    };
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in APP_NAMES {
            let k = by_name(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(k.name(), name);
            assert!(k.rec_words() >= 1);
        }
        assert!(by_name("cg").is_none(), "cg is the historical solve path, not a kernel");
        assert!(by_name("nope").is_none());
    }

    /// The module-level table is the registry's documentation of record:
    /// parse it out of this very file and pin it against [`APP_NAMES`]
    /// (names and order) so neither can drift — the same guard the
    /// partitioner registry carries.
    #[test]
    fn module_table_matches_registry() {
        let src = include_str!("mod.rs");
        let table_names: Vec<&str> = src
            .lines()
            .filter_map(|l| l.strip_prefix("//! | `"))
            .filter_map(|l| l.split('`').next())
            .collect();
        assert_eq!(
            table_names,
            APP_NAMES.to_vec(),
            "module doc table disagrees with APP_NAMES"
        );
    }

    #[test]
    fn edge_weights_are_symmetric_and_bounded() {
        for (u, v) in [(0u32, 1u32), (5, 2), (100, 4099), (7, 8)] {
            let w = edge_weight(u, v);
            assert_eq!(w, edge_weight(v, u), "({u},{v})");
            assert!((1.0..2.0).contains(&w), "({u},{v}) -> {w}");
        }
        assert_ne!(edge_weight(0, 1), edge_weight(0, 2));
    }

    #[test]
    fn app_strips_cover_the_graph() {
        let g = crate::gen::mesh_2d_tri(9, 9, 1);
        for ranks in [1usize, 2, 3, 4, 7] {
            let strips = app_strips(&g, ranks).unwrap();
            assert_eq!(strips.len(), ranks);
            assert_eq!(strips[0].row_lo, 0);
            assert_eq!(strips.last().unwrap().row_hi, g.n());
            let mut arcs = 0;
            for (i, s) in strips.iter().enumerate() {
                if i > 0 {
                    assert_eq!(s.row_lo, strips[i - 1].row_hi, "strips must tile");
                }
                assert_eq!(s.xadj.len(), s.n_local() + 1);
                assert_eq!(*s.xadj.last().unwrap(), s.adjncy.len());
                arcs += s.adjncy.len();
            }
            assert_eq!(arcs, g.adjncy.len());
        }
        assert!(app_strips(&g, 0).is_err());
        assert!(app_strips(&g, g.n() + 1).is_err());
    }

    #[test]
    fn owner_lookup_matches_strip_bounds() {
        let g = crate::gen::mesh_2d_tri(7, 7, 1);
        let strips = app_strips(&g, 3).unwrap();
        let row_starts: Vec<usize> =
            strips.iter().map(|s| s.row_lo).chain([g.n()]).collect();
        let ctx = AppCtx {
            rank: 0,
            ranks: 3,
            n_global: g.n(),
            strip: strips[0].clone(),
            row_starts: &row_starts,
            source: 0,
            seed: 1,
        };
        for (r, s) in app_strips(&g, 3).unwrap().iter().enumerate() {
            for v in s.row_lo..s.row_hi {
                assert_eq!(ctx.owner(v), r, "vertex {v}");
            }
        }
    }

    #[test]
    fn digest_tracks_bits() {
        let a = AppOutput { primary: vec![1.0, 2.0], aux: vec![] };
        let b = AppOutput { primary: vec![1.0, 2.0], aux: vec![] };
        assert_eq!(a.digest(), b.digest());
        let c = AppOutput { primary: vec![1.0, 2.0 + 1e-12], aux: vec![] };
        assert_ne!(a.digest(), c.digest());
        let d = AppOutput { primary: vec![1.0, 2.0], aux: vec![0.0] };
        assert_ne!(a.digest(), d.digest());
    }
}
