//! Frontier (level-synchronous) BFS over row strips.
//!
//! Each superstep expands the current frontier: every frontier vertex
//! pushes a `[dest_gid, parent_gid]` record per neighbor through the
//! aggregation layer, and owners fold the candidates with a **min-parent
//! rule** — a newly reached vertex adopts the smallest candidate parent
//! id, which makes the BFS tree independent of rank count, backend, and
//! delivery order. Termination is a global sum of newly-reached counts
//! (exact in f64: the summands are small integers).

use super::{AppCtx, AppKernel, AppOutput, RankRun};
use crate::exec::{AggComm, Comm, ReduceOp};
use crate::graph::Csr;
use anyhow::{ensure, Result};

/// Level-synchronous breadth-first search (levels + min-parent tree).
pub struct Bfs;

impl AppKernel for Bfs {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn rec_words(&self) -> usize {
        2
    }

    fn run_rank(&self, ctx: &AppCtx, comm: &dyn Comm, agg: &mut AggComm) -> Result<RankRun> {
        let n_local = ctx.strip.n_local();
        let mut dist = vec![f64::INFINITY; n_local];
        let mut parent = vec![-1.0f64; n_local];
        let mut frontier: Vec<usize> = Vec::new();
        if ctx.source >= ctx.strip.row_lo && ctx.source < ctx.strip.row_hi {
            let s = ctx.local(ctx.source);
            dist[s] = 0.0;
            parent[s] = ctx.source as f64;
            frontier.push(s);
        }
        let mut ops = 0.0f64;
        let mut level = 0usize;
        // A connected path has at most n_global levels; the cap is a
        // replicated decision (level counts are globally synchronized),
        // so every rank errors together if it ever bites.
        while level <= ctx.n_global {
            for &u in &frontier {
                let u_gid = (ctx.strip.row_lo + u) as f64;
                let lo = ctx.strip.xadj[u];
                let hi = ctx.strip.xadj[u + 1];
                ops += (hi - lo) as f64;
                for &v in &ctx.strip.adjncy[lo..hi] {
                    agg.push(ctx.owner(v as usize), &[v as f64, u_gid]);
                }
            }
            let recv = agg.drain();
            // Min-fold candidate parents for vertices not yet reached.
            let mut cand = vec![f64::INFINITY; n_local];
            for part in &recv {
                for rec in part.chunks_exact(2) {
                    let lv = ctx.local(rec[0] as usize);
                    ops += 1.0;
                    if dist[lv].is_infinite() {
                        cand[lv] = cand[lv].min(rec[1]);
                    }
                }
            }
            frontier.clear();
            for (lv, &p) in cand.iter().enumerate() {
                if p.is_finite() {
                    dist[lv] = (level + 1) as f64;
                    parent[lv] = p;
                    frontier.push(lv);
                }
            }
            let mut newly = [frontier.len() as f64];
            comm.allreduce_vec(ctx.rank, &mut newly, ReduceOp::Sum);
            level += 1;
            if newly[0] == 0.0 {
                break;
            }
        }
        Ok(RankRun { primary: dist, aux: parent, modeled_ops: ops, iterations: level })
    }

    fn check(&self, g: &Csr, source: usize, out: &AppOutput) -> Result<()> {
        ensure!(out.primary.len() == g.n() && out.aux.len() == g.n());
        let reference = g.bfs(source);
        for v in 0..g.n() {
            let d = out.primary[v];
            if reference[v] == usize::MAX {
                ensure!(d.is_infinite(), "vertex {v} unreachable but level {d}");
                ensure!(out.aux[v] == -1.0, "unreachable vertex {v} has a parent");
                continue;
            }
            ensure!(d == reference[v] as f64, "vertex {v}: level {d} != {}", reference[v]);
            let p = out.aux[v] as usize;
            if v == source {
                ensure!(p == source, "source parent must be itself");
                continue;
            }
            ensure!(
                g.neighbors(v).contains(&(p as u32)),
                "vertex {v}: parent {p} is not a neighbor"
            );
            ensure!(
                out.primary[p] + 1.0 == d,
                "vertex {v}: parent {p} at level {} not one above {d}",
                out.primary[p]
            );
        }
        Ok(())
    }
}
