//! Delta-stepping single-source shortest paths over row strips.
//!
//! Weights come from [`edge_weight`](super::edge_weight) (deterministic,
//! symmetric, in `[1, 2)`), and `Δ = 1.5` splits edges into light
//! (`w ≤ Δ`) and heavy. The classic schedule: settle buckets of width Δ
//! in order; within a bucket, relax light edges to a fixed point
//! (re-relaxing vertices whose tentative distance drops back into the
//! bucket), then relax heavy edges once from everything the bucket
//! touched. Relaxation records `[dest_gid, candidate]` batch through the
//! aggregation layer and apply as a min-fold, so delivery order never
//! matters; bucket selection and inner-round continuation are global
//! allreduces, so every rank walks the identical superstep schedule.
//!
//! Because all weights are ≥ 1 > 0 and candidates from bucket `i` land
//! at `≥ i·Δ + 1`, no relaxation re-opens a settled bucket — the
//! settle-on-close rule is exact, and the checker proves it: triangle
//! inequality over every edge bounds the result from above, a tight
//! predecessor per reached vertex bounds it from below, so together they
//! pin the true distances.

use super::{edge_weight, AppCtx, AppKernel, AppOutput, RankRun};
use crate::exec::{AggComm, Comm, ReduceOp};
use crate::graph::Csr;
use anyhow::{bail, ensure, Result};

/// Bucket width; also the light/heavy edge split (weights span `[1, 2)`).
pub const DELTA: f64 = 1.5;

/// Delta-stepping SSSP (bucketed relaxations, light/heavy phases).
pub struct DeltaSssp;

impl AppKernel for DeltaSssp {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn rec_words(&self) -> usize {
        2
    }

    fn run_rank(&self, ctx: &AppCtx, comm: &dyn Comm, agg: &mut AggComm) -> Result<RankRun> {
        let n_local = ctx.strip.n_local();
        let mut tent = vec![f64::INFINITY; n_local];
        let mut settled = vec![false; n_local];
        let mut done_light = vec![false; n_local];
        if ctx.source >= ctx.strip.row_lo && ctx.source < ctx.strip.row_hi {
            tent[ctx.local(ctx.source)] = 0.0;
        }
        let mut ops = 0.0f64;
        let mut supersteps = 0usize;
        // Distances are < 2·n (weights < 2), so < ⌈2n/Δ⌉ + 1 buckets can
        // ever open; the caps are replicated decisions (every loop is
        // steered by collectives), so all ranks error together.
        let max_buckets = 2 * ctx.n_global + 2;
        for _ in 0..=max_buckets {
            // Next nonempty bucket = global min unsettled tentative.
            let mut gmin = [tent
                .iter()
                .zip(&settled)
                .filter(|(_, &s)| !s)
                .map(|(&t, _)| t)
                .fold(f64::INFINITY, f64::min)];
            comm.allreduce_vec(ctx.rank, &mut gmin, ReduceOp::Min);
            supersteps += 1;
            if gmin[0].is_infinite() {
                return Ok(RankRun {
                    primary: tent,
                    aux: Vec::new(),
                    modeled_ops: ops,
                    iterations: supersteps,
                });
            }
            let bucket = (gmin[0] / DELTA).floor();
            for d in done_light.iter_mut() {
                *d = false;
            }
            let mut touched = vec![false; n_local];
            let in_bucket = |t: f64, s: bool| !s && t.is_finite() && (t / DELTA).floor() == bucket;
            // Light-edge fixed point within the bucket.
            for _round in 0..=ctx.n_global {
                let members: Vec<usize> = (0..n_local)
                    .filter(|&u| in_bucket(tent[u], settled[u]) && !done_light[u])
                    .collect();
                let mut cnt = [members.len() as f64];
                comm.allreduce_vec(ctx.rank, &mut cnt, ReduceOp::Sum);
                supersteps += 1;
                if cnt[0] == 0.0 {
                    break;
                }
                for &u in &members {
                    let u_gid = (ctx.strip.row_lo + u) as u32;
                    let lo = ctx.strip.xadj[u];
                    let hi = ctx.strip.xadj[u + 1];
                    ops += (hi - lo) as f64;
                    for &v in &ctx.strip.adjncy[lo..hi] {
                        let w = edge_weight(u_gid, v);
                        if w <= DELTA {
                            agg.push(ctx.owner(v as usize), &[v as f64, tent[u] + w]);
                        }
                    }
                    done_light[u] = true;
                    touched[u] = true;
                }
                for part in &agg.drain() {
                    for rec in part.chunks_exact(2) {
                        let lv = ctx.local(rec[0] as usize);
                        ops += 1.0;
                        if rec[1] < tent[lv] {
                            tent[lv] = rec[1];
                            // The drop may have pulled it (back) into the
                            // bucket — give its light edges another round.
                            done_light[lv] = false;
                        }
                    }
                }
            }
            // One heavy round from everything the bucket touched, then
            // settle those vertices: candidates land ≥ (bucket+1)·Δ, so
            // the closed bucket can never re-open.
            for u in 0..n_local {
                if !touched[u] {
                    continue;
                }
                let u_gid = (ctx.strip.row_lo + u) as u32;
                let lo = ctx.strip.xadj[u];
                let hi = ctx.strip.xadj[u + 1];
                ops += (hi - lo) as f64;
                for &v in &ctx.strip.adjncy[lo..hi] {
                    let w = edge_weight(u_gid, v);
                    if w > DELTA {
                        agg.push(ctx.owner(v as usize), &[v as f64, tent[u] + w]);
                    }
                }
                settled[u] = true;
            }
            for part in &agg.drain() {
                for rec in part.chunks_exact(2) {
                    let lv = ctx.local(rec[0] as usize);
                    ops += 1.0;
                    if rec[1] < tent[lv] {
                        tent[lv] = rec[1];
                    }
                }
            }
            supersteps += 1;
        }
        bail!("delta-stepping exceeded the bucket cap (rank {})", ctx.rank)
    }

    fn check(&self, g: &Csr, source: usize, out: &AppOutput) -> Result<()> {
        ensure!(out.primary.len() == g.n() && out.aux.is_empty());
        let tent = &out.primary;
        ensure!(tent[source] == 0.0, "source distance must be 0");
        let reference = g.bfs(source);
        for u in 0..g.n() {
            if reference[u] == usize::MAX {
                ensure!(tent[u].is_infinite(), "vertex {u} unreachable but finite distance");
                continue;
            }
            ensure!(tent[u].is_finite(), "vertex {u} reachable but infinite distance");
            // Upper bound: no edge can relax the result any further.
            for &v in g.neighbors(u) {
                let w = edge_weight(u as u32, v);
                ensure!(
                    tent[v as usize] <= tent[u] + w,
                    "edge ({u},{v}) violates the triangle inequality"
                );
            }
            // Lower bound: the distance is realized by some incoming edge.
            if u != source {
                let tight = g.neighbors(u).iter().any(|&v| {
                    tent[v as usize].is_finite()
                        && tent[v as usize] + edge_weight(u as u32, v) == tent[u]
                });
                ensure!(tight, "vertex {u} has no tight predecessor");
            }
        }
        Ok(())
    }
}
