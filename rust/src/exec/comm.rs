//! The `Comm` seam of the virtual-cluster engine: halo exchange and
//! allreduce behind one trait, independent of the transport — the same
//! separation bale/convey draws between conveyor semantics and the
//! underlying communication layer.
//!
//! Two transports:
//! - [`SimComm`]: in-process copies whose cost is *priced* by the α-β
//!   model (the transport the old sequential simulator embodied). Used
//!   by the sequential superstep executor, so `sync` is a no-op — the
//!   executor orders phases globally.
//! - [`ThreadComm`]: a real shared-memory transport for thread-per-PU
//!   execution — per-rank inboxes behind mutexes plus a [`Barrier`];
//!   communication cost is *measured* wall-clock (scatter + copy + wait).
//!
//! Both transports implement the reductions identically — each rank's
//! partial is deposited into a slot and the sum is taken in rank order —
//! so dot products are bit-identical regardless of thread scheduling.
//! That determinism is what lets the `threads` backend reproduce the
//! `sim` backend's residual trajectory exactly.
//!
//! # Nonblocking primitives and overlap pricing
//!
//! Beyond the blocking split-phase calls, the trait carries an
//! MPI-flavored nonblocking protocol — [`Comm::irecv_halo`] /
//! [`Comm::isend_halo`] returning [`CommRequest`] handles, completed by
//! [`Comm::test`] / [`Comm::wait`] / [`Comm::wait_all`] — so executors
//! can overlap the halo exchange with independent computation (the
//! interior rows of the SpMV, see `solver::halo`). The contract is
//! deliberately narrow: **at most one exchange may be in flight per rank**,
//! and data delivered by a completed exchange is read with the ordinary
//! [`Comm::recv_halo`].
//!
//! The two transports realize overlap differently:
//! - [`ThreadComm`] makes it *real*: `isend_halo` puts the payload into
//!   each receiver's inbox (one aggregated write + notification token
//!   per destination, no allocation) and returns immediately; `wait`
//!   blocks until every expected token arrived — compute performed
//!   between the two runs concurrently with the other ranks' transfers
//!   (no barrier is involved in a nonblocking exchange).
//! - [`SimComm`] makes it *priced*: `irecv_halo`/`isend_halo` open an
//!   overlap region whose α-β exchange cost is held pending; compute
//!   performed inside the region is reported via
//!   [`Comm::overlap_compute`]; `wait` then charges only the **exposed**
//!   communication `max(comm_window − compute_window, 0)` — so one
//!   overlap region costs `max(compute, comm)` instead of their sum,
//!   exactly how real hardware rewards overlap. The hidden share
//!   `min(comm, compute)` is tracked per rank
//!   ([`Comm::comm_hidden_secs`]) and feeds the harness's
//!   overlap-efficiency columns.
//!
//! # Generic rendezvous collectives
//!
//! Beyond the halo-shaped traffic, the trait carries four MPI-flavored
//! *generic* collectives — [`Comm::allreduce_vec`] (with [`ReduceOp`]
//! sum/min/max), [`Comm::allgatherv`], [`Comm::alltoallv`], and
//! [`Comm::broadcast`] — the vocabulary distributed *partitioners* need
//! (they run before any partition, and hence any halo structure,
//! exists). These are blocking rendezvous operations: every rank thread
//! calls them in the same order and each call synchronizes internally
//! (a fixed barrier-phase sequence), so they must be driven by `k`
//! concurrent rank threads — `k == 1` passes trivially and is priced as
//! free. `Sum`
//! folds contributions in rank order (bit-deterministic); `Min`/`Max`
//! are exact and order-independent. [`SimComm`] prices each call with an
//! α-β tree model (`ceil(log2 k)` latency rounds + β per byte moved);
//! [`ThreadComm`] charges measured wall-clock including the rendezvous
//! wait.
//!
//! # Hierarchical (two-level) collectives and non-flat networks
//!
//! Real clusters are not flat: ranks share nodes, and the fabric between
//! nodes has hop structure. Two orthogonal seams model this:
//!
//! - A [`HierSchedule`] turns the collectives into a *two-level
//!   schedule*: an intra-node phase (each node's leader stages its
//!   group's contributions) followed by an inter-node phase over node
//!   aggregates. Crucially the staging moves data but performs **no
//!   arithmetic** — the global `Sum` fold still reads the contributions
//!   in flat rank order (node order × rank order within node, which for
//!   the contiguous groups the schedule requires *is* rank order) — so
//!   results are bit-identical to the flat path on both transports. A
//!   genuinely nested fold would re-associate f64 addition and break
//!   every bit-identity contract in the repo; only `Min`/`Max` could
//!   fold per node exactly. [`SimComm`] prices the two phases
//!   separately (intra traffic [`INTRA_SPEEDUP`]× cheaper, inter
//!   traffic over `nodes` participants instead of `k`), which is where
//!   the hierarchical schedule wins. [`ThreadComm`] executes the same
//!   staged phases for real.
//! - A [`NetModel`] prices point-to-point messages by hop count and
//!   collective rounds by network diameter: `FlatAlphaBeta` is the
//!   legacy single-hop model (bit-exact with the PR 5 charges),
//!   `FatTree` counts up-down switch hops, `Torus` counts wraparound
//!   Manhattan hops.
//!
//! [`CollectiveModel`] exposes the same pricing as closed-form functions
//! of (k, bytes) so the `--matrix scale` sweep can price 16384-rank
//! collectives without constructing a transport (the rendezvous
//! collectives need k live threads — a non-starter at that scale).

use crate::partition::Partition;
use crate::solver::halo::HaloMatrix;
use crate::util::timer::Timer;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Barrier, Mutex};

/// Element-wise combine rule for [`Comm::allreduce_vec`].
///
/// `Sum` combines the per-rank contributions **in rank order** (the same
/// determinism contract as the scalar reduction channels); `Min`/`Max`
/// are associative and exact in f64, so they are order-independent by
/// construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Rank-order sum.
    Sum,
    /// Element-wise minimum.
    Min,
    /// Element-wise maximum.
    Max,
}

/// One rank's outgoing traffic to one neighbor.
#[derive(Debug, Clone)]
pub struct SendSegment {
    /// Receiving rank.
    pub to: u32,
    /// Owned-local indices to read on the sender.
    pub src: Vec<u32>,
    /// Ghost slots to fill on the receiver (parallel to `src`).
    pub dst: Vec<u32>,
}

/// The static exchange pattern of a partitioned matrix: who sends which
/// owned values into whose ghost slots. Derived once from the halo
/// structure; every [`Comm`] transport executes the same plan.
#[derive(Debug, Clone)]
pub struct ExchangePlan {
    /// Per rank: outgoing segments.
    pub sends: Vec<Vec<SendSegment>>,
    /// Per rank: number of ghost entries (inbox size).
    pub ghost_len: Vec<usize>,
    /// Per rank: number of owned rows.
    pub own_len: Vec<usize>,
}

impl ExchangePlan {
    /// Build the plan from a halo decomposition. The receiver slots are
    /// the mirror image of the sender lists by construction (asserted by
    /// `halo`'s `send_lists_are_mirror_of_ghosts` test).
    pub fn new(h: &HaloMatrix, part: &Partition) -> ExchangePlan {
        let k = h.blocks.len();
        let mut sends: Vec<Vec<SendSegment>> = Vec::with_capacity(k);
        for o in 0..k {
            let mut segs = Vec::new();
            for (to, src) in &h.blocks[o].send_lists {
                // Ghost slots on the receiver owned by `o`, in ghost
                // order — exactly the order `src` was built in.
                let dst: Vec<u32> = h.blocks[*to as usize]
                    .ghosts
                    .iter()
                    .enumerate()
                    .filter(|(_, &g)| part.assignment[g as usize] as usize == o)
                    .map(|(j, _)| j as u32)
                    .collect();
                debug_assert_eq!(dst.len(), src.len());
                segs.push(SendSegment { to: *to, src: src.clone(), dst });
            }
            sends.push(segs);
        }
        ExchangePlan {
            ghost_len: h.blocks.iter().map(|b| b.ghosts.len()).collect(),
            own_len: h.blocks.iter().map(|b| b.own.len()).collect(),
            sends,
        }
    }

    /// A plan with no halo traffic, for transports used only for the
    /// generic collectives (e.g. distributed partitioning, which runs
    /// *before* any partition — and hence any halo structure — exists).
    pub fn collectives_only(k: usize) -> ExchangePlan {
        ExchangePlan {
            sends: vec![Vec::new(); k],
            ghost_len: vec![0; k],
            own_len: vec![0; k],
        }
    }

    /// Number of ranks in the plan.
    pub fn k(&self) -> usize {
        self.own_len.len()
    }

    /// Words sent by `rank` per exchange.
    pub fn send_volume(&self, rank: usize) -> usize {
        self.sends[rank].iter().map(|s| s.src.len()).sum()
    }

    /// Number of neighbors `rank` sends to.
    pub fn neighbors(&self, rank: usize) -> usize {
        self.sends[rank].len()
    }
}

/// α-β communication constants for the simulated transport (mirrors
/// `solver::ClusterSim`, which converts into this).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency (s).
    pub alpha: f64,
    /// Per-word transfer time (s).
    pub beta: f64,
    /// Per-nonzero SpMV time on a speed-1 PU (s).
    pub t_flop: f64,
    /// Allreduce latency factor per synchronization.
    pub allreduce_base: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { alpha: 2e-6, beta: 1e-9, t_flop: 2e-9, allreduce_base: 1e-6 }
    }
}

/// Network topology model for the priced transport: how many links a
/// message crosses between two ranks, and how far one collective round
/// reaches. `FlatAlphaBeta` (the default) is the legacy single-hop
/// model — its charges are bit-exact with the pre-seam pricing, pinned
/// by `tests/scale.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetModel {
    /// Every rank pair one hop apart; collective rounds cost one unit
    /// of latency. The legacy α-β model.
    FlatAlphaBeta,
    /// Fat tree of `radix`-port switches with ranks at the leaves:
    /// ranks in the same radix-block share an edge switch (2 hops),
    /// each further level adds an up-down pair.
    FatTree {
        /// Ports per switch (≥ 2); ranks per edge switch.
        radix: usize,
    },
    /// 2-D torus of `dims = [x, y]` with rank `r` at `(r % x, r / x)`;
    /// hops are wraparound Manhattan distance.
    Torus {
        /// Grid extents; must satisfy `x * y ≥ k`.
        dims: [usize; 2],
    },
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel::FlatAlphaBeta
    }
}

impl NetModel {
    /// The default fat tree (16-port switches, a common data-center
    /// radix).
    pub fn fat_tree() -> NetModel {
        NetModel::FatTree { radix: 16 }
    }

    /// A near-square torus just large enough for `k` ranks.
    pub fn torus_for(k: usize) -> NetModel {
        let mut x = 1usize;
        while x * x < k {
            x += 1;
        }
        let y = if x == 0 { 1 } else { k.max(1).div_ceil(x) };
        NetModel::Torus { dims: [x.max(1), y.max(1)] }
    }

    /// Stable display name (`flat` / `fattree16` / `torus8x8`).
    pub fn name(&self) -> String {
        match self {
            NetModel::FlatAlphaBeta => "flat".to_string(),
            NetModel::FatTree { radix } => format!("fattree{radix}"),
            NetModel::Torus { dims: [x, y] } => format!("torus{x}x{y}"),
        }
    }

    /// Whether this is the legacy single-hop model.
    pub fn is_flat(&self) -> bool {
        matches!(self, NetModel::FlatAlphaBeta)
    }

    /// Links a point-to-point message from rank `a` to rank `b`
    /// crosses (0 for `a == b`, ≥ 1 otherwise).
    pub fn hops(&self, a: usize, b: usize) -> f64 {
        if a == b {
            return 0.0;
        }
        match *self {
            NetModel::FlatAlphaBeta => 1.0,
            NetModel::FatTree { radix } => {
                let r = radix.max(2);
                let (mut ca, mut cb) = (a, b);
                let mut level = 0u32;
                while ca != cb {
                    ca /= r;
                    cb /= r;
                    level += 1;
                }
                2.0 * level as f64
            }
            NetModel::Torus { dims: [x, y] } => {
                let x = x.max(1);
                let y = y.max(1);
                let (ax, ay) = (a % x, (a / x) % y);
                let (bx, by) = (b % x, (b / x) % y);
                let dx = ax.abs_diff(bx).min(x - ax.abs_diff(bx));
                let dy = ay.abs_diff(by).min(y - ay.abs_diff(by));
                ((dx + dy) as f64).max(1.0)
            }
        }
    }

    /// Latency multiplier for one collective round spanning `n`
    /// participants: the diameter of the sub-network they occupy
    /// (worst-case routing — conservative by design). `1.0` for the
    /// flat model and for `n ≤ 1`; monotone non-decreasing in `n`.
    pub fn round_factor(&self, n: usize) -> f64 {
        if n <= 1 {
            return 1.0;
        }
        match *self {
            NetModel::FlatAlphaBeta => 1.0,
            NetModel::FatTree { radix } => {
                let r = radix.max(2);
                let mut levels = 0u32;
                let mut reach = 1usize;
                while reach < n {
                    reach = reach.saturating_mul(r);
                    levels += 1;
                }
                (2 * levels.max(1)) as f64
            }
            NetModel::Torus { .. } => {
                // Diameter of the near-square sub-grid the n
                // participants occupy.
                let mut x = 1usize;
                while x * x < n {
                    x += 1;
                }
                let y = n.div_ceil(x);
                ((x / 2 + y / 2) as f64).max(1.0)
            }
        }
    }
}

/// CLI- and scenario-facing network-model axis. Unlike [`NetModel`]
/// (whose torus extents depend on the rank count), a `NetKind` is
/// rank-count-independent, so one `--net` flag can apply to a whole
/// scenario matrix; [`NetKind::model`] materializes the concrete model
/// per k.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetKind {
    /// Legacy single-hop α-β pricing (the default).
    Flat,
    /// [`NetModel::fat_tree`].
    FatTree,
    /// [`NetModel::torus_for`] the scenario's rank count.
    Torus,
}

impl Default for NetKind {
    fn default() -> Self {
        NetKind::Flat
    }
}

impl NetKind {
    /// Every axis value, in sweep order.
    pub const ALL: [NetKind; 3] = [NetKind::Flat, NetKind::FatTree, NetKind::Torus];

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            NetKind::Flat => "flat",
            NetKind::FatTree => "fattree",
            NetKind::Torus => "torus",
        }
    }

    /// Parse a CLI name (`flat` / `fattree` / `torus`).
    pub fn parse(s: &str) -> Option<NetKind> {
        match s.to_ascii_lowercase().as_str() {
            "flat" | "alphabeta" | "alpha-beta" => Some(NetKind::Flat),
            "fattree" | "fat-tree" | "fat" => Some(NetKind::FatTree),
            "torus" => Some(NetKind::Torus),
            _ => None,
        }
    }

    /// The concrete [`NetModel`] for a `k`-rank transport.
    pub fn model(&self, k: usize) -> NetModel {
        match self {
            NetKind::Flat => NetModel::FlatAlphaBeta,
            NetKind::FatTree => NetModel::fat_tree(),
            NetKind::Torus => NetModel::torus_for(k),
        }
    }
}

/// How much cheaper an intra-node hop is than an inter-node network hop
/// in the two-level pricing (latency and bandwidth alike): shared
/// memory / NVLink-class links vs the node-to-node fabric.
pub const INTRA_SPEEDUP: f64 = 4.0;

/// Node grouping of the two-level ("hierarchical") collective schedule:
/// ranks partitioned into contiguous ascending groups, one per physical
/// node (`Topology::node_groups` produces exactly this shape from a
/// preset).
///
/// Contiguity is asserted because it is what makes the staged two-level
/// data movement *bit-identical* to the flat path: the global fold
/// reads the node stages in node order, which for contiguous ascending
/// groups is exactly the flat rank order (see `Collectives`).
#[derive(Debug, Clone, PartialEq)]
pub struct HierSchedule {
    groups: Vec<Vec<usize>>,
    node_of: Vec<usize>,
    intra_speedup: f64,
}

impl HierSchedule {
    /// Schedule from explicit groups; panics unless the groups partition
    /// `0..k` contiguously in ascending order.
    pub fn new(groups: Vec<Vec<usize>>) -> HierSchedule {
        let mut node_of = Vec::new();
        for (node, g) in groups.iter().enumerate() {
            assert!(!g.is_empty(), "empty node group {node}");
            for &r in g {
                assert_eq!(
                    r,
                    node_of.len(),
                    "node groups must partition the ranks contiguously in ascending order"
                );
                node_of.push(node);
            }
        }
        HierSchedule { groups, node_of, intra_speedup: INTRA_SPEEDUP }
    }

    /// Contiguous groups of (at most) `node_ranks` ranks each.
    pub fn uniform(k: usize, node_ranks: usize) -> HierSchedule {
        assert!(node_ranks >= 1, "node_ranks must be >= 1");
        let ranks: Vec<usize> = (0..k).collect();
        HierSchedule::new(ranks.chunks(node_ranks).map(|c| c.to_vec()).collect())
    }

    /// Total ranks covered.
    pub fn k(&self) -> usize {
        self.node_of.len()
    }

    /// Number of nodes (groups).
    pub fn nodes(&self) -> usize {
        self.groups.len()
    }

    /// Which node `rank` lives on.
    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of[rank]
    }

    /// The ranks of one node, ascending.
    pub fn group(&self, node: usize) -> &[usize] {
        &self.groups[node]
    }

    /// All groups, node order.
    pub fn groups(&self) -> &[Vec<usize>] {
        &self.groups
    }

    /// Whether `rank` is its node's leader (lowest rank of the group).
    pub fn is_leader(&self, rank: usize) -> bool {
        self.groups[self.node_of[rank]][0] == rank
    }

    /// Largest group size.
    pub fn max_group(&self) -> usize {
        self.groups.iter().map(|g| g.len()).max().unwrap_or(1)
    }

    /// Whether two ranks share a node.
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    /// Intra-node link advantage (see [`INTRA_SPEEDUP`]).
    pub fn intra_speedup(&self) -> f64 {
        self.intra_speedup
    }

    /// The analytic shape of this schedule.
    pub fn shape(&self) -> HierShape {
        HierShape {
            max_group: self.max_group(),
            nodes: self.nodes(),
            intra_speedup: self.intra_speedup,
        }
    }
}

/// Shape of a two-level schedule for *analytic* pricing: enough to
/// price collectives without materializing per-rank groups (a
/// 16384-rank sweep never allocates 16384 of anything).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HierShape {
    /// Ranks on the largest node.
    pub max_group: usize,
    /// Node count.
    pub nodes: usize,
    /// Intra-node link advantage (see [`INTRA_SPEEDUP`]).
    pub intra_speedup: f64,
}

/// Closed-form α-β collective pricing at arbitrary rank counts: the
/// model the `--matrix scale` sweep evaluates at up to 16384 virtual
/// ranks. No transport (threads, barriers, mailboxes) is constructed —
/// every method is a pure function of the cost constants, the
/// [`NetModel`], and the optional two-level [`HierShape`] — so pricing
/// 16384 ranks costs microseconds. [`SimComm`] prices its *executed*
/// non-flat collectives with the same formulas (exact per-destination
/// hop counts where it knows them); with `FlatAlphaBeta` and no
/// schedule the formulas reduce to the legacy charges exactly (pinned
/// by `tests/scale.rs`).
#[derive(Debug, Clone, Copy)]
pub struct CollectiveModel {
    /// α-β constants.
    pub cost: CostModel,
    /// Network hop model.
    pub net: NetModel,
    /// Two-level schedule shape; `None` = flat schedule.
    pub hier: Option<HierShape>,
}

impl CollectiveModel {
    /// Flat-schedule model.
    pub fn flat_schedule(cost: CostModel, net: NetModel) -> CollectiveModel {
        CollectiveModel { cost, net, hier: None }
    }

    /// Two-level model for `k` ranks packed `node_ranks` per node.
    pub fn two_level(cost: CostModel, net: NetModel, k: usize, node_ranks: usize) -> CollectiveModel {
        assert!(node_ranks >= 1, "node_ranks must be >= 1");
        let shape = HierShape {
            max_group: node_ranks.min(k.max(1)),
            nodes: k.max(1).div_ceil(node_ranks),
            intra_speedup: INTRA_SPEEDUP,
        };
        CollectiveModel { cost, net, hier: Some(shape) }
    }

    /// `ceil(log2 n)` tree rounds; 0 for `n ≤ 1`.
    fn depth(n: usize) -> f64 {
        if n <= 1 {
            0.0
        } else {
            (n as f64).log2().ceil()
        }
    }

    /// The (intra-group size, node count) the schedule yields at `k`
    /// ranks — clamped so one-node configurations price no inter level.
    fn levels(&self, k: usize) -> Option<(usize, usize, f64)> {
        self.hier.map(|h| (h.max_group.min(k), h.nodes.min(k), h.intra_speedup))
    }

    /// Per-rank price of one `len`-word f64 allreduce over `k` ranks.
    /// Flat schedule moves the vector once per `ceil(log2 k)` round;
    /// two-level runs `ceil(log2 g)` intra rounds at [`INTRA_SPEEDUP`]×
    /// cheaper links plus `ceil(log2 nodes)` inter rounds over the
    /// (smaller, nearer) node set — strictly cheaper than flat whenever
    /// ranks span more than one node and every node holds ≥ 2 ranks.
    pub fn allreduce_secs(&self, k: usize, len: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let ab = self.cost.allreduce_base;
        let beta = self.cost.beta;
        let bytes = 8.0 * len as f64;
        match self.levels(k) {
            None => {
                let d = Self::depth(k);
                (ab * d + beta * (bytes * d)) * self.net.round_factor(k)
            }
            Some((g, nodes, sp)) => {
                let dg = Self::depth(g);
                let dn = Self::depth(nodes);
                let intra = if dg > 0.0 { (ab * dg + beta * (bytes * dg)) / sp } else { 0.0 };
                let inter = if dn > 0.0 {
                    (ab * dn + beta * (bytes * dn)) * self.net.round_factor(nodes)
                } else {
                    0.0
                };
                intra + inter
            }
        }
    }

    /// Per-rank price of one allgatherv over `k` ranks in which the
    /// rank contributes `local_words` of the `total_words` result
    /// (receive-dominated, like the executed pricing). The two-level
    /// schedule receives the on-node share over cheap links and only
    /// the off-node remainder over the fabric.
    pub fn allgather_secs(&self, k: usize, total_words: usize, local_words: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let ab = self.cost.allreduce_base;
        let beta = self.cost.beta;
        let recv = 8.0 * total_words.saturating_sub(local_words) as f64;
        match self.levels(k) {
            None => (ab * Self::depth(k) + beta * recv) * self.net.round_factor(k),
            Some((g, nodes, sp)) => {
                let dg = Self::depth(g);
                let dn = Self::depth(nodes);
                // Uniform-share estimate of the on-node slice.
                let node_share = 8.0 * total_words as f64 * g as f64 / k as f64;
                let intra_recv = (node_share - 8.0 * local_words as f64).max(0.0).min(recv);
                let inter_recv = (recv - intra_recv).max(0.0);
                let intra = if dg > 0.0 { (ab * dg + beta * intra_recv) / sp } else { 0.0 };
                let inter = if dn > 0.0 {
                    (ab * dn + beta * inter_recv) * self.net.round_factor(nodes)
                } else {
                    0.0
                };
                intra + inter
            }
        }
    }

    /// Per-rank price of one `len`-word broadcast over `k` ranks (the
    /// vector crosses each level once).
    pub fn broadcast_secs(&self, k: usize, len: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let ab = self.cost.allreduce_base;
        let bytes = 8.0 * len as f64;
        match self.levels(k) {
            None => (ab * Self::depth(k) + self.cost.beta * bytes) * self.net.round_factor(k),
            Some((g, nodes, sp)) => {
                let dg = Self::depth(g);
                let dn = Self::depth(nodes);
                let intra =
                    if dg > 0.0 { (ab * dg + self.cost.beta * bytes) / sp } else { 0.0 };
                let inter = if dn > 0.0 {
                    (ab * dn + self.cost.beta * bytes) * self.net.round_factor(nodes)
                } else {
                    0.0
                };
                intra + inter
            }
        }
    }

    /// Latency of one scalar reduction (the CG dot products). Mirrors
    /// the legacy floor of one `allreduce_base` even at `k = 1`.
    pub fn scalar_reduce_secs(&self, k: usize) -> f64 {
        let rounds = match self.levels(k) {
            None => Self::depth(k) * self.net.round_factor(k),
            Some((g, nodes, sp)) => {
                Self::depth(g) / sp + Self::depth(nodes) * self.net.round_factor(nodes)
            }
        };
        self.cost.allreduce_base * rounds.max(1.0)
    }

    /// Per-rank price of one halo exchange: `neighbors` messages of
    /// `words` f32 each. Flat schedule routes every message over the
    /// fabric at worst-case diameter; the two-level schedule keeps all
    /// but one neighbor on-node (the mesh-surface assumption the scale
    /// sweep encodes) when ranks span multiple nodes.
    pub fn halo_exchange_secs(&self, k: usize, neighbors: usize, words: usize) -> f64 {
        if k <= 1 || neighbors == 0 {
            return 0.0;
        }
        let msg = self.cost.alpha + self.cost.beta * 4.0 * words as f64;
        match self.levels(k) {
            None => neighbors as f64 * msg * self.net.round_factor(k),
            Some((_, nodes, sp)) if nodes > 1 => {
                (neighbors - 1) as f64 * msg / sp + msg * self.net.round_factor(nodes)
            }
            Some((_, _, sp)) => neighbors as f64 * msg / sp,
        }
    }

    /// Modeled per-rank seconds of one CG iteration's communication at
    /// `k` ranks: one halo exchange plus the two dot-product
    /// reductions. The number the `--matrix scale` sweep reports.
    pub fn cg_iteration_secs(&self, k: usize, neighbors: usize, halo_words: usize) -> f64 {
        self.halo_exchange_secs(k, neighbors, halo_words) + 2.0 * self.allreduce_secs(k, 1)
    }
}

/// Handle to an in-flight nonblocking halo exchange.
///
/// Returned by [`Comm::irecv_halo`] / [`Comm::isend_halo`] and redeemed
/// by [`Comm::test`] / [`Comm::wait`]. At most one exchange may be in
/// flight per rank; the handle identifies it (rank + sequence number)
/// so stale handles are caught in debug builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommRequest {
    rank: u32,
    seq: u32,
}

/// Transport-independent communication primitives, rank-facing.
///
/// The calling convention is split-phase (post, [`Comm::sync`], read) so
/// that the same rank-level step functions can be driven either by k OS
/// threads (each blocking in `sync`) or by a sequential superstep
/// executor (where `sync` is a no-op because the executor runs each
/// phase for every rank before starting the next).
///
/// The nonblocking subset (`irecv_halo`/`isend_halo`/`test`/`wait`/
/// `wait_all`) replaces the post → `sync` → read sequence for the halo
/// exchange with post → *overlapped compute* → wait → read; see the
/// module docs for the per-transport semantics and the single
/// in-flight-exchange-per-rank contract.
pub trait Comm: Sync {
    /// Number of ranks this transport connects.
    fn k(&self) -> usize;
    /// Scatter `rank`'s owned boundary values into neighbor inboxes.
    fn post_halo(&self, rank: usize, owned: &[f32]);
    /// Copy `rank`'s inbox into its ghost segment. Valid after `sync`
    /// (blocking path) or after the exchange's `wait` (nonblocking path).
    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]);
    /// Deposit a scalar partial on reduction channel `chan` (0 or 1).
    fn reduce_post(&self, chan: usize, rank: usize, v: f64);
    /// Rank-order sum of channel `chan`. Valid after `sync`.
    fn reduce_sum(&self, chan: usize) -> f64;
    /// Synchronization point between post and read phases.
    fn sync(&self, rank: usize);
    /// Per-rank communication seconds accumulated so far.
    fn comm_secs(&self) -> Vec<f64>;
    /// Short transport name (`"sim"` / `"threads"`).
    fn label(&self) -> &'static str;

    // ---- nonblocking extension -----------------------------------------

    /// Post the receive side of a nonblocking halo exchange for `rank`.
    /// Opens the rank's overlap region (at most one in flight).
    fn irecv_halo(&self, rank: usize) -> CommRequest;
    /// Post the send side: ship `rank`'s owned values toward its
    /// neighbors' ghost inboxes and return immediately. One aggregated
    /// message per destination rank.
    fn isend_halo(&self, rank: usize, owned: &[f32]) -> CommRequest;
    /// Report compute seconds `rank` performed *inside* the currently
    /// open overlap region (between `isend_halo` and `wait`). Priced
    /// transports use it to discount hidden communication; measured
    /// transports ignore it (their overlap is real).
    fn overlap_compute(&self, rank: usize, secs: f64);
    /// Poll: would `wait` on this request return without blocking?
    /// Transports may make partial progress (drain arrived messages).
    fn test(&self, rank: usize, req: CommRequest) -> bool;
    /// Complete the exchange: block until every expected message arrived
    /// (measured transports) or close the overlap region and charge the
    /// exposed communication (priced transports). After `wait`, the
    /// ghost values are readable via [`Comm::recv_halo`].
    fn wait(&self, rank: usize, req: CommRequest);
    /// Complete whatever exchange `rank` still has in flight (no-op when
    /// none is outstanding).
    fn wait_all(&self, rank: usize);
    /// Deposit partials on both reduction channels as **one combined
    /// message** — the single-reduction hook pipelined CG uses. Priced
    /// transports charge one allreduce latency instead of two.
    fn reduce_post_pair(&self, rank: usize, v0: f64, v1: f64) {
        self.reduce_post(0, rank, v0);
        self.reduce_post(1, rank, v1);
    }
    /// Per-rank communication seconds *hidden* behind overlapped compute
    /// so far (nonzero only for priced transports; measured transports
    /// realize the overlap instead of accounting it).
    fn comm_hidden_secs(&self) -> Vec<f64> {
        vec![0.0; self.k()]
    }

    // ---- generic rendezvous collectives --------------------------------
    //
    // MPI-flavored blocking collectives for algorithms that run *through*
    // the transport but outside the halo structure (distributed
    // partitioning runs before any partition exists). Unlike the
    // split-phase calls above, these synchronize internally, so they must
    // be invoked from k concurrent rank threads, every rank issuing the
    // same sequence of collective calls (k == 1 trivially passes). The
    // priced transport charges an α-β tree cost per call (free at k = 1);
    // the measured transport charges wall-clock including rendezvous
    // waits.

    /// Combine `data` element-wise across ranks (in place). `Sum` folds
    /// the contributions in rank order, so results are bit-deterministic
    /// regardless of thread scheduling; every rank must pass the same
    /// length.
    fn allreduce_vec(&self, rank: usize, data: &mut [f64], op: ReduceOp);
    /// Gather the variable-length per-rank contributions, concatenated in
    /// rank order; every rank receives the same vector.
    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64>;
    /// Personalized all-to-all: `parts[d]` is shipped to rank `d`;
    /// returns the parts addressed to `rank`, indexed by source rank.
    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>>;
    /// Replicate `root`'s vector on every rank (non-root `data` is
    /// overwritten).
    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>);
}

/// Shared state of the generic *rendezvous* collectives
/// ([`Comm::allreduce_vec`], [`Comm::allgatherv`], [`Comm::alltoallv`],
/// [`Comm::broadcast`]): per-rank contribution slots plus a dedicated
/// barrier. Every collective is a fixed sequence of barrier phases
/// (deposit, rendezvous, read, rendezvous — allreduce inserts a
/// leader-fold phase) so the slots can be reused by the next call.
///
/// Unlike the split-phase halo/reduction calls (which the sequential
/// superstep executor can drive one rank at a time), these collectives
/// block at a real [`Barrier`], so they must be called from `k`
/// concurrent rank threads (`k == 1` trivially passes). Both transports
/// share this mechanism; they differ only in how the call is *costed*
/// (α-β priced vs wall-clock measured).
struct Collectives {
    k: usize,
    barrier: Barrier,
    /// Per-rank contribution for allreduce/allgatherv/broadcast.
    parts: Vec<Mutex<Vec<f64>>>,
    /// The folded allreduce result (leader-written).
    reduced: Mutex<Vec<f64>>,
    /// Per *sender* rank: parts-by-destination for alltoallv.
    a2a: Vec<Mutex<Vec<Vec<f64>>>>,
    /// Two-level schedule (`None` = flat). With a schedule, the
    /// vector-valued collectives run staged: node leaders concatenate
    /// their group's contributions into `stage[node]` first, and the
    /// global step reads the stages instead of the raw slots. The
    /// staging moves data but performs **no arithmetic**, and contiguous
    /// ascending groups make (node order × within-node order) identical
    /// to flat rank order — so the results are bit-identical to the
    /// flat path (pinned by `tests/scale.rs`).
    sched: Option<HierSchedule>,
    /// Per-node staged concatenation (empty when `sched` is `None`).
    stage: Vec<Mutex<Vec<f64>>>,
}

impl Collectives {
    fn new(k: usize, sched: Option<HierSchedule>) -> Collectives {
        if let Some(s) = &sched {
            assert_eq!(s.k(), k, "hierarchical schedule covers {} ranks, transport has {k}", s.k());
        }
        let nodes = sched.as_ref().map_or(0, |s| s.nodes());
        Collectives {
            k,
            barrier: Barrier::new(k),
            parts: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
            reduced: Mutex::new(Vec::new()),
            a2a: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
            sched,
            stage: (0..nodes).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// The schedule, when it actually changes the execution (more than
    /// one node — a one-node schedule degenerates to the flat path).
    fn staging(&self) -> Option<&HierSchedule> {
        self.sched.as_ref().filter(|s| s.nodes() > 1)
    }

    /// Combine `data` element-wise across ranks (`Sum` in rank order).
    /// Every rank must pass the same length.
    ///
    /// One rank (the barrier leader — *which* one is irrelevant, the
    /// fold is in rank order either way) combines the slots once and the
    /// rest copy the result: Θ(k·len) total instead of every rank
    /// redoing the fold, so the measured transport's comm time reflects
    /// a real reduction, not k replicated ones.
    fn allreduce(&self, rank: usize, data: &mut [f64], op: ReduceOp) {
        if self.staging().is_some() {
            self.allreduce_staged(rank, data, op);
            return;
        }
        *self.parts[rank].lock().unwrap() = data.to_vec();
        if self.barrier.wait().is_leader() {
            let mut acc = self.parts[0].lock().unwrap().clone();
            debug_assert_eq!(acc.len(), data.len(), "allreduce_vec length mismatch");
            for r in 1..self.k {
                let part = self.parts[r].lock().unwrap();
                debug_assert_eq!(part.len(), acc.len(), "allreduce_vec length mismatch");
                for (a, &v) in acc.iter_mut().zip(part.iter()) {
                    match op {
                        ReduceOp::Sum => *a += v,
                        ReduceOp::Min => *a = a.min(v),
                        ReduceOp::Max => *a = a.max(v),
                    }
                }
            }
            *self.reduced.lock().unwrap() = acc;
        }
        self.barrier.wait();
        data.copy_from_slice(&self.reduced.lock().unwrap());
        self.barrier.wait();
    }

    /// The two-level allreduce: deposit → node leaders *concatenate*
    /// their group's slots into the node stage (data movement only, no
    /// arithmetic) → one rank folds the stages, reading them in node
    /// order and each stage in within-node rank order — which for the
    /// contiguous ascending groups [`HierSchedule`] requires is exactly
    /// the flat fold's rank order, hence bit-identical results → copy
    /// out. A genuinely nested per-node `Sum` fold would re-associate
    /// f64 addition and break the bit-identity contract.
    fn allreduce_staged(&self, rank: usize, data: &mut [f64], op: ReduceOp) {
        let s = self.sched.as_ref().unwrap();
        let len = data.len();
        *self.parts[rank].lock().unwrap() = data.to_vec();
        self.barrier.wait();
        if s.is_leader(rank) {
            let node = s.node_of(rank);
            let mut st = Vec::with_capacity(s.group(node).len() * len);
            for &r in s.group(node) {
                let part = self.parts[r].lock().unwrap();
                debug_assert_eq!(part.len(), len, "allreduce_vec length mismatch");
                st.extend_from_slice(&part);
            }
            *self.stage[node].lock().unwrap() = st;
        }
        if self.barrier.wait().is_leader() {
            let mut acc: Vec<f64> = Vec::new();
            if len > 0 {
                let mut first = true;
                for node in 0..s.nodes() {
                    let st = self.stage[node].lock().unwrap();
                    for part in st.chunks(len) {
                        if first {
                            acc = part.to_vec();
                            first = false;
                        } else {
                            for (a, &v) in acc.iter_mut().zip(part.iter()) {
                                match op {
                                    ReduceOp::Sum => *a += v,
                                    ReduceOp::Min => *a = a.min(v),
                                    ReduceOp::Max => *a = a.max(v),
                                }
                            }
                        }
                    }
                }
            }
            *self.reduced.lock().unwrap() = acc;
        }
        self.barrier.wait();
        data.copy_from_slice(&self.reduced.lock().unwrap());
        self.barrier.wait();
    }

    /// Concatenate the per-rank contributions in rank order. Returns the
    /// full concatenation (every rank gets the same vector).
    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64> {
        *self.parts[rank].lock().unwrap() = local.to_vec();
        self.barrier.wait();
        let out = if let Some(s) = self.staging() {
            // Leaders stage their node's concatenation; everyone then
            // concatenates the stages in node order — which is rank
            // order, so the result is bit-identical to the flat path.
            if s.is_leader(rank) {
                let node = s.node_of(rank);
                let mut st = Vec::new();
                for &r in s.group(node) {
                    st.extend_from_slice(&self.parts[r].lock().unwrap());
                }
                *self.stage[node].lock().unwrap() = st;
            }
            self.barrier.wait();
            let mut out = Vec::new();
            for node in 0..s.nodes() {
                out.extend_from_slice(&self.stage[node].lock().unwrap());
            }
            out
        } else {
            let mut out = Vec::new();
            for r in 0..self.k {
                out.extend_from_slice(&self.parts[r].lock().unwrap());
            }
            out
        };
        self.barrier.wait();
        out
    }

    /// Personalized exchange: `parts[d]` is shipped to rank `d`; the
    /// return value is indexed by *source* rank.
    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>> {
        debug_assert_eq!(parts.len(), self.k, "alltoallv needs one part per rank");
        *self.a2a[rank].lock().unwrap() = parts.to_vec();
        self.barrier.wait();
        let mut out = Vec::with_capacity(self.k);
        for r in 0..self.k {
            out.push(self.a2a[r].lock().unwrap()[rank].clone());
        }
        self.barrier.wait();
        out
    }

    /// Replicate `root`'s vector on every rank (non-root `data` is
    /// overwritten, resizing as needed).
    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>) {
        debug_assert!(root < self.k, "broadcast root {root} out of range");
        if rank == root {
            *self.parts[root].lock().unwrap() = data.clone();
        }
        self.barrier.wait();
        if let Some(s) = self.staging() {
            // Node leaders pull from the root once; their node-mates
            // read the local stage. Pure copies, so trivially
            // bit-identical to the flat path.
            if s.is_leader(rank) {
                *self.stage[s.node_of(rank)].lock().unwrap() =
                    self.parts[root].lock().unwrap().clone();
            }
            self.barrier.wait();
            if rank != root {
                *data = self.stage[s.node_of(rank)].lock().unwrap().clone();
            }
        } else if rank != root {
            *data = self.parts[root].lock().unwrap().clone();
        }
        self.barrier.wait();
    }
}

/// Shared mailbox state: per-rank ghost inboxes, two reduction channels,
/// and per-rank communication-cost accumulators.
struct Mailboxes {
    inboxes: Vec<Mutex<Vec<f32>>>,
    red: [Mutex<Vec<f64>>; 2],
    secs: Vec<Mutex<f64>>,
}

impl Mailboxes {
    fn new(plan: &ExchangePlan) -> Mailboxes {
        let k = plan.k();
        Mailboxes {
            inboxes: plan.ghost_len.iter().map(|&g| Mutex::new(vec![0.0; g])).collect(),
            red: [Mutex::new(vec![0.0; k]), Mutex::new(vec![0.0; k])],
            secs: (0..k).map(|_| Mutex::new(0.0)).collect(),
        }
    }

    fn scatter(&self, plan: &ExchangePlan, rank: usize, owned: &[f32]) {
        for seg in &plan.sends[rank] {
            let mut inbox = self.inboxes[seg.to as usize].lock().unwrap();
            for (&s, &d) in seg.src.iter().zip(&seg.dst) {
                inbox[d as usize] = owned[s as usize];
            }
        }
    }

    fn collect(&self, rank: usize, ghosts: &mut [f32]) {
        let inbox = self.inboxes[rank].lock().unwrap();
        ghosts.copy_from_slice(&inbox);
    }

    fn deposit(&self, chan: usize, rank: usize, v: f64) {
        self.red[chan].lock().unwrap()[rank] = v;
    }

    /// Deterministic rank-order sum.
    fn sum(&self, chan: usize) -> f64 {
        self.red[chan].lock().unwrap().iter().sum()
    }

    fn charge(&self, rank: usize, secs: f64) {
        *self.secs[rank].lock().unwrap() += secs;
    }

    fn secs(&self) -> Vec<f64> {
        self.secs.iter().map(|m| *m.lock().unwrap()).collect()
    }
}

/// One rank's pending overlap region in the priced transport: the α-β
/// exchange cost held back until `wait`, and the compute reported inside
/// the region so far.
#[derive(Debug, Default)]
struct OverlapRegion {
    open: bool,
    seq: u32,
    comm: f64,
    compute: f64,
}

/// The α-β *simulated* transport: data moves through in-process copies,
/// cost is charged by the model instead of measured.
///
/// Nonblocking exchanges are priced as overlap regions: the exchange's
/// α-β cost is held pending from `isend_halo` until `wait`, compute
/// reported via [`Comm::overlap_compute`] is subtracted, and only the
/// exposed remainder `max(comm − compute, 0)` is charged — so a fully
/// hidden exchange is free and a region costs `max(compute, comm)`
/// overall instead of `compute + comm`.
pub struct SimComm {
    plan: std::sync::Arc<ExchangePlan>,
    mb: Mailboxes,
    cost: CostModel,
    net: NetModel,
    hier: Option<HierSchedule>,
    regions: Vec<Mutex<OverlapRegion>>,
    hidden: Vec<Mutex<f64>>,
    colls: Collectives,
}

impl SimComm {
    /// Priced transport over `plan` with the given α-β constants, the
    /// legacy flat single-hop network, and the flat collective schedule.
    pub fn new(plan: std::sync::Arc<ExchangePlan>, cost: CostModel) -> SimComm {
        SimComm::with_net(plan, cost, NetModel::FlatAlphaBeta, None)
    }

    /// Priced transport with an explicit network model and optional
    /// two-level collective schedule. `with_net(plan, cost,
    /// FlatAlphaBeta, None)` is charge-for-charge identical to
    /// [`SimComm::new`] (pinned by `tests/scale.rs`).
    pub fn with_net(
        plan: std::sync::Arc<ExchangePlan>,
        cost: CostModel,
        net: NetModel,
        hier: Option<HierSchedule>,
    ) -> SimComm {
        let mb = Mailboxes::new(&plan);
        let k = plan.k();
        if let Some(h) = &hier {
            assert_eq!(h.k(), k, "hierarchical schedule covers {} ranks, plan has {k}", h.k());
        }
        SimComm {
            plan,
            mb,
            cost,
            net,
            hier: hier.clone(),
            regions: (0..k).map(|_| Mutex::new(OverlapRegion::default())).collect(),
            hidden: (0..k).map(|_| Mutex::new(0.0)).collect(),
            colls: Collectives::new(k, hier),
        }
    }

    /// Whether the legacy flat pricing applies verbatim. The flat branch
    /// runs the *original* formula code, not a hop-factor-1 rewrite:
    /// e.g. the legacy exchange cost β-prices the rank's aggregate send
    /// volume in one multiplication, and summing per-segment instead
    /// would change f64 rounding — the golden baselines notice.
    fn flat_priced(&self) -> bool {
        self.net.is_flat() && self.hier.is_none()
    }

    /// The closed-form pricing model matching this transport's
    /// configuration (used for the non-flat collective charges).
    fn model(&self) -> CollectiveModel {
        CollectiveModel {
            cost: self.cost,
            net: self.net,
            hier: self.hier.as_ref().map(|h| h.shape()),
        }
    }

    /// Price of one point-to-point message of `bytes` from `a` to `b`:
    /// intra-node messages ride the cheap links, inter-node messages pay
    /// α-β once per network hop.
    fn p2p_price(&self, a: usize, b: usize, bytes: f64) -> f64 {
        let base = self.cost.alpha + self.cost.beta * bytes;
        match &self.hier {
            Some(h) if h.same_node(a, b) => base / h.intra_speedup(),
            _ => base * self.net.hops(a, b).max(1.0),
        }
    }

    /// Tree depth of a k-rank collective: `ceil(log2 k)` rounds, so a
    /// single-rank "collective" is free — unlike the scalar reduction
    /// channels, whose legacy pricing floors at one latency.
    fn tree_depth(&self) -> f64 {
        let k = self.k();
        if k <= 1 {
            0.0
        } else {
            (k as f64).log2().ceil()
        }
    }

    /// Price one generic collective for one rank: `depth` latency rounds
    /// plus β per byte that actually crosses the transport.
    fn charge_collective(&self, rank: usize, bytes_moved: f64) {
        let depth = self.tree_depth();
        if depth > 0.0 {
            self.mb
                .charge(rank, self.cost.allreduce_base * depth + self.cost.beta * bytes_moved);
        }
    }

    /// The α-β price of one full halo exchange posted by `rank`.
    fn exchange_cost(&self, rank: usize) -> f64 {
        if self.flat_priced() {
            // Legacy single-hop formula, verbatim: α per neighbor plus β
            // over the rank's *aggregate* f32 send volume.
            self.cost.alpha * self.plan.neighbors(rank) as f64
                + self.cost.beta * self.plan.send_volume(rank) as f64 * 4.0
        } else {
            // Per-destination: each neighbor message priced by its own
            // hop count (or the intra-node discount).
            self.plan.sends[rank]
                .iter()
                .map(|seg| self.p2p_price(rank, seg.to as usize, seg.src.len() as f64 * 4.0))
                .sum()
        }
    }

    /// Close `rank`'s overlap region: charge the exposed communication,
    /// bank the hidden share.
    fn close_region(&self, rank: usize) {
        let mut reg = self.regions[rank].lock().unwrap();
        if !reg.open {
            return;
        }
        let exposed = (reg.comm - reg.compute).max(0.0);
        self.mb.charge(rank, exposed);
        *self.hidden[rank].lock().unwrap() += reg.comm - exposed;
        reg.open = false;
        reg.comm = 0.0;
        reg.compute = 0.0;
    }

    /// Open (or join) the current overlap region, returning its handle.
    fn open_region(&self, rank: usize) -> CommRequest {
        let mut reg = self.regions[rank].lock().unwrap();
        if !reg.open {
            reg.open = true;
            reg.seq = reg.seq.wrapping_add(1);
            reg.comm = 0.0;
            reg.compute = 0.0;
        }
        CommRequest { rank: rank as u32, seq: reg.seq }
    }
}

impl Comm for SimComm {
    fn k(&self) -> usize {
        self.plan.k()
    }

    fn post_halo(&self, rank: usize, owned: &[f32]) {
        self.mb.scatter(&self.plan, rank, owned);
        // α per neighbor message + β per word (f32 = 4 bytes), the exact
        // formula `ClusterSim::iteration` prices.
        self.mb.charge(rank, self.exchange_cost(rank));
    }

    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]) {
        self.mb.collect(rank, ghosts);
    }

    fn reduce_post(&self, chan: usize, rank: usize, v: f64) {
        self.mb.deposit(chan, rank, v);
        if self.flat_priced() {
            let k = self.k() as f64;
            self.mb.charge(rank, self.cost.allreduce_base * k.log2().max(1.0));
        } else {
            self.mb.charge(rank, self.model().scalar_reduce_secs(self.k()));
        }
    }

    fn reduce_sum(&self, chan: usize) -> f64 {
        self.mb.sum(chan)
    }

    fn sync(&self, _rank: usize) {
        // The sequential superstep executor orders phases globally.
    }

    fn comm_secs(&self) -> Vec<f64> {
        self.mb.secs()
    }

    fn label(&self) -> &'static str {
        "sim"
    }

    fn irecv_halo(&self, rank: usize) -> CommRequest {
        self.open_region(rank)
    }

    fn isend_halo(&self, rank: usize, owned: &[f32]) -> CommRequest {
        // Data moves immediately (in-process); only the *pricing* is
        // deferred to `wait`, into the overlap region.
        self.mb.scatter(&self.plan, rank, owned);
        let req = self.open_region(rank);
        self.regions[rank].lock().unwrap().comm += self.exchange_cost(rank);
        req
    }

    fn overlap_compute(&self, rank: usize, secs: f64) {
        let mut reg = self.regions[rank].lock().unwrap();
        if reg.open {
            reg.compute += secs;
        }
    }

    fn test(&self, rank: usize, req: CommRequest) -> bool {
        debug_assert_eq!(req.rank as usize, rank);
        // In-process copies complete at isend; the region stays open (and
        // priced) until `wait` closes it.
        true
    }

    fn wait(&self, rank: usize, req: CommRequest) {
        debug_assert_eq!(req.rank as usize, rank);
        debug_assert_eq!(req.seq, self.regions[rank].lock().unwrap().seq, "stale CommRequest");
        self.close_region(rank);
    }

    fn wait_all(&self, rank: usize) {
        self.close_region(rank);
    }

    fn reduce_post_pair(&self, rank: usize, v0: f64, v1: f64) {
        // One combined message: both scalars ride a single allreduce, so
        // a single latency charge (the pipelined-CG saving).
        self.mb.deposit(0, rank, v0);
        self.mb.deposit(1, rank, v1);
        if self.flat_priced() {
            let k = self.k() as f64;
            self.mb.charge(rank, self.cost.allreduce_base * k.log2().max(1.0));
        } else {
            self.mb.charge(rank, self.model().scalar_reduce_secs(self.k()));
        }
    }

    fn comm_hidden_secs(&self) -> Vec<f64> {
        self.hidden.iter().map(|m| *m.lock().unwrap()).collect()
    }

    fn allreduce_vec(&self, rank: usize, data: &mut [f64], op: ReduceOp) {
        if self.flat_priced() {
            // A tree allreduce moves the vector once per level.
            self.charge_collective(rank, 8.0 * data.len() as f64 * self.tree_depth());
        } else if self.k() > 1 {
            self.mb.charge(rank, self.model().allreduce_secs(self.k(), data.len()));
        }
        self.colls.allreduce(rank, data, op);
    }

    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64> {
        let out = self.colls.allgatherv(rank, local);
        if self.flat_priced() {
            // Receive-dominated: each rank pulls in everyone else's share.
            self.charge_collective(rank, 8.0 * (out.len() - local.len()) as f64);
        } else if self.k() > 1 {
            self.mb.charge(rank, self.model().allgather_secs(self.k(), out.len(), local.len()));
        }
        out
    }

    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let sent: usize = parts
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != rank)
            .map(|(_, p)| p.len())
            .sum();
        let out = self.colls.alltoallv(rank, parts);
        let recvd: usize = out
            .iter()
            .enumerate()
            .filter(|(s, _)| *s != rank)
            .map(|(_, p)| p.len())
            .sum();
        if self.k() > 1 {
            if self.flat_priced() {
                // One message per peer plus β for every word shipped
                // each way.
                self.mb.charge(
                    rank,
                    self.cost.alpha * (self.k() - 1) as f64
                        + self.cost.beta * 8.0 * (sent + recvd) as f64,
                );
            } else {
                // The transport knows exactly which pairs exchanged
                // data, so price each message by its own hops (still α
                // per peer even when the part is empty, matching the
                // flat model's per-peer latency).
                let mut secs = 0.0;
                for (d, p) in parts.iter().enumerate() {
                    if d != rank {
                        secs += self.p2p_price(rank, d, 8.0 * p.len() as f64);
                    }
                }
                // Receives: bandwidth only (the sender paid its α).
                for (s, p) in out.iter().enumerate() {
                    if s != rank {
                        let bytes = self.cost.beta * 8.0 * p.len() as f64;
                        secs += match &self.hier {
                            Some(h) if h.same_node(rank, s) => bytes / h.intra_speedup(),
                            _ => bytes * self.net.hops(rank, s).max(1.0),
                        };
                    }
                }
                self.mb.charge(rank, secs);
            }
        }
        out
    }

    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>) {
        if rank == root {
            if self.flat_priced() {
                // The payload length is known before the call on the
                // root only; price both ends from it (symmetric tree).
                self.charge_collective(rank, 8.0 * data.len() as f64);
            } else if self.k() > 1 {
                self.mb.charge(rank, self.model().broadcast_secs(self.k(), data.len()));
            }
        }
        self.colls.broadcast(rank, root, data);
        if rank != root {
            if self.flat_priced() {
                self.charge_collective(rank, 8.0 * data.len() as f64);
            } else if self.k() > 1 {
                self.mb.charge(rank, self.model().broadcast_secs(self.k(), data.len()));
            }
        }
    }
}

/// One in-flight notification of the nonblocking thread transport: the
/// sender's rank and segment index. The payload itself does not travel
/// through the channel — `isend_halo` writes it straight into the
/// receiver's inbox (a shared-memory "RMA put", batched per destination
/// under one inbox lock), and the mpsc send/recv pair provides the
/// happens-before edge that makes those writes visible at `wait`.
type NbMsg = (u32, u32);

/// The real shared-memory transport for thread-per-PU execution:
/// mutex-guarded inboxes plus a barrier; cost is measured wall-clock,
/// including time spent waiting at the barrier (the price of imbalance).
///
/// Nonblocking exchanges ride per-rank mpsc channels: `isend_halo` puts
/// the payload into each receiver's inbox (**one aggregated write +
/// notification per destination rank**, no per-iteration allocation) and
/// returns; `wait` blocks until every expected notification arrived. No
/// barrier is involved, so compute between `isend_halo` and `wait`
/// genuinely overlaps the other ranks' transfers.
pub struct ThreadComm {
    plan: std::sync::Arc<ExchangePlan>,
    mb: Mailboxes,
    barrier: Barrier,
    /// Per destination rank: the sending half of its in-flight channel.
    nb_tx: Vec<Mutex<Sender<NbMsg>>>,
    /// Per rank: the receiving half (only the owning rank drains it).
    nb_rx: Vec<Mutex<Receiver<NbMsg>>>,
    /// Per rank: incoming segments per exchange (static, from the plan).
    nb_expected: Vec<usize>,
    /// Per rank: segments drained so far in the current exchange.
    nb_got: Vec<Mutex<usize>>,
    /// Per rank: whether an exchange is in flight, and its sequence.
    nb_open: Vec<Mutex<(bool, u32)>>,
    colls: Collectives,
}

impl ThreadComm {
    /// Measured transport over `plan` for `plan.k()` rank threads.
    pub fn new(plan: std::sync::Arc<ExchangePlan>) -> ThreadComm {
        ThreadComm::with_schedule(plan, None)
    }

    /// Measured transport running the two-level collective schedule —
    /// the same staged phases [`SimComm`] prices, executed for real, so
    /// hierarchical results stay bit-identical across backends.
    pub fn with_schedule(
        plan: std::sync::Arc<ExchangePlan>,
        sched: Option<HierSchedule>,
    ) -> ThreadComm {
        let mb = Mailboxes::new(&plan);
        let k = plan.k();
        let barrier = Barrier::new(k);
        let mut nb_tx = Vec::with_capacity(k);
        let mut nb_rx = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = channel::<NbMsg>();
            nb_tx.push(Mutex::new(tx));
            nb_rx.push(Mutex::new(rx));
        }
        let mut nb_expected = vec![0usize; k];
        for segs in &plan.sends {
            for seg in segs {
                nb_expected[seg.to as usize] += 1;
            }
        }
        ThreadComm {
            plan,
            mb,
            barrier,
            nb_tx,
            nb_rx,
            nb_expected,
            nb_got: (0..k).map(|_| Mutex::new(0usize)).collect(),
            nb_open: (0..k).map(|_| Mutex::new((false, 0u32))).collect(),
            colls: Collectives::new(k, sched),
        }
    }

    /// Validate one arrived notification: the payload was already put
    /// into `rank`'s inbox by the sender before the token was sent.
    fn note_arrival(&self, rank: usize, msg: NbMsg) {
        let (from, seg_idx) = msg;
        debug_assert_eq!(
            self.plan.sends[from as usize][seg_idx as usize].to as usize,
            rank,
            "notification delivered to the wrong rank"
        );
    }

    /// Mark an exchange in flight for `rank` (idempotent within one
    /// exchange) and return its handle.
    fn open_exchange(&self, rank: usize) -> CommRequest {
        let mut st = self.nb_open[rank].lock().unwrap();
        if !st.0 {
            st.0 = true;
            st.1 = st.1.wrapping_add(1);
        }
        CommRequest { rank: rank as u32, seq: st.1 }
    }
}

impl Comm for ThreadComm {
    fn k(&self) -> usize {
        self.plan.k()
    }

    fn post_halo(&self, rank: usize, owned: &[f32]) {
        let t = Timer::start();
        self.mb.scatter(&self.plan, rank, owned);
        self.mb.charge(rank, t.secs());
    }

    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]) {
        let t = Timer::start();
        self.mb.collect(rank, ghosts);
        self.mb.charge(rank, t.secs());
    }

    fn reduce_post(&self, chan: usize, rank: usize, v: f64) {
        self.mb.deposit(chan, rank, v);
    }

    fn reduce_sum(&self, chan: usize) -> f64 {
        self.mb.sum(chan)
    }

    // Note: `Barrier` does not poison — if a rank thread panics between
    // barriers, the remaining ranks would wait forever. The executor
    // therefore validates everything that feeds rank arithmetic (speeds
    // finite, shapes checked) before any thread is spawned.
    fn sync(&self, rank: usize) {
        let t = Timer::start();
        self.barrier.wait();
        self.mb.charge(rank, t.secs());
    }

    fn comm_secs(&self) -> Vec<f64> {
        self.mb.secs()
    }

    fn label(&self) -> &'static str {
        "threads"
    }

    fn irecv_halo(&self, rank: usize) -> CommRequest {
        debug_assert_eq!(
            *self.nb_got[rank].lock().unwrap(),
            0,
            "previous exchange of rank {rank} not fully drained"
        );
        self.open_exchange(rank)
    }

    fn isend_halo(&self, rank: usize, owned: &[f32]) -> CommRequest {
        let t = Timer::start();
        // Put the payload into the receivers' inboxes first (the shared
        // scatter used by the blocking path — one loop body in the whole
        // transport), then post one notification per destination; the
        // channel's send→recv ordering publishes the inbox writes.
        self.mb.scatter(&self.plan, rank, owned);
        for (seg_idx, seg) in self.plan.sends[rank].iter().enumerate() {
            self.nb_tx[seg.to as usize]
                .lock()
                .unwrap()
                .send((rank as u32, seg_idx as u32))
                .expect("receiving rank hung up mid-exchange");
        }
        let req = self.open_exchange(rank);
        self.mb.charge(rank, t.secs());
        req
    }

    fn overlap_compute(&self, _rank: usize, _secs: f64) {
        // Measured transport: the overlap is real, nothing to discount.
    }

    fn test(&self, rank: usize, req: CommRequest) -> bool {
        debug_assert_eq!(req.rank as usize, rank);
        debug_assert_eq!(req.seq, self.nb_open[rank].lock().unwrap().1, "stale CommRequest");
        let mut got = self.nb_got[rank].lock().unwrap();
        loop {
            if *got >= self.nb_expected[rank] {
                return true;
            }
            match self.nb_rx[rank].lock().unwrap().try_recv() {
                Ok(msg) => {
                    self.note_arrival(rank, msg);
                    *got += 1;
                }
                Err(TryRecvError::Empty) => return false,
                Err(TryRecvError::Disconnected) => {
                    panic!("sending rank hung up mid-exchange")
                }
            }
        }
    }

    fn wait(&self, rank: usize, req: CommRequest) {
        debug_assert_eq!(req.rank as usize, rank);
        debug_assert_eq!(req.seq, self.nb_open[rank].lock().unwrap().1, "stale CommRequest");
        let t = Timer::start();
        let mut got = self.nb_got[rank].lock().unwrap();
        while *got < self.nb_expected[rank] {
            let msg = self.nb_rx[rank]
                .lock()
                .unwrap()
                .recv()
                .expect("sending rank hung up mid-exchange");
            self.note_arrival(rank, msg);
            *got += 1;
        }
        *got = 0;
        self.nb_open[rank].lock().unwrap().0 = false;
        self.mb.charge(rank, t.secs());
    }

    fn wait_all(&self, rank: usize) {
        let (outstanding, seq) = *self.nb_open[rank].lock().unwrap();
        if outstanding {
            self.wait(rank, CommRequest { rank: rank as u32, seq });
        }
    }

    fn comm_hidden_secs(&self) -> Vec<f64> {
        // Measured transport: hidden time shows up as *absent* wall-clock,
        // not as an accounting line.
        vec![0.0; self.k()]
    }

    // The measured transport charges each rank the wall-clock of the
    // whole collective, rendezvous waits included — lagging into a
    // collective is the thread analogue of arriving late at the barrier.

    fn allreduce_vec(&self, rank: usize, data: &mut [f64], op: ReduceOp) {
        let t = Timer::start();
        self.colls.allreduce(rank, data, op);
        self.mb.charge(rank, t.secs());
    }

    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64> {
        let t = Timer::start();
        let out = self.colls.allgatherv(rank, local);
        self.mb.charge(rank, t.secs());
        out
    }

    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let t = Timer::start();
        let out = self.colls.alltoallv(rank, parts);
        self.mb.charge(rank, t.secs());
        out
    }

    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>) {
        let t = Timer::start();
        self.colls.broadcast(rank, root, data);
        self.mb.charge(rank, t.secs());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::partition::Partition;
    use crate::solver::EllMatrix;
    use std::sync::Arc;

    fn setup() -> (HaloMatrix, Partition) {
        let g = mesh_2d_tri(16, 16, 3);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let part = Partition::new(
            (0..g.n())
                .map(|u| u32::from(g.coords[u].x > 7.5) + 2 * u32::from(g.coords[u].y > 7.5))
                .collect(),
            4,
        );
        (HaloMatrix::new(&ell, &part), part)
    }

    #[test]
    fn plan_mirrors_halo_send_lists() {
        let (h, part) = setup();
        let plan = ExchangePlan::new(&h, &part);
        assert_eq!(plan.k(), 4);
        for b in 0..4 {
            assert_eq!(plan.send_volume(b), h.send_volume(b));
            assert_eq!(plan.own_len[b], h.blocks[b].own.len());
            assert_eq!(plan.ghost_len[b], h.blocks[b].ghosts.len());
            for seg in &plan.sends[b] {
                assert_eq!(seg.src.len(), seg.dst.len());
                // Every destination slot is a valid ghost index of the
                // receiver and is owned by the sender.
                for &d in &seg.dst {
                    let g = h.blocks[seg.to as usize].ghosts[d as usize];
                    assert_eq!(part.assignment[g as usize] as usize, b);
                }
            }
        }
    }

    #[test]
    fn sim_exchange_delivers_ghost_values() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan.clone(), CostModel::default());
        // Owned value = global id, so ghosts must receive their global id.
        for b in 0..4 {
            let owned: Vec<f32> = h.blocks[b].own.iter().map(|&g| g as f32).collect();
            comm.post_halo(b, &owned);
        }
        for b in 0..4 {
            let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
            comm.recv_halo(b, &mut ghosts);
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(ghosts[j], g as f32, "rank {b} ghost {j}");
            }
        }
        // Cost accounting matches the α-β formula.
        let secs = comm.comm_secs();
        for b in 0..4 {
            let want = 2e-6 * plan.neighbors(b) as f64 + 1e-9 * plan.send_volume(b) as f64 * 4.0;
            assert!((secs[b] - want).abs() < 1e-15, "rank {b}: {} vs {want}", secs[b]);
        }
    }

    #[test]
    fn reductions_sum_in_rank_order() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan, CostModel::default());
        for b in 0..4 {
            comm.reduce_post(0, b, (b + 1) as f64);
            comm.reduce_post(1, b, 0.5);
        }
        assert_eq!(comm.reduce_sum(0), 10.0);
        assert_eq!(comm.reduce_sum(1), 2.0);
    }

    #[test]
    fn sim_nonblocking_prices_max_not_sum() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let cost = CostModel::default();
        let comm = SimComm::new(plan.clone(), cost);
        // Rank 0: fully hidden (plenty of overlapped compute); rank 1:
        // no overlapped compute (fully exposed); rank 2: half hidden.
        let full: Vec<f64> = (0..4)
            .map(|b| {
                cost.alpha * plan.neighbors(b) as f64
                    + cost.beta * plan.send_volume(b) as f64 * 4.0
            })
            .collect();
        for b in 0..4 {
            let owned: Vec<f32> = h.blocks[b].own.iter().map(|&g| g as f32).collect();
            let rq = comm.irecv_halo(b);
            let rq2 = comm.isend_halo(b, &owned);
            assert_eq!(rq, rq2, "both handles name the same in-flight exchange");
            match b {
                0 => comm.overlap_compute(b, 1.0),
                2 => comm.overlap_compute(b, full[2] / 2.0),
                _ => {}
            }
            assert!(comm.test(b, rq), "sim data is delivered at isend");
            comm.wait(b, rq);
        }
        let secs = comm.comm_secs();
        let hidden = comm.comm_hidden_secs();
        assert!(secs[0].abs() < 1e-18, "fully hidden exchange must be free: {}", secs[0]);
        assert!((hidden[0] - full[0]).abs() < 1e-15);
        assert!((secs[1] - full[1]).abs() < 1e-15, "no compute → fully exposed");
        assert!(hidden[1].abs() < 1e-18);
        assert!((secs[2] - full[2] / 2.0).abs() < 1e-15, "half hidden");
        assert!((hidden[2] - full[2] / 2.0).abs() < 1e-15);
        // Exchanged data is identical to the blocking path.
        for b in 0..4 {
            let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
            comm.recv_halo(b, &mut ghosts);
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(ghosts[j], g as f32, "rank {b} ghost {j}");
            }
        }
    }

    #[test]
    fn sim_combined_reduction_charges_one_latency() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let single = SimComm::new(plan.clone(), CostModel::default());
        let paired = SimComm::new(plan, CostModel::default());
        for b in 0..4 {
            single.reduce_post(0, b, b as f64);
            single.reduce_post(1, b, 2.0 * b as f64);
            paired.reduce_post_pair(b, b as f64, 2.0 * b as f64);
        }
        assert_eq!(single.reduce_sum(0), paired.reduce_sum(0));
        assert_eq!(single.reduce_sum(1), paired.reduce_sum(1));
        for b in 0..4 {
            assert!(
                (single.comm_secs()[b] - 2.0 * paired.comm_secs()[b]).abs() < 1e-15,
                "pair must cost half of two posts"
            );
        }
    }

    #[test]
    fn thread_nonblocking_exchange_under_threads() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = ThreadComm::new(plan.clone());
        let h = &h;
        let results: Vec<Vec<f32>> = {
            let mut out: Vec<Mutex<Vec<f32>>> = (0..4).map(|_| Mutex::new(Vec::new())).collect();
            std::thread::scope(|scope| {
                for (b, slot) in out.iter_mut().enumerate() {
                    let comm = &comm;
                    let plan = &plan;
                    scope.spawn(move || {
                        let owned: Vec<f32> =
                            h.blocks[b].own.iter().map(|&g| g as f32).collect();
                        let rq = comm.irecv_halo(b);
                        comm.isend_halo(b, &owned);
                        // Poll a few times (partial progress is legal),
                        // then block.
                        for _ in 0..3 {
                            if comm.test(b, rq) {
                                break;
                            }
                        }
                        comm.wait(b, rq);
                        let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
                        comm.recv_halo(b, &mut ghosts);
                        *slot.lock().unwrap() = ghosts;
                    });
                }
            });
            out.into_iter().map(|m| m.into_inner().unwrap()).collect()
        };
        for b in 0..4 {
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(results[b][j], g as f32, "rank {b} ghost {j}");
            }
        }
        // Hidden accounting stays zero on the measured transport.
        assert!(comm.comm_hidden_secs().iter().all(|&s| s == 0.0));
    }

    #[test]
    fn wait_all_completes_outstanding_and_tolerates_idle_ranks() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan.clone(), CostModel::default());
        // Nothing outstanding: wait_all is a no-op.
        comm.wait_all(0);
        assert!(comm.comm_secs()[0].abs() < 1e-18);
        let owned: Vec<f32> = h.blocks[0].own.iter().map(|&g| g as f32).collect();
        comm.isend_halo(0, &owned);
        comm.wait_all(0);
        assert!(comm.comm_secs()[0] > 0.0, "outstanding exchange must be charged");
    }

    /// Run `f(rank)` on k concurrent rank threads, collecting results in
    /// rank order (the calling convention the rendezvous collectives
    /// require).
    fn on_ranks<R: Send>(k: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
        let slots: Vec<Mutex<Option<R>>> = (0..k).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for (rank, slot) in slots.iter().enumerate() {
                let f = &f;
                scope.spawn(move || {
                    *slot.lock().unwrap() = Some(f(rank));
                });
            }
        });
        slots.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
    }

    #[test]
    fn collectives_sum_in_rank_order_and_agree_across_backends() {
        let k = 4;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let sim = SimComm::new(plan.clone(), CostModel::default());
        let thr = ThreadComm::new(plan);
        let input = |rank: usize| -> Vec<f64> {
            (0..5).map(|i| (rank * 10 + i) as f64 * 0.37).collect()
        };
        let via = |comm: &dyn Comm| -> Vec<Vec<f64>> {
            on_ranks(k, |rank| {
                let mut v = input(rank);
                comm.allreduce_vec(rank, &mut v, ReduceOp::Sum);
                v
            })
        };
        let s = via(&sim);
        let t = via(&thr);
        // Rank-order fold reference.
        let mut want = input(0);
        for r in 1..k {
            for (w, v) in want.iter_mut().zip(input(r)) {
                *w += v;
            }
        }
        for rank in 0..k {
            assert_eq!(s[rank], want, "sim rank {rank}");
            assert_eq!(t[rank], want, "threads rank {rank}");
        }
        // Priced cost recorded on sim, measured on threads.
        assert!(sim.comm_secs().iter().all(|&c| c > 0.0));
        assert!(thr.comm_secs().iter().all(|&c| c >= 0.0));
    }

    #[test]
    fn allgatherv_concatenates_and_broadcast_replicates() {
        let k = 3;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let comm = SimComm::new(plan, CostModel::default());
        let gathered = on_ranks(k, |rank| {
            let local: Vec<f64> = (0..=rank).map(|i| i as f64 + rank as f64).collect();
            comm.allgatherv(rank, &local)
        });
        let want = vec![0.0, 1.0, 2.0, 2.0, 3.0, 4.0];
        for (rank, g) in gathered.iter().enumerate() {
            assert_eq!(g, &want, "rank {rank}");
        }
        let bcast = on_ranks(k, |rank| {
            let mut v = if rank == 1 { vec![7.0, 8.0, 9.0] } else { Vec::new() };
            comm.broadcast(rank, 1, &mut v);
            v
        });
        for (rank, b) in bcast.iter().enumerate() {
            assert_eq!(b, &vec![7.0, 8.0, 9.0], "rank {rank}");
        }
    }

    #[test]
    fn alltoallv_is_a_transpose() {
        let k = 3;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let comm = ThreadComm::new(plan);
        let part = |from: usize, to: usize| -> Vec<f64> {
            (0..(from + to) % 3).map(|i| (from * 100 + to * 10 + i) as f64).collect()
        };
        let got = on_ranks(k, |rank| {
            let parts: Vec<Vec<f64>> = (0..k).map(|d| part(rank, d)).collect();
            comm.alltoallv(rank, &parts)
        });
        for to in 0..k {
            for from in 0..k {
                assert_eq!(got[to][from], part(from, to), "{from} -> {to}");
            }
        }
    }

    #[test]
    fn single_rank_collectives_are_free_and_trivial() {
        let plan = Arc::new(ExchangePlan::collectives_only(1));
        let comm = SimComm::new(plan, CostModel::default());
        let mut v = vec![1.5, -2.0];
        comm.allreduce_vec(0, &mut v, ReduceOp::Sum);
        assert_eq!(v, vec![1.5, -2.0]);
        comm.allreduce_vec(0, &mut v, ReduceOp::Min);
        assert_eq!(v, vec![1.5, -2.0]);
        assert_eq!(comm.allgatherv(0, &v), v);
        let mut b = vec![3.0];
        comm.broadcast(0, 0, &mut b);
        let back = comm.alltoallv(0, &[vec![9.0]]);
        assert_eq!(back, vec![vec![9.0]]);
        assert_eq!(comm.comm_secs(), vec![0.0], "self-collectives must be free");
    }

    #[test]
    fn thread_comm_exchange_under_threads() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = ThreadComm::new(plan.clone());
        let h = &h;
        let results: Vec<Vec<f32>> = {
            let mut out: Vec<Mutex<Vec<f32>>> = (0..4).map(|_| Mutex::new(Vec::new())).collect();
            std::thread::scope(|scope| {
                for (b, slot) in out.iter_mut().enumerate() {
                    let comm = &comm;
                    let plan = &plan;
                    scope.spawn(move || {
                        let owned: Vec<f32> =
                            h.blocks[b].own.iter().map(|&g| g as f32).collect();
                        comm.post_halo(b, &owned);
                        comm.sync(b);
                        let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
                        comm.recv_halo(b, &mut ghosts);
                        *slot.lock().unwrap() = ghosts;
                    });
                }
            });
            out.into_iter().map(|m| m.into_inner().unwrap()).collect()
        };
        for b in 0..4 {
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(results[b][j], g as f32, "rank {b} ghost {j}");
            }
        }
    }

    #[test]
    fn net_model_hops_are_symmetric_with_zero_diagonal() {
        for net in [NetModel::FlatAlphaBeta, NetModel::fat_tree(), NetModel::torus_for(16)] {
            for a in 0..16 {
                assert_eq!(net.hops(a, a), 0.0, "{} self-hops", net.name());
                for b in 0..16 {
                    assert_eq!(net.hops(a, b), net.hops(b, a), "{} asymmetric", net.name());
                    if a != b {
                        assert!(net.hops(a, b) >= 1.0, "{} hops below one", net.name());
                    }
                }
            }
        }
    }

    #[test]
    fn fat_tree_hops_grow_with_block_distance() {
        let net = NetModel::FatTree { radix: 4 };
        assert_eq!(net.hops(0, 1), 2.0, "same edge switch");
        assert_eq!(net.hops(0, 5), 4.0, "one level up");
        assert_eq!(net.hops(0, 17), 6.0, "two levels up");
    }

    #[test]
    fn torus_hops_wrap_around() {
        let net = NetModel::Torus { dims: [4, 4] };
        // (0,0) → (3,0): wraparound distance 1, not 3.
        assert_eq!(net.hops(0, 3), 1.0);
        // (0,0) → (2,2): 2 + 2.
        assert_eq!(net.hops(0, 10), 4.0);
    }

    #[test]
    fn round_factor_is_monotone_in_participants() {
        for net in [NetModel::fat_tree(), NetModel::torus_for(16384)] {
            let mut prev = 0.0;
            for n in [1usize, 2, 64, 256, 1024, 4096, 16384] {
                let f = net.round_factor(n);
                assert!(f >= prev, "{} round factor dropped at n={n}", net.name());
                assert!(f >= 1.0);
                prev = f;
            }
        }
        assert_eq!(NetModel::FlatAlphaBeta.round_factor(16384), 1.0);
    }

    #[test]
    fn net_kind_parses_and_materializes() {
        assert_eq!(NetKind::parse("flat"), Some(NetKind::Flat));
        assert_eq!(NetKind::parse("fat-tree"), Some(NetKind::FatTree));
        assert_eq!(NetKind::parse("TORUS"), Some(NetKind::Torus));
        assert_eq!(NetKind::parse("mesh"), None);
        assert!(NetKind::Flat.model(8).is_flat());
        assert_eq!(NetKind::Torus.model(16).name(), "torus4x4");
        for kind in NetKind::ALL {
            assert_eq!(NetKind::parse(kind.name()), Some(kind));
        }
    }

    #[test]
    fn hier_schedule_uniform_partitions_ranks() {
        let s = HierSchedule::uniform(10, 4);
        assert_eq!(s.k(), 10);
        assert_eq!(s.nodes(), 3);
        assert_eq!(s.group(0), &[0, 1, 2, 3]);
        assert_eq!(s.group(2), &[8, 9]);
        assert_eq!(s.max_group(), 4);
        assert!(s.is_leader(0) && s.is_leader(4) && s.is_leader(8));
        assert!(!s.is_leader(1));
        assert!(s.same_node(4, 7) && !s.same_node(3, 4));
        assert_eq!(s.shape().nodes, 3);
    }

    #[test]
    #[should_panic(expected = "contiguously")]
    fn hier_schedule_rejects_non_contiguous_groups() {
        HierSchedule::new(vec![vec![0, 2], vec![1, 3]]);
    }

    #[test]
    fn collective_model_flat_matches_legacy_allreduce_charge() {
        let cost = CostModel::default();
        let m = CollectiveModel::flat_schedule(cost, NetModel::FlatAlphaBeta);
        for k in [2usize, 4, 8, 64] {
            let d = (k as f64).log2().ceil();
            let len = 17usize;
            let expect = cost.allreduce_base * d + cost.beta * (8.0 * len as f64 * d);
            assert_eq!(m.allreduce_secs(k, len), expect, "k={k}");
        }
        assert_eq!(m.allreduce_secs(1, 100), 0.0);
    }

    #[test]
    fn two_level_allreduce_prices_strictly_below_flat_beyond_one_node() {
        let cost = CostModel::default();
        for net in [NetModel::FlatAlphaBeta, NetModel::fat_tree()] {
            for k in [128usize, 1024, 16384] {
                let flat = CollectiveModel::flat_schedule(cost, net);
                let hier = CollectiveModel::two_level(cost, net, k, 64);
                assert!(
                    hier.allreduce_secs(k, 100) < flat.allreduce_secs(k, 100),
                    "hier not cheaper at k={k} on {}",
                    net.name()
                );
            }
        }
    }

    #[test]
    fn sim_with_net_flat_matches_legacy_charges() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let legacy = SimComm::new(plan.clone(), CostModel::default());
        let seamed =
            SimComm::with_net(plan.clone(), CostModel::default(), NetModel::FlatAlphaBeta, None);
        for rank in 0..plan.k() {
            let owned: Vec<f32> = h.blocks[rank].own.iter().map(|&g| g as f32).collect();
            legacy.post_halo(rank, &owned);
            seamed.post_halo(rank, &owned);
            legacy.reduce_post(0, rank, 1.0);
            seamed.reduce_post(0, rank, 1.0);
        }
        assert_eq!(legacy.comm_secs(), seamed.comm_secs());
    }

    #[test]
    fn sim_nonflat_halo_charges_more_than_flat() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let flat = SimComm::new(plan.clone(), CostModel::default());
        // Radix-2 fat tree: every cross-rank message crosses ≥ 2 hops,
        // so the hop-priced halo must be *strictly* dearer than flat.
        let tree = SimComm::with_net(
            plan.clone(),
            CostModel::default(),
            NetModel::FatTree { radix: 2 },
            None,
        );
        for rank in 0..plan.k() {
            let owned: Vec<f32> = h.blocks[rank].own.iter().map(|&g| g as f32).collect();
            flat.post_halo(rank, &owned);
            tree.post_halo(rank, &owned);
        }
        for rank in 0..plan.k() {
            if plan.neighbors(rank) > 0 {
                assert!(
                    tree.comm_secs()[rank] > flat.comm_secs()[rank],
                    "hop-priced halo not dearer than flat at rank {rank}"
                );
            }
        }
    }
}
