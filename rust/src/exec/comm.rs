//! The `Comm` seam of the virtual-cluster engine: halo exchange and
//! allreduce behind one trait, independent of the transport — the same
//! separation bale/convey draws between conveyor semantics and the
//! underlying communication layer.
//!
//! Two transports:
//! - [`SimComm`]: in-process copies whose cost is *priced* by the α-β
//!   model (the transport the old sequential simulator embodied). Used
//!   by the sequential superstep executor, so `sync` is a no-op — the
//!   executor orders phases globally.
//! - [`ThreadComm`]: a real shared-memory transport for thread-per-PU
//!   execution — per-rank inboxes behind mutexes plus a [`Barrier`];
//!   communication cost is *measured* wall-clock (scatter + copy + wait).
//!
//! Both transports implement the reductions identically — each rank's
//! partial is deposited into a slot and the sum is taken in rank order —
//! so dot products are bit-identical regardless of thread scheduling.
//! That determinism is what lets the `threads` backend reproduce the
//! `sim` backend's residual trajectory exactly.

use crate::partition::Partition;
use crate::solver::halo::HaloMatrix;
use crate::util::timer::Timer;
use std::sync::{Barrier, Mutex};

/// One rank's outgoing traffic to one neighbor.
#[derive(Debug, Clone)]
pub struct SendSegment {
    /// Receiving rank.
    pub to: u32,
    /// Owned-local indices to read on the sender.
    pub src: Vec<u32>,
    /// Ghost slots to fill on the receiver (parallel to `src`).
    pub dst: Vec<u32>,
}

/// The static exchange pattern of a partitioned matrix: who sends which
/// owned values into whose ghost slots. Derived once from the halo
/// structure; every [`Comm`] transport executes the same plan.
#[derive(Debug, Clone)]
pub struct ExchangePlan {
    /// Per rank: outgoing segments.
    pub sends: Vec<Vec<SendSegment>>,
    /// Per rank: number of ghost entries (inbox size).
    pub ghost_len: Vec<usize>,
    /// Per rank: number of owned rows.
    pub own_len: Vec<usize>,
}

impl ExchangePlan {
    /// Build the plan from a halo decomposition. The receiver slots are
    /// the mirror image of the sender lists by construction (asserted by
    /// `halo`'s `send_lists_are_mirror_of_ghosts` test).
    pub fn new(h: &HaloMatrix, part: &Partition) -> ExchangePlan {
        let k = h.blocks.len();
        let mut sends: Vec<Vec<SendSegment>> = Vec::with_capacity(k);
        for o in 0..k {
            let mut segs = Vec::new();
            for (to, src) in &h.blocks[o].send_lists {
                // Ghost slots on the receiver owned by `o`, in ghost
                // order — exactly the order `src` was built in.
                let dst: Vec<u32> = h.blocks[*to as usize]
                    .ghosts
                    .iter()
                    .enumerate()
                    .filter(|(_, &g)| part.assignment[g as usize] as usize == o)
                    .map(|(j, _)| j as u32)
                    .collect();
                debug_assert_eq!(dst.len(), src.len());
                segs.push(SendSegment { to: *to, src: src.clone(), dst });
            }
            sends.push(segs);
        }
        ExchangePlan {
            ghost_len: h.blocks.iter().map(|b| b.ghosts.len()).collect(),
            own_len: h.blocks.iter().map(|b| b.own.len()).collect(),
            sends,
        }
    }

    pub fn k(&self) -> usize {
        self.own_len.len()
    }

    /// Words sent by `rank` per exchange.
    pub fn send_volume(&self, rank: usize) -> usize {
        self.sends[rank].iter().map(|s| s.src.len()).sum()
    }

    /// Number of neighbors `rank` sends to.
    pub fn neighbors(&self, rank: usize) -> usize {
        self.sends[rank].len()
    }
}

/// α-β communication constants for the simulated transport (mirrors
/// `solver::ClusterSim`, which converts into this).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency (s).
    pub alpha: f64,
    /// Per-word transfer time (s).
    pub beta: f64,
    /// Per-nonzero SpMV time on a speed-1 PU (s).
    pub t_flop: f64,
    /// Allreduce latency factor per synchronization.
    pub allreduce_base: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { alpha: 2e-6, beta: 1e-9, t_flop: 2e-9, allreduce_base: 1e-6 }
    }
}

/// Transport-independent communication primitives, rank-facing.
///
/// The calling convention is split-phase (post, [`Comm::sync`], read) so
/// that the same rank-level step functions can be driven either by k OS
/// threads (each blocking in `sync`) or by a sequential superstep
/// executor (where `sync` is a no-op because the executor runs each
/// phase for every rank before starting the next).
pub trait Comm: Sync {
    fn k(&self) -> usize;
    /// Scatter `rank`'s owned boundary values into neighbor inboxes.
    fn post_halo(&self, rank: usize, owned: &[f32]);
    /// Copy `rank`'s inbox into its ghost segment. Valid after `sync`.
    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]);
    /// Deposit a scalar partial on reduction channel `chan` (0 or 1).
    fn reduce_post(&self, chan: usize, rank: usize, v: f64);
    /// Rank-order sum of channel `chan`. Valid after `sync`.
    fn reduce_sum(&self, chan: usize) -> f64;
    /// Synchronization point between post and read phases.
    fn sync(&self, rank: usize);
    /// Per-rank communication seconds accumulated so far.
    fn comm_secs(&self) -> Vec<f64>;
    fn label(&self) -> &'static str;
}

/// Shared mailbox state: per-rank ghost inboxes, two reduction channels,
/// and per-rank communication-cost accumulators.
struct Mailboxes {
    inboxes: Vec<Mutex<Vec<f32>>>,
    red: [Mutex<Vec<f64>>; 2],
    secs: Vec<Mutex<f64>>,
}

impl Mailboxes {
    fn new(plan: &ExchangePlan) -> Mailboxes {
        let k = plan.k();
        Mailboxes {
            inboxes: plan.ghost_len.iter().map(|&g| Mutex::new(vec![0.0; g])).collect(),
            red: [Mutex::new(vec![0.0; k]), Mutex::new(vec![0.0; k])],
            secs: (0..k).map(|_| Mutex::new(0.0)).collect(),
        }
    }

    fn scatter(&self, plan: &ExchangePlan, rank: usize, owned: &[f32]) {
        for seg in &plan.sends[rank] {
            let mut inbox = self.inboxes[seg.to as usize].lock().unwrap();
            for (&s, &d) in seg.src.iter().zip(&seg.dst) {
                inbox[d as usize] = owned[s as usize];
            }
        }
    }

    fn collect(&self, rank: usize, ghosts: &mut [f32]) {
        let inbox = self.inboxes[rank].lock().unwrap();
        ghosts.copy_from_slice(&inbox);
    }

    fn deposit(&self, chan: usize, rank: usize, v: f64) {
        self.red[chan].lock().unwrap()[rank] = v;
    }

    /// Deterministic rank-order sum.
    fn sum(&self, chan: usize) -> f64 {
        self.red[chan].lock().unwrap().iter().sum()
    }

    fn charge(&self, rank: usize, secs: f64) {
        *self.secs[rank].lock().unwrap() += secs;
    }

    fn secs(&self) -> Vec<f64> {
        self.secs.iter().map(|m| *m.lock().unwrap()).collect()
    }
}

/// The α-β *simulated* transport: data moves through in-process copies,
/// cost is charged by the model instead of measured.
pub struct SimComm {
    plan: std::sync::Arc<ExchangePlan>,
    mb: Mailboxes,
    cost: CostModel,
}

impl SimComm {
    pub fn new(plan: std::sync::Arc<ExchangePlan>, cost: CostModel) -> SimComm {
        let mb = Mailboxes::new(&plan);
        SimComm { plan, mb, cost }
    }
}

impl Comm for SimComm {
    fn k(&self) -> usize {
        self.plan.k()
    }

    fn post_halo(&self, rank: usize, owned: &[f32]) {
        self.mb.scatter(&self.plan, rank, owned);
        // α per neighbor message + β per word (f32 = 4 bytes), the exact
        // formula `ClusterSim::iteration` prices.
        let cost = self.cost.alpha * self.plan.neighbors(rank) as f64
            + self.cost.beta * self.plan.send_volume(rank) as f64 * 4.0;
        self.mb.charge(rank, cost);
    }

    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]) {
        self.mb.collect(rank, ghosts);
    }

    fn reduce_post(&self, chan: usize, rank: usize, v: f64) {
        self.mb.deposit(chan, rank, v);
        let k = self.k() as f64;
        self.mb.charge(rank, self.cost.allreduce_base * k.log2().max(1.0));
    }

    fn reduce_sum(&self, chan: usize) -> f64 {
        self.mb.sum(chan)
    }

    fn sync(&self, _rank: usize) {
        // The sequential superstep executor orders phases globally.
    }

    fn comm_secs(&self) -> Vec<f64> {
        self.mb.secs()
    }

    fn label(&self) -> &'static str {
        "sim"
    }
}

/// The real shared-memory transport for thread-per-PU execution:
/// mutex-guarded inboxes plus a barrier; cost is measured wall-clock,
/// including time spent waiting at the barrier (the price of imbalance).
pub struct ThreadComm {
    plan: std::sync::Arc<ExchangePlan>,
    mb: Mailboxes,
    barrier: Barrier,
}

impl ThreadComm {
    pub fn new(plan: std::sync::Arc<ExchangePlan>) -> ThreadComm {
        let mb = Mailboxes::new(&plan);
        let barrier = Barrier::new(plan.k());
        ThreadComm { plan, mb, barrier }
    }
}

impl Comm for ThreadComm {
    fn k(&self) -> usize {
        self.plan.k()
    }

    fn post_halo(&self, rank: usize, owned: &[f32]) {
        let t = Timer::start();
        self.mb.scatter(&self.plan, rank, owned);
        self.mb.charge(rank, t.secs());
    }

    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]) {
        let t = Timer::start();
        self.mb.collect(rank, ghosts);
        self.mb.charge(rank, t.secs());
    }

    fn reduce_post(&self, chan: usize, rank: usize, v: f64) {
        self.mb.deposit(chan, rank, v);
    }

    fn reduce_sum(&self, chan: usize) -> f64 {
        self.mb.sum(chan)
    }

    // Note: `Barrier` does not poison — if a rank thread panics between
    // barriers, the remaining ranks would wait forever. The executor
    // therefore validates everything that feeds rank arithmetic (speeds
    // finite, shapes checked) before any thread is spawned.
    fn sync(&self, rank: usize) {
        let t = Timer::start();
        self.barrier.wait();
        self.mb.charge(rank, t.secs());
    }

    fn comm_secs(&self) -> Vec<f64> {
        self.mb.secs()
    }

    fn label(&self) -> &'static str {
        "threads"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::partition::Partition;
    use crate::solver::EllMatrix;
    use std::sync::Arc;

    fn setup() -> (HaloMatrix, Partition) {
        let g = mesh_2d_tri(16, 16, 3);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let part = Partition::new(
            (0..g.n())
                .map(|u| u32::from(g.coords[u].x > 7.5) + 2 * u32::from(g.coords[u].y > 7.5))
                .collect(),
            4,
        );
        (HaloMatrix::new(&ell, &part), part)
    }

    #[test]
    fn plan_mirrors_halo_send_lists() {
        let (h, part) = setup();
        let plan = ExchangePlan::new(&h, &part);
        assert_eq!(plan.k(), 4);
        for b in 0..4 {
            assert_eq!(plan.send_volume(b), h.send_volume(b));
            assert_eq!(plan.own_len[b], h.blocks[b].own.len());
            assert_eq!(plan.ghost_len[b], h.blocks[b].ghosts.len());
            for seg in &plan.sends[b] {
                assert_eq!(seg.src.len(), seg.dst.len());
                // Every destination slot is a valid ghost index of the
                // receiver and is owned by the sender.
                for &d in &seg.dst {
                    let g = h.blocks[seg.to as usize].ghosts[d as usize];
                    assert_eq!(part.assignment[g as usize] as usize, b);
                }
            }
        }
    }

    #[test]
    fn sim_exchange_delivers_ghost_values() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan.clone(), CostModel::default());
        // Owned value = global id, so ghosts must receive their global id.
        for b in 0..4 {
            let owned: Vec<f32> = h.blocks[b].own.iter().map(|&g| g as f32).collect();
            comm.post_halo(b, &owned);
        }
        for b in 0..4 {
            let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
            comm.recv_halo(b, &mut ghosts);
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(ghosts[j], g as f32, "rank {b} ghost {j}");
            }
        }
        // Cost accounting matches the α-β formula.
        let secs = comm.comm_secs();
        for b in 0..4 {
            let want = 2e-6 * plan.neighbors(b) as f64 + 1e-9 * plan.send_volume(b) as f64 * 4.0;
            assert!((secs[b] - want).abs() < 1e-15, "rank {b}: {} vs {want}", secs[b]);
        }
    }

    #[test]
    fn reductions_sum_in_rank_order() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan, CostModel::default());
        for b in 0..4 {
            comm.reduce_post(0, b, (b + 1) as f64);
            comm.reduce_post(1, b, 0.5);
        }
        assert_eq!(comm.reduce_sum(0), 10.0);
        assert_eq!(comm.reduce_sum(1), 2.0);
    }

    #[test]
    fn thread_comm_exchange_under_threads() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = ThreadComm::new(plan.clone());
        let h = &h;
        let results: Vec<Vec<f32>> = {
            let mut out: Vec<Mutex<Vec<f32>>> = (0..4).map(|_| Mutex::new(Vec::new())).collect();
            std::thread::scope(|scope| {
                for (b, slot) in out.iter_mut().enumerate() {
                    let comm = &comm;
                    let plan = &plan;
                    scope.spawn(move || {
                        let owned: Vec<f32> =
                            h.blocks[b].own.iter().map(|&g| g as f32).collect();
                        comm.post_halo(b, &owned);
                        comm.sync(b);
                        let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
                        comm.recv_halo(b, &mut ghosts);
                        *slot.lock().unwrap() = ghosts;
                    });
                }
            });
            out.into_iter().map(|m| m.into_inner().unwrap()).collect()
        };
        for b in 0..4 {
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(results[b][j], g as f32, "rank {b} ghost {j}");
            }
        }
    }
}
