//! The `Comm` seam of the virtual-cluster engine: halo exchange and
//! allreduce behind one trait, independent of the transport — the same
//! separation bale/convey draws between conveyor semantics and the
//! underlying communication layer.
//!
//! Two transports:
//! - [`SimComm`]: in-process copies whose cost is *priced* by the α-β
//!   model (the transport the old sequential simulator embodied). Used
//!   by the sequential superstep executor, so `sync` is a no-op — the
//!   executor orders phases globally.
//! - [`ThreadComm`]: a real shared-memory transport for thread-per-PU
//!   execution — per-rank inboxes behind mutexes plus a [`Barrier`];
//!   communication cost is *measured* wall-clock (scatter + copy + wait).
//!
//! Both transports implement the reductions identically — each rank's
//! partial is deposited into a slot and the sum is taken in rank order —
//! so dot products are bit-identical regardless of thread scheduling.
//! That determinism is what lets the `threads` backend reproduce the
//! `sim` backend's residual trajectory exactly.
//!
//! # Nonblocking primitives and overlap pricing
//!
//! Beyond the blocking split-phase calls, the trait carries an
//! MPI-flavored nonblocking protocol — [`Comm::irecv_halo`] /
//! [`Comm::isend_halo`] returning [`CommRequest`] handles, completed by
//! [`Comm::test`] / [`Comm::wait`] / [`Comm::wait_all`] — so executors
//! can overlap the halo exchange with independent computation (the
//! interior rows of the SpMV, see `solver::halo`). The contract is
//! deliberately narrow: **at most one exchange may be in flight per rank**,
//! and data delivered by a completed exchange is read with the ordinary
//! [`Comm::recv_halo`].
//!
//! The two transports realize overlap differently:
//! - [`ThreadComm`] makes it *real*: `isend_halo` puts the payload into
//!   each receiver's inbox (one aggregated write + notification token
//!   per destination, no allocation) and returns immediately; `wait`
//!   blocks until every expected token arrived — compute performed
//!   between the two runs concurrently with the other ranks' transfers
//!   (no barrier is involved in a nonblocking exchange).
//! - [`SimComm`] makes it *priced*: `irecv_halo`/`isend_halo` open an
//!   overlap region whose α-β exchange cost is held pending; compute
//!   performed inside the region is reported via
//!   [`Comm::overlap_compute`]; `wait` then charges only the **exposed**
//!   communication `max(comm_window − compute_window, 0)` — so one
//!   overlap region costs `max(compute, comm)` instead of their sum,
//!   exactly how real hardware rewards overlap. The hidden share
//!   `min(comm, compute)` is tracked per rank
//!   ([`Comm::comm_hidden_secs`]) and feeds the harness's
//!   overlap-efficiency columns.
//!
//! # Generic rendezvous collectives
//!
//! Beyond the halo-shaped traffic, the trait carries four MPI-flavored
//! *generic* collectives — [`Comm::allreduce_vec`] (with [`ReduceOp`]
//! sum/min/max), [`Comm::allgatherv`], [`Comm::alltoallv`], and
//! [`Comm::broadcast`] — the vocabulary distributed *partitioners* need
//! (they run before any partition, and hence any halo structure,
//! exists). These are blocking rendezvous operations: every rank thread
//! calls them in the same order and each call synchronizes internally
//! (a fixed barrier-phase sequence), so they must be driven by `k`
//! concurrent rank threads — `k == 1` passes trivially and is priced as
//! free. `Sum`
//! folds contributions in rank order (bit-deterministic); `Min`/`Max`
//! are exact and order-independent. [`SimComm`] prices each call with an
//! α-β tree model (`ceil(log2 k)` latency rounds + β per byte moved);
//! [`ThreadComm`] charges measured wall-clock including the rendezvous
//! wait.

use crate::partition::Partition;
use crate::solver::halo::HaloMatrix;
use crate::util::timer::Timer;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Barrier, Mutex};

/// Element-wise combine rule for [`Comm::allreduce_vec`].
///
/// `Sum` combines the per-rank contributions **in rank order** (the same
/// determinism contract as the scalar reduction channels); `Min`/`Max`
/// are associative and exact in f64, so they are order-independent by
/// construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Rank-order sum.
    Sum,
    /// Element-wise minimum.
    Min,
    /// Element-wise maximum.
    Max,
}

/// One rank's outgoing traffic to one neighbor.
#[derive(Debug, Clone)]
pub struct SendSegment {
    /// Receiving rank.
    pub to: u32,
    /// Owned-local indices to read on the sender.
    pub src: Vec<u32>,
    /// Ghost slots to fill on the receiver (parallel to `src`).
    pub dst: Vec<u32>,
}

/// The static exchange pattern of a partitioned matrix: who sends which
/// owned values into whose ghost slots. Derived once from the halo
/// structure; every [`Comm`] transport executes the same plan.
#[derive(Debug, Clone)]
pub struct ExchangePlan {
    /// Per rank: outgoing segments.
    pub sends: Vec<Vec<SendSegment>>,
    /// Per rank: number of ghost entries (inbox size).
    pub ghost_len: Vec<usize>,
    /// Per rank: number of owned rows.
    pub own_len: Vec<usize>,
}

impl ExchangePlan {
    /// Build the plan from a halo decomposition. The receiver slots are
    /// the mirror image of the sender lists by construction (asserted by
    /// `halo`'s `send_lists_are_mirror_of_ghosts` test).
    pub fn new(h: &HaloMatrix, part: &Partition) -> ExchangePlan {
        let k = h.blocks.len();
        let mut sends: Vec<Vec<SendSegment>> = Vec::with_capacity(k);
        for o in 0..k {
            let mut segs = Vec::new();
            for (to, src) in &h.blocks[o].send_lists {
                // Ghost slots on the receiver owned by `o`, in ghost
                // order — exactly the order `src` was built in.
                let dst: Vec<u32> = h.blocks[*to as usize]
                    .ghosts
                    .iter()
                    .enumerate()
                    .filter(|(_, &g)| part.assignment[g as usize] as usize == o)
                    .map(|(j, _)| j as u32)
                    .collect();
                debug_assert_eq!(dst.len(), src.len());
                segs.push(SendSegment { to: *to, src: src.clone(), dst });
            }
            sends.push(segs);
        }
        ExchangePlan {
            ghost_len: h.blocks.iter().map(|b| b.ghosts.len()).collect(),
            own_len: h.blocks.iter().map(|b| b.own.len()).collect(),
            sends,
        }
    }

    /// A plan with no halo traffic, for transports used only for the
    /// generic collectives (e.g. distributed partitioning, which runs
    /// *before* any partition — and hence any halo structure — exists).
    pub fn collectives_only(k: usize) -> ExchangePlan {
        ExchangePlan {
            sends: vec![Vec::new(); k],
            ghost_len: vec![0; k],
            own_len: vec![0; k],
        }
    }

    /// Number of ranks in the plan.
    pub fn k(&self) -> usize {
        self.own_len.len()
    }

    /// Words sent by `rank` per exchange.
    pub fn send_volume(&self, rank: usize) -> usize {
        self.sends[rank].iter().map(|s| s.src.len()).sum()
    }

    /// Number of neighbors `rank` sends to.
    pub fn neighbors(&self, rank: usize) -> usize {
        self.sends[rank].len()
    }
}

/// α-β communication constants for the simulated transport (mirrors
/// `solver::ClusterSim`, which converts into this).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency (s).
    pub alpha: f64,
    /// Per-word transfer time (s).
    pub beta: f64,
    /// Per-nonzero SpMV time on a speed-1 PU (s).
    pub t_flop: f64,
    /// Allreduce latency factor per synchronization.
    pub allreduce_base: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { alpha: 2e-6, beta: 1e-9, t_flop: 2e-9, allreduce_base: 1e-6 }
    }
}

/// Handle to an in-flight nonblocking halo exchange.
///
/// Returned by [`Comm::irecv_halo`] / [`Comm::isend_halo`] and redeemed
/// by [`Comm::test`] / [`Comm::wait`]. At most one exchange may be in
/// flight per rank; the handle identifies it (rank + sequence number)
/// so stale handles are caught in debug builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommRequest {
    rank: u32,
    seq: u32,
}

/// Transport-independent communication primitives, rank-facing.
///
/// The calling convention is split-phase (post, [`Comm::sync`], read) so
/// that the same rank-level step functions can be driven either by k OS
/// threads (each blocking in `sync`) or by a sequential superstep
/// executor (where `sync` is a no-op because the executor runs each
/// phase for every rank before starting the next).
///
/// The nonblocking subset (`irecv_halo`/`isend_halo`/`test`/`wait`/
/// `wait_all`) replaces the post → `sync` → read sequence for the halo
/// exchange with post → *overlapped compute* → wait → read; see the
/// module docs for the per-transport semantics and the single
/// in-flight-exchange-per-rank contract.
pub trait Comm: Sync {
    /// Number of ranks this transport connects.
    fn k(&self) -> usize;
    /// Scatter `rank`'s owned boundary values into neighbor inboxes.
    fn post_halo(&self, rank: usize, owned: &[f32]);
    /// Copy `rank`'s inbox into its ghost segment. Valid after `sync`
    /// (blocking path) or after the exchange's `wait` (nonblocking path).
    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]);
    /// Deposit a scalar partial on reduction channel `chan` (0 or 1).
    fn reduce_post(&self, chan: usize, rank: usize, v: f64);
    /// Rank-order sum of channel `chan`. Valid after `sync`.
    fn reduce_sum(&self, chan: usize) -> f64;
    /// Synchronization point between post and read phases.
    fn sync(&self, rank: usize);
    /// Per-rank communication seconds accumulated so far.
    fn comm_secs(&self) -> Vec<f64>;
    /// Short transport name (`"sim"` / `"threads"`).
    fn label(&self) -> &'static str;

    // ---- nonblocking extension -----------------------------------------

    /// Post the receive side of a nonblocking halo exchange for `rank`.
    /// Opens the rank's overlap region (at most one in flight).
    fn irecv_halo(&self, rank: usize) -> CommRequest;
    /// Post the send side: ship `rank`'s owned values toward its
    /// neighbors' ghost inboxes and return immediately. One aggregated
    /// message per destination rank.
    fn isend_halo(&self, rank: usize, owned: &[f32]) -> CommRequest;
    /// Report compute seconds `rank` performed *inside* the currently
    /// open overlap region (between `isend_halo` and `wait`). Priced
    /// transports use it to discount hidden communication; measured
    /// transports ignore it (their overlap is real).
    fn overlap_compute(&self, rank: usize, secs: f64);
    /// Poll: would `wait` on this request return without blocking?
    /// Transports may make partial progress (drain arrived messages).
    fn test(&self, rank: usize, req: CommRequest) -> bool;
    /// Complete the exchange: block until every expected message arrived
    /// (measured transports) or close the overlap region and charge the
    /// exposed communication (priced transports). After `wait`, the
    /// ghost values are readable via [`Comm::recv_halo`].
    fn wait(&self, rank: usize, req: CommRequest);
    /// Complete whatever exchange `rank` still has in flight (no-op when
    /// none is outstanding).
    fn wait_all(&self, rank: usize);
    /// Deposit partials on both reduction channels as **one combined
    /// message** — the single-reduction hook pipelined CG uses. Priced
    /// transports charge one allreduce latency instead of two.
    fn reduce_post_pair(&self, rank: usize, v0: f64, v1: f64) {
        self.reduce_post(0, rank, v0);
        self.reduce_post(1, rank, v1);
    }
    /// Per-rank communication seconds *hidden* behind overlapped compute
    /// so far (nonzero only for priced transports; measured transports
    /// realize the overlap instead of accounting it).
    fn comm_hidden_secs(&self) -> Vec<f64> {
        vec![0.0; self.k()]
    }

    // ---- generic rendezvous collectives --------------------------------
    //
    // MPI-flavored blocking collectives for algorithms that run *through*
    // the transport but outside the halo structure (distributed
    // partitioning runs before any partition exists). Unlike the
    // split-phase calls above, these synchronize internally, so they must
    // be invoked from k concurrent rank threads, every rank issuing the
    // same sequence of collective calls (k == 1 trivially passes). The
    // priced transport charges an α-β tree cost per call (free at k = 1);
    // the measured transport charges wall-clock including rendezvous
    // waits.

    /// Combine `data` element-wise across ranks (in place). `Sum` folds
    /// the contributions in rank order, so results are bit-deterministic
    /// regardless of thread scheduling; every rank must pass the same
    /// length.
    fn allreduce_vec(&self, rank: usize, data: &mut [f64], op: ReduceOp);
    /// Gather the variable-length per-rank contributions, concatenated in
    /// rank order; every rank receives the same vector.
    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64>;
    /// Personalized all-to-all: `parts[d]` is shipped to rank `d`;
    /// returns the parts addressed to `rank`, indexed by source rank.
    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>>;
    /// Replicate `root`'s vector on every rank (non-root `data` is
    /// overwritten).
    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>);
}

/// Shared state of the generic *rendezvous* collectives
/// ([`Comm::allreduce_vec`], [`Comm::allgatherv`], [`Comm::alltoallv`],
/// [`Comm::broadcast`]): per-rank contribution slots plus a dedicated
/// barrier. Every collective is a fixed sequence of barrier phases
/// (deposit, rendezvous, read, rendezvous — allreduce inserts a
/// leader-fold phase) so the slots can be reused by the next call.
///
/// Unlike the split-phase halo/reduction calls (which the sequential
/// superstep executor can drive one rank at a time), these collectives
/// block at a real [`Barrier`], so they must be called from `k`
/// concurrent rank threads (`k == 1` trivially passes). Both transports
/// share this mechanism; they differ only in how the call is *costed*
/// (α-β priced vs wall-clock measured).
struct Collectives {
    k: usize,
    barrier: Barrier,
    /// Per-rank contribution for allreduce/allgatherv/broadcast.
    parts: Vec<Mutex<Vec<f64>>>,
    /// The folded allreduce result (leader-written).
    reduced: Mutex<Vec<f64>>,
    /// Per *sender* rank: parts-by-destination for alltoallv.
    a2a: Vec<Mutex<Vec<Vec<f64>>>>,
}

impl Collectives {
    fn new(k: usize) -> Collectives {
        Collectives {
            k,
            barrier: Barrier::new(k),
            parts: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
            reduced: Mutex::new(Vec::new()),
            a2a: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Combine `data` element-wise across ranks (`Sum` in rank order).
    /// Every rank must pass the same length.
    ///
    /// One rank (the barrier leader — *which* one is irrelevant, the
    /// fold is in rank order either way) combines the slots once and the
    /// rest copy the result: Θ(k·len) total instead of every rank
    /// redoing the fold, so the measured transport's comm time reflects
    /// a real reduction, not k replicated ones.
    fn allreduce(&self, rank: usize, data: &mut [f64], op: ReduceOp) {
        *self.parts[rank].lock().unwrap() = data.to_vec();
        if self.barrier.wait().is_leader() {
            let mut acc = self.parts[0].lock().unwrap().clone();
            debug_assert_eq!(acc.len(), data.len(), "allreduce_vec length mismatch");
            for r in 1..self.k {
                let part = self.parts[r].lock().unwrap();
                debug_assert_eq!(part.len(), acc.len(), "allreduce_vec length mismatch");
                for (a, &v) in acc.iter_mut().zip(part.iter()) {
                    match op {
                        ReduceOp::Sum => *a += v,
                        ReduceOp::Min => *a = a.min(v),
                        ReduceOp::Max => *a = a.max(v),
                    }
                }
            }
            *self.reduced.lock().unwrap() = acc;
        }
        self.barrier.wait();
        data.copy_from_slice(&self.reduced.lock().unwrap());
        self.barrier.wait();
    }

    /// Concatenate the per-rank contributions in rank order. Returns the
    /// full concatenation (every rank gets the same vector).
    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64> {
        *self.parts[rank].lock().unwrap() = local.to_vec();
        self.barrier.wait();
        let mut out = Vec::new();
        for r in 0..self.k {
            out.extend_from_slice(&self.parts[r].lock().unwrap());
        }
        self.barrier.wait();
        out
    }

    /// Personalized exchange: `parts[d]` is shipped to rank `d`; the
    /// return value is indexed by *source* rank.
    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>> {
        debug_assert_eq!(parts.len(), self.k, "alltoallv needs one part per rank");
        *self.a2a[rank].lock().unwrap() = parts.to_vec();
        self.barrier.wait();
        let mut out = Vec::with_capacity(self.k);
        for r in 0..self.k {
            out.push(self.a2a[r].lock().unwrap()[rank].clone());
        }
        self.barrier.wait();
        out
    }

    /// Replicate `root`'s vector on every rank (non-root `data` is
    /// overwritten, resizing as needed).
    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>) {
        debug_assert!(root < self.k, "broadcast root {root} out of range");
        if rank == root {
            *self.parts[root].lock().unwrap() = data.clone();
        }
        self.barrier.wait();
        if rank != root {
            *data = self.parts[root].lock().unwrap().clone();
        }
        self.barrier.wait();
    }
}

/// Shared mailbox state: per-rank ghost inboxes, two reduction channels,
/// and per-rank communication-cost accumulators.
struct Mailboxes {
    inboxes: Vec<Mutex<Vec<f32>>>,
    red: [Mutex<Vec<f64>>; 2],
    secs: Vec<Mutex<f64>>,
}

impl Mailboxes {
    fn new(plan: &ExchangePlan) -> Mailboxes {
        let k = plan.k();
        Mailboxes {
            inboxes: plan.ghost_len.iter().map(|&g| Mutex::new(vec![0.0; g])).collect(),
            red: [Mutex::new(vec![0.0; k]), Mutex::new(vec![0.0; k])],
            secs: (0..k).map(|_| Mutex::new(0.0)).collect(),
        }
    }

    fn scatter(&self, plan: &ExchangePlan, rank: usize, owned: &[f32]) {
        for seg in &plan.sends[rank] {
            let mut inbox = self.inboxes[seg.to as usize].lock().unwrap();
            for (&s, &d) in seg.src.iter().zip(&seg.dst) {
                inbox[d as usize] = owned[s as usize];
            }
        }
    }

    fn collect(&self, rank: usize, ghosts: &mut [f32]) {
        let inbox = self.inboxes[rank].lock().unwrap();
        ghosts.copy_from_slice(&inbox);
    }

    fn deposit(&self, chan: usize, rank: usize, v: f64) {
        self.red[chan].lock().unwrap()[rank] = v;
    }

    /// Deterministic rank-order sum.
    fn sum(&self, chan: usize) -> f64 {
        self.red[chan].lock().unwrap().iter().sum()
    }

    fn charge(&self, rank: usize, secs: f64) {
        *self.secs[rank].lock().unwrap() += secs;
    }

    fn secs(&self) -> Vec<f64> {
        self.secs.iter().map(|m| *m.lock().unwrap()).collect()
    }
}

/// One rank's pending overlap region in the priced transport: the α-β
/// exchange cost held back until `wait`, and the compute reported inside
/// the region so far.
#[derive(Debug, Default)]
struct OverlapRegion {
    open: bool,
    seq: u32,
    comm: f64,
    compute: f64,
}

/// The α-β *simulated* transport: data moves through in-process copies,
/// cost is charged by the model instead of measured.
///
/// Nonblocking exchanges are priced as overlap regions: the exchange's
/// α-β cost is held pending from `isend_halo` until `wait`, compute
/// reported via [`Comm::overlap_compute`] is subtracted, and only the
/// exposed remainder `max(comm − compute, 0)` is charged — so a fully
/// hidden exchange is free and a region costs `max(compute, comm)`
/// overall instead of `compute + comm`.
pub struct SimComm {
    plan: std::sync::Arc<ExchangePlan>,
    mb: Mailboxes,
    cost: CostModel,
    regions: Vec<Mutex<OverlapRegion>>,
    hidden: Vec<Mutex<f64>>,
    colls: Collectives,
}

impl SimComm {
    /// Priced transport over `plan` with the given α-β constants.
    pub fn new(plan: std::sync::Arc<ExchangePlan>, cost: CostModel) -> SimComm {
        let mb = Mailboxes::new(&plan);
        let k = plan.k();
        SimComm {
            plan,
            mb,
            cost,
            regions: (0..k).map(|_| Mutex::new(OverlapRegion::default())).collect(),
            hidden: (0..k).map(|_| Mutex::new(0.0)).collect(),
            colls: Collectives::new(k),
        }
    }

    /// Tree depth of a k-rank collective: `ceil(log2 k)` rounds, so a
    /// single-rank "collective" is free — unlike the scalar reduction
    /// channels, whose legacy pricing floors at one latency.
    fn tree_depth(&self) -> f64 {
        let k = self.k();
        if k <= 1 {
            0.0
        } else {
            (k as f64).log2().ceil()
        }
    }

    /// Price one generic collective for one rank: `depth` latency rounds
    /// plus β per byte that actually crosses the transport.
    fn charge_collective(&self, rank: usize, bytes_moved: f64) {
        let depth = self.tree_depth();
        if depth > 0.0 {
            self.mb
                .charge(rank, self.cost.allreduce_base * depth + self.cost.beta * bytes_moved);
        }
    }

    /// The α-β price of one full halo exchange posted by `rank`.
    fn exchange_cost(&self, rank: usize) -> f64 {
        self.cost.alpha * self.plan.neighbors(rank) as f64
            + self.cost.beta * self.plan.send_volume(rank) as f64 * 4.0
    }

    /// Close `rank`'s overlap region: charge the exposed communication,
    /// bank the hidden share.
    fn close_region(&self, rank: usize) {
        let mut reg = self.regions[rank].lock().unwrap();
        if !reg.open {
            return;
        }
        let exposed = (reg.comm - reg.compute).max(0.0);
        self.mb.charge(rank, exposed);
        *self.hidden[rank].lock().unwrap() += reg.comm - exposed;
        reg.open = false;
        reg.comm = 0.0;
        reg.compute = 0.0;
    }

    /// Open (or join) the current overlap region, returning its handle.
    fn open_region(&self, rank: usize) -> CommRequest {
        let mut reg = self.regions[rank].lock().unwrap();
        if !reg.open {
            reg.open = true;
            reg.seq = reg.seq.wrapping_add(1);
            reg.comm = 0.0;
            reg.compute = 0.0;
        }
        CommRequest { rank: rank as u32, seq: reg.seq }
    }
}

impl Comm for SimComm {
    fn k(&self) -> usize {
        self.plan.k()
    }

    fn post_halo(&self, rank: usize, owned: &[f32]) {
        self.mb.scatter(&self.plan, rank, owned);
        // α per neighbor message + β per word (f32 = 4 bytes), the exact
        // formula `ClusterSim::iteration` prices.
        self.mb.charge(rank, self.exchange_cost(rank));
    }

    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]) {
        self.mb.collect(rank, ghosts);
    }

    fn reduce_post(&self, chan: usize, rank: usize, v: f64) {
        self.mb.deposit(chan, rank, v);
        let k = self.k() as f64;
        self.mb.charge(rank, self.cost.allreduce_base * k.log2().max(1.0));
    }

    fn reduce_sum(&self, chan: usize) -> f64 {
        self.mb.sum(chan)
    }

    fn sync(&self, _rank: usize) {
        // The sequential superstep executor orders phases globally.
    }

    fn comm_secs(&self) -> Vec<f64> {
        self.mb.secs()
    }

    fn label(&self) -> &'static str {
        "sim"
    }

    fn irecv_halo(&self, rank: usize) -> CommRequest {
        self.open_region(rank)
    }

    fn isend_halo(&self, rank: usize, owned: &[f32]) -> CommRequest {
        // Data moves immediately (in-process); only the *pricing* is
        // deferred to `wait`, into the overlap region.
        self.mb.scatter(&self.plan, rank, owned);
        let req = self.open_region(rank);
        self.regions[rank].lock().unwrap().comm += self.exchange_cost(rank);
        req
    }

    fn overlap_compute(&self, rank: usize, secs: f64) {
        let mut reg = self.regions[rank].lock().unwrap();
        if reg.open {
            reg.compute += secs;
        }
    }

    fn test(&self, rank: usize, req: CommRequest) -> bool {
        debug_assert_eq!(req.rank as usize, rank);
        // In-process copies complete at isend; the region stays open (and
        // priced) until `wait` closes it.
        true
    }

    fn wait(&self, rank: usize, req: CommRequest) {
        debug_assert_eq!(req.rank as usize, rank);
        debug_assert_eq!(req.seq, self.regions[rank].lock().unwrap().seq, "stale CommRequest");
        self.close_region(rank);
    }

    fn wait_all(&self, rank: usize) {
        self.close_region(rank);
    }

    fn reduce_post_pair(&self, rank: usize, v0: f64, v1: f64) {
        // One combined message: both scalars ride a single allreduce, so
        // a single latency charge (the pipelined-CG saving).
        self.mb.deposit(0, rank, v0);
        self.mb.deposit(1, rank, v1);
        let k = self.k() as f64;
        self.mb.charge(rank, self.cost.allreduce_base * k.log2().max(1.0));
    }

    fn comm_hidden_secs(&self) -> Vec<f64> {
        self.hidden.iter().map(|m| *m.lock().unwrap()).collect()
    }

    fn allreduce_vec(&self, rank: usize, data: &mut [f64], op: ReduceOp) {
        // A tree allreduce moves the vector once per level.
        self.charge_collective(rank, 8.0 * data.len() as f64 * self.tree_depth());
        self.colls.allreduce(rank, data, op);
    }

    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64> {
        let out = self.colls.allgatherv(rank, local);
        // Receive-dominated: each rank pulls in everyone else's share.
        self.charge_collective(rank, 8.0 * (out.len() - local.len()) as f64);
        out
    }

    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let sent: usize = parts
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != rank)
            .map(|(_, p)| p.len())
            .sum();
        let out = self.colls.alltoallv(rank, parts);
        let recvd: usize = out
            .iter()
            .enumerate()
            .filter(|(s, _)| *s != rank)
            .map(|(_, p)| p.len())
            .sum();
        if self.k() > 1 {
            // One message per peer plus β for every word shipped each way.
            self.mb.charge(
                rank,
                self.cost.alpha * (self.k() - 1) as f64
                    + self.cost.beta * 8.0 * (sent + recvd) as f64,
            );
        }
        out
    }

    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>) {
        if rank == root {
            // The payload length is known before the call on the root
            // only; price both ends from it (symmetric tree).
            self.charge_collective(rank, 8.0 * data.len() as f64);
        }
        self.colls.broadcast(rank, root, data);
        if rank != root {
            self.charge_collective(rank, 8.0 * data.len() as f64);
        }
    }
}

/// One in-flight notification of the nonblocking thread transport: the
/// sender's rank and segment index. The payload itself does not travel
/// through the channel — `isend_halo` writes it straight into the
/// receiver's inbox (a shared-memory "RMA put", batched per destination
/// under one inbox lock), and the mpsc send/recv pair provides the
/// happens-before edge that makes those writes visible at `wait`.
type NbMsg = (u32, u32);

/// The real shared-memory transport for thread-per-PU execution:
/// mutex-guarded inboxes plus a barrier; cost is measured wall-clock,
/// including time spent waiting at the barrier (the price of imbalance).
///
/// Nonblocking exchanges ride per-rank mpsc channels: `isend_halo` puts
/// the payload into each receiver's inbox (**one aggregated write +
/// notification per destination rank**, no per-iteration allocation) and
/// returns; `wait` blocks until every expected notification arrived. No
/// barrier is involved, so compute between `isend_halo` and `wait`
/// genuinely overlaps the other ranks' transfers.
pub struct ThreadComm {
    plan: std::sync::Arc<ExchangePlan>,
    mb: Mailboxes,
    barrier: Barrier,
    /// Per destination rank: the sending half of its in-flight channel.
    nb_tx: Vec<Mutex<Sender<NbMsg>>>,
    /// Per rank: the receiving half (only the owning rank drains it).
    nb_rx: Vec<Mutex<Receiver<NbMsg>>>,
    /// Per rank: incoming segments per exchange (static, from the plan).
    nb_expected: Vec<usize>,
    /// Per rank: segments drained so far in the current exchange.
    nb_got: Vec<Mutex<usize>>,
    /// Per rank: whether an exchange is in flight, and its sequence.
    nb_open: Vec<Mutex<(bool, u32)>>,
    colls: Collectives,
}

impl ThreadComm {
    /// Measured transport over `plan` for `plan.k()` rank threads.
    pub fn new(plan: std::sync::Arc<ExchangePlan>) -> ThreadComm {
        let mb = Mailboxes::new(&plan);
        let k = plan.k();
        let barrier = Barrier::new(k);
        let mut nb_tx = Vec::with_capacity(k);
        let mut nb_rx = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = channel::<NbMsg>();
            nb_tx.push(Mutex::new(tx));
            nb_rx.push(Mutex::new(rx));
        }
        let mut nb_expected = vec![0usize; k];
        for segs in &plan.sends {
            for seg in segs {
                nb_expected[seg.to as usize] += 1;
            }
        }
        ThreadComm {
            plan,
            mb,
            barrier,
            nb_tx,
            nb_rx,
            nb_expected,
            nb_got: (0..k).map(|_| Mutex::new(0usize)).collect(),
            nb_open: (0..k).map(|_| Mutex::new((false, 0u32))).collect(),
            colls: Collectives::new(k),
        }
    }

    /// Validate one arrived notification: the payload was already put
    /// into `rank`'s inbox by the sender before the token was sent.
    fn note_arrival(&self, rank: usize, msg: NbMsg) {
        let (from, seg_idx) = msg;
        debug_assert_eq!(
            self.plan.sends[from as usize][seg_idx as usize].to as usize,
            rank,
            "notification delivered to the wrong rank"
        );
    }

    /// Mark an exchange in flight for `rank` (idempotent within one
    /// exchange) and return its handle.
    fn open_exchange(&self, rank: usize) -> CommRequest {
        let mut st = self.nb_open[rank].lock().unwrap();
        if !st.0 {
            st.0 = true;
            st.1 = st.1.wrapping_add(1);
        }
        CommRequest { rank: rank as u32, seq: st.1 }
    }
}

impl Comm for ThreadComm {
    fn k(&self) -> usize {
        self.plan.k()
    }

    fn post_halo(&self, rank: usize, owned: &[f32]) {
        let t = Timer::start();
        self.mb.scatter(&self.plan, rank, owned);
        self.mb.charge(rank, t.secs());
    }

    fn recv_halo(&self, rank: usize, ghosts: &mut [f32]) {
        let t = Timer::start();
        self.mb.collect(rank, ghosts);
        self.mb.charge(rank, t.secs());
    }

    fn reduce_post(&self, chan: usize, rank: usize, v: f64) {
        self.mb.deposit(chan, rank, v);
    }

    fn reduce_sum(&self, chan: usize) -> f64 {
        self.mb.sum(chan)
    }

    // Note: `Barrier` does not poison — if a rank thread panics between
    // barriers, the remaining ranks would wait forever. The executor
    // therefore validates everything that feeds rank arithmetic (speeds
    // finite, shapes checked) before any thread is spawned.
    fn sync(&self, rank: usize) {
        let t = Timer::start();
        self.barrier.wait();
        self.mb.charge(rank, t.secs());
    }

    fn comm_secs(&self) -> Vec<f64> {
        self.mb.secs()
    }

    fn label(&self) -> &'static str {
        "threads"
    }

    fn irecv_halo(&self, rank: usize) -> CommRequest {
        debug_assert_eq!(
            *self.nb_got[rank].lock().unwrap(),
            0,
            "previous exchange of rank {rank} not fully drained"
        );
        self.open_exchange(rank)
    }

    fn isend_halo(&self, rank: usize, owned: &[f32]) -> CommRequest {
        let t = Timer::start();
        // Put the payload into the receivers' inboxes first (the shared
        // scatter used by the blocking path — one loop body in the whole
        // transport), then post one notification per destination; the
        // channel's send→recv ordering publishes the inbox writes.
        self.mb.scatter(&self.plan, rank, owned);
        for (seg_idx, seg) in self.plan.sends[rank].iter().enumerate() {
            self.nb_tx[seg.to as usize]
                .lock()
                .unwrap()
                .send((rank as u32, seg_idx as u32))
                .expect("receiving rank hung up mid-exchange");
        }
        let req = self.open_exchange(rank);
        self.mb.charge(rank, t.secs());
        req
    }

    fn overlap_compute(&self, _rank: usize, _secs: f64) {
        // Measured transport: the overlap is real, nothing to discount.
    }

    fn test(&self, rank: usize, req: CommRequest) -> bool {
        debug_assert_eq!(req.rank as usize, rank);
        debug_assert_eq!(req.seq, self.nb_open[rank].lock().unwrap().1, "stale CommRequest");
        let mut got = self.nb_got[rank].lock().unwrap();
        loop {
            if *got >= self.nb_expected[rank] {
                return true;
            }
            match self.nb_rx[rank].lock().unwrap().try_recv() {
                Ok(msg) => {
                    self.note_arrival(rank, msg);
                    *got += 1;
                }
                Err(TryRecvError::Empty) => return false,
                Err(TryRecvError::Disconnected) => {
                    panic!("sending rank hung up mid-exchange")
                }
            }
        }
    }

    fn wait(&self, rank: usize, req: CommRequest) {
        debug_assert_eq!(req.rank as usize, rank);
        debug_assert_eq!(req.seq, self.nb_open[rank].lock().unwrap().1, "stale CommRequest");
        let t = Timer::start();
        let mut got = self.nb_got[rank].lock().unwrap();
        while *got < self.nb_expected[rank] {
            let msg = self.nb_rx[rank]
                .lock()
                .unwrap()
                .recv()
                .expect("sending rank hung up mid-exchange");
            self.note_arrival(rank, msg);
            *got += 1;
        }
        *got = 0;
        self.nb_open[rank].lock().unwrap().0 = false;
        self.mb.charge(rank, t.secs());
    }

    fn wait_all(&self, rank: usize) {
        let (outstanding, seq) = *self.nb_open[rank].lock().unwrap();
        if outstanding {
            self.wait(rank, CommRequest { rank: rank as u32, seq });
        }
    }

    fn comm_hidden_secs(&self) -> Vec<f64> {
        // Measured transport: hidden time shows up as *absent* wall-clock,
        // not as an accounting line.
        vec![0.0; self.k()]
    }

    // The measured transport charges each rank the wall-clock of the
    // whole collective, rendezvous waits included — lagging into a
    // collective is the thread analogue of arriving late at the barrier.

    fn allreduce_vec(&self, rank: usize, data: &mut [f64], op: ReduceOp) {
        let t = Timer::start();
        self.colls.allreduce(rank, data, op);
        self.mb.charge(rank, t.secs());
    }

    fn allgatherv(&self, rank: usize, local: &[f64]) -> Vec<f64> {
        let t = Timer::start();
        let out = self.colls.allgatherv(rank, local);
        self.mb.charge(rank, t.secs());
        out
    }

    fn alltoallv(&self, rank: usize, parts: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let t = Timer::start();
        let out = self.colls.alltoallv(rank, parts);
        self.mb.charge(rank, t.secs());
        out
    }

    fn broadcast(&self, rank: usize, root: usize, data: &mut Vec<f64>) {
        let t = Timer::start();
        self.colls.broadcast(rank, root, data);
        self.mb.charge(rank, t.secs());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::partition::Partition;
    use crate::solver::EllMatrix;
    use std::sync::Arc;

    fn setup() -> (HaloMatrix, Partition) {
        let g = mesh_2d_tri(16, 16, 3);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let part = Partition::new(
            (0..g.n())
                .map(|u| u32::from(g.coords[u].x > 7.5) + 2 * u32::from(g.coords[u].y > 7.5))
                .collect(),
            4,
        );
        (HaloMatrix::new(&ell, &part), part)
    }

    #[test]
    fn plan_mirrors_halo_send_lists() {
        let (h, part) = setup();
        let plan = ExchangePlan::new(&h, &part);
        assert_eq!(plan.k(), 4);
        for b in 0..4 {
            assert_eq!(plan.send_volume(b), h.send_volume(b));
            assert_eq!(plan.own_len[b], h.blocks[b].own.len());
            assert_eq!(plan.ghost_len[b], h.blocks[b].ghosts.len());
            for seg in &plan.sends[b] {
                assert_eq!(seg.src.len(), seg.dst.len());
                // Every destination slot is a valid ghost index of the
                // receiver and is owned by the sender.
                for &d in &seg.dst {
                    let g = h.blocks[seg.to as usize].ghosts[d as usize];
                    assert_eq!(part.assignment[g as usize] as usize, b);
                }
            }
        }
    }

    #[test]
    fn sim_exchange_delivers_ghost_values() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan.clone(), CostModel::default());
        // Owned value = global id, so ghosts must receive their global id.
        for b in 0..4 {
            let owned: Vec<f32> = h.blocks[b].own.iter().map(|&g| g as f32).collect();
            comm.post_halo(b, &owned);
        }
        for b in 0..4 {
            let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
            comm.recv_halo(b, &mut ghosts);
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(ghosts[j], g as f32, "rank {b} ghost {j}");
            }
        }
        // Cost accounting matches the α-β formula.
        let secs = comm.comm_secs();
        for b in 0..4 {
            let want = 2e-6 * plan.neighbors(b) as f64 + 1e-9 * plan.send_volume(b) as f64 * 4.0;
            assert!((secs[b] - want).abs() < 1e-15, "rank {b}: {} vs {want}", secs[b]);
        }
    }

    #[test]
    fn reductions_sum_in_rank_order() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan, CostModel::default());
        for b in 0..4 {
            comm.reduce_post(0, b, (b + 1) as f64);
            comm.reduce_post(1, b, 0.5);
        }
        assert_eq!(comm.reduce_sum(0), 10.0);
        assert_eq!(comm.reduce_sum(1), 2.0);
    }

    #[test]
    fn sim_nonblocking_prices_max_not_sum() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let cost = CostModel::default();
        let comm = SimComm::new(plan.clone(), cost);
        // Rank 0: fully hidden (plenty of overlapped compute); rank 1:
        // no overlapped compute (fully exposed); rank 2: half hidden.
        let full: Vec<f64> = (0..4)
            .map(|b| {
                cost.alpha * plan.neighbors(b) as f64
                    + cost.beta * plan.send_volume(b) as f64 * 4.0
            })
            .collect();
        for b in 0..4 {
            let owned: Vec<f32> = h.blocks[b].own.iter().map(|&g| g as f32).collect();
            let rq = comm.irecv_halo(b);
            let rq2 = comm.isend_halo(b, &owned);
            assert_eq!(rq, rq2, "both handles name the same in-flight exchange");
            match b {
                0 => comm.overlap_compute(b, 1.0),
                2 => comm.overlap_compute(b, full[2] / 2.0),
                _ => {}
            }
            assert!(comm.test(b, rq), "sim data is delivered at isend");
            comm.wait(b, rq);
        }
        let secs = comm.comm_secs();
        let hidden = comm.comm_hidden_secs();
        assert!(secs[0].abs() < 1e-18, "fully hidden exchange must be free: {}", secs[0]);
        assert!((hidden[0] - full[0]).abs() < 1e-15);
        assert!((secs[1] - full[1]).abs() < 1e-15, "no compute → fully exposed");
        assert!(hidden[1].abs() < 1e-18);
        assert!((secs[2] - full[2] / 2.0).abs() < 1e-15, "half hidden");
        assert!((hidden[2] - full[2] / 2.0).abs() < 1e-15);
        // Exchanged data is identical to the blocking path.
        for b in 0..4 {
            let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
            comm.recv_halo(b, &mut ghosts);
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(ghosts[j], g as f32, "rank {b} ghost {j}");
            }
        }
    }

    #[test]
    fn sim_combined_reduction_charges_one_latency() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let single = SimComm::new(plan.clone(), CostModel::default());
        let paired = SimComm::new(plan, CostModel::default());
        for b in 0..4 {
            single.reduce_post(0, b, b as f64);
            single.reduce_post(1, b, 2.0 * b as f64);
            paired.reduce_post_pair(b, b as f64, 2.0 * b as f64);
        }
        assert_eq!(single.reduce_sum(0), paired.reduce_sum(0));
        assert_eq!(single.reduce_sum(1), paired.reduce_sum(1));
        for b in 0..4 {
            assert!(
                (single.comm_secs()[b] - 2.0 * paired.comm_secs()[b]).abs() < 1e-15,
                "pair must cost half of two posts"
            );
        }
    }

    #[test]
    fn thread_nonblocking_exchange_under_threads() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = ThreadComm::new(plan.clone());
        let h = &h;
        let results: Vec<Vec<f32>> = {
            let mut out: Vec<Mutex<Vec<f32>>> = (0..4).map(|_| Mutex::new(Vec::new())).collect();
            std::thread::scope(|scope| {
                for (b, slot) in out.iter_mut().enumerate() {
                    let comm = &comm;
                    let plan = &plan;
                    scope.spawn(move || {
                        let owned: Vec<f32> =
                            h.blocks[b].own.iter().map(|&g| g as f32).collect();
                        let rq = comm.irecv_halo(b);
                        comm.isend_halo(b, &owned);
                        // Poll a few times (partial progress is legal),
                        // then block.
                        for _ in 0..3 {
                            if comm.test(b, rq) {
                                break;
                            }
                        }
                        comm.wait(b, rq);
                        let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
                        comm.recv_halo(b, &mut ghosts);
                        *slot.lock().unwrap() = ghosts;
                    });
                }
            });
            out.into_iter().map(|m| m.into_inner().unwrap()).collect()
        };
        for b in 0..4 {
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(results[b][j], g as f32, "rank {b} ghost {j}");
            }
        }
        // Hidden accounting stays zero on the measured transport.
        assert!(comm.comm_hidden_secs().iter().all(|&s| s == 0.0));
    }

    #[test]
    fn wait_all_completes_outstanding_and_tolerates_idle_ranks() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = SimComm::new(plan.clone(), CostModel::default());
        // Nothing outstanding: wait_all is a no-op.
        comm.wait_all(0);
        assert!(comm.comm_secs()[0].abs() < 1e-18);
        let owned: Vec<f32> = h.blocks[0].own.iter().map(|&g| g as f32).collect();
        comm.isend_halo(0, &owned);
        comm.wait_all(0);
        assert!(comm.comm_secs()[0] > 0.0, "outstanding exchange must be charged");
    }

    /// Run `f(rank)` on k concurrent rank threads, collecting results in
    /// rank order (the calling convention the rendezvous collectives
    /// require).
    fn on_ranks<R: Send>(k: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
        let slots: Vec<Mutex<Option<R>>> = (0..k).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for (rank, slot) in slots.iter().enumerate() {
                let f = &f;
                scope.spawn(move || {
                    *slot.lock().unwrap() = Some(f(rank));
                });
            }
        });
        slots.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
    }

    #[test]
    fn collectives_sum_in_rank_order_and_agree_across_backends() {
        let k = 4;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let sim = SimComm::new(plan.clone(), CostModel::default());
        let thr = ThreadComm::new(plan);
        let input = |rank: usize| -> Vec<f64> {
            (0..5).map(|i| (rank * 10 + i) as f64 * 0.37).collect()
        };
        let via = |comm: &dyn Comm| -> Vec<Vec<f64>> {
            on_ranks(k, |rank| {
                let mut v = input(rank);
                comm.allreduce_vec(rank, &mut v, ReduceOp::Sum);
                v
            })
        };
        let s = via(&sim);
        let t = via(&thr);
        // Rank-order fold reference.
        let mut want = input(0);
        for r in 1..k {
            for (w, v) in want.iter_mut().zip(input(r)) {
                *w += v;
            }
        }
        for rank in 0..k {
            assert_eq!(s[rank], want, "sim rank {rank}");
            assert_eq!(t[rank], want, "threads rank {rank}");
        }
        // Priced cost recorded on sim, measured on threads.
        assert!(sim.comm_secs().iter().all(|&c| c > 0.0));
        assert!(thr.comm_secs().iter().all(|&c| c >= 0.0));
    }

    #[test]
    fn allgatherv_concatenates_and_broadcast_replicates() {
        let k = 3;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let comm = SimComm::new(plan, CostModel::default());
        let gathered = on_ranks(k, |rank| {
            let local: Vec<f64> = (0..=rank).map(|i| i as f64 + rank as f64).collect();
            comm.allgatherv(rank, &local)
        });
        let want = vec![0.0, 1.0, 2.0, 2.0, 3.0, 4.0];
        for (rank, g) in gathered.iter().enumerate() {
            assert_eq!(g, &want, "rank {rank}");
        }
        let bcast = on_ranks(k, |rank| {
            let mut v = if rank == 1 { vec![7.0, 8.0, 9.0] } else { Vec::new() };
            comm.broadcast(rank, 1, &mut v);
            v
        });
        for (rank, b) in bcast.iter().enumerate() {
            assert_eq!(b, &vec![7.0, 8.0, 9.0], "rank {rank}");
        }
    }

    #[test]
    fn alltoallv_is_a_transpose() {
        let k = 3;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let comm = ThreadComm::new(plan);
        let part = |from: usize, to: usize| -> Vec<f64> {
            (0..(from + to) % 3).map(|i| (from * 100 + to * 10 + i) as f64).collect()
        };
        let got = on_ranks(k, |rank| {
            let parts: Vec<Vec<f64>> = (0..k).map(|d| part(rank, d)).collect();
            comm.alltoallv(rank, &parts)
        });
        for to in 0..k {
            for from in 0..k {
                assert_eq!(got[to][from], part(from, to), "{from} -> {to}");
            }
        }
    }

    #[test]
    fn single_rank_collectives_are_free_and_trivial() {
        let plan = Arc::new(ExchangePlan::collectives_only(1));
        let comm = SimComm::new(plan, CostModel::default());
        let mut v = vec![1.5, -2.0];
        comm.allreduce_vec(0, &mut v, ReduceOp::Sum);
        assert_eq!(v, vec![1.5, -2.0]);
        comm.allreduce_vec(0, &mut v, ReduceOp::Min);
        assert_eq!(v, vec![1.5, -2.0]);
        assert_eq!(comm.allgatherv(0, &v), v);
        let mut b = vec![3.0];
        comm.broadcast(0, 0, &mut b);
        let back = comm.alltoallv(0, &[vec![9.0]]);
        assert_eq!(back, vec![vec![9.0]]);
        assert_eq!(comm.comm_secs(), vec![0.0], "self-collectives must be free");
    }

    #[test]
    fn thread_comm_exchange_under_threads() {
        let (h, part) = setup();
        let plan = Arc::new(ExchangePlan::new(&h, &part));
        let comm = ThreadComm::new(plan.clone());
        let h = &h;
        let results: Vec<Vec<f32>> = {
            let mut out: Vec<Mutex<Vec<f32>>> = (0..4).map(|_| Mutex::new(Vec::new())).collect();
            std::thread::scope(|scope| {
                for (b, slot) in out.iter_mut().enumerate() {
                    let comm = &comm;
                    let plan = &plan;
                    scope.spawn(move || {
                        let owned: Vec<f32> =
                            h.blocks[b].own.iter().map(|&g| g as f32).collect();
                        comm.post_halo(b, &owned);
                        comm.sync(b);
                        let mut ghosts = vec![-1.0f32; plan.ghost_len[b]];
                        comm.recv_halo(b, &mut ghosts);
                        *slot.lock().unwrap() = ghosts;
                    });
                }
            });
            out.into_iter().map(|m| m.into_inner().unwrap()).collect()
        };
        for b in 0..4 {
            for (j, &g) in h.blocks[b].ghosts.iter().enumerate() {
                assert_eq!(results[b][j], g as f32, "rank {b} ghost {j}");
            }
        }
    }
}
