//! Virtual-cluster execution engine.
//!
//! The paper's objective — minimize `max_i w(b_i)/c_s(p_i)` plus
//! halo-exchange cost — is a statement about *concurrent* execution, but
//! the original application layer replayed it with a sequential loop
//! over blocks. This module makes the cluster real (in-process): the
//! [`Comm`] trait abstracts halo exchange and allreduce away from the
//! transport, and the [`VirtualCluster`] executor runs distributed CG
//! over per-PU row blocks through either transport:
//!
//! - `sim` — the α-β-priced transport driven by a sequential superstep
//!   executor (the old simulator's accounting, now produced by actually
//!   executing the distributed algorithm);
//! - `threads` — a shared-memory transport with one OS thread per PU,
//!   real barriers, and per-PU speed throttling.
//!
//! The `Comm` seam is deliberately transport-shaped (post / sync / read,
//! like bale's conveyors): an MPI or GPU transport slots in without
//! touching the executor or the solvers.
//!
//! The seam also carries nonblocking primitives (`isend`/`irecv` request
//! handles plus `test`/`wait`/`wait_all`, see [`Comm`]) so executors can
//! overlap the halo exchange with the interior rows of the SpMV and run
//! the pipelined single-reduction CG variant ([`CgVariant::Pipelined`]).
//! `SimComm` prices an overlap region at `max(compute, comm)` instead of
//! their sum — the simulator rewards overlap the way real hardware does —
//! while `ThreadComm` realizes the overlap through in-flight channels.
//! Overlap never changes numerics: on/off runs are bit-identical.
//!
//! Beyond the solver-shaped traffic, the seam carries generic
//! rendezvous collectives (`allreduce_vec`/`allgatherv`/`alltoallv`/
//! `broadcast`, see [`Comm`]) so *partitioning itself* can execute on
//! the cluster: [`run_dist_partition`] drives a
//! `partitioners::dist::DistPartitioner` with one rank thread per row
//! strip and reports priced (`sim`) or measured (`threads`)
//! partitioning time per rank ([`DistPartReport`]) — the paper's
//! quality-vs-partitioning-time axis. Distributed partitions are
//! bit-identical to their sequential counterparts at every rank count.
//!
//! On top of the collectives sits the aggregating message layer
//! ([`AggComm`], Bale's convey protocol): irregular kernels push tiny
//! fixed-size records per destination rank and the layer flushes them
//! as bulk `alltoallv` exchanges, amortizing the α latency across the
//! whole buffer — with a `direct` baseline mode ([`AggMode`]) so the
//! aggregation win is measurable on both transports.
//!
//! At scale the flat α-β picture stops being credible, so the seam also
//! models the machine's shape: a [`NetModel`] prices messages and
//! collective rounds by hop count (fat-tree / torus, [`NetKind`] is the
//! CLI axis), a [`HierSchedule`] runs the collectives as a two-level
//! intra-node/inter-node schedule (bit-identical to flat, strictly
//! cheaper in sim once ranks span nodes), and the closed-form
//! [`CollectiveModel`] prices the same schedules at rank counts far
//! beyond what rendezvous transports can instantiate (the `--matrix
//! scale` sweep runs it at 16384 virtual ranks).

mod agg;
mod cluster;
mod comm;
mod partition;

pub use cluster::{
    CgVariant, ClusterBackend, ExecBackend, ExecReport, SolveOpts, VirtualCluster,
};
// Re-exported so engine consumers name the layout axis without reaching
// into `solver::sell`.
pub use crate::solver::SpmvLayout;
pub use agg::{AggComm, AggMode, AggStats};
pub use partition::{run_dist_partition, run_dist_partition_net, DistPartReport};
pub use comm::{
    Comm, CommRequest, CollectiveModel, CostModel, ExchangePlan, HierSchedule, HierShape,
    NetKind, NetModel, ReduceOp, SendSegment, SimComm, ThreadComm, INTRA_SPEEDUP,
};
