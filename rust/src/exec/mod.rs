//! Virtual-cluster execution engine.
//!
//! The paper's objective — minimize `max_i w(b_i)/c_s(p_i)` plus
//! halo-exchange cost — is a statement about *concurrent* execution, but
//! the original application layer replayed it with a sequential loop
//! over blocks. This module makes the cluster real (in-process): the
//! [`Comm`] trait abstracts halo exchange and allreduce away from the
//! transport, and the [`VirtualCluster`] executor runs distributed CG
//! over per-PU row blocks through either transport:
//!
//! - `sim` — the α-β-priced transport driven by a sequential superstep
//!   executor (the old simulator's accounting, now produced by actually
//!   executing the distributed algorithm);
//! - `threads` — a shared-memory transport with one OS thread per PU,
//!   real barriers, and per-PU speed throttling.
//!
//! The `Comm` seam is deliberately transport-shaped (post / sync / read,
//! like bale's conveyors): an MPI or GPU transport slots in without
//! touching the executor or the solvers.

mod cluster;
mod comm;

pub use cluster::{ClusterBackend, ExecBackend, ExecReport, VirtualCluster};
pub use comm::{Comm, CostModel, ExchangePlan, SendSegment, SimComm, ThreadComm};
