//! Distributed-partitioning driver: run a
//! [`DistPartitioner`](crate::partitioners::dist::DistPartitioner) on
//! the virtual cluster and report *partitioning time* the same way the
//! solver reports solve time — α-β priced on the `sim` transport,
//! wall-clock measured on `threads`.
//!
//! Both backends drive the algorithm with one OS thread per rank (the
//! rendezvous collectives require concurrent ranks); the backends differ
//! only in costing. On `sim`, compute is modeled from the algorithm's
//! deterministic operation count (`modeled_ops · t_flop`, speed-1 ranks)
//! and communication is α-β priced per collective, so the reported
//! [`DistPartReport::part_secs`] is exactly reproducible run to run —
//! the number the harness's `partSecs` column and the paper's
//! quality-vs-partitioning-time scatter consume. On `threads`, both
//! shares are measured.

use super::cluster::ExecBackend;
use super::comm::{Comm, CostModel, ExchangePlan, NetModel, SimComm, ThreadComm};
use crate::graph::Csr;
use crate::partition::Partition;
use crate::partitioners::dist::{build_strips, DistCtx, DistPartitioner};
use crate::util::timer::Timer;
use anyhow::{anyhow, ensure, Context, Result};
use std::sync::{Arc, Mutex};

/// Per-rank cost breakdown of one distributed partitioning run.
#[derive(Debug, Clone)]
pub struct DistPartReport {
    /// Which transport ran (`"sim"` / `"threads"`).
    pub backend: &'static str,
    /// Rank count the partitioner executed on.
    pub ranks: usize,
    /// Algorithm name.
    pub algo: String,
    /// Per-rank compute seconds: modeled (`sim`) or measured (`threads`).
    pub compute_secs: Vec<f64>,
    /// Per-rank communication seconds: α-β priced (`sim`) or measured
    /// scatter/rendezvous (`threads`).
    pub comm_secs: Vec<f64>,
    /// Leader wall-clock for the whole run (thread spawn included).
    pub wall_secs: f64,
}

impl DistPartReport {
    /// Partitioning makespan: the slowest rank's compute + communication
    /// — deterministic on the priced backend, measured on `threads`.
    pub fn part_secs(&self) -> f64 {
        (0..self.ranks)
            .map(|r| self.compute_secs[r] + self.comm_secs[r])
            .fold(0.0f64, f64::max)
    }

    /// Rank whose compute + comm bounds the run.
    pub fn bottleneck_rank(&self) -> usize {
        (0..self.ranks)
            .max_by(|&a, &b| {
                let ta = self.compute_secs[a] + self.comm_secs[a];
                let tb = self.compute_secs[b] + self.comm_secs[b];
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap_or(0)
    }
}

/// Run `algo` over `ranks` virtual-cluster ranks and assemble the
/// global partition.
///
/// The graph is cut into segment-aligned row strips, one rank thread per
/// strip; ranks communicate exclusively through the transport's generic
/// collectives. Error paths inside rank functions must be replicated
/// decisions (every implementation in `partitioners::dist` keeps them
/// so), otherwise a lone failing rank would abandon its peers at a
/// rendezvous.
#[allow(clippy::too_many_arguments)]
pub fn run_dist_partition(
    g: &Csr,
    targets: &[f64],
    epsilon: f64,
    seed: u64,
    algo: &dyn DistPartitioner,
    backend: ExecBackend,
    ranks: usize,
    cost: CostModel,
) -> Result<(Partition, DistPartReport)> {
    run_dist_partition_net(
        g,
        targets,
        epsilon,
        seed,
        algo,
        backend,
        ranks,
        cost,
        NetModel::FlatAlphaBeta,
    )
}

/// [`run_dist_partition`] with an explicit network model for the priced
/// backend (`--net` on the CLI). `NetModel::FlatAlphaBeta` reproduces
/// the legacy charges exactly; the `threads` backend measures wall-clock
/// and ignores the model.
#[allow(clippy::too_many_arguments)]
pub fn run_dist_partition_net(
    g: &Csr,
    targets: &[f64],
    epsilon: f64,
    seed: u64,
    algo: &dyn DistPartitioner,
    backend: ExecBackend,
    ranks: usize,
    cost: CostModel,
    net: NetModel,
) -> Result<(Partition, DistPartReport)> {
    ensure!(g.n() >= 1, "empty graph");
    let k = targets.len();
    let wall = Timer::start();
    let strips = build_strips(g, ranks)?;
    let dim = g.coords[0].dim;
    let plan = Arc::new(ExchangePlan::collectives_only(ranks));
    let comm: Box<dyn Comm> = match backend {
        ExecBackend::Sim => Box::new(SimComm::with_net(plan, cost, net, None)),
        ExecBackend::Threads => Box::new(ThreadComm::new(plan)),
    };
    let comm = &*comm;
    type RankRet = Result<(Vec<u32>, f64, f64)>;
    let slots: Vec<Mutex<Option<RankRet>>> = (0..ranks).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for (rank, (strip, slot)) in strips.into_iter().zip(&slots).enumerate() {
            scope.spawn(move || {
                let ctx = DistCtx {
                    rank,
                    ranks,
                    n_global: g.n(),
                    dim,
                    strip,
                    targets,
                    epsilon,
                    seed,
                };
                let t = Timer::start();
                let run = || -> RankRet {
                    let outcome = algo
                        .partition_rank(&ctx, comm)
                        .with_context(|| format!("rank {rank}"))?;
                    ensure!(
                        outcome.assignment.len() == ctx.strip.n_local(),
                        "rank {rank}: strip assignment has wrong length"
                    );
                    Ok((outcome.assignment, outcome.modeled_ops, t.secs()))
                };
                *slot.lock().unwrap() = Some(run());
            });
        }
    });
    let mut assignment = Vec::with_capacity(g.n());
    let mut modeled_ops = vec![0.0f64; ranks];
    let mut elapsed = vec![0.0f64; ranks];
    for (rank, slot) in slots.into_iter().enumerate() {
        let (strip_assign, ops, secs) = slot
            .into_inner()
            .unwrap()
            .ok_or_else(|| anyhow!("rank {rank} produced no result"))??;
        assignment.extend_from_slice(&strip_assign);
        modeled_ops[rank] = ops;
        elapsed[rank] = secs;
    }
    let comm_secs = comm.comm_secs();
    let compute_secs: Vec<f64> = match backend {
        ExecBackend::Sim => modeled_ops.iter().map(|&ops| ops * cost.t_flop).collect(),
        ExecBackend::Threads => (0..ranks)
            .map(|r| (elapsed[r] - comm_secs[r]).max(0.0))
            .collect(),
    };
    let report = DistPartReport {
        backend: comm.label(),
        ranks,
        algo: algo.name().to_string(),
        compute_secs,
        comm_secs,
        wall_secs: wall.secs(),
    };
    Ok((Partition::new(assignment, k), report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::partitioners::dist::dist_by_name;
    use crate::partitioners::{by_name, Ctx};
    use crate::topology::Topology;

    #[test]
    fn dist_geokm_matches_sequential_and_prices_ranks() {
        let g = mesh_2d_tri(30, 30, 1);
        let topo = Topology::homogeneous(4, 1.0, 1e9);
        let targets = vec![g.n() as f64 / 4.0; 4];
        let ctx = Ctx { graph: &g, targets: &targets, topo: &topo, epsilon: 0.05, seed: 3 };
        let seq = by_name("geoKM").unwrap().partition(&ctx).unwrap();
        let algo = dist_by_name("geoKM").unwrap();
        for ranks in [1, 2, 4] {
            let (p, rep) = run_dist_partition(
                &g,
                &targets,
                0.05,
                3,
                algo.as_ref(),
                ExecBackend::Sim,
                ranks,
                CostModel::default(),
            )
            .unwrap();
            assert_eq!(p.assignment, seq.assignment, "ranks={ranks}");
            assert_eq!(rep.ranks, ranks);
            assert_eq!(rep.backend, "sim");
            assert!(rep.part_secs() > 0.0);
            assert!(rep.bottleneck_rank() < ranks);
            if ranks == 1 {
                assert_eq!(rep.comm_secs, vec![0.0], "self-collectives must be free");
            } else {
                assert!(rep.comm_secs.iter().all(|&c| c > 0.0));
            }
        }
    }

    #[test]
    fn unknown_sizes_are_rejected() {
        let g = mesh_2d_tri(10, 10, 1);
        let targets = vec![g.n() as f64 / 2.0; 2];
        let algo = dist_by_name("zRCB").unwrap();
        assert!(run_dist_partition(
            &g,
            &targets,
            0.05,
            1,
            algo.as_ref(),
            ExecBackend::Sim,
            3,
            CostModel::default(),
        )
        .is_err());
    }
}
