//! Aggregating message layer over the [`Comm`] seam (Bale's convey
//! protocol).
//!
//! Irregular graph kernels generate torrents of tiny records — a BFS
//! frontier expansion, a delta-stepping relaxation, a PageRank
//! contribution — each a handful of words addressed to whichever rank
//! owns the target vertex. Shipping them one at a time pays the α
//! latency per message; the whole point of Bale/Conveyor-style
//! aggregation is to pay α once per *buffer* instead. [`AggComm`] is
//! that layer: callers [`AggComm::push`] fixed-size records into
//! per-destination buffers, and [`AggComm::drain`] flushes them as bulk
//! `alltoallv` exchanges at the epoch boundary.
//!
//! # Flush protocol
//!
//! A flush is a collective (`alltoallv` needs every rank), so a rank
//! whose buffer fills cannot flush unilaterally. Instead [`drain`]
//! agrees on a global round count — one `allreduce` max of
//! `ceil(buffered records / capacity)` — and every rank then performs
//! exactly that many `alltoallv` flushes, each carrying at most
//! `buffer_bytes` per destination (ranks whose buffers ran dry
//! contribute empty parts). [`AggMode::Direct`] is the degenerate
//! capacity of **one record per destination per flush**: every record
//! becomes its own exchange round, which is exactly the unaggregated
//! message-per-edge baseline the aggregation win is measured against.
//!
//! # Pricing and bit-identity
//!
//! The transports price/measure flushes with no new seams: on `SimComm`
//! each flush is one `alltoallv` charge — α per peer plus β for every
//! byte — so a buffer of B records costs `(k−1)·α + β·bytes` where the
//! direct mode pays `B·(k−1)·α + β·bytes`; on `ThreadComm` each flush
//! is a real rendezvous, so direct mode's extra rounds are measured
//! wall-clock waits. Delivered data is **bit-identical across modes and
//! backends**: a receiver always sees, per source rank, that source's
//! records in push order (chunking only splits the concatenation,
//! `alltoallv` preserves both the per-source grouping and the order
//! within each part).
//!
//! [`drain`]: AggComm::drain

use super::comm::{Comm, ReduceOp};

/// Aggregation mode of an [`AggComm`]: the only knob that separates the
/// amortized transport from the message-per-record baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AggMode {
    /// Buffer records and flush at most `buffer_bytes` per destination
    /// per exchange round (the aggregating default).
    #[default]
    Agg,
    /// One record per destination per exchange round — the unaggregated
    /// baseline (`--agg off`).
    Direct,
}

impl AggMode {
    /// Parse a CLI mode (`on`/`agg` aggregate, `off`/`direct` do not).
    pub fn parse(s: &str) -> Option<AggMode> {
        match s.to_ascii_lowercase().as_str() {
            "on" | "agg" | "true" | "1" => Some(AggMode::Agg),
            "off" | "direct" | "false" | "0" => Some(AggMode::Direct),
            _ => None,
        }
    }

    /// Canonical mode name (`"agg"` / `"direct"`).
    pub fn name(&self) -> &'static str {
        match self {
            AggMode::Agg => "agg",
            AggMode::Direct => "direct",
        }
    }
}

/// Traffic counters of one rank's [`AggComm`] (all counters exclude
/// self-destined records, which never touch the wire).
#[derive(Debug, Clone, Default)]
pub struct AggStats {
    /// Exchange rounds (`alltoallv` calls) performed by [`AggComm::drain`].
    pub flushes: usize,
    /// Records pushed to other ranks.
    pub records: usize,
    /// Bytes shipped to other ranks (8 per word).
    pub bytes_sent: usize,
    /// Bytes shipped per destination rank (the rank's row of the link
    /// matrix behind the `maxLinkBytes` bottleneck metric).
    pub bytes_to: Vec<usize>,
}

/// Per-rank aggregating endpoint: buffers fixed-size records per
/// destination and flushes them through the wrapped transport's
/// `alltoallv`. One instance per rank thread; [`AggComm::drain`] is a
/// collective and must be called by every rank in the same sequence
/// (the rendezvous contract of the underlying [`Comm`]).
pub struct AggComm<'a> {
    comm: &'a dyn Comm,
    rank: usize,
    /// Words per record (fixed per kernel; pushes are length-checked).
    rec_words: usize,
    /// Records per destination per flush (1 in direct mode).
    cap_records: usize,
    /// Per-destination outgoing buffers (encoded records, back to back).
    bufs: Vec<Vec<f64>>,
    /// Traffic counters.
    stats: AggStats,
}

impl<'a> AggComm<'a> {
    /// New endpoint for `rank` pushing `rec_words`-word records. In
    /// [`AggMode::Agg`], each destination flushes up to `buffer_bytes`
    /// per round (at least one record); [`AggMode::Direct`] ignores
    /// `buffer_bytes` and flushes one record per destination per round.
    pub fn new(
        comm: &'a dyn Comm,
        rank: usize,
        mode: AggMode,
        rec_words: usize,
        buffer_bytes: usize,
    ) -> AggComm<'a> {
        assert!(rec_words >= 1, "records must carry at least one word");
        let cap_records = match mode {
            AggMode::Agg => (buffer_bytes / (8 * rec_words)).max(1),
            AggMode::Direct => 1,
        };
        let k = comm.k();
        AggComm {
            comm,
            rank,
            rec_words,
            cap_records,
            bufs: vec![Vec::new(); k],
            stats: AggStats { bytes_to: vec![0; k], ..AggStats::default() },
        }
    }

    /// Rank count of the wrapped transport.
    pub fn k(&self) -> usize {
        self.comm.k()
    }

    /// Buffer one record for `dest`. Purely local: nothing moves until
    /// the next [`AggComm::drain`]. `rec` must be exactly the record
    /// width this endpoint was built with.
    pub fn push(&mut self, dest: usize, rec: &[f64]) {
        assert_eq!(rec.len(), self.rec_words, "record width mismatch");
        self.bufs[dest].extend_from_slice(rec);
        if dest != self.rank {
            self.stats.records += 1;
        }
    }

    /// Records currently buffered (all destinations).
    pub fn buffered_records(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum::<usize>() / self.rec_words
    }

    /// Collective epoch boundary: agree on the global round count, flush
    /// every buffered record, and return the received words grouped by
    /// source rank (each source's records in its push order — the order
    /// is independent of mode, backend, and buffer size).
    pub fn drain(&mut self) -> Vec<Vec<f64>> {
        let k = self.comm.k();
        let chunk_words = self.cap_records * self.rec_words;
        let local_rounds = self
            .bufs
            .iter()
            .map(|b| b.len().div_ceil(chunk_words))
            .max()
            .unwrap_or(0);
        let mut v = [local_rounds as f64];
        self.comm.allreduce_vec(self.rank, &mut v, ReduceOp::Max);
        let rounds = v[0] as usize;
        let mut recv: Vec<Vec<f64>> = vec![Vec::new(); k];
        for round in 0..rounds {
            let parts: Vec<Vec<f64>> = self
                .bufs
                .iter()
                .map(|b| {
                    let lo = (round * chunk_words).min(b.len());
                    let hi = ((round + 1) * chunk_words).min(b.len());
                    b[lo..hi].to_vec()
                })
                .collect();
            for (d, p) in parts.iter().enumerate() {
                if d != self.rank {
                    self.stats.bytes_sent += 8 * p.len();
                    self.stats.bytes_to[d] += 8 * p.len();
                }
            }
            let out = self.comm.alltoallv(self.rank, &parts);
            for (src, part) in out.into_iter().enumerate() {
                recv[src].extend(part);
            }
            self.stats.flushes += 1;
        }
        for b in &mut self.bufs {
            b.clear();
        }
        recv
    }

    /// Traffic counters so far.
    pub fn stats(&self) -> &AggStats {
        &self.stats
    }

    /// Words per record.
    pub fn rec_words(&self) -> usize {
        self.rec_words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{CostModel, ExchangePlan, SimComm, ThreadComm};
    use std::sync::{Arc, Mutex};

    fn on_ranks<R: Send>(k: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
        let slots: Vec<Mutex<Option<R>>> = (0..k).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for (rank, slot) in slots.iter().enumerate() {
                let f = &f;
                scope.spawn(move || {
                    *slot.lock().unwrap() = Some(f(rank));
                });
            }
        });
        slots.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
    }

    /// Each rank pushes (rank·16 + i) records round-robin; receivers must
    /// see per-source push order regardless of mode/backend/buffer size.
    fn exchange(comm: &dyn Comm, k: usize, mode: AggMode, buffer_bytes: usize) -> Vec<Vec<Vec<f64>>> {
        on_ranks(k, |rank| {
            let mut agg = AggComm::new(comm, rank, mode, 2, buffer_bytes);
            for i in 0..(rank + 2) * 3 {
                let dest = i % k;
                agg.push(dest, &[(rank * 100 + i) as f64, i as f64]);
            }
            agg.drain()
        })
    }

    #[test]
    fn modes_and_buffer_sizes_deliver_identically() {
        for k in [1usize, 2, 4] {
            let plan = Arc::new(ExchangePlan::collectives_only(k));
            let sim = SimComm::new(plan.clone(), CostModel::default());
            let want = exchange(&sim, k, AggMode::Agg, 1 << 16);
            for (mode, bytes) in
                [(AggMode::Agg, 64), (AggMode::Agg, 16), (AggMode::Direct, 1 << 16)]
            {
                let sim2 = SimComm::new(plan.clone(), CostModel::default());
                assert_eq!(exchange(&sim2, k, mode, bytes), want, "k={k} {mode:?} {bytes}");
                let thr = ThreadComm::new(plan.clone());
                assert_eq!(exchange(&thr, k, mode, bytes), want, "threads k={k} {mode:?}");
            }
        }
    }

    #[test]
    fn direct_mode_pays_more_alpha_than_agg() {
        let k = 4;
        let run = |mode: AggMode| {
            let plan = Arc::new(ExchangePlan::collectives_only(k));
            let sim = SimComm::new(plan, CostModel::default());
            exchange(&sim, k, mode, 1 << 16);
            sim.comm_secs().iter().sum::<f64>()
        };
        let agg = run(AggMode::Agg);
        let direct = run(AggMode::Direct);
        assert!(
            direct > agg,
            "direct priced comm {direct} must exceed aggregated {agg}"
        );
    }

    #[test]
    fn stats_count_off_rank_traffic_only() {
        let k = 2;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let sim = SimComm::new(plan, CostModel::default());
        let stats = on_ranks(k, |rank| {
            let mut agg = AggComm::new(&sim, rank, AggMode::Agg, 3, 1 << 16);
            agg.push(rank, &[1.0, 2.0, 3.0]); // self: free
            agg.push(1 - rank, &[4.0, 5.0, 6.0]);
            agg.drain();
            agg.stats().clone()
        });
        for (rank, s) in stats.iter().enumerate() {
            assert_eq!(s.records, 1, "rank {rank}");
            assert_eq!(s.bytes_sent, 24, "rank {rank}");
            assert_eq!(s.bytes_to[rank], 0, "self link must stay empty");
            assert_eq!(s.bytes_to[1 - rank], 24);
            assert_eq!(s.flushes, 1);
        }
    }

    #[test]
    fn empty_drain_performs_no_flush() {
        let k = 2;
        let plan = Arc::new(ExchangePlan::collectives_only(k));
        let sim = SimComm::new(plan, CostModel::default());
        let stats = on_ranks(k, |rank| {
            let mut agg = AggComm::new(&sim, rank, AggMode::Agg, 2, 1 << 16);
            let recv = agg.drain();
            assert!(recv.iter().all(|p| p.is_empty()));
            agg.stats().flushes
        });
        assert_eq!(stats, vec![0, 0]);
    }

    #[test]
    fn mode_names_round_trip() {
        assert_eq!(AggMode::parse("on"), Some(AggMode::Agg));
        assert_eq!(AggMode::parse("agg"), Some(AggMode::Agg));
        assert_eq!(AggMode::parse("off"), Some(AggMode::Direct));
        assert_eq!(AggMode::parse("direct"), Some(AggMode::Direct));
        assert_eq!(AggMode::parse("nope"), None);
        assert_eq!(AggMode::Agg.name(), "agg");
        assert_eq!(AggMode::Direct.name(), "direct");
    }
}
