//! Thread-per-PU virtual-cluster executor.
//!
//! [`VirtualCluster`] owns the per-PU row blocks of a partitioned ELL
//! matrix (the halo decomposition) and runs *distributed* CG through a
//! [`Comm`] transport: every dot product is a deposit + rank-order
//! allreduce, every SpMV is preceded by a halo exchange. Two backends:
//!
//! - [`ExecBackend::Sim`] — the sequential superstep executor: one
//!   thread plays all ranks phase by phase, communication cost is priced
//!   by the α-β [`CostModel`] and compute by `t_flop / speed` (this is
//!   the old `distsim` accounting, now produced by actually executing
//!   the distributed algorithm through the `Comm` seam);
//! - [`ExecBackend::Threads`] — one OS thread per PU: real barriers,
//!   real shared-memory exchange, and per-PU *speed throttling* (slower
//!   PUs sleep proportionally to `max_speed / speed`), so the measured
//!   makespan shows the same bottleneck structure the paper measures on
//!   tuned-down nodes.
//!
//! Both backends run the identical per-rank step functions and the same
//! rank-ordered reductions, so their residual trajectories agree to the
//! last bit — which is exactly the property the integration tests pin.

use super::comm::{Comm, CostModel, ExchangePlan, NetModel, SimComm, ThreadComm};
use crate::partition::Partition;
use crate::solver::cg::{CgResult, SpmvBackend};
use crate::solver::halo::HaloMatrix;
use crate::solver::sell::{SellMatrix, DEFAULT_CHUNK, DEFAULT_SIGMA};
use crate::solver::{EllMatrix, SpmvLayout};
use crate::topology::Topology;
use crate::util::timer::Timer;
use anyhow::{ensure, Result};
use std::sync::Arc;

const TINY: f64 = 1e-30;

/// Which engine drives the virtual cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecBackend {
    /// Sequential superstep execution, α-β-priced communication.
    Sim,
    /// One OS thread per PU, measured wall-clock, speed throttling.
    Threads,
}

impl ExecBackend {
    /// Parse a CLI backend name (`sim` / `threads`), case-insensitive.
    pub fn parse(s: &str) -> Option<ExecBackend> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Some(ExecBackend::Sim),
            "threads" | "thread" => Some(ExecBackend::Threads),
            _ => None,
        }
    }

    /// Canonical backend name (`"sim"` / `"threads"`).
    pub fn name(&self) -> &'static str {
        match self {
            ExecBackend::Sim => "sim",
            ExecBackend::Threads => "threads",
        }
    }
}

/// Which distributed-CG iteration the executor runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CgVariant {
    /// Textbook CG: two allreduces per iteration (p·Ap, then r·r).
    #[default]
    Classic,
    /// Saad/Eller-style single-reduction CG: p·Ap and Ap·Ap ride **one**
    /// combined allreduce right after the SpMV, and ‖r‖² follows from
    /// the recurrence `rs' = α²·(Ap·Ap) − rs` instead of a second
    /// reduction. Same solution, slightly different round-off trajectory
    /// (the recurrence is exact in real arithmetic but not in f64); one
    /// synchronization per iteration instead of two.
    Pipelined,
}

impl CgVariant {
    /// Parse a CLI variant name (`classic` / `pipelined`).
    pub fn parse(s: &str) -> Option<CgVariant> {
        match s.to_ascii_lowercase().as_str() {
            "classic" | "cg" => Some(CgVariant::Classic),
            "pipelined" | "pipe" | "pipecg" => Some(CgVariant::Pipelined),
            _ => None,
        }
    }

    /// Canonical variant name (`"classic"` / `"pipelined"`).
    pub fn name(&self) -> &'static str {
        match self {
            CgVariant::Classic => "classic",
            CgVariant::Pipelined => "pipelined",
        }
    }
}

/// Execution options for a virtual-cluster solve.
#[derive(Debug, Clone, Copy, Default)]
pub struct SolveOpts {
    /// Overlap the halo exchange with the interior SpMV through the
    /// nonblocking `Comm` path. Numerics are bit-identical to the
    /// blocking path (row order changes, per-row arithmetic does not);
    /// only the communication accounting / wall-clock changes.
    pub overlap: bool,
    /// Which CG iteration to run (see [`CgVariant`]).
    pub variant: CgVariant,
    /// Which SpMV storage layout the rank kernels run on (see
    /// `solver::sell`). Results are `==`-equal across layouts; modeled
    /// `sim` compute cost is layout-independent by design (the simulator
    /// prices the algorithm, the `threads` backend and the benches
    /// measure the layout).
    pub layout: SpmvLayout,
    /// Network model the priced (`sim`) backend charges halo messages
    /// and collective rounds with. The default `FlatAlphaBeta` keeps the
    /// legacy charges bit-exact; the measured backend ignores it.
    pub net: NetModel,
}

impl SolveOpts {
    /// Options for an overlapped classic-CG solve.
    pub fn overlapped() -> SolveOpts {
        SolveOpts { overlap: true, ..SolveOpts::default() }
    }
}

/// Per-solve kernel structures for the chosen [`SpmvLayout`], built once
/// before the iteration loop (never inside it — the loop allocates
/// nothing). The SELL pair covers interior and boundary rows separately
/// so the overlap path hides exactly the same rows as on ELL.
enum LayoutKernels {
    /// Run the blocks' ELL kernels directly.
    Ell,
    /// Per-rank (interior, boundary) SELL-C-σ kernels.
    Sell(Vec<(SellMatrix, SellMatrix)>),
}

/// Per-rank cost breakdown of one engine run.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Which transport ran (`"sim"` / `"threads"`).
    pub backend: &'static str,
    /// CG iterations executed.
    pub iterations: usize,
    /// Per-rank compute seconds: modeled (`sim`) or measured+throttled
    /// (`threads`).
    pub compute_secs: Vec<f64>,
    /// Per-rank communication seconds: α-β priced (`sim`) or measured
    /// scatter/copy/barrier-wait (`threads`). For the priced transport
    /// with overlap on, this is the *exposed* communication only.
    pub comm_secs: Vec<f64>,
    /// Per-rank priced communication seconds hidden behind overlapped
    /// compute (zero for the measured transport and for blocking runs).
    pub comm_hidden_secs: Vec<f64>,
    /// Leader wall-clock for the whole solve.
    pub wall_secs: f64,
}

impl ExecReport {
    /// Total priced communication hidden behind compute (seconds).
    pub fn comm_hidden_total(&self) -> f64 {
        self.comm_hidden_secs.iter().sum()
    }

    /// Overlap efficiency: hidden / (hidden + exposed) priced
    /// communication, over all ranks — 0 for a blocking run, higher the
    /// more of the *total* communication bill (halo exchange **and**
    /// allreduce latency) vanished behind compute. Because reduction
    /// latency is never hidden by the halo overlap, fully hidden
    /// exchanges still leave this below 1; a stubbornly low value with
    /// hidden > 0 points at allreduce-dominated cost (try
    /// [`CgVariant::Pipelined`], which halves it).
    pub fn overlap_efficiency(&self) -> f64 {
        let hidden = self.comm_hidden_total();
        let total = hidden + self.comm_secs.iter().sum::<f64>();
        if total > 0.0 {
            hidden / total
        } else {
            0.0
        }
    }
    /// Rank whose compute + comm bounds the run (the makespan PU).
    pub fn bottleneck_rank(&self) -> usize {
        (0..self.compute_secs.len())
            .max_by(|&a, &b| {
                let ta = self.compute_secs[a] + self.comm_secs[a];
                let tb = self.compute_secs[b] + self.comm_secs[b];
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap_or(0)
    }

    /// Bottleneck (compute + comm) seconds per iteration.
    pub fn time_per_iter(&self) -> f64 {
        let b = self.bottleneck_rank();
        (self.compute_secs[b] + self.comm_secs[b]) / self.iterations.max(1) as f64
    }
}

/// Mutable per-rank CG state; `p` is in local layout `[own | ghosts]`.
struct RankState {
    x: Vec<f32>,
    r: Vec<f32>,
    ap: Vec<f32>,
    p: Vec<f32>,
}

/// The virtual cluster: per-PU row blocks plus speeds and a cost model.
pub struct VirtualCluster {
    /// Per-PU halo row blocks (rank order).
    pub halo: HaloMatrix,
    /// The static halo-exchange pattern every transport executes.
    pub plan: Arc<ExchangePlan>,
    /// Per-PU normalized speeds (topology order).
    pub speeds: Vec<f64>,
    /// Global number of rows.
    pub n: usize,
    w: usize,
    cost: CostModel,
    /// Throttle threaded compute to emulate per-PU speeds (numerics are
    /// unaffected; only wall-clock changes).
    pub throttle: bool,
}

impl VirtualCluster {
    /// Decompose `ell` by `part` onto the PUs of `topo`.
    pub fn new(
        ell: &EllMatrix,
        part: &Partition,
        topo: &Topology,
        cost: CostModel,
    ) -> Result<VirtualCluster> {
        ensure!(part.k == topo.k(), "partition k={} vs topology k={}", part.k, topo.k());
        let speeds: Vec<f64> = topo.pus.iter().map(|p| p.speed).collect();
        Self::with_speeds(ell, part, speeds, cost)
    }

    /// Decompose with explicit per-PU speeds (benches, tests).
    pub fn with_speeds(
        ell: &EllMatrix,
        part: &Partition,
        speeds: Vec<f64>,
        cost: CostModel,
    ) -> Result<VirtualCluster> {
        ensure!(part.k == speeds.len(), "partition k={} vs speeds {}", part.k, speeds.len());
        // Finite and positive: an infinite/NaN speed would make the
        // throttle factor panic inside a rank thread, and a panicking
        // rank deadlocks the others at the barrier (see ThreadComm).
        ensure!(
            speeds.iter().all(|&s| s > 0.0 && s.is_finite()),
            "PU speeds must be positive and finite"
        );
        let halo = HaloMatrix::new(ell, part);
        let plan = Arc::new(ExchangePlan::new(&halo, part));
        Ok(VirtualCluster {
            plan,
            speeds,
            n: ell.n,
            w: ell.w,
            cost,
            throttle: true,
            halo,
        })
    }

    /// Homogeneous speed-1 cluster (the bench baseline).
    pub fn homogeneous(ell: &EllMatrix, part: &Partition) -> Result<VirtualCluster> {
        let mut vc =
            Self::with_speeds(ell, part, vec![1.0; part.k], CostModel::default())?;
        vc.throttle = false;
        Ok(vc)
    }

    /// Number of PUs.
    pub fn k(&self) -> usize {
        self.speeds.len()
    }

    /// Run a *partitioner* on the virtual cluster: cut `g` into `ranks`
    /// row strips and execute the distributed implementation of `algo`
    /// (see `partitioners::dist::DIST_NAMES`) through the chosen
    /// transport, returning the assembled partition plus the per-rank
    /// partitioning-time report (priced on `sim`, measured on
    /// `threads`).
    ///
    /// This is an associated constructor-style entry point rather than a
    /// method: partitioning is what *produces* the partition a
    /// `VirtualCluster` instance is built from. The result is
    /// bit-identical to the sequential `partitioners::by_name(algo)` run
    /// with the same inputs (pinned by `tests/dist_partition.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn partition_dist(
        g: &crate::graph::Csr,
        targets: &[f64],
        epsilon: f64,
        seed: u64,
        algo: &str,
        backend: ExecBackend,
        ranks: usize,
        cost: CostModel,
    ) -> Result<(Partition, super::partition::DistPartReport)> {
        Self::partition_dist_net(
            g,
            targets,
            epsilon,
            seed,
            algo,
            backend,
            ranks,
            cost,
            NetModel::FlatAlphaBeta,
        )
    }

    /// [`VirtualCluster::partition_dist`] with an explicit network model
    /// for the priced backend (the `--net` axis).
    #[allow(clippy::too_many_arguments)]
    pub fn partition_dist_net(
        g: &crate::graph::Csr,
        targets: &[f64],
        epsilon: f64,
        seed: u64,
        algo: &str,
        backend: ExecBackend,
        ranks: usize,
        cost: CostModel,
        net: NetModel,
    ) -> Result<(Partition, super::partition::DistPartReport)> {
        use crate::partitioners::dist::{dist_by_name, DIST_NAMES};
        let p = dist_by_name(algo).ok_or_else(|| {
            anyhow::anyhow!(
                "no distributed implementation for '{algo}' (available: {})",
                DIST_NAMES.join(", ")
            )
        })?;
        super::partition::run_dist_partition_net(
            g, targets, epsilon, seed, p.as_ref(), backend, ranks, cost, net,
        )
    }

    /// Run distributed CG from x₀ = 0 through the chosen backend
    /// (blocking exchange, classic CG — see
    /// [`VirtualCluster::solve_cg_opts`] for overlap and variants).
    pub fn solve_cg(
        &self,
        backend: ExecBackend,
        b: &[f32],
        max_iters: usize,
        tol: f32,
    ) -> Result<(CgResult, ExecReport)> {
        self.solve_cg_opts(backend, b, max_iters, tol, SolveOpts::default())
    }

    /// Run distributed CG with explicit execution options: nonblocking
    /// compute/communication overlap (`opts.overlap`) and/or the
    /// pipelined single-reduction variant (`opts.variant`).
    ///
    /// For a fixed variant, overlap on/off produces **bit-identical**
    /// iterates and residuals (pinned by `tests/overlap.rs`); on the
    /// `sim` backend overlap strictly lowers the priced communication of
    /// every rank that has both interior rows and neighbors.
    pub fn solve_cg_opts(
        &self,
        backend: ExecBackend,
        b: &[f32],
        max_iters: usize,
        tol: f32,
        opts: SolveOpts,
    ) -> Result<(CgResult, ExecReport)> {
        ensure!(b.len() == self.n, "rhs length {} != n {}", b.len(), self.n);
        match backend {
            ExecBackend::Sim => self.solve_sim(b, max_iters, tol, opts),
            ExecBackend::Threads => self.solve_threads(b, max_iters, tol, opts),
        }
    }

    /// One distributed SpMV `y = A·x` through the chosen backend
    /// (exchange ghosts, compute per-PU blocks, gather).
    ///
    /// The `threads` backend spawns k OS threads *per call* — fine for a
    /// one-shot product, wasteful inside an iteration loop. Iterative
    /// solves should use [`VirtualCluster::solve_cg`], which keeps the
    /// rank threads alive across all iterations.
    pub fn spmv(&self, backend: ExecBackend, x: &[f32], y: &mut [f32]) -> Result<()> {
        ensure!(x.len() == self.n && y.len() == self.n, "vector length");
        match backend {
            ExecBackend::Sim => {
                let comm = SimComm::new(self.plan.clone(), self.cost);
                let locals: Vec<Vec<f32>> =
                    (0..self.k()).map(|rank| self.gather_local(rank, x)).collect();
                for (rank, xl) in locals.iter().enumerate() {
                    comm.post_halo(rank, &xl[..self.plan.own_len[rank]]);
                }
                for (rank, mut xl) in locals.into_iter().enumerate() {
                    let nb = self.plan.own_len[rank];
                    comm.recv_halo(rank, &mut xl[nb..]);
                    let mut y_local = vec![0.0f32; nb];
                    self.local_spmv(rank, &xl, &mut y_local);
                    self.scatter_owned(rank, &y_local, y);
                }
            }
            ExecBackend::Threads => {
                let comm = ThreadComm::new(self.plan.clone());
                let parts: Vec<(usize, Vec<f32>)> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..self.k())
                        .map(|rank| {
                            let comm = &comm;
                            scope.spawn(move || {
                                let mut xl = self.gather_local(rank, x);
                                let nb = self.plan.own_len[rank];
                                comm.post_halo(rank, &xl[..nb]);
                                comm.sync(rank);
                                comm.recv_halo(rank, &mut xl[nb..]);
                                let mut y_local = vec![0.0f32; nb];
                                self.local_spmv(rank, &xl, &mut y_local);
                                (rank, y_local)
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                for (rank, y_local) in parts {
                    self.scatter_owned(rank, &y_local, y);
                }
            }
        }
        Ok(())
    }

    // ---- rank-level helpers (shared verbatim by both backends, so the
    // ---- arithmetic is identical) --------------------------------------

    /// Local vector `[x at own ids | zeros for ghosts]`.
    fn gather_local(&self, rank: usize, x: &[f32]) -> Vec<f32> {
        let blk = &self.halo.blocks[rank];
        let mut xl = Vec::with_capacity(blk.own.len() + blk.ghosts.len());
        for &g in &blk.own {
            xl.push(x[g as usize]);
        }
        xl.resize(blk.own.len() + blk.ghosts.len(), 0.0);
        xl
    }

    fn scatter_owned(&self, rank: usize, local: &[f32], global: &mut [f32]) {
        for (li, &g) in self.halo.blocks[rank].own.iter().enumerate() {
            global[g as usize] = local[li];
        }
    }

    /// Local ELL SpMV including the diagonal — the shared `HaloBlock`
    /// kernel, so the executor cannot drop the diagonal and every
    /// distributed path runs the same loop.
    fn local_spmv(&self, rank: usize, xl: &[f32], y_local: &mut [f32]) {
        self.halo.blocks[rank].spmv_local(xl, y_local);
    }

    fn init_state(&self, rank: usize, b: &[f32]) -> RankState {
        let blk = &self.halo.blocks[rank];
        let nb = blk.own.len();
        let b_local: Vec<f32> = blk.own.iter().map(|&g| b[g as usize]).collect();
        let mut p = b_local.clone();
        p.resize(nb + blk.ghosts.len(), 0.0);
        RankState { x: vec![0.0; nb], r: b_local, ap: vec![0.0; nb], p }
    }

    fn step_post(&self, comm: &dyn Comm, rank: usize, st: &RankState) {
        comm.post_halo(rank, &st.p[..self.plan.own_len[rank]]);
    }

    /// Receive this rank's ghost values into `p`'s ghost segment (time is
    /// charged to the transport, not to compute).
    fn step_recv(&self, comm: &dyn Comm, rank: usize, st: &mut RankState) {
        let nb = self.plan.own_len[rank];
        comm.recv_halo(rank, &mut st.p[nb..]);
    }

    /// Build the per-rank kernel structures for `layout`, once per solve.
    fn layout_kernels(&self, layout: SpmvLayout) -> LayoutKernels {
        match layout {
            SpmvLayout::Ell => LayoutKernels::Ell,
            SpmvLayout::SellCs => LayoutKernels::Sell(
                self.halo
                    .blocks
                    .iter()
                    .map(|blk| {
                        (
                            SellMatrix::from_ell_rows(
                                &blk.ell, &blk.interior, DEFAULT_CHUNK, DEFAULT_SIGMA,
                            ),
                            SellMatrix::from_ell_rows(
                                &blk.ell, &blk.boundary, DEFAULT_CHUNK, DEFAULT_SIGMA,
                            ),
                        )
                    })
                    .collect(),
            ),
        }
    }

    /// Full local SpMV into the state's `ap` (no reduction deposit —
    /// [`VirtualCluster::deposit_partials`] handles that per variant).
    /// On SELL the interior and boundary kernels together cover every
    /// owned row exactly once, so this is the fused full product.
    fn local_spmv_into_state(&self, kernels: &LayoutKernels, rank: usize, st: &mut RankState) {
        match kernels {
            LayoutKernels::Ell => self.local_spmv(rank, &st.p, &mut st.ap),
            LayoutKernels::Sell(pairs) => {
                pairs[rank].0.spmv_into(&st.p, &mut st.ap);
                pairs[rank].1.spmv_into(&st.p, &mut st.ap);
            }
        }
    }

    /// Apply only the interior rows (no ghost columns) — the compute the
    /// nonblocking halo exchange hides.
    fn spmv_interior(&self, kernels: &LayoutKernels, rank: usize, st: &mut RankState) {
        let blk = &self.halo.blocks[rank];
        match kernels {
            LayoutKernels::Ell => blk.spmv_rows(&st.p, &mut st.ap, &blk.interior),
            LayoutKernels::Sell(pairs) => pairs[rank].0.spmv_into(&st.p, &mut st.ap),
        }
    }

    /// Apply the boundary rows (valid once the ghost segment of `p` is
    /// filled).
    fn spmv_boundary(&self, kernels: &LayoutKernels, rank: usize, st: &mut RankState) {
        let blk = &self.halo.blocks[rank];
        match kernels {
            LayoutKernels::Ell => blk.spmv_rows(&st.p, &mut st.ap, &blk.boundary),
            LayoutKernels::Sell(pairs) => pairs[rank].1.spmv_into(&st.p, &mut st.ap),
        }
    }

    /// Deposit the iteration's reduction partial(s): p·Ap on channel 0
    /// (classic), or the combined (p·Ap, Ap·Ap) pair as one message
    /// (pipelined). The partials sum in local index order either way, so
    /// the classic deposit is bit-identical across blocking/overlap paths.
    fn deposit_partials(&self, comm: &dyn Comm, rank: usize, st: &RankState, variant: CgVariant) {
        let nb = self.plan.own_len[rank];
        let p_ap: f64 = (0..nb).map(|i| (st.p[i] * st.ap[i]) as f64).sum();
        match variant {
            CgVariant::Classic => comm.reduce_post(0, rank, p_ap),
            CgVariant::Pipelined => {
                let ap_ap: f64 = (0..nb).map(|i| (st.ap[i] * st.ap[i]) as f64).sum();
                comm.reduce_post_pair(rank, p_ap, ap_ap);
            }
        }
    }

    /// Pipelined update: read the combined sums, derive α and the ‖r‖²
    /// recurrence `rs' = α²·(Ap·Ap) − rs` (clamped at 0 against late
    /// round-off), then fuse the x/r/p updates into one sweep. Returns
    /// the new rs. One reduction read per iteration — the Saad/Eller
    /// single-synchronization form.
    fn step_pipelined_update(
        &self,
        comm: &dyn Comm,
        rank: usize,
        st: &mut RankState,
        rs: f64,
    ) -> f64 {
        let p_ap = comm.reduce_sum(0).max(TINY);
        let ap_ap = comm.reduce_sum(1);
        let alpha = rs / p_ap;
        let rs_new = (alpha * alpha * ap_ap - rs).max(0.0);
        let beta = (rs_new / rs.max(TINY)) as f32;
        let alpha = alpha as f32;
        let nb = self.plan.own_len[rank];
        for i in 0..nb {
            st.x[i] += alpha * st.p[i];
            st.r[i] -= alpha * st.ap[i];
            st.p[i] = st.r[i] + beta * st.p[i];
        }
        rs_new
    }

    /// Modeled seconds for `rows` ELL rows on `rank` (the distsim
    /// formula: one fused op per slot + diagonal, scaled by speed).
    fn modeled_secs(&self, rank: usize, rows: usize) -> f64 {
        rows as f64 * (self.w + 1) as f64 * self.cost.t_flop / self.speeds[rank]
    }

    /// Read p·Ap, update x and r, deposit the r·r partial.
    fn step_update(&self, comm: &dyn Comm, rank: usize, st: &mut RankState, rs: f64) {
        let p_ap = comm.reduce_sum(0).max(TINY);
        let alpha = (rs / p_ap) as f32;
        let nb = self.plan.own_len[rank];
        for i in 0..nb {
            st.x[i] += alpha * st.p[i];
            st.r[i] -= alpha * st.ap[i];
        }
        let partial: f64 = st.r.iter().map(|&v| (v as f64) * (v as f64)).sum();
        comm.reduce_post(1, rank, partial);
    }

    /// Read r·r, update the search direction, return the new rs.
    fn step_direction(&self, comm: &dyn Comm, rank: usize, st: &mut RankState, rs: f64) -> f64 {
        let rs_new = comm.reduce_sum(1);
        let beta = (rs_new / rs.max(TINY)) as f32;
        let nb = self.plan.own_len[rank];
        for i in 0..nb {
            st.p[i] = st.r[i] + beta * st.p[i];
        }
        rs_new
    }

    fn assemble(&self, states: &[RankState], iterations: usize, norms: Vec<f32>) -> CgResult {
        let mut x = vec![0.0f32; self.n];
        for (rank, st) in states.iter().enumerate() {
            self.scatter_owned(rank, &st.x, &mut x);
        }
        CgResult { x, residual_norms: norms, iterations }
    }

    // ---- sequential superstep executor ---------------------------------

    fn solve_sim(
        &self,
        b: &[f32],
        max_iters: usize,
        tol: f32,
        opts: SolveOpts,
    ) -> Result<(CgResult, ExecReport)> {
        let wall = Timer::start();
        let k = self.k();
        let comm = SimComm::with_net(self.plan.clone(), self.cost, opts.net, None);
        let kernels = self.layout_kernels(opts.layout);
        let mut states: Vec<RankState> = (0..k).map(|r| self.init_state(r, b)).collect();
        let mut compute = vec![0.0f64; k];
        for (rank, st) in states.iter().enumerate() {
            let partial: f64 = st.r.iter().map(|&v| (v as f64) * (v as f64)).sum();
            comm.reduce_post(0, rank, partial);
        }
        let mut rs = comm.reduce_sum(0);
        let b_norm = rs.sqrt().max(TINY);
        let mut norms = Vec::with_capacity(max_iters);
        let mut iters = 0;
        for _ in 0..max_iters {
            if opts.overlap {
                // Nonblocking exchange: post, hide the interior rows
                // inside the overlap region, wait (charging only the
                // exposed remainder), then finish the boundary rows.
                for (rank, st) in states.iter().enumerate() {
                    let _ = comm.irecv_halo(rank);
                    comm.isend_halo(rank, &st.p[..self.plan.own_len[rank]]);
                }
                for (rank, st) in states.iter_mut().enumerate() {
                    self.spmv_interior(&kernels, rank, st);
                    let secs = self.modeled_secs(rank, self.halo.blocks[rank].interior.len());
                    compute[rank] += secs;
                    comm.overlap_compute(rank, secs);
                }
                for (rank, st) in states.iter_mut().enumerate() {
                    comm.wait_all(rank);
                    self.step_recv(&comm, rank, st);
                    self.spmv_boundary(&kernels, rank, st);
                    compute[rank] +=
                        self.modeled_secs(rank, self.halo.blocks[rank].boundary.len());
                    self.deposit_partials(&comm, rank, st, opts.variant);
                }
            } else {
                for (rank, st) in states.iter().enumerate() {
                    self.step_post(&comm, rank, st);
                }
                for (rank, st) in states.iter_mut().enumerate() {
                    self.step_recv(&comm, rank, st);
                    self.local_spmv_into_state(&kernels, rank, st);
                    self.deposit_partials(&comm, rank, st, opts.variant);
                    // Modeled compute: one fused op per ELL slot +
                    // diagonal, scaled by the PU's speed — the distsim
                    // formula.
                    compute[rank] += self.modeled_secs(rank, self.plan.own_len[rank]);
                }
            }
            match opts.variant {
                CgVariant::Classic => {
                    for (rank, st) in states.iter_mut().enumerate() {
                        self.step_update(&comm, rank, st, rs);
                    }
                    let mut rs_new = rs;
                    for (rank, st) in states.iter_mut().enumerate() {
                        rs_new = self.step_direction(&comm, rank, st, rs);
                    }
                    rs = rs_new;
                }
                CgVariant::Pipelined => {
                    let mut rs_new = rs;
                    for (rank, st) in states.iter_mut().enumerate() {
                        rs_new = self.step_pipelined_update(&comm, rank, st, rs);
                    }
                    rs = rs_new;
                }
            }
            iters += 1;
            norms.push(rs.sqrt() as f32);
            if rs.sqrt() <= tol as f64 * b_norm {
                break;
            }
        }
        let report = ExecReport {
            backend: comm.label(),
            iterations: iters,
            compute_secs: compute,
            comm_secs: comm.comm_secs(),
            comm_hidden_secs: comm.comm_hidden_secs(),
            wall_secs: wall.secs(),
        };
        Ok((self.assemble(&states, iters, norms), report))
    }

    // ---- thread-per-PU executor -----------------------------------------

    fn solve_threads(
        &self,
        b: &[f32],
        max_iters: usize,
        tol: f32,
        opts: SolveOpts,
    ) -> Result<(CgResult, ExecReport)> {
        let wall = Timer::start();
        let k = self.k();
        let comm = ThreadComm::new(self.plan.clone());
        let kernels = self.layout_kernels(opts.layout);
        let max_speed = self.speeds.iter().cloned().fold(f64::MIN, f64::max);
        let mut states: Vec<RankState> = (0..k).map(|r| self.init_state(r, b)).collect();
        let mut compute = vec![0.0f64; k];
        let mut norms: Vec<f32> = Vec::new();
        let mut iters = 0usize;
        std::thread::scope(|scope| {
            let handles: Vec<_> = states
                .iter_mut()
                .enumerate()
                .map(|(rank, st)| {
                    let comm = &comm;
                    let kernels = &kernels;
                    scope.spawn(move || {
                        let throttle_factor = if self.throttle {
                            max_speed / self.speeds[rank]
                        } else {
                            1.0
                        };
                        // Cap the per-segment sleep so a timer hiccup
                        // cannot stall the whole cluster (every rank
                        // waits at the barrier).
                        let throttle = |secs: f64| {
                            if throttle_factor > 1.0 {
                                let extra = (secs * (throttle_factor - 1.0)).min(1.0);
                                std::thread::sleep(std::time::Duration::from_secs_f64(extra));
                            }
                            secs * throttle_factor
                        };
                        let mut compute_secs = 0.0f64;
                        let mut my_norms = Vec::with_capacity(max_iters);
                        let partial: f64 =
                            st.r.iter().map(|&v| (v as f64) * (v as f64)).sum();
                        comm.reduce_post(0, rank, partial);
                        comm.sync(rank);
                        let mut rs = comm.reduce_sum(0);
                        if opts.overlap {
                            // Without the blocking path's exchange
                            // barrier, a fast rank could redeposit on
                            // channel 0 before a slow rank read the
                            // initial sum — fence once.
                            comm.sync(rank);
                        }
                        let b_norm = rs.sqrt().max(TINY);
                        let mut my_iters = 0usize;
                        for _ in 0..max_iters {
                            if opts.overlap {
                                // Nonblocking exchange: the interior rows
                                // run while the other ranks' messages are
                                // in flight (no barrier in this phase).
                                let rq = comm.irecv_halo(rank);
                                comm.isend_halo(rank, &st.p[..self.plan.own_len[rank]]);
                                let t = Timer::start();
                                self.spmv_interior(kernels, rank, st);
                                let secs = throttle(t.secs());
                                compute_secs += secs;
                                comm.overlap_compute(rank, secs);
                                comm.wait(rank, rq);
                                self.step_recv(comm, rank, st);
                                let t = Timer::start();
                                self.spmv_boundary(kernels, rank, st);
                                self.deposit_partials(comm, rank, st, opts.variant);
                                compute_secs += throttle(t.secs());
                            } else {
                                self.step_post(comm, rank, st);
                                comm.sync(rank);
                                self.step_recv(comm, rank, st);
                                let t = Timer::start();
                                self.local_spmv_into_state(kernels, rank, st);
                                self.deposit_partials(comm, rank, st, opts.variant);
                                compute_secs += throttle(t.secs());
                            }
                            comm.sync(rank);
                            match opts.variant {
                                CgVariant::Classic => {
                                    self.step_update(comm, rank, st, rs);
                                    comm.sync(rank);
                                    rs = self.step_direction(comm, rank, st, rs);
                                }
                                CgVariant::Pipelined => {
                                    rs = self.step_pipelined_update(comm, rank, st, rs);
                                    // Fence the combined channels against
                                    // the next iteration's deposit.
                                    comm.sync(rank);
                                }
                            }
                            my_iters += 1;
                            my_norms.push(rs.sqrt() as f32);
                            if rs.sqrt() <= tol as f64 * b_norm {
                                break;
                            }
                        }
                        (rank, compute_secs, my_iters, my_norms)
                    })
                })
                .collect();
            for h in handles {
                let (rank, secs, my_iters, my_norms) = h.join().unwrap();
                compute[rank] = secs;
                // Every rank runs the same trajectory; keep rank 0's.
                if rank == 0 {
                    iters = my_iters;
                    norms = my_norms;
                }
            }
        });
        let report = ExecReport {
            backend: comm.label(),
            iterations: iters,
            compute_secs: compute,
            comm_secs: comm.comm_secs(),
            comm_hidden_secs: comm.comm_hidden_secs(),
            wall_secs: wall.secs(),
        };
        Ok((self.assemble(&states, iters, norms), report))
    }
}

/// Adapter: drive the generic `cg_solve` loop with its SpMV routed
/// through the virtual cluster — the seam `solver::cg` uses to run on
/// the engine.
///
/// With `ExecBackend::Threads` every iteration pays a k-thread spawn
/// (see [`VirtualCluster::spmv`]); prefer [`VirtualCluster::solve_cg`]
/// for thread-per-PU iterative solves and this adapter when the generic
/// driver (preconditioning, external loops) is what matters.
pub struct ClusterBackend<'a> {
    /// The cluster SpMVs are routed through.
    pub vc: &'a VirtualCluster,
    /// Engine backend each `spmv` call runs on.
    pub backend: ExecBackend,
}

impl SpmvBackend for ClusterBackend<'_> {
    fn n(&self) -> usize {
        self.vc.n
    }

    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> Result<()> {
        self.vc.spmv(self.backend, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::solver::cg::{cg_solve, NativeBackend};
    use crate::solver::spmv::spmv_ell_native;

    fn setup() -> (EllMatrix, Partition) {
        let g = mesh_2d_tri(20, 20, 1);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let part = Partition::new(
            (0..g.n())
                .map(|u| u32::from(g.coords[u].x > 9.5) + 2 * u32::from(g.coords[u].y > 9.5))
                .collect(),
            4,
        );
        (ell, part)
    }

    #[test]
    fn backend_parse() {
        assert_eq!(ExecBackend::parse("sim"), Some(ExecBackend::Sim));
        assert_eq!(ExecBackend::parse("Threads"), Some(ExecBackend::Threads));
        assert_eq!(ExecBackend::parse("mpi"), None);
        assert_eq!(ExecBackend::Sim.name(), "sim");
    }

    #[test]
    fn engine_spmv_matches_native_both_backends() {
        let (ell, part) = setup();
        let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.31).sin()).collect();
        let whole = spmv_ell_native(&ell, &x);
        for backend in [ExecBackend::Sim, ExecBackend::Threads] {
            let mut y = vec![0.0f32; ell.n];
            vc.spmv(backend, &x, &mut y).unwrap();
            for i in 0..ell.n {
                assert!(
                    (y[i] - whole[i]).abs() < 1e-5,
                    "{} row {i}: {} vs {}",
                    backend.name(),
                    y[i],
                    whole[i]
                );
            }
        }
    }

    #[test]
    fn sim_solve_matches_sequential_cg() {
        let (ell, part) = setup();
        let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
        let (res, rep) = vc.solve_cg(ExecBackend::Sim, &b, 80, 0.0).unwrap();
        let mut whole = NativeBackend { a: &ell };
        let seq = cg_solve(&mut whole, &b, 80, 0.0).unwrap();
        let max_diff = seq
            .x
            .iter()
            .zip(&res.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "engine CG diverged from sequential: {max_diff}");
        assert_eq!(rep.iterations, 80);
        assert_eq!(rep.compute_secs.len(), 4);
        assert!(rep.compute_secs.iter().all(|&t| t > 0.0));
        assert!(rep.comm_secs.iter().all(|&t| t > 0.0));
        assert!(rep.time_per_iter() > 0.0);
    }

    #[test]
    fn threads_reproduce_sim_trajectory_exactly() {
        let (ell, part) = setup();
        let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 11) as f32 - 5.0) / 3.0).collect();
        let (sim, _) = vc.solve_cg(ExecBackend::Sim, &b, 60, 1e-6).unwrap();
        let (thr, rep) = vc.solve_cg(ExecBackend::Threads, &b, 60, 1e-6).unwrap();
        assert_eq!(rep.backend, "threads");
        assert_eq!(sim.iterations, thr.iterations);
        assert_eq!(sim.residual_norms, thr.residual_norms);
        assert_eq!(sim.x, thr.x);
    }

    #[test]
    fn throttled_heterogeneous_speeds_keep_numerics() {
        let (ell, part) = setup();
        let vc = VirtualCluster::with_speeds(
            &ell,
            &part,
            vec![4.0, 1.0, 1.0, 2.0],
            CostModel::default(),
        )
        .unwrap();
        let b: Vec<f32> = (0..ell.n).map(|i| (i % 5) as f32 - 2.0).collect();
        let (thr, rep) = vc.solve_cg(ExecBackend::Threads, &b, 40, 0.0).unwrap();
        let (sim, _) = vc.solve_cg(ExecBackend::Sim, &b, 40, 0.0).unwrap();
        assert_eq!(sim.residual_norms, thr.residual_norms);
        // Throttled ranks must report more compute time per row than the
        // fast rank (speeds 1 vs 4 → factor 4 sleep).
        assert!(rep.compute_secs[1] > rep.compute_secs[0] * 0.5);
    }

    #[test]
    fn empty_block_is_harmless() {
        let (ell, _) = setup();
        // Block 2 of 3 stays empty.
        let part = Partition::new((0..ell.n).map(|u| (u % 2) as u32).collect(), 3);
        let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
        let b = vec![1.0f32; ell.n];
        for backend in [ExecBackend::Sim, ExecBackend::Threads] {
            let (res, _) = vc.solve_cg(backend, &b, 50, 1e-5).unwrap();
            assert!(res.x.iter().all(|v| v.is_finite()));
            assert!(res.residual_norms.last().unwrap() < &1e-2);
        }
    }

    #[test]
    fn overlap_is_bit_identical_and_priced_cheaper() {
        let (ell, part) = setup();
        let vc = VirtualCluster::with_speeds(
            &ell,
            &part,
            vec![4.0, 1.0, 1.0, 2.0],
            CostModel::default(),
        )
        .unwrap();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 9) as f32 - 4.0) / 3.0).collect();
        let off = SolveOpts::default();
        let on = SolveOpts::overlapped();
        let (r_off, rep_off) = vc.solve_cg_opts(ExecBackend::Sim, &b, 50, 0.0, off).unwrap();
        let (r_on, rep_on) = vc.solve_cg_opts(ExecBackend::Sim, &b, 50, 0.0, on).unwrap();
        assert_eq!(r_off.x, r_on.x, "overlap changed the solution");
        assert_eq!(r_off.residual_norms, r_on.residual_norms);
        assert_eq!(r_off.iterations, r_on.iterations);
        // Same modeled compute; strictly less exposed communication on
        // every rank (all blocks have interior rows and neighbors here).
        for rank in 0..4 {
            assert!(
                (rep_on.compute_secs[rank] - rep_off.compute_secs[rank]).abs() < 1e-12,
                "rank {rank} compute changed"
            );
            assert!(
                rep_on.comm_secs[rank] < rep_off.comm_secs[rank],
                "rank {rank}: exposed {} !< blocking {}",
                rep_on.comm_secs[rank],
                rep_off.comm_secs[rank]
            );
            assert!(rep_on.comm_hidden_secs[rank] > 0.0, "rank {rank} hid nothing");
        }
        assert!(rep_on.time_per_iter() < rep_off.time_per_iter());
        let eff = rep_on.overlap_efficiency();
        assert!(eff > 0.0 && eff <= 1.0, "efficiency {eff}");
        assert_eq!(rep_off.overlap_efficiency(), 0.0);
        // The threads backend reproduces the same numerics under overlap.
        let (r_thr, rep_thr) = vc.solve_cg_opts(ExecBackend::Threads, &b, 50, 0.0, on).unwrap();
        assert_eq!(r_thr.x, r_on.x);
        assert_eq!(r_thr.residual_norms, r_on.residual_norms);
        assert_eq!(rep_thr.comm_hidden_secs, vec![0.0; 4], "threads overlap is real, not priced");
    }

    #[test]
    fn sell_layout_reproduces_ell_solutions_everywhere() {
        let (ell, part) = setup();
        let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 9) as f32 - 4.0) / 3.0).collect();
        let ell_opts = SolveOpts::default();
        let sell_opts = SolveOpts { layout: SpmvLayout::SellCs, ..SolveOpts::default() };
        let (r_ell, _) = vc.solve_cg_opts(ExecBackend::Sim, &b, 50, 0.0, ell_opts).unwrap();
        // Sim and threads, blocking and overlapped, classic and pipelined:
        // the layout seam must never change a solution.
        for backend in [ExecBackend::Sim, ExecBackend::Threads] {
            for overlap in [false, true] {
                let opts = SolveOpts { overlap, ..sell_opts };
                let (r, _) = vc.solve_cg_opts(backend, &b, 50, 0.0, opts).unwrap();
                assert_eq!(r.x, r_ell.x, "{} overlap={overlap}", backend.name());
                assert_eq!(r.residual_norms, r_ell.residual_norms);
            }
        }
        let pipe_ell = SolveOpts { variant: CgVariant::Pipelined, ..SolveOpts::default() };
        let pipe_sell = SolveOpts { variant: CgVariant::Pipelined, ..sell_opts };
        let (p_ell, _) = vc.solve_cg_opts(ExecBackend::Sim, &b, 50, 0.0, pipe_ell).unwrap();
        let (p_sell, _) = vc.solve_cg_opts(ExecBackend::Sim, &b, 50, 0.0, pipe_sell).unwrap();
        assert_eq!(p_ell.x, p_sell.x);
        assert_eq!(p_ell.residual_norms, p_sell.residual_norms);
    }

    #[test]
    fn pipelined_variant_converges_and_halves_reduction_latency() {
        let (ell, part) = setup();
        let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
        let classic = SolveOpts::default();
        let pipe = SolveOpts { variant: CgVariant::Pipelined, ..SolveOpts::default() };
        let (r_c, rep_c) = vc.solve_cg_opts(ExecBackend::Sim, &b, 40, 0.0, classic).unwrap();
        let (r_p, rep_p) = vc.solve_cg_opts(ExecBackend::Sim, &b, 40, 0.0, pipe).unwrap();
        // Same solution within CG round-off (the ‖r‖² recurrence drifts
        // slightly from the explicit reduction).
        let max_dx = r_c
            .x
            .iter()
            .zip(&r_p.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_dx < 1e-3, "pipelined diverged from classic by {max_dx}");
        assert_eq!(rep_p.iterations, rep_c.iterations);
        // One combined allreduce per iteration instead of two: strictly
        // less priced communication (halo traffic is identical).
        for rank in 0..4 {
            assert!(
                rep_p.comm_secs[rank] < rep_c.comm_secs[rank],
                "rank {rank}: pipelined {} !< classic {}",
                rep_p.comm_secs[rank],
                rep_c.comm_secs[rank]
            );
        }
        // Overlap on/off is bit-identical for the pipelined variant too,
        // and the threads backend reproduces the trajectory exactly.
        let pipe_ov =
            SolveOpts { overlap: true, variant: CgVariant::Pipelined, ..SolveOpts::default() };
        let (r_po, _) = vc.solve_cg_opts(ExecBackend::Sim, &b, 40, 0.0, pipe_ov).unwrap();
        assert_eq!(r_p.x, r_po.x);
        assert_eq!(r_p.residual_norms, r_po.residual_norms);
        let (r_pt, _) = vc.solve_cg_opts(ExecBackend::Threads, &b, 40, 0.0, pipe_ov).unwrap();
        assert_eq!(r_po.x, r_pt.x);
        assert_eq!(r_po.residual_norms, r_pt.residual_norms);
    }

    #[test]
    fn variant_and_backend_parse_round_trip() {
        assert_eq!(CgVariant::parse("classic"), Some(CgVariant::Classic));
        assert_eq!(CgVariant::parse("Pipelined"), Some(CgVariant::Pipelined));
        assert_eq!(CgVariant::parse("bogus"), None);
        assert_eq!(CgVariant::Pipelined.name(), "pipelined");
        assert_eq!(CgVariant::default(), CgVariant::Classic);
    }

    #[test]
    fn cluster_backend_routes_cg_solve() {
        let (ell, part) = setup();
        let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 13) as f32 - 6.0) / 4.0).collect();
        let mut via_engine = ClusterBackend { vc: &vc, backend: ExecBackend::Sim };
        let res = cg_solve(&mut via_engine, &b, 80, 1e-5).unwrap();
        let mut native = NativeBackend { a: &ell };
        let seq = cg_solve(&mut native, &b, 80, 1e-5).unwrap();
        let max_diff = seq
            .x
            .iter()
            .zip(&res.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "ClusterBackend diverged: {max_diff}");
    }
}
