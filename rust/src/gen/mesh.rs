//! Structured simulation meshes.
//!
//! - [`mesh_2d_tri`]: a jittered triangular 2-D mesh — the stand-in for
//!   the DIMACS'10 mesh instances (333SP, NLR, hugetric/hugetrace/
//!   hugebubbles): planar, bounded degree, mildly irregular.
//! - [`mesh_3d_tet`]: a 3-D tetrahedral grid mesh — the stand-in for the
//!   PRACE alya respiratory-system meshes (3-D, higher average degree
//!   ~8, see Table II: alyaTestCaseB has m/n ≈ 4).

use crate::geometry::Point;
use crate::graph::{Csr, GraphBuilder};
use crate::util::rng::Rng;

/// Jittered triangular mesh on an nx × ny grid: grid edges plus one
/// diagonal per cell (direction pseudo-random), coordinates jittered so
/// geometric partitioners face realistic, non-axis-aligned input.
pub fn mesh_2d_tri(nx: usize, ny: usize, seed: u64) -> Csr {
    assert!(nx >= 2 && ny >= 2);
    let n = nx * ny;
    let mut rng = Rng::new(seed);
    let id = |i: usize, j: usize| -> usize { j * nx + i };
    let mut b = GraphBuilder::new(n);
    let jitter = 0.25;
    let mut coords = Vec::with_capacity(n);
    for j in 0..ny {
        for i in 0..nx {
            coords.push(Point::new2(
                i as f64 + jitter * (rng.f64() - 0.5),
                j as f64 + jitter * (rng.f64() - 0.5),
            ));
        }
    }
    for j in 0..ny {
        for i in 0..nx {
            if i + 1 < nx {
                b.add_edge(id(i, j), id(i + 1, j));
            }
            if j + 1 < ny {
                b.add_edge(id(i, j), id(i, j + 1));
            }
            if i + 1 < nx && j + 1 < ny {
                // One diagonal per cell, pseudo-random direction.
                if rng.bool(0.5) {
                    b.add_edge(id(i, j), id(i + 1, j + 1));
                } else {
                    b.add_edge(id(i + 1, j), id(i, j + 1));
                }
            }
        }
    }
    b.set_coords(coords);
    b.build()
}

/// Tetrahedral-style 3-D grid mesh: grid edges plus body/face diagonals,
/// average degree ≈ 8 like the alya meshes.
pub fn mesh_3d_tet(nx: usize, ny: usize, nz: usize, seed: u64) -> Csr {
    assert!(nx >= 2 && ny >= 2 && nz >= 2);
    let n = nx * ny * nz;
    let mut rng = Rng::new(seed);
    let id = |i: usize, j: usize, k: usize| -> usize { (k * ny + j) * nx + i };
    let mut b = GraphBuilder::new(n);
    let jitter = 0.2;
    let mut coords = Vec::with_capacity(n);
    for k in 0..nz {
        for j in 0..ny {
            for i in 0..nx {
                coords.push(Point::new3(
                    i as f64 + jitter * (rng.f64() - 0.5),
                    j as f64 + jitter * (rng.f64() - 0.5),
                    k as f64 + jitter * (rng.f64() - 0.5),
                ));
            }
        }
    }
    for k in 0..nz {
        for j in 0..ny {
            for i in 0..nx {
                let u = id(i, j, k);
                if i + 1 < nx {
                    b.add_edge(u, id(i + 1, j, k));
                }
                if j + 1 < ny {
                    b.add_edge(u, id(i, j + 1, k));
                }
                if k + 1 < nz {
                    b.add_edge(u, id(i, j, k + 1));
                }
                // One face diagonal per xy face (tet-splitting style).
                if i + 1 < nx && j + 1 < ny {
                    if rng.bool(0.5) {
                        b.add_edge(u, id(i + 1, j + 1, k));
                    } else {
                        b.add_edge(id(i + 1, j, k), id(i, j + 1, k));
                    }
                }
                // Body diagonal in each cell for degree ≈ 8.
                if i + 1 < nx && j + 1 < ny && k + 1 < nz {
                    b.add_edge(u, id(i + 1, j + 1, k + 1));
                }
            }
        }
    }
    b.set_coords(coords);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tri_mesh_structure() {
        let g = mesh_2d_tri(20, 30, 1);
        g.validate().unwrap();
        assert_eq!(g.n(), 600);
        assert_eq!(g.num_components(), 1);
        // Grid edges: 19*30 + 20*29 = 1150; diagonals: 19*29 = 551.
        assert_eq!(g.m(), 1150 + 551);
        assert!(g.has_coords());
    }

    #[test]
    fn tri_mesh_degree_bounded() {
        let g = mesh_2d_tri(30, 30, 2);
        assert!(g.max_degree() <= 8, "max degree {}", g.max_degree());
    }

    #[test]
    fn tet_mesh_structure() {
        let g = mesh_3d_tet(8, 8, 8, 3);
        g.validate().unwrap();
        assert_eq!(g.n(), 512);
        assert_eq!(g.num_components(), 1);
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!((5.0..10.0).contains(&avg), "avg degree {avg}");
        assert_eq!(g.coords[0].dim, 3);
    }

    #[test]
    fn deterministic() {
        let a = mesh_2d_tri(10, 10, 7);
        let b = mesh_2d_tri(10, 10, 7);
        assert_eq!(a.adjncy, b.adjncy);
    }

    #[test]
    fn minimal_sizes() {
        let g = mesh_2d_tri(2, 2, 0);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 5);
        let g3 = mesh_3d_tet(2, 2, 2, 0);
        assert_eq!(g3.n(), 8);
        g3.validate().unwrap();
    }
}
