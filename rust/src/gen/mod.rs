//! Graph/mesh generators standing in for the paper's benchmark instances
//! (Table II): KaGen-style random geometric graphs (`rgg_2d`, `rgg_3d`),
//! random Delaunay triangulations (`rdg_2d`), structured triangle/tetra
//! meshes ("hugeX-like" 2-D, "alya-like" 3-D), and adaptively refined
//! meshes ("refinetrace-like", Marquardt–Schamberger style).
//!
//! All generators are deterministic for a given seed and attach vertex
//! coordinates so both geometric and combinatorial partitioners apply.

pub mod delaunay;
pub mod mesh;
pub mod refine;
pub mod rgg;

pub use delaunay::rdg_2d;
pub use mesh::{mesh_2d_tri, mesh_3d_tet};
pub use refine::{front_center, front_weights, refined_mesh_2d, FRONT_BAND, FRONT_RADIUS};
pub use rgg::{rgg_2d, rgg_3d};

use crate::graph::Csr;

/// Named instance families used by the experiment grids; `scale` is the
/// approximate vertex count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Random geometric graph in the unit square (KaGen rgg_2d).
    Rgg2d,
    /// Random geometric graph in the unit cube (KaGen rgg_3d).
    Rgg3d,
    /// Random Delaunay triangulation in the unit square (KaGen rdg_2d).
    Rdg2d,
    /// Structured 2-D triangle mesh (stands in for the DIMACS hugeX meshes).
    Tri2d,
    /// Structured 3-D tetrahedral mesh (stands in for the alya PRACE meshes).
    Tet3d,
    /// Adaptively refined 2-D mesh (stands in for refinetrace).
    Refined2d,
}

impl Family {
    /// Parse a family name as written on the CLI (e.g. `rdg2d`).
    pub fn parse(s: &str) -> Option<Family> {
        Some(match s {
            "rgg2d" | "rgg_2d" => Family::Rgg2d,
            "rgg3d" | "rgg_3d" => Family::Rgg3d,
            "rdg2d" | "rdg_2d" => Family::Rdg2d,
            "tri2d" | "huge" | "hugeX" => Family::Tri2d,
            "tet3d" | "alya" => Family::Tet3d,
            "refined2d" | "refinetrace" => Family::Refined2d,
            _ => return None,
        })
    }

    /// Canonical family name (e.g. `rdg_2d`).
    pub fn name(&self) -> &'static str {
        match self {
            Family::Rgg2d => "rgg_2d",
            Family::Rgg3d => "rgg_3d",
            Family::Rdg2d => "rdg_2d",
            Family::Tri2d => "tri_2d",
            Family::Tet3d => "tet_3d",
            Family::Refined2d => "refined_2d",
        }
    }

    /// Generate an instance with ~`n` vertices.
    pub fn generate(&self, n: usize, seed: u64) -> Csr {
        match self {
            Family::Rgg2d => rgg_2d(n, seed),
            Family::Rgg3d => rgg_3d(n, seed),
            Family::Rdg2d => rdg_2d(n, seed),
            Family::Tri2d => {
                let side = (n as f64).sqrt().round() as usize;
                mesh_2d_tri(side.max(2), side.max(2), seed)
            }
            Family::Tet3d => {
                let side = (n as f64).cbrt().round() as usize;
                mesh_3d_tet(side.max(2), side.max(2), side.max(2), seed)
            }
            Family::Refined2d => refined_mesh_2d(n, seed),
        }
    }
}

/// All families (for sweep-style tests).
pub const ALL_FAMILIES: [Family; 6] = [
    Family::Rgg2d,
    Family::Rgg3d,
    Family::Rdg2d,
    Family::Tri2d,
    Family::Tet3d,
    Family::Refined2d,
];
