//! Random geometric graphs (KaGen-style `rgg_2d` / `rgg_3d`).
//!
//! n points uniform in the unit square/cube; vertices are adjacent iff
//! within Euclidean distance r. The radius is chosen so the expected
//! average degree ≈ 6 in 2-D and ≈ 6 in 3-D, matching Table II's
//! "edges ≈ 3n". Neighbor search uses a uniform grid with cell size r, so
//! generation is O(n) expected.

use crate::geometry::Point;
use crate::graph::{Csr, GraphBuilder};
use crate::util::rng::Rng;

/// Radius giving expected average degree `deg` for n uniform points in
/// the unit square: E[deg] = n·π·r².
pub fn rgg2d_radius(n: usize, deg: f64) -> f64 {
    (deg / (n as f64 * std::f64::consts::PI)).sqrt()
}

/// Radius giving expected average degree `deg` in the unit cube:
/// E[deg] = n·(4/3)·π·r³.
pub fn rgg3d_radius(n: usize, deg: f64) -> f64 {
    (deg / (n as f64 * 4.0 / 3.0 * std::f64::consts::PI)).cbrt()
}

/// Random geometric graph in the unit square with average degree ≈ 6.
pub fn rgg_2d(n: usize, seed: u64) -> Csr {
    rgg_2d_deg(n, 6.0, seed)
}

/// Random geometric graph with a chosen expected average degree.
pub fn rgg_2d_deg(n: usize, deg: f64, seed: u64) -> Csr {
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let pts: Vec<Point> = (0..n)
        .map(|_| Point::new2(rng.f64(), rng.f64()))
        .collect();
    let r = rgg2d_radius(n, deg);
    let cells = ((1.0 / r).floor() as usize).clamp(1, 4096);
    let cell_of = |p: &Point| -> (usize, usize) {
        (
            ((p.x * cells as f64) as usize).min(cells - 1),
            ((p.y * cells as f64) as usize).min(cells - 1),
        )
    };
    // Bucket points.
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); cells * cells];
    for (i, p) in pts.iter().enumerate() {
        let (cx, cy) = cell_of(p);
        buckets[cy * cells + cx].push(i as u32);
    }
    let r2 = r * r;
    let mut b = GraphBuilder::new(n);
    for (i, p) in pts.iter().enumerate() {
        let (cx, cy) = cell_of(p);
        for dy in -1i64..=1 {
            for dx in -1i64..=1 {
                let nx = cx as i64 + dx;
                let ny = cy as i64 + dy;
                if nx < 0 || ny < 0 || nx >= cells as i64 || ny >= cells as i64 {
                    continue;
                }
                for &j in &buckets[ny as usize * cells + nx as usize] {
                    if (j as usize) > i && p.dist2(&pts[j as usize]) <= r2 {
                        b.add_edge(i, j as usize);
                    }
                }
            }
        }
    }
    b.set_coords(pts);
    b.build()
}

/// Random geometric graph in the unit cube with average degree ≈ 6.
pub fn rgg_3d(n: usize, seed: u64) -> Csr {
    rgg_3d_deg(n, 6.0, seed)
}

/// 3-D random geometric graph with a chosen expected average degree.
pub fn rgg_3d_deg(n: usize, deg: f64, seed: u64) -> Csr {
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let pts: Vec<Point> = (0..n)
        .map(|_| Point::new3(rng.f64(), rng.f64(), rng.f64()))
        .collect();
    let r = rgg3d_radius(n, deg);
    let cells = ((1.0 / r).floor() as usize).clamp(1, 256);
    let cell_of = |p: &Point| -> (usize, usize, usize) {
        (
            ((p.x * cells as f64) as usize).min(cells - 1),
            ((p.y * cells as f64) as usize).min(cells - 1),
            ((p.z * cells as f64) as usize).min(cells - 1),
        )
    };
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); cells * cells * cells];
    for (i, p) in pts.iter().enumerate() {
        let (cx, cy, cz) = cell_of(p);
        buckets[(cz * cells + cy) * cells + cx].push(i as u32);
    }
    let r2 = r * r;
    let mut b = GraphBuilder::new(n);
    for (i, p) in pts.iter().enumerate() {
        let (cx, cy, cz) = cell_of(p);
        for dz in -1i64..=1 {
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let nx = cx as i64 + dx;
                    let ny = cy as i64 + dy;
                    let nz = cz as i64 + dz;
                    if nx < 0
                        || ny < 0
                        || nz < 0
                        || nx >= cells as i64
                        || ny >= cells as i64
                        || nz >= cells as i64
                    {
                        continue;
                    }
                    for &j in &buckets[(nz as usize * cells + ny as usize) * cells + nx as usize] {
                        if (j as usize) > i && p.dist2(&pts[j as usize]) <= r2 {
                            b.add_edge(i, j as usize);
                        }
                    }
                }
            }
        }
    }
    b.set_coords(pts);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rgg2d_structure() {
        let g = rgg_2d(2000, 42);
        g.validate().unwrap();
        assert_eq!(g.n(), 2000);
        assert!(g.has_coords());
        // Average degree should be near 6 (edges near 3n).
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!((4.0..8.0).contains(&avg), "avg degree {avg}");
    }

    #[test]
    fn rgg3d_structure() {
        let g = rgg_3d(2000, 42);
        g.validate().unwrap();
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!((4.0..8.0).contains(&avg), "avg degree {avg}");
        assert_eq!(g.coords[0].dim, 3);
    }

    #[test]
    fn deterministic() {
        let a = rgg_2d(500, 7);
        let b = rgg_2d(500, 7);
        assert_eq!(a.adjncy, b.adjncy);
        let c = rgg_2d(500, 8);
        assert_ne!(a.adjncy, c.adjncy);
    }

    #[test]
    fn edges_respect_radius() {
        let g = rgg_2d_deg(800, 6.0, 3);
        let r = rgg2d_radius(800, 6.0);
        for u in 0..g.n() {
            for &v in g.neighbors(u) {
                let d = g.coords[u].dist(&g.coords[v as usize]);
                assert!(d <= r * (1.0 + 1e-12), "edge ({u},{v}) distance {d} > r {r}");
            }
        }
    }

    #[test]
    fn no_missed_pairs_small() {
        // Brute-force cross-check on a small instance.
        let g = rgg_2d_deg(200, 8.0, 11);
        let r2 = rgg2d_radius(200, 8.0).powi(2);
        for u in 0..g.n() {
            for v in (u + 1)..g.n() {
                let within = g.coords[u].dist2(&g.coords[v]) <= r2;
                let edge = g.neighbors(u).binary_search(&(v as u32)).is_ok();
                assert_eq!(within, edge, "pair ({u},{v}) within={within} edge={edge}");
            }
        }
    }

    #[test]
    fn mostly_connected_at_degree6() {
        // At avg degree 6 a 2-D RGG has a giant component; allow stragglers.
        let g = rgg_2d(3000, 1);
        let comps = g.num_components();
        assert!(comps < 100, "components {comps}");
    }
}
