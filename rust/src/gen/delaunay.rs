//! Random Delaunay graphs (`rdg_2d`): true incremental Delaunay
//! triangulation of uniform random points (KaGen's rdg family).
//!
//! Algorithm: Bowyer–Watson insertion with *walking* point location.
//! Points are inserted in Hilbert order, so the walk from the previously
//! created triangle to the triangle containing the next point takes O(1)
//! expected steps, giving near O(n log n) total time — the standard trick
//! behind fast incremental Delaunay codes (and what lets us generate
//! 10^5–10^6-vertex rdg instances on this testbed).
//!
//! Predicates are plain f64 determinants; inputs are random, so the
//! near-degenerate configurations that require exact arithmetic have
//! probability ~0 (asserted by the empty-circumcircle property test).

use crate::geometry::{hilbert_index, Aabb, Point};
use crate::graph::{Csr, GraphBuilder};
use crate::util::rng::Rng;

const NONE: u32 = u32::MAX;

/// Triangle: vertices CCW; `n[i]` is the neighbor across the edge opposite
/// `v[i]` (NONE on the hull).
#[derive(Clone, Copy, Debug)]
struct Tri {
    v: [u32; 3],
    n: [u32; 3],
    alive: bool,
}

/// Orientation predicate: > 0 if (a,b,c) is counter-clockwise.
#[inline]
fn orient2d(a: &Point, b: &Point, c: &Point) -> f64 {
    (b.x - a.x) * (c.y - a.y) - (b.y - a.y) * (c.x - a.x)
}

/// In-circumcircle predicate: > 0 if `d` lies inside the circumcircle of
/// CCW triangle (a,b,c).
#[inline]
fn incircle(a: &Point, b: &Point, c: &Point, d: &Point) -> f64 {
    let adx = a.x - d.x;
    let ady = a.y - d.y;
    let bdx = b.x - d.x;
    let bdy = b.y - d.y;
    let cdx = c.x - d.x;
    let cdy = c.y - d.y;
    let ad2 = adx * adx + ady * ady;
    let bd2 = bdx * bdx + bdy * bdy;
    let cd2 = cdx * cdx + cdy * cdy;
    adx * (bdy * cd2 - bd2 * cdy) - ady * (bdx * cd2 - bd2 * cdx)
        + ad2 * (bdx * cdy - bdy * cdx)
}

/// Incremental Delaunay triangulator.
pub struct Delaunay {
    pts: Vec<Point>,
    tris: Vec<Tri>,
    /// Triangle to start the next walk from.
    last: u32,
}

impl Delaunay {
    /// Triangulate `points` (at least 3, general position assumed).
    pub fn triangulate(points: &[Point]) -> Delaunay {
        assert!(points.len() >= 3);
        let n = points.len();
        // Super-triangle comfortably containing the unit square (and any
        // reasonable input range after normalization below).
        let bb = Aabb::of(points);
        let cx = 0.5 * (bb.min.x + bb.max.x);
        let cy = 0.5 * (bb.min.y + bb.max.y);
        let span = (bb.extent(0).max(bb.extent(1))).max(1e-9);
        let s = 20.0 * span;
        let mut pts = points.to_vec();
        pts.push(Point::new2(cx - s, cy - s)); // n
        pts.push(Point::new2(cx + s, cy - s)); // n+1
        pts.push(Point::new2(cx, cy + s)); // n+2
        let mut d = Delaunay {
            pts,
            tris: vec![Tri {
                v: [n as u32, n as u32 + 1, n as u32 + 2],
                n: [NONE, NONE, NONE],
                alive: true,
            }],
            last: 0,
        };
        // Insert in Hilbert order for short walks.
        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut keys: Vec<u64> = points.iter().map(|p| hilbert_index(p, &bb)).collect();
        order.sort_unstable_by_key(|&i| keys[i as usize]);
        keys.clear();
        for &i in &order {
            d.insert(i);
        }
        d
    }

    /// Walk from `self.last` to a triangle containing point `p`.
    fn locate(&self, p: &Point) -> u32 {
        let mut t = self.last;
        if !self.tris[t as usize].alive {
            t = (0..self.tris.len())
                .rfind(|&i| self.tris[i].alive)
                .expect("no alive triangle") as u32;
        }
        let mut steps = 0usize;
        let max_steps = 4 * self.tris.len() + 16;
        'walk: loop {
            let tri = &self.tris[t as usize];
            for i in 0..3 {
                let a = &self.pts[tri.v[(i + 1) % 3] as usize];
                let b = &self.pts[tri.v[(i + 2) % 3] as usize];
                // p strictly on the right of directed CCW edge (a,b) → cross.
                if orient2d(a, b, p) < 0.0 {
                    if tri.n[i] == NONE {
                        // Outside the hull: shouldn't happen with the
                        // super-triangle, but stop gracefully.
                        return t;
                    }
                    t = tri.n[i];
                    steps += 1;
                    if steps > max_steps {
                        // Degenerate walk; fall back to linear scan.
                        return self.locate_linear(p);
                    }
                    continue 'walk;
                }
            }
            return t;
        }
    }

    fn locate_linear(&self, p: &Point) -> u32 {
        for (i, tri) in self.tris.iter().enumerate() {
            if !tri.alive {
                continue;
            }
            let inside = (0..3).all(|j| {
                let a = &self.pts[tri.v[(j + 1) % 3] as usize];
                let b = &self.pts[tri.v[(j + 2) % 3] as usize];
                orient2d(a, b, p) >= 0.0
            });
            if inside {
                return i as u32;
            }
        }
        panic!("point not located in any triangle");
    }

    /// Bowyer–Watson insertion of point index `pi`.
    fn insert(&mut self, pi: u32) {
        let p = self.pts[pi as usize];
        let t0 = self.locate(&p);
        // Grow the cavity: all triangles whose circumcircle contains p.
        let mut cavity: Vec<u32> = vec![t0];
        let mut in_cavity = std::collections::HashSet::new();
        in_cavity.insert(t0);
        let mut stack = vec![t0];
        while let Some(t) = stack.pop() {
            let tri = self.tris[t as usize];
            for i in 0..3 {
                let nb = tri.n[i];
                if nb == NONE || in_cavity.contains(&nb) {
                    continue;
                }
                let nt = &self.tris[nb as usize];
                let (a, b, c) = (
                    &self.pts[nt.v[0] as usize],
                    &self.pts[nt.v[1] as usize],
                    &self.pts[nt.v[2] as usize],
                );
                if incircle(a, b, c, &p) > 0.0 {
                    in_cavity.insert(nb);
                    cavity.push(nb);
                    stack.push(nb);
                }
            }
        }
        // Boundary edges of the cavity: directed (a, b) with outer neighbor.
        let mut boundary: Vec<(u32, u32, u32)> = Vec::new(); // (a, b, outer)
        for &t in &cavity {
            let tri = self.tris[t as usize];
            for i in 0..3 {
                let nb = tri.n[i];
                if nb == NONE || !in_cavity.contains(&nb) {
                    let a = tri.v[(i + 1) % 3];
                    let b = tri.v[(i + 2) % 3];
                    boundary.push((a, b, nb));
                }
            }
        }
        // Kill cavity triangles.
        for &t in &cavity {
            self.tris[t as usize].alive = false;
        }
        // Create the fan: one new CCW triangle (p, a, b) per boundary edge.
        let base = self.tris.len() as u32;
        let mut start_at: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
        for (idx, &(a, _b, _o)) in boundary.iter().enumerate() {
            start_at.insert(a, base + idx as u32);
        }
        for (idx, &(a, b, o)) in boundary.iter().enumerate() {
            let tn = base + idx as u32;
            // v = [p, a, b]; neighbor opposite p (edge a-b) = outer o;
            // opposite a (edge p-b) = new tri starting at b;
            // opposite b (edge p-a) = new tri ending at a = start_at lookup
            // by its own start — tri ending at a is the one starting at x
            // with boundary edge (x, a); we find it via end map below.
            let n_opp_a = *start_at.get(&b).expect("fan must close");
            self.tris.push(Tri {
                v: [pi, a, b],
                n: [o, n_opp_a, NONE], // n[2] patched in the second pass
                alive: true,
            });
            // Patch the outer neighbor's back-pointer — match by shared
            // edge {a, b} (an outer triangle can border the cavity on two
            // edges, so "points into cavity" is not specific enough).
            if o != NONE {
                let ot = &mut self.tris[o as usize];
                for j in 0..3 {
                    let ea = ot.v[(j + 1) % 3];
                    let eb = ot.v[(j + 2) % 3];
                    if (ea == a && eb == b) || (ea == b && eb == a) {
                        ot.n[j] = tn;
                    }
                }
            }
        }
        // Second pass: neighbor opposite b (edge p-a) is the tri ending at
        // a, i.e. the tri T' with boundary edge (a', b'=a); equivalently
        // start_at[a']'s successor. Since each boundary vertex appears once
        // as a start and once as an end, tri ending at a = the tri whose
        // n[1] (opposite a') points at... simplest: build end map.
        let mut end_at: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
        for (idx, &(_a, b, _o)) in boundary.iter().enumerate() {
            end_at.insert(b, base + idx as u32);
        }
        for (idx, &(a, _b, _o)) in boundary.iter().enumerate() {
            let tn = (base + idx as u32) as usize;
            self.tris[tn].n[2] = *end_at.get(&a).expect("fan must close");
        }
        self.last = base;
    }

    /// Extract the Delaunay edges among the original n points (dropping
    /// everything incident to the super-triangle).
    pub fn edges(&self, n: usize) -> Vec<(u32, u32)> {
        let mut edges = Vec::new();
        for tri in &self.tris {
            if !tri.alive {
                continue;
            }
            for i in 0..3 {
                let a = tri.v[i];
                let b = tri.v[(i + 1) % 3];
                if a < b && (a as usize) < n && (b as usize) < n {
                    edges.push((a, b));
                }
            }
        }
        edges.sort_unstable();
        edges.dedup();
        edges
    }

    /// Alive triangles (vertex triples), super-triangle excluded.
    pub fn triangles(&self, n: usize) -> Vec<[u32; 3]> {
        self.tris
            .iter()
            .filter(|t| t.alive && t.v.iter().all(|&v| (v as usize) < n))
            .map(|t| t.v)
            .collect()
    }
}

/// Random Delaunay graph: n uniform points in the unit square,
/// triangulated; edges of the triangulation become graph edges
/// (avg degree < 6 by Euler's formula).
pub fn rdg_2d(n: usize, seed: u64) -> Csr {
    assert!(n >= 3);
    let mut rng = Rng::new(seed);
    let pts: Vec<Point> = (0..n)
        .map(|_| Point::new2(rng.f64(), rng.f64()))
        .collect();
    let d = Delaunay::triangulate(&pts);
    let mut b = GraphBuilder::new(n);
    for (u, v) in d.edges(n) {
        b.add_edge(u as usize, v as usize);
    }
    b.set_coords(pts);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_gives_two_triangles() {
        let pts = vec![
            Point::new2(0.0, 0.0),
            Point::new2(1.0, 0.0),
            Point::new2(1.0, 1.0),
            Point::new2(0.1, 0.9), // slightly inside to break cocircularity
        ];
        let d = Delaunay::triangulate(&pts);
        assert_eq!(d.triangles(4).len(), 2);
        let e = d.edges(4);
        assert_eq!(e.len(), 5); // 4 hull edges + 1 diagonal
    }

    #[test]
    fn triangulation_is_planar_sized() {
        let g = rdg_2d(1000, 42);
        g.validate().unwrap();
        assert_eq!(g.n(), 1000);
        // Planar: m <= 3n - 6; Delaunay of random points ~ 3n.
        assert!(g.m() <= 3 * g.n() - 6);
        assert!(g.m() >= 2 * g.n(), "suspiciously sparse: m={}", g.m());
        assert_eq!(g.num_components(), 1);
    }

    #[test]
    fn deterministic() {
        let a = rdg_2d(300, 9);
        let b = rdg_2d(300, 9);
        assert_eq!(a.adjncy, b.adjncy);
    }

    #[test]
    fn empty_circumcircle_property() {
        // The defining property: no point lies strictly inside the
        // circumcircle of any triangle. Check exhaustively on a small set.
        let mut rng = Rng::new(17);
        let pts: Vec<Point> = (0..60)
            .map(|_| Point::new2(rng.f64(), rng.f64()))
            .collect();
        let d = Delaunay::triangulate(&pts);
        for t in d.triangles(pts.len()) {
            let (a, b, c) = (
                &pts[t[0] as usize],
                &pts[t[1] as usize],
                &pts[t[2] as usize],
            );
            for (i, p) in pts.iter().enumerate() {
                if t.contains(&(i as u32)) {
                    continue;
                }
                let v = incircle(a, b, c, p);
                assert!(
                    v <= 1e-12,
                    "point {i} inside circumcircle of {t:?} (incircle={v})"
                );
            }
        }
    }

    #[test]
    fn triangle_count_matches_euler() {
        // For n points with h on the hull: triangles = 2n - h - 2,
        // edges = 3n - h - 3.
        let mut rng = Rng::new(5);
        let pts: Vec<Point> = (0..200)
            .map(|_| Point::new2(rng.f64(), rng.f64()))
            .collect();
        let d = Delaunay::triangulate(&pts);
        let t = d.triangles(pts.len()).len();
        let e = d.edges(pts.len()).len();
        // Euler: e - t = n + h' ... combine the two identities:
        // 3t = 2e - h  and  t = 2n - h - 2  ⇒  e = 3n - h - 3.
        let h_from_t = 2 * pts.len() as i64 - 2 - t as i64;
        let h_from_e = 3 * pts.len() as i64 - 3 - e as i64;
        assert_eq!(h_from_t, h_from_e, "t={t} e={e}");
        assert!(h_from_t >= 3);
    }

    #[test]
    fn all_triangles_ccw() {
        let mut rng = Rng::new(23);
        let pts: Vec<Point> = (0..150)
            .map(|_| Point::new2(rng.f64(), rng.f64()))
            .collect();
        let d = Delaunay::triangulate(&pts);
        for t in d.triangles(pts.len()) {
            let o = orient2d(
                &pts[t[0] as usize],
                &pts[t[1] as usize],
                &pts[t[2] as usize],
            );
            assert!(o > 0.0, "triangle {t:?} not CCW");
        }
    }

    #[test]
    fn grid_points_with_jitter() {
        // Structured-ish input (near-degenerate): jittered grid still works.
        let mut rng = Rng::new(3);
        let mut pts = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                pts.push(Point::new2(
                    i as f64 + 0.01 * rng.f64(),
                    j as f64 + 0.01 * rng.f64(),
                ));
            }
        }
        let d = Delaunay::triangulate(&pts);
        let e = d.edges(pts.len());
        assert!(e.len() >= 2 * pts.len());
    }
}
