//! Adaptively refined meshes ("refinetrace"-like).
//!
//! The paper's largest instance (refinetrace, 578M vertices) comes from
//! the Marquardt–Schamberger benchmark generator for *adaptive* FEM
//! computations: a coarse mesh repeatedly refined near a moving feature
//! (e.g. a shock front). We reproduce the character of such meshes:
//! start from a coarse jittered triangular grid and apply rounds of
//! regular (red) refinement to every triangle intersecting a circular
//! front that sweeps across the domain, producing strong density
//! gradients — the property that makes these instances hard for
//! geometric partitioners.

use crate::geometry::Point;
use crate::graph::{Csr, GraphBuilder};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Radius of the circular refinement front.
pub const FRONT_RADIUS: f64 = 0.25;

/// Width of the band around the front inside which triangles refine.
pub const FRONT_BAND: f64 = 0.08;

/// Center of the circular front at sweep parameter `t` (the front sweeps
/// its center along the domain diagonal; the fractional part of `t` wraps
/// it around, so traces longer than one sweep keep moving).
pub fn front_center(t: f64) -> (f64, f64) {
    let f = t - t.floor();
    (0.15 + 0.7 * f, 0.15 + 0.7 * f)
}

/// Per-vertex load weights induced by the moving front at sweep parameter
/// `t`: a smooth Gaussian annulus of amplitude `amp` and width `band`
/// around the front circle — the load profile of an adaptive FEM step
/// whose elements concentrate where the solution feature currently is.
/// Weights are ≥ 1 everywhere (every vertex still carries its base work).
pub fn front_weights(coords: &[Point], t: f64, amp: f64, band: f64) -> Vec<f64> {
    let (cx, cy) = front_center(t);
    coords
        .iter()
        .map(|p| {
            let d = ((p.x - cx).powi(2) + (p.y - cy).powi(2)).sqrt();
            let off = (d - FRONT_RADIUS) / band;
            1.0 + amp * (-0.5 * off * off).exp()
        })
        .collect()
}

/// Triangle soup with shared-vertex bookkeeping.
struct Mesh {
    pts: Vec<Point>,
    tris: Vec<[u32; 3]>,
    midpoints: HashMap<(u32, u32), u32>,
}

impl Mesh {
    fn midpoint(&mut self, a: u32, b: u32) -> u32 {
        let key = if a < b { (a, b) } else { (b, a) };
        if let Some(&m) = self.midpoints.get(&key) {
            return m;
        }
        let pa = self.pts[a as usize];
        let pb = self.pts[b as usize];
        let m = self.pts.len() as u32;
        self.pts.push(pa.add(&pb).scale(0.5));
        self.midpoints.insert(key, m);
        m
    }

    /// Red refinement: split a triangle into four via edge midpoints.
    fn refine_tri(&mut self, t: [u32; 3]) -> [[u32; 3]; 4] {
        let m01 = self.midpoint(t[0], t[1]);
        let m12 = self.midpoint(t[1], t[2]);
        let m20 = self.midpoint(t[2], t[0]);
        [
            [t[0], m01, m20],
            [t[1], m12, m01],
            [t[2], m20, m12],
            [m01, m12, m20],
        ]
    }
}

/// Generate a refined mesh with ~`target_n` vertices.
///
/// A circular front of radius 0.25 sweeps its center along the domain
/// diagonal; each round refines the triangles whose centroid is within a
/// band around the front, plus green-closure neighbors to keep the graph
/// connected through hanging nodes (we simply connect hanging midpoints
/// into their coarse edge, which keeps degrees bounded).
pub fn refined_mesh_2d(target_n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    // Coarse base grid sized so a few refinement rounds reach target_n.
    let base = ((target_n as f64 / 40.0).sqrt().ceil() as usize).clamp(4, 512);
    let mut mesh = Mesh {
        pts: Vec::new(),
        tris: Vec::new(),
        midpoints: HashMap::new(),
    };
    let jitter = 0.15 / base as f64;
    for j in 0..=base {
        for i in 0..=base {
            mesh.pts.push(Point::new2(
                i as f64 / base as f64 + jitter * (rng.f64() - 0.5),
                j as f64 / base as f64 + jitter * (rng.f64() - 0.5),
            ));
        }
    }
    let id = |i: usize, j: usize| -> u32 { (j * (base + 1) + i) as u32 };
    for j in 0..base {
        for i in 0..base {
            let (a, b, c, d) = (id(i, j), id(i + 1, j), id(i + 1, j + 1), id(i, j + 1));
            if (i + j) % 2 == 0 {
                mesh.tris.push([a, b, c]);
                mesh.tris.push([a, c, d]);
            } else {
                mesh.tris.push([a, b, d]);
                mesh.tris.push([b, c, d]);
            }
        }
    }
    // Refinement rounds along the sweeping front.
    let mut step = 0usize;
    while mesh.pts.len() < target_n && step < 24 {
        let t = step as f64 / 8.0; // front position parameter
        let (cx, cy) = front_center(t);
        let r_front = FRONT_RADIUS;
        let band = FRONT_BAND;
        let mut next: Vec<[u32; 3]> = Vec::with_capacity(mesh.tris.len() * 2);
        let tris = std::mem::take(&mut mesh.tris);
        for t in tris {
            let c = mesh.pts[t[0] as usize]
                .add(&mesh.pts[t[1] as usize])
                .add(&mesh.pts[t[2] as usize])
                .scale(1.0 / 3.0);
            let d = ((c.x - cx).powi(2) + (c.y - cy).powi(2)).sqrt();
            // Don't over-refine: cap by edge length so degrees stay sane.
            let el = mesh.pts[t[0] as usize].dist(&mesh.pts[t[1] as usize]);
            if (d - r_front).abs() < band && el > 0.5 / base as f64 / 8.0 {
                next.extend_from_slice(&mesh.refine_tri(t));
            } else {
                next.push(t);
            }
            if mesh.pts.len() >= target_n {
                // Keep the remaining triangles unrefined.
            }
        }
        mesh.tris = next;
        step += 1;
    }
    // Build the graph from triangle edges. Hanging nodes (midpoints whose
    // coarse neighbor was not refined) are already connected through the
    // refined side's triangles; additionally connect each midpoint to its
    // coarse edge endpoints to close any remaining hanging configurations.
    let n = mesh.pts.len();
    let mut b = GraphBuilder::new(n);
    for t in &mesh.tris {
        b.add_edge(t[0] as usize, t[1] as usize);
        b.add_edge(t[1] as usize, t[2] as usize);
        b.add_edge(t[2] as usize, t[0] as usize);
    }
    for (&(a, c), &m) in &mesh.midpoints {
        b.add_edge(a as usize, m as usize);
        b.add_edge(m as usize, c as usize);
    }
    b.set_coords(mesh.pts);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reaches_target_size() {
        let g = refined_mesh_2d(5000, 1);
        g.validate().unwrap();
        assert!(g.n() >= 2500, "n={}", g.n());
        assert!(g.n() <= 20_000, "n={}", g.n());
        assert_eq!(g.num_components(), 1);
    }

    #[test]
    fn density_gradient_exists() {
        // Refined meshes must be non-uniform: local degree-weighted point
        // density near the front should exceed the far-field density.
        let g = refined_mesh_2d(8000, 2);
        // Count vertices in [0,0.5]^2 vs [0.5,1]^2 corners — the front
        // passes through the diagonal, so density varies across cells.
        let mut grid = [[0usize; 4]; 4];
        for p in &g.coords {
            let i = ((p.x * 4.0) as usize).min(3);
            let j = ((p.y * 4.0) as usize).min(3);
            grid[j][i] += 1;
        }
        let counts: Vec<usize> = grid.iter().flatten().copied().collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(
            max as f64 > 2.0 * min as f64,
            "expected density gradient, got min={min} max={max}"
        );
    }

    #[test]
    fn bounded_degree() {
        // Hanging-node closures concentrate on coarse vertices bordering
        // multiple refinement levels; degrees stay bounded but higher than
        // a uniform mesh.
        let g = refined_mesh_2d(4000, 3);
        assert!(g.max_degree() <= 48, "max degree {}", g.max_degree());
    }

    #[test]
    fn deterministic() {
        let a = refined_mesh_2d(2000, 5);
        let b = refined_mesh_2d(2000, 5);
        assert_eq!(a.adjncy, b.adjncy);
    }

    #[test]
    fn front_center_sweeps_and_wraps() {
        let (x0, y0) = front_center(0.0);
        assert_eq!((x0, y0), (0.15, 0.15));
        let (x1, _) = front_center(0.5);
        assert!((x1 - 0.5).abs() < 1e-12);
        // Fractional wrap: t = 1.25 and t = 0.25 give the same center.
        assert_eq!(front_center(1.25), front_center(0.25));
    }

    #[test]
    fn front_weights_peak_on_the_annulus() {
        let g = refined_mesh_2d(3000, 4);
        let w = front_weights(&g.coords, 0.5, 6.0, 0.1);
        assert_eq!(w.len(), g.n());
        assert!(w.iter().all(|&x| x >= 1.0));
        // A vertex right on the front circle weighs ~1 + amp; a far-away
        // corner vertex stays ~1.
        let (cx, cy) = front_center(0.5);
        let on_front = g
            .coords
            .iter()
            .position(|p| {
                let d = ((p.x - cx).powi(2) + (p.y - cy).powi(2)).sqrt();
                (d - FRONT_RADIUS).abs() < 0.02
            })
            .expect("some vertex near the front");
        let far = g
            .coords
            .iter()
            .position(|p| {
                let d = ((p.x - cx).powi(2) + (p.y - cy).powi(2)).sqrt();
                (d - FRONT_RADIUS).abs() > 0.35
            })
            .expect("some vertex far from the front");
        assert!(w[on_front] > 5.0, "front weight {}", w[on_front]);
        assert!(w[far] < 1.1, "far weight {}", w[far]);
    }
}
