//! # hetpart — heterogeneous load distribution for sparse matrix/graph apps
//!
//! Reproduction of Tzovas, Predari & Meyerhenke, *"Distributing Sparse
//! Matrix/Graph Applications in Heterogeneous Clusters — an Experimental
//! Study"* (2020), as a three-layer rust + JAX + Pallas system.
//!
//! The library provides:
//! - the **LDHT problem** machinery: heterogeneous topology trees
//!   ([`topology`]), optimal block-size computation (Algorithm 1,
//!   [`blocksizes`]), and partition quality metrics ([`partition`]);
//! - **eleven partitioning algorithms** ([`partitioners`]): balanced
//!   k-means (`geoKM`), its hierarchical variant, Geographer-R refinement
//!   (`geoRef`, `geoPMRef`), ParMetis-like multilevel (`pmGraph`,
//!   `pmGeom`), the Zoltan geometric trio (`zSFC`, `zRCB`, `zRIB`), and
//!   the paper-excluded tools (`lpPulp`, `zMJ`); the paper-central
//!   parallel families additionally run *distributed on the virtual
//!   cluster* ([`partitioners::dist`]) with bit-identical output and
//!   priced/measured partitioning time;
//! - **mesh/graph substrates**: CSR graphs ([`graph`]), generators for
//!   random geometric graphs, Delaunay triangulations and adaptive meshes
//!   ([`gen`]);
//! - the **application layer**: SpMV/CG solvers and a heterogeneous
//!   cluster execution simulator ([`solver`]), with the numeric hot path
//!   AOT-compiled from JAX/Pallas and executed via PJRT ([`runtime`]);
//! - the **virtual-cluster execution engine** ([`exec`]): distributed CG
//!   over per-PU row blocks behind a `Comm` transport abstraction, with
//!   a sequential α-β-priced backend and a thread-per-PU shared-memory
//!   backend; the seam carries nonblocking primitives (isend/irecv +
//!   request handles) so the halo exchange overlaps the interior SpMV —
//!   priced at `max(compute, comm)` by the simulator — and a pipelined
//!   single-reduction CG variant;
//! - **irregular graph-application kernels** ([`apps`]): frontier BFS,
//!   delta-stepping SSSP and push-style PageRank over distributed row
//!   strips, batching their per-edge messages through the aggregating
//!   transport ([`exec::AggComm`], Bale's convey protocol) with a
//!   `direct` baseline mode, bit-identical results across modes,
//!   backends and rank counts, and the bottleneck-link byte metric
//!   reported per run;
//! - the **dynamic repartitioning subsystem** ([`repart`]): epoch traces
//!   replaying adaptive workloads (moving refinement front, PU speed
//!   drift), three repartitioners behind one `Repartitioner` trait
//!   (scratch-remap, diffusive rebalancing, incremental geoKM), and data
//!   migration executed and priced through the `exec::Comm` seam;
//! - an experiment **coordinator** ([`coordinator`]) and scenario-matrix
//!   **harness** ([`harness`]): declarative scenarios with paper-faithful
//!   topology presets (plus a `dynamic` axis for multi-epoch scenarios),
//!   a parallel matrix runner with CSV/JSON artifacts, golden-baseline
//!   regression gates, and the drivers regenerating every table and
//!   figure of the paper.
//!
//! See the top-level `README.md` for the module map and CLI tour,
//! `DESIGN.md` for the architecture, and `EXPERIMENTS.md` for how to
//! regenerate the paper-vs-measured results.

// Every public item carries documentation; `cargo doc --no-deps` runs in
// CI with RUSTDOCFLAGS="-D warnings", so a missing doc is a CI failure.
#![warn(missing_docs)]

pub mod apps;
pub mod blocksizes;
pub mod coordinator;
pub mod exec;
pub mod gen;
pub mod geometry;
pub mod graph;
pub mod harness;
pub mod mapping;
pub mod partition;
pub mod partitioners;
pub mod prop;
pub mod repart;
pub mod runtime;
pub mod solver;
pub mod topology;
pub mod util;
