//! Experiment harness: declarative scenario matrices, the runner that
//! fans them out over the leader/worker job queue, golden-baseline
//! regression gates, and the per-table/figure drivers of the paper's §VI.
//!
//! Three layers:
//! - [`scenario`] — a [`Scenario`](scenario::Scenario) is one matrix cell
//!   (mesh family × size × topology preset × partitioner × ε × seed,
//!   plus a `dynamic` axis for multi-epoch repartitioning traces);
//!   [`MatrixKind`](scenario::MatrixKind) registers the named sweeps
//!   (`smoke`, `paper-small`, `paper-full`, `dynamic`, `partdist`,
//!   `serve`, `sweep`, `apps`, `scale`) reachable via
//!   `hetpart harness --matrix <name>`; the `scale` matrix prices
//!   thousand-rank virtual clusters (flat vs hierarchical collectives ×
//!   fat-tree/torus networks) through the analytic
//!   [`CollectiveModel`](crate::exec::CollectiveModel);
//! - [`runner`] — executes a matrix in parallel and writes structured
//!   artifacts (CSV + JSON per run, per-partitioner geomean summaries);
//! - [`golden`] — compares a deterministic matrix against checked-in
//!   baselines (`rust/tests/golden/*.json`) with per-metric tolerances,
//!   the regression gate wired into `cargo test`.
//!
//! The [`experiments`] drivers regenerate every table and figure of the
//! paper's evaluation (shared by the `cargo bench` targets and
//! `hetpart experiment <name>`). [`bench_snapshot`] adds the
//! machine-readable side of the benches: `BENCH_*.json` snapshots
//! (fingerprint + per-kernel ns/row and GB/s) diffed by
//! `tools/bench_compare.py`.
//!
//! Scaling: the paper's instances are 1M–578M vertices on up to 12288
//! PUs; this testbed is one CPU core. [`BenchScale`] shrinks instance
//! sizes and PU counts ~100× while preserving the comparisons (who wins,
//! by what factor, where heterogeneity hurts).

pub mod bench_snapshot;
pub mod experiments;
pub mod golden;
pub mod runner;
pub mod scenario;

pub use bench_snapshot::{BenchSnapshot, Direction, Fingerprint, KernelEntry};
pub use golden::{compare, GoldenFile, GoldenMetrics, GoldenReport, Tolerances};
pub use runner::{
    run_matrix, run_scenario, summarize, write_artifacts, AppSummary, DynamicSummary,
    ScaleSummary, ScenarioResult, ServeSummary,
};
pub use scenario::{
    alg1_targets, AppSpec, MatrixKind, ScaleSpec, Scenario, ServeSpec, TopoPreset,
    ALL_PRESETS, SCALE_NODE_RANKS,
};

use crate::util::table::Table;

/// Global size knobs, overridable via environment:
/// `HETPART_BENCH_SCALE=quick|default|full`.
#[derive(Debug, Clone, Copy)]
pub struct BenchScale {
    /// Base vertex count for 2-D instances.
    pub n2d: usize,
    /// Base vertex count for 3-D instances.
    pub n3d: usize,
    /// Base PU count ("96" in the paper's TOPO1/TOPO2 tables).
    pub k: usize,
    /// k sweep for Figs. 3–4: k = base·2^i, i in 0..sweep.
    pub sweep: usize,
}

impl BenchScale {
    /// Read the scale from `HETPART_BENCH_SCALE` (`quick|default|full`).
    pub fn from_env() -> BenchScale {
        match std::env::var("HETPART_BENCH_SCALE").as_deref() {
            Ok("quick") => BenchScale { n2d: 2_500, n3d: 2_000, k: 24, sweep: 2 },
            Ok("full") => BenchScale { n2d: 60_000, n3d: 40_000, k: 96, sweep: 4 },
            _ => BenchScale { n2d: 12_000, n3d: 8_000, k: 48, sweep: 3 },
        }
    }
}

/// Print a driver's table and persist it as CSV under `results/`.
pub fn emit(name: &str, title: &str, t: &Table) {
    println!("\n=== {name}: {title} ===");
    print!("{}", t.to_text());
    match t.save_csv(name) {
        Ok(p) => println!("[saved {}]", p.display()),
        Err(e) => eprintln!("[csv save failed: {e}]"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_from_env_default() {
        // Whatever the env, all fields must be sane.
        let s = BenchScale::from_env();
        assert!(s.n2d >= 1000 && s.k >= 8 && s.sweep >= 1);
    }
}
