//! Machine-readable bench snapshots: the `BENCH_*.json` perf trajectory.
//!
//! The micro and exec-engine benches print human tables; this module adds
//! the machine-readable side: a [`BenchSnapshot`] captures the machine
//! fingerprint, the bench scale, and one [`KernelEntry`] per measured
//! kernel (median ms, ns/row, effective GB/s). Snapshots are written as
//! `BENCH_<bench>.json` and diffed against the committed copies at the
//! repo root by `tools/bench_compare.py` (advisory in CI — perf deltas
//! are reported, not build-breaking, because CI machines vary).
//!
//! A snapshot whose `bootstrap` flag is `true` carries *no* measurements:
//! it marks a baseline that has never been recorded on real hardware
//! (the offline seed of this repo). `bench_compare.py` treats bootstrap
//! baselines as "unarmed" and passes loudly; the first run on a real
//! machine with `--save-baseline` replaces them with measured data.

use crate::util::json::{obj, Json};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Which way "better" points for a kernel's pinned metric.
///
/// Latency-style metrics (ns/row) regress when they *grow*; rate-style
/// metrics (goodput in req/s) regress when they *shrink*. The baseline
/// entry's direction governs how `tools/bench_compare.py` reads a delta.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Lower is better (latencies, ns/row). The historical default.
    Lower,
    /// Higher is better (throughput rates such as serve goodput).
    Higher,
}

impl Direction {
    /// The on-disk string (`"lower"` / `"higher"`).
    pub fn name(self) -> &'static str {
        match self {
            Direction::Lower => "lower",
            Direction::Higher => "higher",
        }
    }

    /// Parse the on-disk string; unknown values read as `Lower` so old
    /// snapshots (which predate the field) keep their meaning.
    pub fn parse(s: &str) -> Direction {
        if s == "higher" {
            Direction::Higher
        } else {
            Direction::Lower
        }
    }
}

/// One measured kernel inside a snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelEntry {
    /// Kernel label (e.g. `"native_ell"`, `"sell_c8_s64"`).
    pub name: String,
    /// Rows the kernel processed per invocation.
    pub n: usize,
    /// Median wall time per invocation, milliseconds.
    pub median_ms: f64,
    /// Median time divided by rows, nanoseconds (the pinned metric —
    /// scale-independent enough to compare across quick/default runs of
    /// the same machine). For `Direction::Higher` entries this slot
    /// carries the rate itself (e.g. req/s) rather than a per-row time.
    pub ns_per_row: f64,
    /// Effective bandwidth: bytes the kernel streams per invocation
    /// divided by the median time, GB/s.
    pub gbs: f64,
    /// Which way "better" points for the pinned metric.
    pub direction: Direction,
}

/// Identity of the machine a snapshot was recorded on. Comparisons
/// across different fingerprints are advisory-only by definition.
#[derive(Debug, Clone, PartialEq)]
pub struct Fingerprint {
    /// CPU model string from `/proc/cpuinfo` (or `"unknown"`).
    pub cpu: String,
    /// `std::thread::available_parallelism` at record time.
    pub threads: usize,
    /// `std::env::consts::OS` / `ARCH`, joined (`"linux/x86_64"`).
    pub os: String,
}

impl Fingerprint {
    /// Capture the current machine's fingerprint.
    pub fn capture() -> Fingerprint {
        let cpu = std::fs::read_to_string("/proc/cpuinfo")
            .ok()
            .and_then(|s| {
                s.lines()
                    .find(|l| l.starts_with("model name"))
                    .and_then(|l| l.split(':').nth(1))
                    .map(|v| v.trim().to_string())
            })
            .unwrap_or_else(|| "unknown".to_string());
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Fingerprint {
            cpu,
            threads,
            os: format!("{}/{}", std::env::consts::OS, std::env::consts::ARCH),
        }
    }
}

/// A full bench snapshot: what `BENCH_<bench>.json` holds.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchSnapshot {
    /// Bench family (`"spmv"` / `"cg"`).
    pub bench: String,
    /// `true` for a seed baseline that carries no real measurements.
    pub bootstrap: bool,
    /// The `HETPART_BENCH_SCALE` the run used (`quick|default|full`).
    pub scale: String,
    /// Machine identity at record time.
    pub fingerprint: Fingerprint,
    /// Measured kernels (empty iff `bootstrap`).
    pub kernels: Vec<KernelEntry>,
}

impl BenchSnapshot {
    /// Fresh snapshot for a real measured run on this machine.
    pub fn new(bench: &str) -> BenchSnapshot {
        BenchSnapshot {
            bench: bench.to_string(),
            bootstrap: false,
            scale: std::env::var("HETPART_BENCH_SCALE").unwrap_or_else(|_| "default".into()),
            fingerprint: Fingerprint::capture(),
            kernels: Vec::new(),
        }
    }

    /// Append one kernel, deriving ns/row and GB/s from the median time
    /// and the bytes the kernel streams per invocation.
    pub fn push(&mut self, name: &str, n: usize, median_secs: f64, bytes: f64) {
        let safe = median_secs.max(1e-12);
        self.kernels.push(KernelEntry {
            name: name.to_string(),
            n,
            median_ms: median_secs * 1e3,
            ns_per_row: safe * 1e9 / n.max(1) as f64,
            gbs: bytes / safe / 1e9,
            direction: Direction::Lower,
        });
    }

    /// Append one rate-style entry (higher is better). The rate (e.g.
    /// serve goodput in req/s) rides in the `ns_per_row` slot — the
    /// pinned metric `bench_compare.py` diffs — with the time/bandwidth
    /// fields zeroed because they have no meaning for a rate.
    pub fn push_rate(&mut self, name: &str, n: usize, rate: f64) {
        self.kernels.push(KernelEntry {
            name: name.to_string(),
            n,
            median_ms: 0.0,
            ns_per_row: rate,
            gbs: 0.0,
            direction: Direction::Higher,
        });
    }

    /// Render as the on-disk JSON document.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bench", Json::Str(self.bench.clone())),
            ("bootstrap", Json::Bool(self.bootstrap)),
            ("scale", Json::Str(self.scale.clone())),
            (
                "fingerprint",
                obj(vec![
                    ("cpu", Json::Str(self.fingerprint.cpu.clone())),
                    ("threads", Json::Num(self.fingerprint.threads as f64)),
                    ("os", Json::Str(self.fingerprint.os.clone())),
                ]),
            ),
            (
                "kernels",
                Json::Arr(
                    self.kernels
                        .iter()
                        .map(|k| {
                            obj(vec![
                                ("name", Json::Str(k.name.clone())),
                                ("n", Json::Num(k.n as f64)),
                                ("median_ms", Json::Num(k.median_ms)),
                                ("ns_per_row", Json::Num(k.ns_per_row)),
                                ("gbs", Json::Num(k.gbs)),
                                ("direction", Json::Str(k.direction.name().to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a snapshot back from its JSON document.
    pub fn from_json(j: &Json) -> Result<BenchSnapshot> {
        let str_of = |j: &Json, k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("snapshot missing string field '{k}'"))?
                .to_string())
        };
        let num_of = |j: &Json, k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("snapshot missing number field '{k}'"))
        };
        let fp = j.get("fingerprint").ok_or_else(|| anyhow!("snapshot missing fingerprint"))?;
        let kernels = match j.get("kernels") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|k| {
                    Ok(KernelEntry {
                        name: str_of(k, "name")?,
                        n: num_of(k, "n")? as usize,
                        median_ms: num_of(k, "median_ms")?,
                        ns_per_row: num_of(k, "ns_per_row")?,
                        gbs: num_of(k, "gbs")?,
                        // Tolerant: snapshots written before the field
                        // existed read as lower-is-better.
                        direction: k
                            .get("direction")
                            .and_then(Json::as_str)
                            .map(Direction::parse)
                            .unwrap_or(Direction::Lower),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            _ => return Err(anyhow!("snapshot missing kernels array")),
        };
        Ok(BenchSnapshot {
            bench: str_of(j, "bench")?,
            bootstrap: j.get("bootstrap").and_then(Json::as_bool).unwrap_or(false),
            scale: str_of(j, "scale")?,
            fingerprint: Fingerprint {
                cpu: str_of(fp, "cpu")?,
                threads: num_of(fp, "threads")? as usize,
                os: str_of(fp, "os")?,
            },
            kernels,
        })
    }

    /// Write `BENCH_<bench>.json` under `dir` (created if absent);
    /// returns the path.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.to_json().render())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    /// Read a snapshot from a `BENCH_*.json` file.
    pub fn load(path: &Path) -> Result<BenchSnapshot> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

/// Where to save a fresh snapshot, given the process args and the
/// `HETPART_BENCH_SAVE` environment value: the env names a directory,
/// a bare `--save-baseline` arg means the current directory, anything
/// else means "don't save". Pure so tests can exercise the policy.
pub fn save_dir_from(args: &[String], env: Option<&str>) -> Option<PathBuf> {
    if let Some(dir) = env {
        if !dir.is_empty() {
            return Some(PathBuf::from(dir));
        }
    }
    if args.iter().any(|a| a == "--save-baseline") {
        return Some(PathBuf::from("."));
    }
    None
}

/// [`save_dir_from`] on the real process arguments and environment.
pub fn save_requested() -> Option<PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    let env = std::env::var("HETPART_BENCH_SAVE").ok();
    save_dir_from(&args, env.as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_derives_per_row_and_bandwidth() {
        let mut s = BenchSnapshot::new("spmv");
        // 1000 rows in 1 ms moving 8 MB → 1000 ns/row and 8 GB/s.
        s.push("k", 1000, 1e-3, 8e6);
        let k = &s.kernels[0];
        assert!((k.ns_per_row - 1000.0).abs() < 1e-9, "{}", k.ns_per_row);
        assert!((k.gbs - 8.0).abs() < 1e-9, "{}", k.gbs);
        assert!((k.median_ms - 1.0).abs() < 1e-12);
    }

    #[test]
    fn push_defaults_to_lower_is_better() {
        let mut s = BenchSnapshot::new("spmv");
        s.push("k", 1000, 1e-3, 8e6);
        assert_eq!(s.kernels[0].direction, Direction::Lower);
    }

    #[test]
    fn push_rate_marks_higher_is_better_and_pins_the_rate() {
        let mut s = BenchSnapshot::new("serve");
        s.push_rate("goodput@500", 800, 498.5);
        let k = &s.kernels[0];
        assert_eq!(k.direction, Direction::Higher);
        assert!((k.ns_per_row - 498.5).abs() < 1e-12);
        assert_eq!(k.median_ms, 0.0);
        assert_eq!(k.gbs, 0.0);
    }

    #[test]
    fn direction_survives_the_json_round_trip() {
        let mut s = BenchSnapshot::new("serve");
        s.push("lat", 800, 1e-3, 0.0);
        s.push_rate("goodput@500", 800, 498.5);
        let back = BenchSnapshot::from_json(&Json::parse(&s.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.kernels[0].direction, Direction::Lower);
        assert_eq!(back.kernels[1].direction, Direction::Higher);
    }

    #[test]
    fn missing_direction_reads_as_lower() {
        // A hand-built kernel object without the field — the pre-field
        // on-disk shape.
        let text = r#"{"bench":"spmv","bootstrap":false,"scale":"quick",
            "fingerprint":{"cpu":"x","threads":1,"os":"linux/x86_64"},
            "kernels":[{"name":"k","n":10,"median_ms":1.0,
                        "ns_per_row":5.0,"gbs":2.0}]}"#;
        let back = BenchSnapshot::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(back.kernels[0].direction, Direction::Lower);
        assert_eq!(Direction::parse("weird"), Direction::Lower);
        assert_eq!(Direction::parse("higher"), Direction::Higher);
    }

    #[test]
    fn json_round_trip() {
        let mut s = BenchSnapshot::new("cg");
        s.push("native_cg", 2500, 2.5e-4, 1.2e6);
        s.push("sell_c8_s64", 2500, 1.9e-4, 1.2e6);
        let text = s.to_json().render();
        let back = BenchSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn bootstrap_snapshot_round_trips_with_no_kernels() {
        let s = BenchSnapshot {
            bench: "spmv".to_string(),
            bootstrap: true,
            scale: "quick".to_string(),
            fingerprint: Fingerprint {
                cpu: "unknown".to_string(),
                threads: 1,
                os: "linux/x86_64".to_string(),
            },
            kernels: Vec::new(),
        };
        let back = BenchSnapshot::from_json(&Json::parse(&s.to_json().render()).unwrap()).unwrap();
        assert!(back.bootstrap);
        assert!(back.kernels.is_empty());
    }

    #[test]
    fn save_and_load_file() {
        let dir = std::env::temp_dir().join("hetpart_bench_snapshot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = BenchSnapshot::new("spmv");
        s.push("native_ell", 100, 1e-5, 1e5);
        let path = s.save(&dir).unwrap();
        assert!(path.ends_with("BENCH_spmv.json"));
        let back = BenchSnapshot::load(&path).unwrap();
        assert_eq!(back, s);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_policy() {
        let args = |xs: &[&str]| xs.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(save_dir_from(&args(&["bench"]), None), None);
        assert_eq!(
            save_dir_from(&args(&["bench", "--save-baseline"]), None),
            Some(PathBuf::from("."))
        );
        assert_eq!(
            save_dir_from(&args(&["bench"]), Some("/tmp/out")),
            Some(PathBuf::from("/tmp/out"))
        );
        assert_eq!(save_dir_from(&args(&["bench"]), Some("")), None);
        // Env wins over the flag (CI sets the env; the flag is for
        // humans refreshing the committed baseline in-place).
        assert_eq!(
            save_dir_from(&args(&["bench", "--save-baseline"]), Some("/x")),
            Some(PathBuf::from("/x"))
        );
    }

    #[test]
    fn fingerprint_is_sane() {
        let f = Fingerprint::capture();
        assert!(f.threads >= 1);
        assert!(!f.cpu.is_empty());
        assert!(f.os.contains('/'));
    }
}
