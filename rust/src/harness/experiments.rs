//! Experiment drivers — one per table/figure of the paper's §VI.
//!
//! Every driver returns a [`Table`] with the same rows/series the paper
//! reports (scaled per DESIGN.md §2). The `cargo bench` targets call
//! these and [`super::emit`] the results.

use super::BenchScale;
use crate::coordinator::{instance, run_one, run_solve, Grid, RunResult};
use crate::exec::ExecBackend;
use crate::gen::Family;
use crate::partition::metrics;
use crate::partitioners::{by_name, Ctx, ALL_NAMES};
use crate::solver::{ClusterSim, EllMatrix};
use crate::topology::{
    topo1, topo2, topo3, Pu, Topo1Spec, Topo2Spec, Topo3Spec, Topology, TABLE3_STEPS,
};
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::util::fmt_f64;

const EPS: f64 = 0.03;
const SEED: u64 = 20200501;

/// **Table III**: Algorithm-1 block-size ratios tw(fast)/tw(slow) for the
/// five speed/memory steps, |F| ∈ {k/12, k/6}, k = 96.
pub fn table3() -> Table {
    let paper = [(1.0, 1.0), (2.0, 2.0), (3.2, 3.5), (5.5, 6.1), (9.4, 11.5)];
    let k = 96;
    let mut t = Table::new(vec![
        "exp", "speed", "memory", "ratio_f8", "paper_f8", "ratio_f16", "paper_f16",
    ]);
    for (i, (&(s, m), &(p8, p16))) in TABLE3_STEPS.iter().zip(paper.iter()).enumerate() {
        let fast = Pu { speed: s, memory: m };
        let mut ratios = Vec::new();
        for num_fast in [k / 12, k / 6] {
            let topo = topo1(Topo1Spec { k, num_fast, fast });
            let n = crate::blocksizes::TABLE3_FILL * topo.total_memory();
            let bs = crate::blocksizes::block_sizes(n, &topo).unwrap();
            ratios.push(bs.ratio(0, k - 1));
        }
        t.row(vec![
            (i + 1).to_string(),
            fmt_f64(s),
            fmt_f64(m),
            format!("{:.2}", ratios[0]),
            format!("{p8}"),
            format!("{:.2}", ratios[1]),
            format!("{p16}"),
        ]);
    }
    t
}

/// **Fig. 1**: balanced k-means vs hierarchical version — relative edge
/// cut and max communication volume (hier / flat; paper: within ±1%,
/// hierarchical slightly worse).
pub fn fig1(scale: BenchScale) -> Table {
    let graphs = [
        instance(Family::Tri2d, scale.n2d, SEED),
        instance(Family::Rdg2d, scale.n2d, SEED + 1),
        instance(Family::Refined2d, scale.n2d, SEED + 2),
    ];
    // Hierarchies: nodes × cores-per-node fanouts over homogeneous PUs.
    let fanouts: Vec<Vec<usize>> = vec![vec![4, scale.k / 4], vec![2, 2, scale.k / 4]];
    let mut t = Table::new(vec!["graph", "hierarchy", "rel_cut", "rel_maxCommVol"]);
    for (name, g) in &graphs {
        for f in &fanouts {
            let topo = Topology::hierarchical(
                f,
                |_| Pu { speed: 1.0, memory: 2.0 },
                format!("h{f:?}"),
            );
            let (flat, _) = run_one(name, g, &topo, "geoKM", EPS, SEED).unwrap();
            let (hier, _) = run_one(name, g, &topo, "hierKM", EPS, SEED).unwrap();
            t.row(vec![
                name.clone(),
                format!("{f:?}"),
                format!("{:.3}", hier.cut / flat.cut),
                format!("{:.3}", hier.max_comm_volume / flat.max_comm_volume),
            ]);
        }
    }
    t
}

/// The 16 topologies of Fig. 2's x-axis: {TOPO1, TOPO2} × f ∈ {k/12, k/6}
/// × fs ∈ {2, 4, 8, 16} (Table III steps 2–5).
pub fn fig2_topologies(k: usize) -> Vec<Topology> {
    let mut out = Vec::new();
    for topo_kind in [1, 2] {
        for num_fast in [k / 12, k / 6] {
            for &(s, m) in &TABLE3_STEPS[1..] {
                let fast = Pu { speed: s, memory: m };
                out.push(if topo_kind == 1 {
                    topo1(Topo1Spec { k, num_fast, fast })
                } else {
                    topo2(Topo2Spec { k, num_fast, fast })
                });
            }
        }
    }
    out
}

/// **Fig. 2**: all eight algorithms across the 16 topologies; values are
/// geometric means over the graphs, relative to geoKM (lower is better).
/// `part` = 'a' (hugeX-like 2-D meshes) or 'b' (alya-like 3-D meshes).
pub fn fig2(scale: BenchScale, part: char) -> Table {
    let graphs = if part == 'a' {
        vec![
            instance(Family::Tri2d, scale.n2d, SEED),
            instance(Family::Refined2d, scale.n2d, SEED + 1),
            instance(Family::Rdg2d, scale.n2d, SEED + 2),
        ]
    } else {
        vec![
            instance(Family::Tet3d, scale.n3d, SEED),
            instance(Family::Tet3d, scale.n3d * 2, SEED + 1),
        ]
    };
    let grid = Grid {
        graphs,
        topologies: fig2_topologies(scale.k),
        algos: ALL_NAMES.iter().map(|s| s.to_string()).collect(),
        epsilon: EPS,
        seed: SEED,
    };
    let results = grid.run();
    relative_table(&results, &["cut", "maxCommVol", "time"])
}

/// Geomean-relative table: one row per (topology, algo), columns are the
/// requested metrics relative to geoKM on the same (graph, topology).
fn relative_table(results: &[RunResult], cols: &[&str]) -> Table {
    let get = |r: &RunResult, c: &str| -> f64 {
        match c {
            "cut" => r.cut,
            "maxCommVol" => r.max_comm_volume,
            "time" => r.time_partition.max(1e-6),
            _ => unreachable!(),
        }
    };
    let mut header = vec!["topology".to_string(), "algo".to_string()];
    header.extend(cols.iter().map(|c| format!("rel_{c}")));
    let mut t = Table::new(header);
    // Collect (topo, algo) combos in first-seen order.
    let mut combos: Vec<(String, String)> = Vec::new();
    for r in results {
        let key = (r.topo_label.clone(), r.algo.clone());
        if !combos.contains(&key) {
            combos.push(key);
        }
    }
    for (topo, algo) in combos {
        let mut row = vec![topo.clone(), algo.clone()];
        for c in cols {
            let ratios: Vec<f64> = results
                .iter()
                .filter(|r| r.topo_label == topo && r.algo == algo)
                .filter_map(|r| {
                    results
                        .iter()
                        .find(|b| {
                            b.graph_name == r.graph_name
                                && b.topo_label == topo
                                && b.algo == "geoKM"
                        })
                        .map(|b| get(r, c) / get(b, c).max(1e-12))
                })
                .filter(|v| *v > 0.0)
                .collect();
            row.push(if ratios.is_empty() {
                "-".to_string()
            } else {
                format!("{:.3}", geomean(&ratios))
            });
        }
        t.row(row);
    }
    t
}

/// **Fig. 3**: the refinetrace-like graph under TOPO2 with growing PU
/// counts k = 24·2^i — absolute cut/maxCommVol/time per (k, algo).
pub fn fig3(scale: BenchScale) -> Table {
    let (name, g) = instance(Family::Refined2d, scale.n2d * 2, SEED);
    let mut t = Table::new(vec!["k", "algo", "cut", "maxCommVol", "time(s)"]);
    for i in 0..scale.sweep {
        let k = 24 << i;
        if g.n() < 50 * k {
            break; // keep ≥50 vertices per block
        }
        let fast = Pu { speed: 16.0, memory: 13.8 };
        let topo = topo2(Topo2Spec { k, num_fast: k / 6, fast });
        for algo in ALL_NAMES {
            match run_one(&name, &g, &topo, algo, EPS, SEED) {
                Ok((r, _)) => t.row(vec![
                    k.to_string(),
                    algo.to_string(),
                    fmt_f64(r.cut),
                    fmt_f64(r.max_comm_volume),
                    format!("{:.3}", r.time_partition),
                ]),
                Err(e) => eprintln!("WARN fig3 {algo} k={k}: {e}"),
            }
        }
    }
    t
}

/// **Fig. 4**: 3-D rgg and rdg graphs under TOPO2, k sweep; geomean
/// relative to geoKM.
pub fn fig4(scale: BenchScale) -> Table {
    let graphs = vec![
        instance(Family::Rgg3d, scale.n3d, SEED),
        instance(Family::Rdg2d, scale.n2d, SEED + 1),
    ];
    let mut topologies = Vec::new();
    for i in 0..scale.sweep {
        let k = 24 << i;
        if graphs.iter().any(|(_, g)| g.n() < 50 * k) {
            break;
        }
        let fast = Pu { speed: 16.0, memory: 13.8 };
        topologies.push(topo2(Topo2Spec { k, num_fast: k / 6, fast }));
    }
    let grid = Grid {
        graphs,
        topologies,
        algos: ALL_NAMES.iter().map(|s| s.to_string()).collect(),
        epsilon: EPS,
        seed: SEED,
    };
    let results = grid.run();
    relative_table(&results, &["cut", "maxCommVol", "time"])
}

/// **Fig. 5**: TOPO3 — cut values and simulated CG time/iteration on the
/// rdg_2d graph, for 4/8-node clusters with 1–2 fast nodes.
pub fn fig5(scale: BenchScale) -> Table {
    let (name, g) = instance(Family::Rdg2d, scale.n2d * 2, SEED);
    let ell = EllMatrix::from_graph(&g, 0.05);
    let mut sim = ClusterSim::default();
    sim.calibrate(&ell);
    let pus_per_node = (scale.k / 4).max(2);
    let mut t = Table::new(vec![
        "setting", "algo", "cut", "maxCommVol", "simCG_t/iter(ms)", "bottleneck",
    ]);
    for (nodes, fast_nodes) in [(4usize, 1usize), (4, 2), (8, 1), (8, 2)] {
        let topo = topo3(Topo3Spec {
            nodes,
            pus_per_node,
            fast_nodes,
            slowdown: 4.0,
        });
        for algo in ALL_NAMES {
            match run_one(&name, &g, &topo, algo, EPS, SEED) {
                Ok((r, p)) => {
                    let rep = sim.iteration(&g, &p, &topo, ell.w);
                    t.row(vec![
                        format!("n{nodes}_f{fast_nodes}"),
                        algo.to_string(),
                        fmt_f64(r.cut),
                        fmt_f64(r.max_comm_volume),
                        format!("{:.4}", rep.time_per_iter * 1e3),
                        format!(
                            "pu{} c={:.0}% m={:.0}%",
                            rep.bottleneck_pu,
                            100.0 * rep.bottleneck_compute / rep.time_per_iter,
                            100.0 * rep.bottleneck_comm / rep.time_per_iter
                        ),
                    ]);
                }
                Err(e) => eprintln!("WARN fig5 {algo}: {e}"),
            }
        }
    }
    t
}

/// **Table IV**: exact values (cut, maxCommVol, partition time) for a
/// 4-instance × 4-topology grid at fs = 16, mirroring the paper's layout.
pub fn table4(scale: BenchScale) -> Table {
    let graphs = vec![
        instance(Family::Tri2d, scale.n2d, SEED),       // 333SP-like
        instance(Family::Rdg2d, scale.n2d, SEED + 1),   // NLR-like
        instance(Family::Refined2d, scale.n2d, SEED + 2), // hugetrace-like
        instance(Family::Tet3d, scale.n3d, SEED + 3),   // alya-like
    ];
    let k = scale.k;
    let fast = Pu { speed: 16.0, memory: 13.8 };
    let topologies = vec![
        topo1(Topo1Spec { k, num_fast: k / 12, fast }), // t1_f8 (scaled)
        topo1(Topo1Spec { k, num_fast: k / 6, fast }),  // t1_f16
        topo2(Topo2Spec { k, num_fast: k / 12, fast }), // t2_f8
        topo2(Topo2Spec { k, num_fast: k / 6, fast }),  // t2_f16
    ];
    let grid = Grid {
        graphs,
        topologies,
        algos: ALL_NAMES.iter().map(|s| s.to_string()).collect(),
        epsilon: EPS,
        seed: SEED,
    };
    let results = grid.run();
    let mut t = Table::new(vec![
        "graph", "algo", "t1_f8_cut", "t1_f16_cut", "t2_f8_cut", "t2_f16_cut",
        "t1_f8_vol", "t1_f16_vol", "t2_f8_vol", "t2_f16_vol",
        "t1_f8_time", "t1_f16_time", "t2_f8_time", "t2_f16_time",
    ]);
    let mut graph_names: Vec<String> = Vec::new();
    for r in &results {
        if !graph_names.contains(&r.graph_name) {
            graph_names.push(r.graph_name.clone());
        }
    }
    let topo_labels: Vec<String> = {
        let mut v = Vec::new();
        for r in &results {
            if !v.contains(&r.topo_label) {
                v.push(r.topo_label.clone());
            }
        }
        v
    };
    for gname in &graph_names {
        for algo in ALL_NAMES {
            let cell = |topo: &str, f: &dyn Fn(&RunResult) -> f64| -> String {
                results
                    .iter()
                    .find(|r| &r.graph_name == gname && r.algo == algo && r.topo_label == topo)
                    .map(|r| fmt_f64(f(r)))
                    .unwrap_or_else(|| "-".into())
            };
            let mut row = vec![gname.clone(), algo.to_string()];
            for tl in &topo_labels {
                row.push(cell(tl, &|r| r.cut));
            }
            for tl in &topo_labels {
                row.push(cell(tl, &|r| r.max_comm_volume));
            }
            for tl in &topo_labels {
                row.push(cell(tl, &|r| r.time_partition));
            }
            t.row(row);
        }
    }
    t
}

/// **Exec engine**: the virtual cluster's two backends on a TOPO3-style
/// heterogeneous cluster — residual-trajectory agreement between the
/// sequential α-β `sim` backend and the thread-per-PU `threads` backend,
/// plus each backend's bottleneck time per iteration.
pub fn exec_compare(scale: BenchScale) -> Table {
    let (name, g) = instance(Family::Rdg2d, scale.n2d, SEED);
    let pus_per_node = (scale.k / 4).max(2);
    let topo = topo3(Topo3Spec {
        nodes: 4,
        pus_per_node,
        fast_nodes: 1,
        slowdown: 4.0,
    });
    let mut t = Table::new(vec![
        "algo", "sim_t/iter(ms)", "thr_t/iter(ms)", "thr_wall(s)", "resid", "resid_agree",
    ]);
    for algo in ["geoKM", "zSFC", "pmGraph"] {
        let p = match run_one(&name, &g, &topo, algo, EPS, SEED) {
            Ok((_, p)) => p,
            Err(e) => {
                eprintln!("WARN exec_compare {algo}: {e}");
                continue;
            }
        };
        let sim = run_solve(&g, &p, &topo, ExecBackend::Sim, 0.05, 40, 0.0);
        let thr = run_solve(&g, &p, &topo, ExecBackend::Threads, 0.05, 40, 0.0);
        match (sim, thr) {
            (Ok((ss, cs)), Ok((st, ct))) => {
                let agree = cs
                    .residual_norms
                    .iter()
                    .zip(&ct.residual_norms)
                    .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1.0));
                t.row(vec![
                    algo.to_string(),
                    format!("{:.4}", ss.time_per_iter * 1e3),
                    format!("{:.4}", st.time_per_iter * 1e3),
                    format!("{:.3}", st.wall_secs),
                    format!("{:.2e}", ss.final_residual),
                    agree.to_string(),
                ]);
            }
            (Err(e), _) | (_, Err(e)) => eprintln!("WARN exec_compare {algo}: {e}"),
        }
    }
    t
}

/// **Overlap study**: the nonblocking `Comm` path on a twospeed
/// halo-heavy instance — sim-priced seconds per iteration with the halo
/// exchange blocking vs overlapped with the interior SpMV, for the
/// classic and pipelined CG variants, plus the hidden-communication and
/// overlap-efficiency columns the harness reports. The `identical`
/// column confirms overlap on/off residual trajectories agree bit for
/// bit (the engine's contract).
pub fn exec_overlap(scale: BenchScale) -> Table {
    use crate::coordinator::run_solve_opts;
    use crate::exec::{CgVariant, SolveOpts};
    use crate::harness::TopoPreset;
    let (name, g) = instance(Family::Rdg2d, scale.n2d, SEED);
    let k = (scale.k / 2).max(6);
    let topo = TopoPreset::TwoSpeed.build(k);
    let mut t = Table::new(vec![
        "algo", "cg", "off_t/iter(ms)", "on_t/iter(ms)", "speedup", "hidden(ms)", "ovEff",
        "identical",
    ]);
    for algo in ["geoKM", "zSFC"] {
        let p = match run_one(&name, &g, &topo, algo, EPS, SEED) {
            Ok((_, p)) => p,
            Err(e) => {
                eprintln!("WARN exec_overlap {algo}: {e}");
                continue;
            }
        };
        for variant in [CgVariant::Classic, CgVariant::Pipelined] {
            let off = SolveOpts { overlap: false, variant, ..SolveOpts::default() };
            let on = SolveOpts { overlap: true, variant, ..SolveOpts::default() };
            let run = |o| run_solve_opts(&g, &p, &topo, ExecBackend::Sim, 0.05, 40, 0.0, o);
            match (run(off), run(on)) {
                (Ok((so, co)), Ok((sn, cn))) => {
                    t.row(vec![
                        algo.to_string(),
                        variant.name().to_string(),
                        format!("{:.4}", so.time_per_iter * 1e3),
                        format!("{:.4}", sn.time_per_iter * 1e3),
                        format!("{:.3}", so.time_per_iter / sn.time_per_iter),
                        format!("{:.4}", sn.comm_hidden_secs * 1e3),
                        format!("{:.4}", sn.overlap_efficiency),
                        (co.residual_norms == cn.residual_norms).to_string(),
                    ]);
                }
                (Err(e), _) | (_, Err(e)) => eprintln!("WARN exec_overlap {algo}: {e}"),
            }
        }
    }
    t
}

/// Warmup + 5 samples of one SpMV path; returns the median seconds.
fn sample_spmv(y: &mut [f32], mut f: impl FnMut(&mut [f32])) -> f64 {
    f(y);
    let times: Vec<f64> = (0..5)
        .map(|_| {
            let t = crate::util::timer::Timer::start();
            f(y);
            t.secs()
        })
        .collect();
    crate::util::stats::median(&times)
}

/// **SpMV hot path**: the sequential whole-matrix loop vs the chunked
/// job-queue path vs per-block execution (sequential block loop, halo
/// blocks over the job queue, and the thread-per-PU engine).
pub fn exec_spmv(scale: BenchScale) -> Table {
    use crate::coordinator::jobqueue::default_workers;
    use crate::exec::VirtualCluster;
    use crate::solver::cg::SpmvBackend;
    use crate::solver::spmv::{par_spmv_ell_into, spmv_ell_into};
    use crate::solver::{DistributedMatrix, HaloMatrix};

    let (name, g) = instance(Family::Rdg2d, scale.n2d * 4, SEED);
    let ell = EllMatrix::from_graph(&g, 0.05);
    let topo = Topology::homogeneous(scale.k, 1.0, 2.0);
    let targets = vec![g.n() as f64 / scale.k as f64; scale.k];
    let ctx = Ctx { graph: &g, targets: &targets, topo: &topo, epsilon: EPS, seed: SEED };
    let part = by_name("geoKM").unwrap().partition(&ctx).unwrap();
    let workers = default_workers();

    let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.13).sin()).collect();
    let mut y = vec![0.0f32; ell.n];

    let t_seq = sample_spmv(&mut y, |y| spmv_ell_into(&ell, &x, y));
    let t_par = sample_spmv(&mut y, |y| par_spmv_ell_into(&ell, &x, y, workers));
    let mut dist = DistributedMatrix::new(&ell, &part);
    let t_dist = sample_spmv(&mut y, |y| dist.spmv(&x, y).unwrap());
    let halo = HaloMatrix::new(&ell, &part);
    let t_halo = sample_spmv(&mut y, |y| halo.par_spmv(&x, y, workers));
    let vc = VirtualCluster::homogeneous(&ell, &part).unwrap();
    let t_vc = sample_spmv(&mut y, |y| vc.spmv(ExecBackend::Threads, &x, y).unwrap());

    let mut t = Table::new(vec!["path", "median(ms)", "speedup_vs_seq"]);
    for (path, secs) in [
        ("seq_whole", t_seq),
        ("par_jobqueue", t_par),
        ("seq_block_loop", t_dist),
        ("halo_par_blocks", t_halo),
        ("vc_threads", t_vc),
    ] {
        t.row(vec![
            path.to_string(),
            format!("{:.4}", secs * 1e3),
            format!("{:.2}", t_seq / secs.max(1e-12)),
        ]);
    }
    println!("[exec_spmv on {name}: n={} w={} k={} workers={workers}]", ell.n, ell.w, scale.k);
    t
}

/// Micro-bench helper: time one partitioner on one instance (used by the
/// `micro` bench target for §Perf tracking).
pub fn time_algo(family: Family, n: usize, k: usize, algo: &str) -> (f64, f64) {
    let (name, g) = instance(family, n, SEED);
    let topo = Topology::homogeneous(k, 1.0, 2.0);
    let (r, _) = run_one(&name, &g, &topo, algo, EPS, SEED).unwrap();
    (r.time_partition, r.cut)
}

/// Sanity-check a partitioner exists before grids reference it.
pub fn assert_algos_exist() {
    for a in ALL_NAMES {
        assert!(by_name(a).is_some());
    }
}

/// Heterogeneity-benefit headline: simulated iteration time with
/// Algorithm-1 targets vs uniform targets on a TOPO1 system (quantifies
/// the motivation of the paper: LDHT-aware distribution is faster).
pub fn ldht_benefit(scale: BenchScale) -> Table {
    let (name, g) = instance(Family::Rdg2d, scale.n2d, SEED);
    let ell = EllMatrix::from_graph(&g, 0.05);
    let mut sim = ClusterSim::default();
    sim.calibrate(&ell);
    let k = scale.k;
    let mut t = Table::new(vec!["topology", "targets", "simCG_t/iter(ms)", "ldht_objective"]);
    for &(s, m) in &TABLE3_STEPS[2..] {
        let fast = Pu { speed: s, memory: m };
        let topo = topo1(Topo1Spec { k, num_fast: k / 6, fast });
        // Algorithm-1 targets.
        let (r1, p1) = run_one(&name, &g, &topo, "geoKM", EPS, SEED).unwrap();
        let rep1 = sim.iteration(&g, &p1, &topo, ell.w);
        // Uniform targets (heterogeneity-oblivious baseline).
        let uni = Topology::homogeneous(k, 1.0, 2.0);
        let ctx_targets: Vec<f64> = vec![g.n() as f64 / k as f64; k];
        let ctx = Ctx { graph: &g, targets: &ctx_targets, topo: &uni, epsilon: EPS, seed: SEED };
        let p2 = by_name("geoKM").unwrap().partition(&ctx).unwrap();
        let rep2 = sim.iteration(&g, &p2, &topo, ell.w);
        let m2 = metrics(&g, &p2, &ctx_targets);
        t.row(vec![
            topo.label.clone(),
            "alg1".into(),
            format!("{:.4}", rep1.time_per_iter * 1e3),
            format!("{:.3}", r1.ldht_objective),
        ]);
        let speeds: Vec<f64> = topo.pus.iter().map(|p| p.speed).collect();
        t.row(vec![
            topo.label.clone(),
            "uniform".into(),
            format!("{:.4}", rep2.time_per_iter * 1e3),
            format!("{:.3}", m2.ldht_objective(&speeds)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchScale {
        BenchScale { n2d: 1200, n3d: 800, k: 12, sweep: 1 }
    }

    #[test]
    fn table3_matches_paper_within_10pct() {
        let t = table3();
        assert_eq!(t.rows.len(), 5);
        for row in &t.rows {
            let got8: f64 = row[3].parse().unwrap();
            let want8: f64 = row[4].parse().unwrap();
            let got16: f64 = row[5].parse().unwrap();
            let want16: f64 = row[6].parse().unwrap();
            assert!((got8 - want8).abs() / want8 < 0.1, "{row:?}");
            assert!((got16 - want16).abs() / want16 < 0.1, "{row:?}");
        }
    }

    #[test]
    fn fig2_topology_grid_is_16() {
        let topos = fig2_topologies(96);
        assert_eq!(topos.len(), 16);
        // Labels unique.
        let mut labels: Vec<&str> = topos.iter().map(|t| t.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 16);
    }

    #[test]
    fn fig1_runs_tiny() {
        let t = fig1(tiny());
        assert_eq!(t.rows.len(), 6);
        // Hierarchical cut within 2x of flat on every instance.
        for row in &t.rows {
            let rel: f64 = row[2].parse().unwrap();
            assert!(rel > 0.4 && rel < 2.5, "{row:?}");
        }
    }

    #[test]
    fn fig5_runs_tiny() {
        let t = fig5(tiny());
        assert!(!t.rows.is_empty());
        // Sim times positive.
        for row in &t.rows {
            let ms: f64 = row[4].parse().unwrap();
            assert!(ms > 0.0);
        }
    }

    #[test]
    fn exec_compare_backends_agree() {
        let t = exec_compare(tiny());
        assert!(!t.rows.is_empty());
        for row in &t.rows {
            assert_eq!(row[5], "true", "backends disagree: {row:?}");
            let sim_ms: f64 = row[1].parse().unwrap();
            assert!(sim_ms > 0.0);
        }
    }

    #[test]
    fn ldht_benefit_favors_alg1() {
        let t = ldht_benefit(tiny());
        // For each topology pair (alg1, uniform): alg1's objective must
        // be no worse.
        for pair in t.rows.chunks(2) {
            let o1: f64 = pair[0][3].parse().unwrap();
            let o2: f64 = pair[1][3].parse().unwrap();
            assert!(o1 <= o2 * 1.1, "alg1 {o1} vs uniform {o2}");
        }
    }
}
