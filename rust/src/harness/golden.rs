//! Golden-baseline regression gate.
//!
//! A golden file (`rust/tests/golden/<matrix>.json`) pins the partition
//! quality of a small deterministic scenario matrix: per scenario id, the
//! cut, max communication volume, and LDHT objective. `cargo test`
//! re-runs the matrix and fails when any metric *regresses* (grows)
//! beyond the file's tolerances — the gate that keeps partitioner quality
//! from rotting silently.
//!
//! Lifecycle:
//! - a fresh file carries `"bootstrap": true` and no runs; the first test
//!   run fills it from the current code and flips bootstrap off;
//! - `HETPART_UPDATE_GOLDEN=1 cargo test --test golden_baselines`
//!   refreshes the recorded values after an *intentional* quality change
//!   (commit the rewritten file with the change that caused it);
//! - improvements beyond tolerance don't fail the gate but are reported
//!   as stale-baseline notes, so refreshed files keep headroom honest.

use super::runner::ScenarioResult;
use crate::util::json::{obj, Json};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Relative tolerances per gated metric (0.05 = +5% allowed).
#[derive(Debug, Clone, Copy)]
pub struct Tolerances {
    /// Relative tolerance on the edge cut.
    pub cut: f64,
    /// Relative tolerance on the max communication volume.
    pub max_comm_volume: f64,
    /// Relative tolerance on the LDHT objective.
    pub ldht_objective: f64,
}

impl Default for Tolerances {
    fn default() -> Self {
        // The matrix is deterministic, so these bound real quality drift,
        // not run-to-run noise; volume tolerance is looser because a
        // single boundary vertex moves it by a whole unit on small
        // instances.
        Tolerances { cut: 0.02, max_comm_volume: 0.05, ldht_objective: 0.02 }
    }
}

/// The gated metrics of one scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GoldenMetrics {
    /// Recorded edge cut.
    pub cut: f64,
    /// Recorded max per-block communication volume.
    pub max_comm_volume: f64,
    /// Recorded LDHT objective.
    pub ldht_objective: f64,
}

/// A parsed golden-baseline file.
#[derive(Debug, Clone)]
pub struct GoldenFile {
    /// Matrix name this baseline pins (`smoke`, ...).
    pub matrix: String,
    /// True until the first run records real values.
    pub bootstrap: bool,
    /// Per-metric relative tolerances.
    pub tolerances: Tolerances,
    /// (scenario id, metrics) in recorded order.
    pub runs: Vec<(String, GoldenMetrics)>,
}

impl GoldenFile {
    /// An empty bootstrap-mode file for a matrix.
    pub fn bootstrap(matrix: &str) -> GoldenFile {
        GoldenFile {
            matrix: matrix.to_string(),
            bootstrap: true,
            tolerances: Tolerances::default(),
            runs: Vec::new(),
        }
    }

    /// Capture current results as the new baseline (keeps tolerances).
    pub fn from_results(&self, results: &[ScenarioResult]) -> GoldenFile {
        GoldenFile {
            matrix: self.matrix.clone(),
            bootstrap: false,
            tolerances: self.tolerances,
            runs: results
                .iter()
                .map(|r| {
                    (
                        r.scenario.id(),
                        GoldenMetrics {
                            cut: r.cut,
                            max_comm_volume: r.max_comm_volume,
                            ldht_objective: r.ldht_objective,
                        },
                    )
                })
                .collect(),
        }
    }

    /// Parse a golden file from disk.
    pub fn load(path: &Path) -> Result<GoldenFile> {
        let txt = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&txt).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let get_f64 = |v: &Json, key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("{}: missing number '{key}'", path.display()))
        };
        let matrix = j
            .get("matrix")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{}: missing 'matrix'", path.display()))?
            .to_string();
        let bootstrap = j.get("bootstrap").and_then(Json::as_bool).unwrap_or(false);
        let d = Tolerances::default();
        // A field absent from the tolerances object falls back to the
        // default; a field *present* but malformed (string, typo'd value)
        // is a hard error — a gate must never silently run looser than
        // its file reads.
        let opt_f64 = |v: &Json, key: &str| -> Result<Option<f64>> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => x.as_f64().map(Some).ok_or_else(|| {
                    anyhow!("{}: tolerance '{key}' is not a number", path.display())
                }),
            }
        };
        let tolerances = match j.get("tolerances") {
            Some(t) => Tolerances {
                cut: opt_f64(t, "cut")?.unwrap_or(d.cut),
                max_comm_volume: opt_f64(t, "max_comm_volume")?.unwrap_or(d.max_comm_volume),
                ldht_objective: opt_f64(t, "ldht_objective")?.unwrap_or(d.ldht_objective),
            },
            None => d,
        };
        let mut runs = Vec::new();
        if let Some(kv) = j.get("runs").and_then(Json::as_obj) {
            for (id, m) in kv {
                runs.push((
                    id.clone(),
                    GoldenMetrics {
                        cut: get_f64(m, "cut")?,
                        max_comm_volume: get_f64(m, "max_comm_volume")?,
                        ldht_objective: get_f64(m, "ldht_objective")?,
                    },
                ));
            }
        }
        Ok(GoldenFile { matrix, bootstrap, tolerances, runs })
    }

    /// Render as the on-disk JSON document.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("matrix", Json::Str(self.matrix.clone())),
            ("bootstrap", Json::Bool(self.bootstrap)),
            (
                "tolerances",
                obj(vec![
                    ("cut", Json::Num(self.tolerances.cut)),
                    ("max_comm_volume", Json::Num(self.tolerances.max_comm_volume)),
                    ("ldht_objective", Json::Num(self.tolerances.ldht_objective)),
                ]),
            ),
            (
                "runs",
                Json::Obj(
                    self.runs
                        .iter()
                        .map(|(id, m)| {
                            (
                                id.clone(),
                                obj(vec![
                                    ("cut", Json::Num(m.cut)),
                                    ("max_comm_volume", Json::Num(m.max_comm_volume)),
                                    ("ldht_objective", Json::Num(m.ldht_objective)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the file (creating parent directories as needed).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().render())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

/// Outcome of comparing a run against a baseline: hard failures
/// (regressions, coverage drift) and informational notes (improvements
/// beyond tolerance — the baseline is stale but nothing is broken).
#[derive(Debug, Clone, Default)]
pub struct GoldenReport {
    /// Hard failures: regressions and coverage drift.
    pub violations: Vec<String>,
    /// Informational notes: improvements beyond tolerance (stale baseline).
    pub notes: Vec<String>,
}

/// Compare current results against the baseline.
pub fn compare(baseline: &GoldenFile, results: &[ScenarioResult]) -> GoldenReport {
    let mut report = GoldenReport::default();
    let tol = baseline.tolerances;
    for (id, want) in &baseline.runs {
        let Some(got) = results.iter().find(|r| &r.scenario.id() == id) else {
            report
                .violations
                .push(format!("{id}: in baseline but missing from the current run"));
            continue;
        };
        let mut check = |metric: &str, got: f64, want: f64, tol: f64| {
            if want <= 0.0 {
                return; // degenerate baseline value; nothing to gate
            }
            let rel = got / want - 1.0;
            if rel > tol {
                report.violations.push(format!(
                    "{id}: {metric} regressed {got:.4} vs baseline {want:.4} (+{:.1}% > {:.1}%)",
                    rel * 100.0,
                    tol * 100.0
                ));
            } else if rel < -tol {
                report.notes.push(format!(
                    "{id}: {metric} improved {got:.4} vs baseline {want:.4} ({:.1}%) — refresh goldens",
                    rel * 100.0
                ));
            }
        };
        check("cut", got.cut, want.cut, tol.cut);
        check(
            "max_comm_volume",
            got.max_comm_volume,
            want.max_comm_volume,
            tol.max_comm_volume,
        );
        check("ldht_objective", got.ldht_objective, want.ldht_objective, tol.ldht_objective);
    }
    for r in results {
        let id = r.scenario.id();
        if !baseline.runs.iter().any(|(b, _)| *b == id) {
            report.violations.push(format!(
                "{id}: ran but absent from baseline — refresh goldens to extend coverage"
            ));
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::Family;
    use crate::harness::scenario::{Scenario, TopoPreset};

    fn result(id_algo: &str, cut: f64, vol: f64, obj: f64) -> ScenarioResult {
        ScenarioResult {
            scenario: Scenario {
                family: Family::Tri2d,
                n: 100,
                k: 4,
                topo: TopoPreset::Uniform,
                algo: id_algo.to_string(),
                epsilon: 0.03,
                seed: 1,
                solve_iters: 0,
                dynamic: crate::repart::DynamicKind::None,
                epochs: 0,
                overlap: false,
                layout: crate::solver::SpmvLayout::Ell,
                part_backend: None,
                part_ranks: 0,
                serve: None,
                app: None,
                net: crate::exec::NetKind::Flat,
                scale: None,
            },
            n: 100,
            m: 180,
            cut,
            max_comm_volume: vol,
            total_comm_volume: vol * 3.0,
            imbalance: 0.01,
            ldht_objective: obj,
            ldht_ratio: 1.02,
            time_partition: 0.001,
            sim_time_per_iter: None,
            final_residual: None,
            comm_hidden_secs: None,
            overlap_efficiency: None,
            part_secs: None,
            dynamic: None,
            serve: None,
            app: None,
            bottleneck_volume: None,
            scale: None,
        }
    }

    fn baseline_for(results: &[ScenarioResult]) -> GoldenFile {
        GoldenFile::bootstrap("test").from_results(results)
    }

    #[test]
    fn identical_run_passes() {
        let rs = vec![result("a", 100.0, 20.0, 30.0), result("b", 50.0, 10.0, 28.0)];
        let base = baseline_for(&rs);
        assert!(!base.bootstrap);
        let rep = compare(&base, &rs);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert!(rep.notes.is_empty(), "{:?}", rep.notes);
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let rs = vec![result("a", 100.0, 20.0, 30.0)];
        let base = baseline_for(&rs);
        // +10% cut with 2% tolerance → violation.
        let bad = vec![result("a", 110.0, 20.0, 30.0)];
        let rep = compare(&base, &bad);
        assert_eq!(rep.violations.len(), 1);
        assert!(rep.violations[0].contains("cut regressed"), "{}", rep.violations[0]);
    }

    #[test]
    fn regression_within_tolerance_passes() {
        let rs = vec![result("a", 100.0, 20.0, 30.0)];
        let base = baseline_for(&rs);
        let ok = vec![result("a", 101.5, 20.9, 30.5)];
        let rep = compare(&base, &ok);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    }

    #[test]
    fn volume_regression_detected() {
        let rs = vec![result("a", 100.0, 20.0, 30.0)];
        let base = baseline_for(&rs);
        let bad = vec![result("a", 100.0, 24.0, 30.0)];
        let rep = compare(&base, &bad);
        assert_eq!(rep.violations.len(), 1);
        assert!(rep.violations[0].contains("max_comm_volume"), "{}", rep.violations[0]);
    }

    #[test]
    fn improvement_is_note_not_violation() {
        let rs = vec![result("a", 100.0, 20.0, 30.0)];
        let base = baseline_for(&rs);
        let better = vec![result("a", 80.0, 20.0, 30.0)];
        let rep = compare(&base, &better);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.notes.len(), 1);
        assert!(rep.notes[0].contains("improved"));
    }

    #[test]
    fn coverage_drift_fails_both_ways() {
        let rs = vec![result("a", 100.0, 20.0, 30.0), result("b", 50.0, 10.0, 28.0)];
        let base = baseline_for(&rs);
        // Missing scenario.
        let rep = compare(&base, &rs[..1]);
        assert!(rep.violations.iter().any(|v| v.contains("missing from the current run")));
        // Extra scenario.
        let mut extra = rs.clone();
        extra.push(result("c", 10.0, 5.0, 9.0));
        let rep = compare(&base, &extra);
        assert!(rep.violations.iter().any(|v| v.contains("absent from baseline")));
    }

    #[test]
    fn json_round_trip_via_tempfile() {
        let rs = vec![result("a", 100.25, 20.5, 30.125)];
        let base = baseline_for(&rs);
        let dir = std::env::temp_dir().join("hetpart_golden_test");
        let path = dir.join("roundtrip.json");
        base.save(&path).unwrap();
        let back = GoldenFile::load(&path).unwrap();
        assert_eq!(back.matrix, "test");
        assert!(!back.bootstrap);
        assert_eq!(back.runs.len(), 1);
        assert_eq!(back.runs[0].1, base.runs[0].1);
        assert!((back.tolerances.cut - base.tolerances.cut).abs() < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_tolerance_is_a_hard_error() {
        let dir = std::env::temp_dir().join("hetpart_golden_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_tol.json");
        std::fs::write(
            &path,
            r#"{"matrix": "t", "tolerances": {"cut": "0.005"}, "runs": {}}"#,
        )
        .unwrap();
        let err = GoldenFile::load(&path).unwrap_err().to_string();
        assert!(err.contains("tolerance 'cut'"), "{err}");
        // A missing field still falls back to the default.
        std::fs::write(&path, r#"{"matrix": "t", "tolerances": {"cut": 0.01}, "runs": {}}"#)
            .unwrap();
        let f = GoldenFile::load(&path).unwrap();
        assert!((f.tolerances.cut - 0.01).abs() < 1e-12);
        let d = Tolerances::default();
        assert!((f.tolerances.max_comm_volume - d.max_comm_volume).abs() < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bootstrap_file_parses() {
        let f = GoldenFile::bootstrap("smoke");
        let txt = f.to_json().render();
        let dir = std::env::temp_dir().join("hetpart_golden_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bootstrap.json");
        std::fs::write(&path, &txt).unwrap();
        let back = GoldenFile::load(&path).unwrap();
        assert!(back.bootstrap);
        assert!(back.runs.is_empty());
        std::fs::remove_file(&path).ok();
    }
}
