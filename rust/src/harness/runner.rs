//! Scenario-matrix runner: fan scenarios out over the leader/worker job
//! queue, collect structured results, and persist artifacts.
//!
//! Artifacts per matrix run (under `<out>/<matrix>/`):
//! - `runs.csv` — one row per scenario (the raw sweep data);
//! - `runs/<scenario-id>.json` — one self-describing JSON per run;
//! - `summary.csv` / `summary.json` — per-partitioner geometric means of
//!   cut, max communication volume, and LDHT ratio (achieved objective /
//!   Algorithm-1 optimum), plus cut and volume relative to geoKM on the
//!   same (graph, topology) cell, as the paper reports (Figs. 2–4).

use super::scenario::{AppSpec, ScaleSpec, Scenario, ServeSpec, SCALE_NODE_RANKS};
use crate::apps::{by_name as app_by_name, run_app, AppConfig};
use crate::coordinator::serve::{run_serve, ServeConfig, Tenant};
use crate::coordinator::{instance, run_jobs, run_one, run_solve_opts};
use crate::exec::{CollectiveModel, CostModel, ExecBackend, NetModel, SolveOpts};
use crate::gen::Family;
use crate::graph::{Csr, QuotientGraph};
use crate::repart::{
    repartitioner_for_trace, run_trace, DynamicKind, EpochTrace, TraceOptions,
};
use crate::util::json::{obj, Json};
use crate::util::stats::geomean;
use crate::util::table::Table;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One completed scenario: the full description plus every measured
/// quantity the artifacts and golden gates consume.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// The scenario that produced this result.
    pub scenario: Scenario,
    /// Actual generated graph size (generators hit ~n approximately).
    pub n: usize,
    /// Generated edge count.
    pub m: usize,
    /// Edge cut of the partition.
    pub cut: f64,
    /// Largest per-block communication volume.
    pub max_comm_volume: f64,
    /// Total communication volume over all blocks.
    pub total_comm_volume: f64,
    /// Relative imbalance vs the Algorithm-1 targets.
    pub imbalance: f64,
    /// Achieved LDHT objective `max_i w(b_i)/c_s(p_i)`.
    pub ldht_objective: f64,
    /// Achieved LDHT objective / Algorithm-1 optimum (≥ 1; 1 = optimal).
    pub ldht_ratio: f64,
    /// Partitioning seconds.
    pub time_partition: f64,
    /// Simulated CG seconds/iteration through the virtual-cluster `sim`
    /// backend (None when `solve_iters == 0`).
    pub sim_time_per_iter: Option<f64>,
    /// Final CG residual after `solve_iters` iterations (deterministic).
    pub final_residual: Option<f64>,
    /// Priced communication seconds hidden behind overlapped compute,
    /// summed over ranks (None without a solve; 0 with `overlap: off`).
    pub comm_hidden_secs: Option<f64>,
    /// Hidden / (hidden + exposed) priced communication (None without a
    /// solve; 0 with `overlap: off`).
    pub overlap_efficiency: Option<f64>,
    /// Partitioning makespan through the virtual cluster — priced
    /// (`sim`) or measured (`threads`) bottleneck-rank seconds — for
    /// scenarios on the `part_backend` axis (None for the sequential
    /// path, whose wall-clock is `time_partition`).
    pub part_secs: Option<f64>,
    /// Multi-epoch aggregates for dynamic scenarios (None for static).
    pub dynamic: Option<DynamicSummary>,
    /// Serving-trace aggregates for scenarios on the serve axis (None
    /// otherwise). Deterministic: the axis runs on the virtual-time
    /// backend.
    pub serve: Option<ServeSummary>,
    /// Application-kernel aggregates for scenarios on the app axis (None
    /// otherwise — the historical CG-only pipeline).
    pub app: Option<AppSummary>,
    /// Bytes over the most-congested link under the scenario's topology
    /// (`mapping::bottleneck_volume` of the partition's quotient graph
    /// with blocks placed identically on PUs). None for dynamic
    /// scenarios, whose quotient changes every epoch.
    pub bottleneck_volume: Option<f64>,
    /// Closed-form scale-axis pricing (None off the scale axis).
    pub scale: Option<ScaleSummary>,
}

/// Analytic pricing of one CG-style iteration at the scale axis's
/// virtual rank count — no per-rank state, so it reaches 16384 ranks
/// and beyond in microseconds.
#[derive(Debug, Clone)]
pub struct ScaleSummary {
    /// Virtual rank count the iteration was priced at.
    pub ranks: usize,
    /// Collective schedule that was priced (`flat`/`hier`).
    pub sched: &'static str,
    /// Network model name (e.g. `fattree16`, `torus128x128`).
    pub net: String,
    /// Priced seconds for one iteration under the requested schedule.
    pub iter_secs: f64,
    /// Priced seconds for the same iteration under the flat schedule on
    /// the same network (the baseline for the `scaleVsFlat` ratio).
    pub flat_iter_secs: f64,
}

/// Aggregates of one irregular-kernel run (`apps::run_app`) — the
/// columns the harness surfaces for `--matrix apps` scenarios.
#[derive(Debug, Clone)]
pub struct AppSummary {
    /// Kernel name (`bfs`/`sssp`/`pagerank`).
    pub app: String,
    /// Message-layer mode (`agg`/`direct`).
    pub agg_mode: &'static str,
    /// Engine backend the kernel ran on (`sim`/`threads`).
    pub backend: &'static str,
    /// Virtual-cluster rank count.
    pub ranks: usize,
    /// Supersteps the kernel executed.
    pub iterations: usize,
    /// `alltoallv` exchange rounds through the aggregation layer.
    pub flushes: usize,
    /// Total off-rank bytes shipped through the aggregation layer.
    pub agg_bytes: usize,
    /// Bytes over the most-congested ordered rank pair (the
    /// bottleneck-link metric).
    pub max_link_bytes: usize,
    /// Kernel makespan: slowest rank's compute + comm seconds (priced on
    /// `sim`, measured on `threads`).
    pub app_secs: f64,
    /// Result digest — bit-identical across modes/backends/rank counts.
    pub digest: u64,
}

/// Aggregates of one serving trace (`coordinator::serve`) — the columns
/// the harness surfaces for `--matrix serve` scenarios.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    /// Requests the trace generator offered.
    pub offered: usize,
    /// Requests served to completion.
    pub completed: usize,
    /// Requests rejected at admission (bounded queue full).
    pub rejected: usize,
    /// Completed requests per (virtual) second.
    pub req_per_sec: f64,
    /// Median completion latency (ms).
    pub latency_p50_ms: f64,
    /// 95th-percentile completion latency (ms).
    pub latency_p95_ms: f64,
    /// 99th-percentile completion latency (ms).
    pub latency_p99_ms: f64,
    /// Fraction of completed requests whose partition was cache-served.
    pub cache_hit_rate: f64,
    /// Warm-started repartitions executed.
    pub warm_starts: usize,
    /// Mean migrated-weight fraction over warm repartitions.
    pub mean_migrated_frac: f64,
    /// Offered load in requests/second (the sweep's x-axis).
    pub offered_rate: f64,
    /// Completions per second of trace time (the sweep's y-axis; flat
    /// past the saturation knee while `latency_p99_ms` grows).
    pub goodput: f64,
}

/// Aggregates of a dynamic (multi-epoch) scenario. The per-epoch quality
/// fields of [`ScenarioResult`] hold the *final* epoch's values.
#[derive(Debug, Clone)]
pub struct DynamicSummary {
    /// Epochs the trace ran.
    pub epochs: usize,
    /// Total vertex weight migrated across epochs.
    pub migrated_weight: f64,
    /// Total words shipped through the `Comm` transport.
    pub migration_volume: usize,
    /// Weight a naive scratch repartition would have migrated.
    pub naive_migrated_weight: f64,
    /// Worst per-epoch LDHT objective relative to from-scratch.
    pub worst_obj_vs_scratch: f64,
}

/// Run one scenario against an already-generated instance.
pub fn run_scenario(s: &Scenario, graph_name: &str, g: &Csr) -> Result<ScenarioResult> {
    if s.dynamic != DynamicKind::None {
        anyhow::ensure!(
            s.part_backend.is_none(),
            "scenario {}: the part_backend axis applies to static scenarios only",
            s.id()
        );
        anyhow::ensure!(
            s.serve.is_none(),
            "scenario {}: the serve axis applies to static scenarios only",
            s.id()
        );
        anyhow::ensure!(
            s.app.is_none(),
            "scenario {}: the app axis applies to static scenarios only",
            s.id()
        );
        anyhow::ensure!(
            s.scale.is_none(),
            "scenario {}: the scale axis applies to static scenarios only",
            s.id()
        );
        return run_dynamic_scenario(s, g);
    }
    let topo = s.topology();
    // Partitioning path: sequential (the historical default) or on the
    // virtual cluster through partitioners::dist — the latter yields a
    // bit-identical partition plus the partSecs column.
    let mut part_secs = None;
    let (r, part) = match s.part_backend {
        None => run_one(graph_name, g, &topo, &s.algo, s.epsilon, s.seed)
            .with_context(|| format!("scenario {}", s.id()))?,
        Some(backend) => {
            let (r, part, report) = crate::coordinator::run_one_dist_net(
                graph_name,
                g,
                &topo,
                &s.algo,
                s.epsilon,
                s.seed,
                backend,
                s.part_ranks,
                s.net.model(s.part_ranks),
            )
            .with_context(|| format!("scenario {}", s.id()))?;
            part_secs = Some(report.part_secs());
            (r, part)
        }
    };
    // Bottleneck-link volume of the achieved partition: build the block
    // quotient and charge each inter-block volume to the link its
    // (identity-placed) endpoints share under the scenario's topology.
    let quotient = QuotientGraph::build(g, &part.assignment, s.k);
    let identity: Vec<u32> = (0..s.k as u32).collect();
    let bottleneck_volume =
        Some(crate::mapping::bottleneck_volume(&quotient, &topo, &identity));
    let ldht_ratio = if r.ldht_optimum > 0.0 {
        r.ldht_objective / r.ldht_optimum
    } else {
        f64::NAN
    };
    let (mut sim_time_per_iter, mut final_residual) = (None, None);
    let (mut comm_hidden_secs, mut overlap_efficiency) = (None, None);
    if s.solve_iters > 0 {
        let opts = SolveOpts {
            overlap: s.overlap,
            layout: s.layout,
            net: s.net.model(s.k),
            ..SolveOpts::default()
        };
        let (solve, _cg) =
            run_solve_opts(g, &part, &topo, ExecBackend::Sim, 0.05, s.solve_iters, 0.0, opts)
                .with_context(|| format!("solve for scenario {}", s.id()))?;
        sim_time_per_iter = Some(solve.time_per_iter);
        final_residual = Some(solve.final_residual as f64);
        comm_hidden_secs = Some(solve.comm_hidden_secs);
        overlap_efficiency = Some(solve.overlap_efficiency);
    }
    let serve = match &s.serve {
        None => None,
        Some(spec) => Some(
            run_serve_axis(s, spec).with_context(|| format!("serve axis for {}", s.id()))?,
        ),
    };
    let app = match &s.app {
        None => None,
        Some(spec) => Some(
            run_app_axis(spec, g, s.net.model(spec.ranks))
                .with_context(|| format!("app axis for {}", s.id()))?,
        ),
    };
    let scale = s.scale.as_ref().map(|spec| run_scale_axis(s, spec, g.n()));
    Ok(ScenarioResult {
        scenario: s.clone(),
        n: g.n(),
        m: g.m(),
        cut: r.cut,
        max_comm_volume: r.max_comm_volume,
        total_comm_volume: r.total_comm_volume,
        imbalance: r.imbalance,
        ldht_objective: r.ldht_objective,
        ldht_ratio,
        time_partition: r.time_partition,
        sim_time_per_iter,
        final_residual,
        comm_hidden_secs,
        overlap_efficiency,
        part_secs,
        dynamic: None,
        serve,
        app,
        bottleneck_volume,
        scale,
    })
}

/// Price one CG-style iteration at the scale axis's virtual rank count
/// through the analytic [`CollectiveModel`] — both the requested
/// schedule and the flat baseline on the same network, so the
/// `scaleVsFlat` ratio isolates the two-level schedule's effect. The
/// halo follows a 2-D strip decomposition of the generated instance:
/// each rank owns ~n/ranks vertices and exchanges a boundary that
/// scales with the local side length.
fn run_scale_axis(s: &Scenario, spec: &ScaleSpec, n: usize) -> ScaleSummary {
    let cost = CostModel::default();
    let net = s.net.model(spec.ranks);
    let flat = CollectiveModel::flat_schedule(cost, net);
    let model = if spec.hier {
        CollectiveModel::two_level(cost, net, spec.ranks, SCALE_NODE_RANKS)
    } else {
        flat
    };
    let local = (n / spec.ranks.max(1)).max(1) as f64;
    let halo_words = (local.sqrt().ceil() as usize).max(1);
    let neighbors = spec.ranks.saturating_sub(1).min(4);
    ScaleSummary {
        ranks: spec.ranks,
        sched: if spec.hier { "hier" } else { "flat" },
        net: net.name(),
        iter_secs: model.cg_iteration_secs(spec.ranks, neighbors, halo_words),
        flat_iter_secs: flat.cg_iteration_secs(spec.ranks, neighbors, halo_words),
    }
}

/// Run the scenario's irregular kernel over the generated instance on
/// the virtual cluster, reducing the report to the harness's app
/// columns. The kernel runs over plain row strips of the instance (the
/// partition under study is orthogonal: this axis measures the
/// *transport*, aggregated vs direct).
fn run_app_axis(spec: &AppSpec, g: &Csr, net: NetModel) -> Result<AppSummary> {
    let kernel =
        app_by_name(&spec.kernel).ok_or_else(|| anyhow!("unknown app kernel {}", spec.kernel))?;
    let cfg = AppConfig {
        backend: spec.backend,
        ranks: spec.ranks,
        mode: spec.agg,
        net,
        ..AppConfig::default()
    };
    let (_, rep) = run_app(g, kernel.as_ref(), &cfg)?;
    Ok(AppSummary {
        app: rep.app.clone(),
        agg_mode: rep.mode.name(),
        backend: rep.backend,
        ranks: rep.ranks,
        iterations: rep.iterations,
        flushes: rep.flushes,
        agg_bytes: rep.agg_bytes,
        max_link_bytes: rep.max_link_bytes(),
        app_secs: rep.app_secs(),
        digest: rep.digest,
    })
}

/// Replay the scenario's serving trace through the resident service on
/// the deterministic virtual-time backend, reducing the report to the
/// harness's serve columns.
fn run_serve_axis(s: &Scenario, spec: &ServeSpec) -> Result<ServeSummary> {
    let primary = Tenant {
        family: s.family,
        n: s.n,
        graph_seed: s.seed,
        preset: s.topo,
        k: s.k,
        algo: s.algo.clone(),
        epsilon: s.epsilon,
    };
    let mut cfg = ServeConfig::new(
        primary,
        spec.duration_secs,
        spec.arrival_rate,
        s.seed,
        ExecBackend::Sim,
    );
    cfg.servers = spec.servers;
    cfg.queue_cap = spec.queue_cap;
    let rep = run_serve(&cfg)?;
    Ok(ServeSummary {
        offered: rep.offered,
        completed: rep.completed,
        rejected: rep.rejected,
        req_per_sec: rep.req_per_sec,
        latency_p50_ms: rep.latency_p50_ms,
        latency_p95_ms: rep.latency_p95_ms,
        latency_p99_ms: rep.latency_p99_ms,
        cache_hit_rate: rep.cache_hit_rate,
        warm_starts: rep.warm_starts,
        mean_migrated_frac: rep.mean_migrated_frac,
        offered_rate: rep.offered_rate,
        goodput: rep.goodput,
    })
}

/// Run a multi-epoch (dynamic) scenario: `algo` names a repartitioner,
/// the trace follows the scenario's dynamic kind, and the recorded
/// quality metrics are the *final* epoch's (the state the system ends
/// in), with migration aggregated over the whole trace.
fn run_dynamic_scenario(s: &Scenario, g: &Csr) -> Result<ScenarioResult> {
    let opts = TraceOptions {
        scratch_algo: "geoKM".to_string(),
        backend: ExecBackend::Sim,
        nonblocking: s.overlap,
        epsilon: s.epsilon,
        seed: s.seed,
    };
    let rp = repartitioner_for_trace(&s.algo, &opts.scratch_algo)
        .ok_or_else(|| anyhow!("unknown repartitioner {}", s.algo))?;
    let trace = EpochTrace::new(g, s.topology(), s.dynamic, s.epochs.max(2), s.seed);
    let res = run_trace(&trace, rp.as_ref(), &opts)
        .with_context(|| format!("dynamic scenario {}", s.id()))?;
    let last = res.records.last().expect("trace has at least one epoch");
    let ldht_ratio = if last.ldht_optimum > 0.0 {
        last.ldht_objective / last.ldht_optimum
    } else {
        f64::NAN
    };
    Ok(ScenarioResult {
        scenario: s.clone(),
        n: g.n(),
        m: g.m(),
        cut: last.cut,
        max_comm_volume: last.max_comm_volume,
        total_comm_volume: last.total_comm_volume,
        imbalance: last.imbalance,
        ldht_objective: last.ldht_objective,
        ldht_ratio,
        time_partition: res.records.iter().map(|r| r.time_repartition).sum(),
        sim_time_per_iter: None,
        final_residual: None,
        comm_hidden_secs: None,
        overlap_efficiency: None,
        part_secs: None,
        dynamic: Some(DynamicSummary {
            epochs: res.records.len(),
            migrated_weight: res.total_migrated_weight(),
            migration_volume: res.total_migration_volume(),
            naive_migrated_weight: res.total_naive_migrated_weight(),
            worst_obj_vs_scratch: res.worst_obj_vs_scratch(),
        }),
        serve: None,
        app: None,
        bottleneck_volume: None,
        scale: None,
    })
}

/// Run a whole matrix over `workers` threads. Each unique (family, n,
/// seed) instance is generated once and shared read-only by all scenarios
/// that reference it. Failed scenarios come back as `Err` strings keyed
/// by scenario id; the rest of the matrix still completes.
pub fn run_matrix(
    scenarios: &[Scenario],
    workers: usize,
) -> (Vec<ScenarioResult>, Vec<(String, String)>) {
    // Dedup instances.
    let mut keys: Vec<(Family, usize, u64)> = Vec::new();
    let mut graph_of: Vec<usize> = Vec::with_capacity(scenarios.len());
    for s in scenarios {
        let key = (s.family, s.n, s.seed);
        let idx = match keys.iter().position(|k| *k == key) {
            Some(i) => i,
            None => {
                keys.push(key);
                keys.len() - 1
            }
        };
        graph_of.push(idx);
    }
    let graphs: Vec<(String, Csr)> = keys
        .iter()
        .map(|&(family, n, seed)| instance(family, n, seed))
        .collect();

    let jobs: Vec<usize> = (0..scenarios.len()).collect();
    let outcomes = run_jobs(jobs, workers, |&i| {
        let s = &scenarios[i];
        let (name, g) = &graphs[graph_of[i]];
        run_scenario(s, name, g).map_err(|e| format!("{e:#}"))
    });

    let mut ok = Vec::new();
    let mut failed = Vec::new();
    for (s, outcome) in scenarios.iter().zip(outcomes) {
        match outcome {
            Ok(r) => ok.push(r),
            Err(e) => failed.push((s.id(), e)),
        }
    }
    (ok, failed)
}

/// Per-partitioner aggregate over a matrix run.
#[derive(Debug, Clone)]
pub struct AlgoSummary {
    /// Partitioner (or repartitioner) name.
    pub algo: String,
    /// Scenarios aggregated.
    pub runs: usize,
    /// Geometric mean of the edge cut.
    pub gm_cut: f64,
    /// Geometric mean of the max communication volume.
    pub gm_max_comm_volume: f64,
    /// Geometric mean of the LDHT ratio (achieved / optimum).
    pub gm_ldht_ratio: f64,
    /// Geomean of cut relative to geoKM on the same (graph, topology)
    /// cell (NaN when no geoKM baseline ran).
    pub gm_rel_cut: f64,
    /// Like `gm_rel_cut`, for the max communication volume.
    pub gm_rel_max_comm_volume: f64,
}

/// Aggregate results per partitioner (first-seen order).
pub fn summarize(results: &[ScenarioResult]) -> Vec<AlgoSummary> {
    let mut algos: Vec<String> = Vec::new();
    for r in results {
        if !algos.contains(&r.scenario.algo) {
            algos.push(r.scenario.algo.clone());
        }
    }
    let cell = |r: &ScenarioResult| (r.scenario.family, r.scenario.n, r.scenario.topo, r.scenario.k);
    algos
        .iter()
        .map(|algo| {
            let mine: Vec<&ScenarioResult> =
                results.iter().filter(|r| &r.scenario.algo == algo).collect();
            let pos = |f: &dyn Fn(&ScenarioResult) -> f64| -> Vec<f64> {
                mine.iter().map(|r| f(r)).filter(|v| *v > 0.0).collect()
            };
            let gm = |xs: &[f64]| if xs.is_empty() { f64::NAN } else { geomean(xs) };
            // Relative to geoKM on the same cell.
            let mut rel_cut = Vec::new();
            let mut rel_vol = Vec::new();
            for r in &mine {
                if let Some(base) = results
                    .iter()
                    .find(|b| b.scenario.algo == "geoKM" && cell(b) == cell(r))
                {
                    if base.cut > 0.0 && r.cut > 0.0 {
                        rel_cut.push(r.cut / base.cut);
                    }
                    if base.max_comm_volume > 0.0 && r.max_comm_volume > 0.0 {
                        rel_vol.push(r.max_comm_volume / base.max_comm_volume);
                    }
                }
            }
            AlgoSummary {
                algo: algo.clone(),
                runs: mine.len(),
                gm_cut: gm(&pos(&|r| r.cut)),
                gm_max_comm_volume: gm(&pos(&|r| r.max_comm_volume)),
                gm_ldht_ratio: gm(&pos(&|r| r.ldht_ratio)),
                gm_rel_cut: gm(&rel_cut),
                gm_rel_max_comm_volume: gm(&rel_vol),
            }
        })
        .collect()
}

fn fmt_opt(v: Option<f64>, scale: f64) -> String {
    match v {
        Some(x) => format!("{:.6}", x * scale),
        None => "-".to_string(),
    }
}

/// The `runs.csv` table (also printed by the CLI with `--verbose`).
pub fn runs_table(results: &[ScenarioResult]) -> Table {
    let mut t = Table::new(vec![
        "id", "family", "n", "m", "k", "preset", "algo", "epsilon", "seed", "cut",
        "maxCommVol", "totalCommVol", "imbalance", "ldhtObj", "ldhtRatio", "timePart(s)",
        "partBackend", "partRanks", "partSecs(ms)", "simT/iter(ms)", "residual", "overlap",
        "layout", "commHidden(ms)", "ovEff", "dynamic", "epochs", "migWeight", "migW/naive",
        "objVsScratch", "reqs", "reqPerSec", "offeredRate", "goodput", "latP50(ms)",
        "latP95(ms)", "latP99(ms)", "cacheHit", "rejected", "app", "aggMode", "flushes", "aggBytes", "maxLinkBytes",
        "bottleneckVol", "appSecs(ms)", "net", "scaleRanks", "sched", "scaleIter(ms)",
        "scaleVsFlat",
    ]);
    for r in results {
        let s = &r.scenario;
        let (dynamic, epochs, mig_w, mig_vs_naive, obj_vs) = match &r.dynamic {
            None => (
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ),
            Some(d) => (
                s.dynamic.name().to_string(),
                d.epochs.to_string(),
                format!("{:.1}", d.migrated_weight),
                if d.naive_migrated_weight > 0.0 {
                    format!("{:.3}", d.migrated_weight / d.naive_migrated_weight)
                } else {
                    "-".to_string()
                },
                if d.worst_obj_vs_scratch.is_finite() {
                    format!("{:.4}", d.worst_obj_vs_scratch)
                } else {
                    "-".to_string()
                },
            ),
        };
        let (reqs, req_per_sec, offered_rate, goodput, lat_p50, lat_p95, lat_p99, cache_hit, rejected) =
            match &r.serve {
                None => (
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ),
                Some(v) => (
                    v.offered.to_string(),
                    format!("{:.1}", v.req_per_sec),
                    format!("{:.1}", v.offered_rate),
                    format!("{:.1}", v.goodput),
                    format!("{:.3}", v.latency_p50_ms),
                    format!("{:.3}", v.latency_p95_ms),
                    format!("{:.3}", v.latency_p99_ms),
                    format!("{:.3}", v.cache_hit_rate),
                    v.rejected.to_string(),
                ),
            };
        // The app column defaults to "cg": every historical scenario
        // exercises the partition through the CG/solve pipeline.
        let (app, agg_mode, flushes, agg_bytes, max_link, app_secs) = match &r.app {
            None => (
                "cg".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ),
            Some(a) => (
                a.app.clone(),
                a.agg_mode.to_string(),
                a.flushes.to_string(),
                a.agg_bytes.to_string(),
                a.max_link_bytes.to_string(),
                format!("{:.6}", a.app_secs * 1e3),
            ),
        };
        let (scale_ranks, sched, scale_iter, scale_vs_flat) = match &r.scale {
            None => (
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ),
            Some(sc) => (
                sc.ranks.to_string(),
                sc.sched.to_string(),
                format!("{:.6}", sc.iter_secs * 1e3),
                if sc.flat_iter_secs > 0.0 {
                    format!("{:.4}", sc.iter_secs / sc.flat_iter_secs)
                } else {
                    "-".to_string()
                },
            ),
        };
        t.row(vec![
            s.id(),
            s.family.name().to_string(),
            r.n.to_string(),
            r.m.to_string(),
            s.k.to_string(),
            s.topo.name().to_string(),
            s.algo.clone(),
            format!("{}", s.epsilon),
            s.seed.to_string(),
            format!("{:.3}", r.cut),
            format!("{:.3}", r.max_comm_volume),
            format!("{:.3}", r.total_comm_volume),
            format!("{:+.4}", r.imbalance),
            format!("{:.4}", r.ldht_objective),
            format!("{:.4}", r.ldht_ratio),
            format!("{:.4}", r.time_partition),
            match s.part_backend {
                Some(b) => b.name().to_string(),
                None => "-".to_string(),
            },
            if s.part_backend.is_some() {
                s.part_ranks.to_string()
            } else {
                "-".to_string()
            },
            fmt_opt(r.part_secs, 1e3),
            fmt_opt(r.sim_time_per_iter, 1e3),
            match r.final_residual {
                Some(x) => format!("{x:.3e}"),
                None => "-".to_string(),
            },
            if s.overlap { "on" } else { "off" }.to_string(),
            s.layout.name().to_string(),
            fmt_opt(r.comm_hidden_secs, 1e3),
            match r.overlap_efficiency {
                Some(x) => format!("{x:.4}"),
                None => "-".to_string(),
            },
            dynamic,
            epochs,
            mig_w,
            mig_vs_naive,
            obj_vs,
            reqs,
            req_per_sec,
            offered_rate,
            goodput,
            lat_p50,
            lat_p95,
            lat_p99,
            cache_hit,
            rejected,
            app,
            agg_mode,
            flushes,
            agg_bytes,
            max_link,
            fmt_opt(r.bottleneck_volume, 1.0),
            app_secs,
            s.net.name().to_string(),
            scale_ranks,
            sched,
            scale_iter,
            scale_vs_flat,
        ]);
    }
    t
}

/// The `summary.csv` table (printed by the CLI after every run).
pub fn summary_table(summaries: &[AlgoSummary]) -> Table {
    let mut t = Table::new(vec![
        "algo", "runs", "gm_cut", "gm_maxCommVol", "gm_ldhtRatio", "gm_relCut", "gm_relMaxVol",
    ]);
    let f = |v: f64| if v.is_finite() { format!("{v:.4}") } else { "-".to_string() };
    for s in summaries {
        t.row(vec![
            s.algo.clone(),
            s.runs.to_string(),
            f(s.gm_cut),
            f(s.gm_max_comm_volume),
            f(s.gm_ldht_ratio),
            f(s.gm_rel_cut),
            f(s.gm_rel_max_comm_volume),
        ]);
    }
    t
}

/// JSON document for one scenario result.
pub fn result_json(r: &ScenarioResult) -> Json {
    let s = &r.scenario;
    obj(vec![
        ("id", Json::Str(s.id())),
        ("family", Json::Str(s.family.name().to_string())),
        ("n_requested", Json::Num(s.n as f64)),
        ("n", Json::Num(r.n as f64)),
        ("m", Json::Num(r.m as f64)),
        ("k", Json::Num(s.k as f64)),
        ("preset", Json::Str(s.topo.name().to_string())),
        ("algo", Json::Str(s.algo.clone())),
        ("epsilon", Json::Num(s.epsilon)),
        ("seed", Json::Num(s.seed as f64)),
        ("net", Json::Str(s.net.name().to_string())),
        ("cut", Json::Num(r.cut)),
        ("max_comm_volume", Json::Num(r.max_comm_volume)),
        ("total_comm_volume", Json::Num(r.total_comm_volume)),
        ("imbalance", Json::Num(r.imbalance)),
        ("ldht_objective", Json::Num(r.ldht_objective)),
        ("ldht_ratio", Json::Num(r.ldht_ratio)),
        ("time_partition_s", Json::Num(r.time_partition)),
        (
            "part_backend",
            match s.part_backend {
                Some(b) => Json::Str(b.name().to_string()),
                None => Json::Null,
            },
        ),
        (
            "part_ranks",
            match s.part_backend {
                Some(_) => Json::Num(s.part_ranks as f64),
                None => Json::Null,
            },
        ),
        ("part_secs", r.part_secs.map(Json::Num).unwrap_or(Json::Null)),
        (
            "sim_time_per_iter_s",
            r.sim_time_per_iter.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "final_residual",
            r.final_residual.map(Json::Num).unwrap_or(Json::Null),
        ),
        ("overlap", Json::Bool(s.overlap)),
        ("layout", Json::Str(s.layout.name().to_string())),
        (
            "comm_hidden_secs",
            r.comm_hidden_secs.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "overlap_efficiency",
            r.overlap_efficiency.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "dynamic",
            match &r.dynamic {
                None => Json::Null,
                Some(d) => obj(vec![
                    ("kind", Json::Str(r.scenario.dynamic.name().to_string())),
                    ("epochs", Json::Num(d.epochs as f64)),
                    ("migrated_weight", Json::Num(d.migrated_weight)),
                    ("migration_volume", Json::Num(d.migration_volume as f64)),
                    (
                        "naive_migrated_weight",
                        Json::Num(d.naive_migrated_weight),
                    ),
                    (
                        "worst_obj_vs_scratch",
                        Json::Num(d.worst_obj_vs_scratch),
                    ),
                ]),
            },
        ),
        (
            "serve",
            match &r.serve {
                None => Json::Null,
                Some(v) => obj(vec![
                    ("offered", Json::Num(v.offered as f64)),
                    ("completed", Json::Num(v.completed as f64)),
                    ("rejected", Json::Num(v.rejected as f64)),
                    ("req_per_sec", Json::Num(v.req_per_sec)),
                    ("latency_p50_ms", Json::Num(v.latency_p50_ms)),
                    ("latency_p95_ms", Json::Num(v.latency_p95_ms)),
                    ("latency_p99_ms", Json::Num(v.latency_p99_ms)),
                    ("cache_hit_rate", Json::Num(v.cache_hit_rate)),
                    ("warm_starts", Json::Num(v.warm_starts as f64)),
                    ("mean_migrated_frac", Json::Num(v.mean_migrated_frac)),
                    ("offered_rate", Json::Num(v.offered_rate)),
                    ("goodput", Json::Num(v.goodput)),
                ]),
            },
        ),
        (
            "app",
            match &r.app {
                None => Json::Null,
                Some(a) => obj(vec![
                    ("kernel", Json::Str(a.app.clone())),
                    ("agg_mode", Json::Str(a.agg_mode.to_string())),
                    ("backend", Json::Str(a.backend.to_string())),
                    ("ranks", Json::Num(a.ranks as f64)),
                    ("iterations", Json::Num(a.iterations as f64)),
                    ("flushes", Json::Num(a.flushes as f64)),
                    ("agg_bytes", Json::Num(a.agg_bytes as f64)),
                    ("max_link_bytes", Json::Num(a.max_link_bytes as f64)),
                    ("app_secs", Json::Num(a.app_secs)),
                    // u64 digests don't fit f64 exactly; hex keeps bits.
                    ("digest", Json::Str(format!("{:016x}", a.digest))),
                ]),
            },
        ),
        (
            "bottleneck_volume",
            r.bottleneck_volume.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "scale",
            match &r.scale {
                None => Json::Null,
                Some(sc) => obj(vec![
                    ("ranks", Json::Num(sc.ranks as f64)),
                    ("sched", Json::Str(sc.sched.to_string())),
                    ("net", Json::Str(sc.net.clone())),
                    ("iter_secs", Json::Num(sc.iter_secs)),
                    ("flat_iter_secs", Json::Num(sc.flat_iter_secs)),
                    (
                        "vs_flat",
                        if sc.flat_iter_secs > 0.0 {
                            Json::Num(sc.iter_secs / sc.flat_iter_secs)
                        } else {
                            Json::Null
                        },
                    ),
                ]),
            },
        ),
    ])
}

/// Persist all artifacts for a matrix run; returns the output directory.
pub fn write_artifacts(
    out_root: &str,
    matrix: &str,
    results: &[ScenarioResult],
    failed: &[(String, String)],
) -> Result<PathBuf> {
    let dir = Path::new(out_root).join(matrix);
    let runs_dir = dir.join("runs");
    std::fs::create_dir_all(&runs_dir)
        .with_context(|| format!("creating {}", runs_dir.display()))?;

    std::fs::write(dir.join("runs.csv"), runs_table(results).to_csv())?;
    for r in results {
        std::fs::write(
            runs_dir.join(format!("{}.json", r.scenario.id())),
            result_json(r).render(),
        )?;
    }

    let summaries = summarize(results);
    std::fs::write(dir.join("summary.csv"), summary_table(&summaries).to_csv())?;
    let summary_json = obj(vec![
        ("matrix", Json::Str(matrix.to_string())),
        ("scenarios_ok", Json::Num(results.len() as f64)),
        ("scenarios_failed", Json::Num(failed.len() as f64)),
        (
            "failed",
            Json::Arr(
                failed
                    .iter()
                    .map(|(id, e)| {
                        obj(vec![
                            ("id", Json::Str(id.clone())),
                            ("error", Json::Str(e.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "per_algo",
            Json::Arr(
                summaries
                    .iter()
                    .map(|s| {
                        obj(vec![
                            ("algo", Json::Str(s.algo.clone())),
                            ("runs", Json::Num(s.runs as f64)),
                            ("gm_cut", Json::Num(s.gm_cut)),
                            ("gm_max_comm_volume", Json::Num(s.gm_max_comm_volume)),
                            ("gm_ldht_ratio", Json::Num(s.gm_ldht_ratio)),
                            ("gm_rel_cut", Json::Num(s.gm_rel_cut)),
                            (
                                "gm_rel_max_comm_volume",
                                Json::Num(s.gm_rel_max_comm_volume),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(dir.join("summary.json"), summary_json.render())?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::NetKind;
    use crate::harness::scenario::TopoPreset;
    use crate::solver::SpmvLayout;

    fn tiny_scenarios() -> Vec<Scenario> {
        ["geoKM", "zSFC"]
            .iter()
            .map(|algo| Scenario {
                family: Family::Tri2d,
                n: 400,
                k: 4,
                topo: TopoPreset::Uniform,
                algo: algo.to_string(),
                epsilon: 0.05,
                seed: 7,
                solve_iters: 0,
                dynamic: DynamicKind::None,
                epochs: 0,
                overlap: false,
                layout: SpmvLayout::Ell,
                part_backend: None,
                part_ranks: 0,
                serve: None,
                app: None,
                net: NetKind::Flat,
                scale: None,
            })
            .collect()
    }

    #[test]
    fn run_matrix_tiny() {
        let scenarios = tiny_scenarios();
        let (ok, failed) = run_matrix(&scenarios, 2);
        assert!(failed.is_empty(), "{failed:?}");
        assert_eq!(ok.len(), 2);
        for r in &ok {
            assert!(r.cut > 0.0);
            assert!(r.max_comm_volume > 0.0);
            assert!(r.ldht_ratio >= 1.0 - 1e-9, "ratio {}", r.ldht_ratio);
        }
    }

    #[test]
    fn run_matrix_reports_failures_without_aborting() {
        let mut scenarios = tiny_scenarios();
        let template = scenarios[0].clone();
        scenarios.push(Scenario {
            algo: "no-such-algo".to_string(),
            ..template
        });
        let (ok, failed) = run_matrix(&scenarios, 1);
        assert_eq!(ok.len(), 2);
        assert_eq!(failed.len(), 1);
        assert!(failed[0].1.contains("no-such-algo"), "{}", failed[0].1);
    }

    #[test]
    fn solve_fields_populated_when_requested() {
        let mut s = tiny_scenarios();
        s.truncate(1);
        s[0].solve_iters = 5;
        let (ok, failed) = run_matrix(&s, 1);
        assert!(failed.is_empty(), "{failed:?}");
        assert!(ok[0].sim_time_per_iter.unwrap() > 0.0);
        assert!(ok[0].final_residual.unwrap().is_finite());
    }

    #[test]
    fn overlap_axis_populates_efficiency_and_preserves_quality() {
        let mut off = tiny_scenarios();
        off.truncate(1);
        off[0].solve_iters = 5;
        let mut on = off.clone();
        on[0].overlap = true;
        assert_eq!(on[0].id(), format!("{}-ov", off[0].id()), "overlap id suffix");
        let (r_off, f1) = run_matrix(&off, 1);
        let (r_on, f2) = run_matrix(&on, 1);
        assert!(f1.is_empty() && f2.is_empty(), "{f1:?} {f2:?}");
        // Partition quality is untouched by the axis; the solve numerics
        // are bit-identical (the residual is deterministic).
        assert_eq!(r_off[0].cut, r_on[0].cut);
        assert_eq!(r_off[0].final_residual, r_on[0].final_residual);
        assert_eq!(r_off[0].comm_hidden_secs, Some(0.0));
        assert_eq!(r_off[0].overlap_efficiency, Some(0.0));
        let eff = r_on[0].overlap_efficiency.unwrap();
        assert!(eff > 0.0 && eff <= 1.0, "efficiency {eff}");
        assert!(r_on[0].comm_hidden_secs.unwrap() > 0.0);
        // The columns render and round-trip.
        let table = runs_table(&r_on);
        assert!(table.rows[0].iter().any(|c| c == "on"));
        let back = Json::parse(&result_json(&r_on[0]).render()).unwrap();
        assert_eq!(back.get("overlap").unwrap(), &Json::Bool(true));
        assert!(back.get("overlap_efficiency").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn layout_axis_is_bit_identical_and_renders_columns() {
        let mut ell = tiny_scenarios();
        ell.truncate(1);
        ell[0].solve_iters = 5;
        let mut sell = ell.clone();
        sell[0].layout = SpmvLayout::SellCs;
        assert_eq!(sell[0].id(), format!("{}-lsellcs", ell[0].id()), "layout id suffix");
        let (r_ell, f1) = run_matrix(&ell, 1);
        let (r_sell, f2) = run_matrix(&sell, 1);
        assert!(f1.is_empty() && f2.is_empty(), "{f1:?} {f2:?}");
        // The layout axis changes storage, never numerics: partition
        // quality and the CG trajectory are bit-identical.
        assert_eq!(r_ell[0].cut, r_sell[0].cut);
        assert_eq!(r_ell[0].final_residual, r_sell[0].final_residual);
        let table = runs_table(&r_sell);
        let li = table.header.iter().position(|h| h == "layout").unwrap();
        assert_eq!(table.rows[0][li], "sellcs");
        let back = Json::parse(&result_json(&r_sell[0]).render()).unwrap();
        assert_eq!(back.get("layout").unwrap().as_str().unwrap(), "sellcs");
    }

    #[test]
    fn part_backend_axis_is_bit_identical_and_records_part_secs() {
        let mut seq = tiny_scenarios();
        seq.truncate(1); // geoKM, which has a distributed implementation
        let mut dist = seq.clone();
        dist[0].part_backend = Some(ExecBackend::Sim);
        dist[0].part_ranks = 2;
        assert_eq!(dist[0].id(), format!("{}-pbsimR2", seq[0].id()));
        let (r_seq, f1) = run_matrix(&seq, 1);
        let (r_dist, f2) = run_matrix(&dist, 1);
        assert!(f1.is_empty() && f2.is_empty(), "{f1:?} {f2:?}");
        // Same partition, hence identical quality columns.
        assert_eq!(r_seq[0].cut, r_dist[0].cut);
        assert_eq!(r_seq[0].max_comm_volume, r_dist[0].max_comm_volume);
        assert_eq!(r_seq[0].ldht_objective, r_dist[0].ldht_objective);
        assert_eq!(r_seq[0].part_secs, None);
        assert!(r_dist[0].part_secs.unwrap() > 0.0);
        // Columns render and round-trip.
        let table = runs_table(&r_dist);
        assert!(table.rows[0].iter().any(|c| c == "sim"));
        let back = Json::parse(&result_json(&r_dist[0]).render()).unwrap();
        assert_eq!(back.get("part_backend").unwrap().as_str().unwrap(), "sim");
        assert_eq!(back.get("part_ranks").unwrap().as_f64().unwrap(), 2.0);
        assert!(back.get("part_secs").unwrap().as_f64().unwrap() > 0.0);
        let back_seq = Json::parse(&result_json(&r_seq[0]).render()).unwrap();
        assert_eq!(back_seq.get("part_backend").unwrap(), &Json::Null);
        assert_eq!(back_seq.get("part_secs").unwrap(), &Json::Null);
    }

    #[test]
    fn serve_axis_populates_columns_and_round_trips() {
        let mut s = tiny_scenarios();
        s.truncate(1);
        s[0].serve = Some(ServeSpec {
            duration_secs: 1.0,
            arrival_rate: 40.0,
            queue_cap: 32,
            servers: 2,
        });
        assert!(s[0].id().ends_with("-serveD1R40"), "{}", s[0].id());
        let (ok, failed) = run_matrix(&s, 1);
        assert!(failed.is_empty(), "{failed:?}");
        let v = ok[0].serve.as_ref().expect("serve summary missing");
        assert!(v.offered > 0);
        assert_eq!(v.completed + v.rejected, v.offered);
        assert!(v.req_per_sec > 0.0);
        assert!(v.cache_hit_rate > 0.0, "repeat tenants must hit the cache");
        assert!(v.latency_p50_ms <= v.latency_p99_ms);
        // Quality columns still come from the one-shot pipeline.
        assert!(ok[0].cut > 0.0);
        // The sweep columns: offered rate echoes the spec's λ, goodput is
        // completions over trace time.
        assert_eq!(v.offered_rate, 40.0);
        assert!(v.goodput > 0.0);
        // The table renders the serve columns...
        let table = runs_table(&ok);
        let ci = table.header.iter().position(|h| h == "cacheHit").unwrap();
        assert_ne!(table.rows[0][ci], "-");
        let gi = table.header.iter().position(|h| h == "goodput").unwrap();
        assert_ne!(table.rows[0][gi], "-");
        let oi = table.header.iter().position(|h| h == "offeredRate").unwrap();
        assert_eq!(table.rows[0][oi], "40.0");
        // ...and the JSON carries the serve block.
        let back = Json::parse(&result_json(&ok[0]).render()).unwrap();
        let sj = back.get("serve").unwrap();
        assert!(sj.get("req_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(sj.get("cache_hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(sj.get("goodput").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(sj.get("offered_rate").unwrap().as_f64().unwrap(), 40.0);
        // Static results leave the column empty.
        let plain = tiny_scenarios();
        let (ok2, _) = run_matrix(&plain[..1].to_vec(), 1);
        assert!(ok2[0].serve.is_none());
        let back2 = Json::parse(&result_json(&ok2[0]).render()).unwrap();
        assert_eq!(back2.get("serve").unwrap(), &Json::Null);
    }

    #[test]
    fn app_axis_populates_columns_and_round_trips() {
        use crate::exec::AggMode;
        let mut s = tiny_scenarios();
        s.truncate(1);
        s[0].app = Some(AppSpec {
            kernel: "bfs".into(),
            agg: AggMode::Agg,
            backend: ExecBackend::Sim,
            ranks: 2,
        });
        assert!(s[0].id().ends_with("-appbfs-aggsimR2"), "{}", s[0].id());
        let (ok, failed) = run_matrix(&s, 1);
        assert!(failed.is_empty(), "{failed:?}");
        let a = ok[0].app.as_ref().expect("app summary missing");
        assert_eq!(a.app, "bfs");
        assert_eq!(a.agg_mode, "agg");
        assert_eq!(a.ranks, 2);
        assert!(a.iterations > 0);
        assert!(a.flushes > 0);
        assert!(a.agg_bytes > 0, "a 2-rank BFS must cross the strip boundary");
        assert!(a.max_link_bytes > 0 && a.max_link_bytes <= a.agg_bytes);
        assert!(a.app_secs > 0.0);
        // Quality columns still come from the one-shot pipeline.
        assert!(ok[0].cut > 0.0);
        // The table renders the app columns...
        let table = runs_table(&ok);
        let ai = table.header.iter().position(|h| h == "app").unwrap();
        assert_eq!(table.rows[0][ai], "bfs");
        let mi = table.header.iter().position(|h| h == "maxLinkBytes").unwrap();
        assert_ne!(table.rows[0][mi], "-");
        // ...and the JSON carries the app block.
        let back = Json::parse(&result_json(&ok[0]).render()).unwrap();
        let aj = back.get("app").unwrap();
        assert_eq!(aj.get("kernel").unwrap().as_str().unwrap(), "bfs");
        assert!(aj.get("max_link_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            aj.get("digest").unwrap().as_str().unwrap(),
            format!("{:016x}", a.digest)
        );
        // Static results default the app column to "cg" and null JSON.
        let plain = tiny_scenarios();
        let (ok2, _) = run_matrix(&plain[..1].to_vec(), 1);
        assert!(ok2[0].app.is_none());
        let t2 = runs_table(&ok2);
        assert_eq!(t2.rows[0][ai], "cg");
        let back2 = Json::parse(&result_json(&ok2[0]).render()).unwrap();
        assert_eq!(back2.get("app").unwrap(), &Json::Null);
    }

    #[test]
    fn bottleneck_volume_is_populated_for_static_runs() {
        let (ok, failed) = run_matrix(&tiny_scenarios(), 1);
        assert!(failed.is_empty(), "{failed:?}");
        for r in &ok {
            let b = r.bottleneck_volume.expect("static runs carry a bottleneck volume");
            assert!(b > 0.0 && b.is_finite(), "bottleneck {b}");
        }
        let table = runs_table(&ok);
        let bi = table.header.iter().position(|h| h == "bottleneckVol").unwrap();
        assert_ne!(table.rows[0][bi], "-");
        let back = Json::parse(&result_json(&ok[0]).render()).unwrap();
        assert!(back.get("bottleneck_volume").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn scale_axis_populates_columns_and_hier_beats_flat() {
        let mut flat = tiny_scenarios();
        flat.truncate(1);
        flat[0].net = NetKind::FatTree;
        flat[0].scale = Some(ScaleSpec { ranks: 1024, hier: false });
        let mut hier = flat.clone();
        hier[0].scale = Some(ScaleSpec { ranks: 1024, hier: true });
        assert!(
            hier[0].id().ends_with("-netfattree-scaleR1024-hier"),
            "{}",
            hier[0].id()
        );
        let (r_flat, f1) = run_matrix(&flat, 1);
        let (r_hier, f2) = run_matrix(&hier, 1);
        assert!(f1.is_empty() && f2.is_empty(), "{f1:?} {f2:?}");
        let a = r_flat[0].scale.as_ref().expect("scale summary missing");
        let b = r_hier[0].scale.as_ref().expect("scale summary missing");
        // The flat schedule is its own baseline, bit for bit; beyond one
        // node the two-level schedule is strictly cheaper.
        assert_eq!(a.iter_secs, a.flat_iter_secs);
        assert_eq!(a.iter_secs, b.flat_iter_secs, "same baseline on both rows");
        assert!(
            b.iter_secs < b.flat_iter_secs,
            "hier {} !< flat {}",
            b.iter_secs,
            b.flat_iter_secs
        );
        // The table renders the new columns...
        let table = runs_table(&r_hier);
        let ni = table.header.iter().position(|h| h == "net").unwrap();
        assert_eq!(table.rows[0][ni], "fattree");
        let si = table.header.iter().position(|h| h == "sched").unwrap();
        assert_eq!(table.rows[0][si], "hier");
        let ri = table.header.iter().position(|h| h == "scaleRanks").unwrap();
        assert_eq!(table.rows[0][ri], "1024");
        // ...and the JSON carries the scale block.
        let back = Json::parse(&result_json(&r_hier[0]).render()).unwrap();
        assert_eq!(back.get("net").unwrap().as_str().unwrap(), "fattree");
        let sj = back.get("scale").unwrap();
        assert_eq!(sj.get("ranks").unwrap().as_f64().unwrap(), 1024.0);
        assert_eq!(sj.get("sched").unwrap().as_str().unwrap(), "hier");
        assert!(sj.get("vs_flat").unwrap().as_f64().unwrap() < 1.0);
        // Off the axis the columns stay empty.
        let (ok2, _) = run_matrix(&tiny_scenarios()[..1].to_vec(), 1);
        assert!(ok2[0].scale.is_none());
        let back2 = Json::parse(&result_json(&ok2[0]).render()).unwrap();
        assert_eq!(back2.get("scale").unwrap(), &Json::Null);
        assert_eq!(back2.get("net").unwrap().as_str().unwrap(), "flat");
    }

    #[test]
    fn summary_geomeans() {
        let (ok, _) = run_matrix(&tiny_scenarios(), 1);
        let sums = summarize(&ok);
        assert_eq!(sums.len(), 2);
        let km = sums.iter().find(|s| s.algo == "geoKM").unwrap();
        assert_eq!(km.runs, 1);
        assert!((km.gm_rel_cut - 1.0).abs() < 1e-12, "geoKM relative to itself");
        let sfc = sums.iter().find(|s| s.algo == "zSFC").unwrap();
        assert!(sfc.gm_cut > 0.0);
        assert!(sfc.gm_rel_cut > 0.0);
    }

    #[test]
    fn tables_have_one_row_per_item() {
        let (ok, _) = run_matrix(&tiny_scenarios(), 1);
        assert_eq!(runs_table(&ok).rows.len(), ok.len());
        assert_eq!(summary_table(&summarize(&ok)).rows.len(), 2);
    }

    #[test]
    fn result_json_round_trips() {
        let (ok, _) = run_matrix(&tiny_scenarios(), 1);
        let j = result_json(&ok[0]);
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.get("id").unwrap().as_str().unwrap(), ok[0].scenario.id());
        assert_eq!(back.get("cut").unwrap().as_f64().unwrap(), ok[0].cut);
        assert_eq!(back.get("sim_time_per_iter_s").unwrap(), &Json::Null);
        assert_eq!(back.get("dynamic").unwrap(), &Json::Null);
    }

    #[test]
    fn dynamic_scenario_runs_through_the_repart_driver() {
        let s = Scenario {
            family: Family::Refined2d,
            n: 900,
            k: 4,
            topo: TopoPreset::Uniform,
            algo: "diffusion".to_string(),
            epsilon: 0.03,
            seed: 7,
            solve_iters: 0,
            dynamic: DynamicKind::RefineFront,
            epochs: 3,
            overlap: false,
            layout: SpmvLayout::Ell,
            part_backend: None,
            part_ranks: 0,
            serve: None,
            app: None,
            net: NetKind::Flat,
            scale: None,
        };
        let (ok, failed) = run_matrix(&[s], 1);
        assert!(failed.is_empty(), "{failed:?}");
        let r = &ok[0];
        let d = r.dynamic.as_ref().expect("dynamic summary missing");
        assert_eq!(d.epochs, 3);
        assert!(d.migrated_weight > 0.0, "nothing migrated on a front trace");
        assert!(d.migration_volume > 0);
        assert!(d.worst_obj_vs_scratch.is_finite());
        assert!(r.cut > 0.0);
        // JSON carries the dynamic block.
        let back = Json::parse(&result_json(r).render()).unwrap();
        let dj = back.get("dynamic").unwrap();
        assert_eq!(dj.get("epochs").unwrap().as_f64().unwrap(), 3.0);
        // The table renders dynamic columns.
        let table = runs_table(&ok);
        assert_eq!(table.rows.len(), 1);
        assert!(table.rows[0].iter().any(|c| c == "refine-front"));
    }

    #[test]
    fn runs_table_stays_rectangular_across_every_axis() {
        // One scenario per axis kind (static, dynamic, serve, sweep-style
        // serve, app, scale): every new axis adds columns to runs.csv,
        // and a header/row length mismatch silently shears the CSV. Pin
        // header width == row width for all of them at once.
        use crate::exec::AggMode;
        let base = &tiny_scenarios()[0];
        let mut dynamic = base.clone();
        dynamic.family = Family::Refined2d;
        dynamic.algo = "diffusion".to_string();
        dynamic.dynamic = DynamicKind::RefineFront;
        dynamic.epochs = 2;
        let mut serve = base.clone();
        serve.serve = Some(ServeSpec {
            duration_secs: 0.5,
            arrival_rate: 40.0,
            queue_cap: 16,
            servers: 2,
        });
        // The sweep rows are serve rows on a single server pushed past
        // capacity — structurally the shape `--matrix sweep` emits.
        let mut sweep = base.clone();
        sweep.serve = Some(ServeSpec {
            duration_secs: 0.5,
            arrival_rate: 400.0,
            queue_cap: 16,
            servers: 1,
        });
        let mut app = base.clone();
        app.app = Some(AppSpec {
            kernel: "bfs".into(),
            agg: AggMode::Agg,
            backend: ExecBackend::Sim,
            ranks: 2,
        });
        let mut scale = base.clone();
        scale.net = NetKind::FatTree;
        scale.scale = Some(ScaleSpec { ranks: 64, hier: true });
        let scenarios = vec![base.clone(), dynamic, serve, sweep, app, scale];
        let (ok, failed) = run_matrix(&scenarios, 1);
        assert!(failed.is_empty(), "{failed:?}");
        assert_eq!(ok.len(), scenarios.len());
        let table = runs_table(&ok);
        assert_eq!(table.rows.len(), scenarios.len());
        for (i, row) in table.rows.iter().enumerate() {
            assert_eq!(
                row.len(),
                table.header.len(),
                "row {i} ({}) width {} != header width {}",
                row[0],
                row.len(),
                table.header.len()
            );
        }
    }
}
