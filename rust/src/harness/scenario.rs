//! Declarative scenarios: one cell of the paper's experiment matrix.
//!
//! A [`Scenario`] names everything needed to reproduce one measurement —
//! mesh family × size × topology preset × partitioner × ε × seed — and
//! the [`MatrixKind`] registry enumerates the paper-faithful sweeps
//! (`smoke`, `paper-small`, `paper-full`). Scenarios are plain data: the
//! runner ([`super::runner`]) fans them out over the job queue and the
//! golden gate ([`super::golden`]) keys baselines by [`Scenario::id`].

use crate::blocksizes::{block_sizes, TABLE3_FILL};
use crate::exec::{AggMode, ExecBackend, NetKind};
use crate::gen::Family;
use crate::graph::Csr;
use crate::partitioners::dist::DIST_NAMES;
use crate::partitioners::ALL_NAMES;
use crate::repart::{DynamicKind, REPART_NAMES};
use crate::solver::SpmvLayout;
use crate::topology::{topo1, Pu, Topo1Spec, Topology};
use anyhow::{Context, Result};

/// Paper-faithful topology presets (§VI's categories, scaled for this
/// testbed). Each builds a concrete [`Topology`] for a requested k.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopoPreset {
    /// Homogeneous PUs (speed 1, memory 2) — the paper's baseline.
    Uniform,
    /// TOPO1-style two-speed system: k/6 fast CPU+GPU-class PUs at Table
    /// III's step 5 (speed 16, memory 13.8), the rest slow.
    TwoSpeed,
    /// Hierarchical 2×2×(k/4) cluster (nodes → sockets → cores) of
    /// homogeneous PUs — exercises tree-aware partitioning/mapping.
    Hier,
    /// Memory-saturated TOPO1 variant: fast PUs (speed 16) get memory 4,
    /// so Algorithm 1 saturates them and spills load to the slow PUs.
    MemSaturated,
}

/// All presets, in registry order.
pub const ALL_PRESETS: [TopoPreset; 4] = [
    TopoPreset::Uniform,
    TopoPreset::TwoSpeed,
    TopoPreset::Hier,
    TopoPreset::MemSaturated,
];

impl TopoPreset {
    /// Canonical preset name (the harness's `preset` column).
    pub fn name(&self) -> &'static str {
        match self {
            TopoPreset::Uniform => "uniform",
            TopoPreset::TwoSpeed => "twospeed",
            TopoPreset::Hier => "hier2x2",
            TopoPreset::MemSaturated => "memsat",
        }
    }

    /// Parse a preset name as written on the CLI.
    pub fn parse(s: &str) -> Option<TopoPreset> {
        Some(match s {
            "uniform" | "homog" => TopoPreset::Uniform,
            "twospeed" | "2speed" => TopoPreset::TwoSpeed,
            "hier2x2" | "hier" => TopoPreset::Hier,
            "memsat" | "saturated" => TopoPreset::MemSaturated,
            _ => return None,
        })
    }

    /// Build the concrete topology for `k` PUs. The hierarchical preset
    /// requires `k` divisible by 4 (fan-out 2×2×(k/4)).
    pub fn build(&self, k: usize) -> Topology {
        let fast = Pu { speed: 16.0, memory: 13.8 };
        match self {
            TopoPreset::Uniform => Topology::homogeneous(k, 1.0, 2.0),
            TopoPreset::TwoSpeed => topo1(Topo1Spec {
                k,
                num_fast: (k / 6).max(1),
                fast,
            }),
            TopoPreset::Hier => {
                assert!(k % 4 == 0 && k >= 4, "hier preset needs k divisible by 4, got {k}");
                Topology::hierarchical(
                    &[2, 2, k / 4],
                    |_| Pu { speed: 1.0, memory: 2.0 },
                    format!("hier2x2x{}", k / 4),
                )
            }
            TopoPreset::MemSaturated => topo1(Topo1Spec {
                k,
                num_fast: (k / 6).max(1),
                fast: Pu { speed: 16.0, memory: 4.0 },
            }),
        }
    }
}

/// One experiment-matrix cell, fully determined (every scenario is
/// reproducible bit-for-bit from this description).
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Mesh/graph family to generate.
    pub family: Family,
    /// Approximate vertex count handed to the generator.
    pub n: usize,
    /// Number of PUs/blocks.
    pub k: usize,
    /// Topology preset.
    pub topo: TopoPreset,
    /// Partitioner name (see `partitioners::by_name`).
    pub algo: String,
    /// Imbalance tolerance ε.
    pub epsilon: f64,
    /// Seed for both graph generation and partitioning.
    pub seed: u64,
    /// If > 0, also run this many distributed-CG iterations through the
    /// virtual-cluster engine (`sim` backend) and record time/iteration.
    pub solve_iters: usize,
    /// The dynamic axis: `none` runs the classic one-shot pipeline;
    /// `refine-front`/`speed-drift` replay a multi-epoch trace where
    /// `algo` names a *repartitioner* (`repart::repartitioner_by_name`).
    pub dynamic: DynamicKind,
    /// Number of epochs for dynamic scenarios (≥ 2; ignored for `none`).
    pub epochs: usize,
    /// The overlap axis: run the scenario's distributed solve (and a
    /// dynamic scenario's migration) through the nonblocking `Comm` path,
    /// hiding the halo exchange behind the interior SpMV. Numerics are
    /// identical to `off`; only the priced/measured communication drops.
    pub overlap: bool,
    /// The partitioning-backend axis: `None` runs the sequential
    /// partitioner (the historical path); `Some(backend)` computes the
    /// partition *on the virtual cluster* over [`Scenario::part_ranks`]
    /// ranks via `partitioners::dist` — bit-identical partition, plus
    /// the priced/measured `partSecs` column. Only meaningful for algos
    /// in `partitioners::dist::DIST_NAMES` and static scenarios.
    pub part_backend: Option<ExecBackend>,
    /// Rank count for the distributed partitioning axis (ignored when
    /// `part_backend` is `None`).
    pub part_ranks: usize,
    /// The SpMV-layout axis: which storage layout the scenario's
    /// distributed solve runs its rank kernels on (`solver::sell`).
    /// Solutions are `==`-equal across layouts, so golden metrics are
    /// layout-independent; only measured kernel time moves.
    pub layout: SpmvLayout,
    /// The serving axis: `Some(spec)` additionally runs a deterministic
    /// virtual-time serving trace (`coordinator::serve`, `sim` backend)
    /// against this scenario's instance and records throughput/latency/
    /// cache columns. `None` (all historical scenarios) is the one-shot
    /// pipeline only.
    pub serve: Option<ServeSpec>,
    /// The application axis: `None` (every historical scenario) is the
    /// CG/solve pipeline; `Some(spec)` additionally runs one irregular
    /// graph kernel (`apps::by_name`) over the scenario's instance on
    /// the virtual cluster and records `app`/`aggMode`/`flushes`/
    /// `aggBytes`/`maxLinkBytes` columns.
    pub app: Option<AppSpec>,
    /// The network-model axis: which `exec::NetModel` the priced
    /// backend charges messages and collective rounds with. The default
    /// `Flat` is the legacy single-hop α-β model and never perturbs
    /// golden ids; non-flat kinds append `-net<name>` to the id.
    pub net: NetKind,
    /// The scale axis: `Some(spec)` additionally prices the scenario's
    /// communication at `spec.ranks` *virtual* ranks through the
    /// closed-form `exec::CollectiveModel` (no transport is built — the
    /// whole point is rank counts no thread pool can host) and records
    /// the `scaleRanks`/`sched`/`scaleIter(ms)`/`scaleVsFlat` columns.
    pub scale: Option<ScaleSpec>,
}

/// Parameters of the scale axis: how many virtual ranks the analytic
/// pricing runs at, and whether the collectives use the two-level
/// hierarchical schedule ([`SCALE_NODE_RANKS`] ranks per node) or the
/// flat one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleSpec {
    /// Virtual rank count (64 … 16384 in `--matrix scale`).
    pub ranks: usize,
    /// Two-level hierarchical collective schedule instead of flat.
    pub hier: bool,
}

/// Ranks per physical node assumed by the scale axis's hierarchical
/// schedule — a dense modern node (64 cores), so 16384 ranks span 256
/// nodes.
pub const SCALE_NODE_RANKS: usize = 64;

/// Parameters of the application axis: which irregular kernel runs, and
/// how its messages travel.
#[derive(Debug, Clone, PartialEq)]
pub struct AppSpec {
    /// Kernel name (`apps::APP_NAMES`: `bfs`, `sssp`, `pagerank`).
    pub kernel: String,
    /// Aggregated or direct message layer.
    pub agg: AggMode,
    /// Engine backend the kernel runs on.
    pub backend: ExecBackend,
    /// Rank count of the virtual cluster.
    pub ranks: usize,
}

/// Parameters of the serving axis: the open-loop trace the scenario
/// replays through `coordinator::serve` on the virtual-time backend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeSpec {
    /// Virtual trace length in seconds.
    pub duration_secs: f64,
    /// Mean arrival rate λ (req/s; 3× during the burst window).
    pub arrival_rate: f64,
    /// Admission bound (arrivals beyond it are rejected).
    pub queue_cap: usize,
    /// Virtual FCFS servers.
    pub servers: usize,
}

impl Scenario {
    /// Stable identifier used as the golden-baseline key and artifact
    /// file name. Static blocking scenarios keep their historical id (so
    /// golden baselines survive the dynamic, overlap, and partitioning
    /// axes); dynamic scenarios append `-dyn<kind>-E<epochs>`,
    /// overlapped scenarios append `-ov`, non-default SpMV layouts append
    /// `-l<layout>`, distributed-partitioning scenarios append
    /// `-pb<backend>R<ranks>`, serving scenarios append
    /// `-serveD<duration>R<rate>`, application scenarios append
    /// `-app<kernel>-<aggmode><backend>R<ranks>`, non-flat network
    /// models append `-net<name>`, and scale scenarios append
    /// `-scaleR<ranks>[-hier]`.
    pub fn id(&self) -> String {
        let mut id = format!(
            "{}-n{}-k{}-{}-{}-e{}-s{}",
            self.family.name(),
            self.n,
            self.k,
            self.topo.name(),
            self.algo,
            self.epsilon,
            self.seed
        );
        if self.dynamic != DynamicKind::None {
            id.push_str(&format!("-dyn{}-E{}", self.dynamic.name(), self.epochs));
        }
        if self.overlap {
            id.push_str("-ov");
        }
        if self.layout != SpmvLayout::default() {
            id.push_str(&format!("-l{}", self.layout.name()));
        }
        if let Some(backend) = self.part_backend {
            id.push_str(&format!("-pb{}R{}", backend.name(), self.part_ranks));
        }
        if let Some(spec) = &self.serve {
            id.push_str(&format!("-serveD{}R{}", spec.duration_secs, spec.arrival_rate));
        }
        if let Some(spec) = &self.app {
            id.push_str(&format!(
                "-app{}-{}{}R{}",
                spec.kernel,
                spec.agg.name(),
                spec.backend.name(),
                spec.ranks
            ));
        }
        if self.net != NetKind::Flat {
            id.push_str(&format!("-net{}", self.net.name()));
        }
        if let Some(spec) = &self.scale {
            id.push_str(&format!("-scaleR{}", spec.ranks));
            if spec.hier {
                id.push_str("-hier");
            }
        }
        id
    }

    /// The concrete topology this scenario runs on.
    pub fn topology(&self) -> Topology {
        self.topo.build(self.k)
    }
}

/// Algorithm-1 targets for a (graph, topology) pair, using the same
/// memory calibration as `coordinator::run_one` (load fills
/// [`TABLE3_FILL`] of total memory). Returns `(tw, optimal_max_ratio)`;
/// the second value is the LDHT optimum a partitioner's achieved
/// objective is compared against (ratio ≥ 1).
pub fn alg1_targets(g: &Csr, topo: &Topology) -> Result<(Vec<f64>, f64)> {
    let load = g.total_vertex_weight();
    let scaled = topo.scaled_for_load(load, TABLE3_FILL);
    let bs = block_sizes(load, &scaled)
        .with_context(|| format!("Algorithm 1 on {}", topo.label))?;
    Ok((bs.tw, bs.max_ratio))
}

/// Named scenario matrices runnable via `hetpart harness --matrix <name>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatrixKind {
    /// 12 tiny scenarios (2 graphs × 2 presets × 3 algorithms) — the CI
    /// gate and golden-baseline matrix; finishes in seconds, debug build
    /// included.
    Smoke,
    /// The paper's sweep shrunk ~100×: 4 graph families × all 4 presets
    /// × the 8 study algorithms (+ hierKM on the hierarchical preset).
    PaperSmall,
    /// Same structure at benchmark sizes, plus the paper-excluded tools
    /// (lpPulp, zMJ) on the uniform preset.
    PaperFull,
    /// The dynamic-repartitioning matrix: refine-front and speed-drift
    /// traces × the three repartitioners on the twospeed preset.
    Dynamic,
    /// The distributed-partitioning matrix: the dist-capable algorithms
    /// (`partitioners::dist::DIST_NAMES`) × partitioning backend/rank
    /// axes, plus the sequential baseline row per cell — one run
    /// reproduces the paper's quality-vs-partitioning-time scatter
    /// (`partSecs` against cut/LDHT).
    PartDist,
    /// The serving matrix: 2 graph families × 2 arrival rates replayed
    /// through the resident partition service (`coordinator::serve`) on
    /// the deterministic virtual-time backend — throughput, latency
    /// percentiles, and cache hit rate become harness columns.
    Serve,
    /// The application matrix: 2 graph families × the three irregular
    /// kernels (`apps::APP_NAMES`) × aggregation mode × engine backend at
    /// 4 ranks — one run reproduces the aggregation-win table (`flushes`,
    /// `aggBytes`, and the bottleneck-link `maxLinkBytes` columns).
    Apps,
    /// The scale matrix: 2 graph families × 2 algorithms × virtual rank
    /// counts {64, 256, 1024, 4096, 16384} × flat-vs-hierarchical
    /// collective schedule × 2 non-flat network models, priced through
    /// the closed-form `exec::CollectiveModel` on the sim backend — the
    /// scaling chapter the paper never had (`scaleRanks`/`sched`/
    /// `scaleIter(ms)`/`scaleVsFlat` columns).
    Scale,
    /// The saturation sweep: one serving cell stepped across ~6 offered
    /// arrival rates on the virtual-time backend — `offeredRate` /
    /// `goodput` / `latP99(ms)` become harness columns, so the knee
    /// (goodput flattens while p99 grows) is readable from one CSV.
    Sweep,
}

impl MatrixKind {
    /// Canonical matrix name (the `--matrix` value).
    pub fn name(&self) -> &'static str {
        match self {
            MatrixKind::Smoke => "smoke",
            MatrixKind::PaperSmall => "paper-small",
            MatrixKind::PaperFull => "paper-full",
            MatrixKind::Dynamic => "dynamic",
            MatrixKind::PartDist => "partdist",
            MatrixKind::Serve => "serve",
            MatrixKind::Apps => "apps",
            MatrixKind::Scale => "scale",
            MatrixKind::Sweep => "sweep",
        }
    }

    /// Parse a matrix name as written on the CLI.
    pub fn parse(s: &str) -> Option<MatrixKind> {
        Some(match s {
            "smoke" => MatrixKind::Smoke,
            "paper-small" | "paper_small" | "small" => MatrixKind::PaperSmall,
            "paper-full" | "paper_full" | "full" => MatrixKind::PaperFull,
            "dynamic" | "dyn" | "repart" => MatrixKind::Dynamic,
            "partdist" | "part-dist" | "part_dist" => MatrixKind::PartDist,
            "serve" | "serving" => MatrixKind::Serve,
            "apps" | "app" => MatrixKind::Apps,
            "scale" | "scaling" => MatrixKind::Scale,
            "sweep" | "saturation" => MatrixKind::Sweep,
            _ => return None,
        })
    }

    /// Enumerate the matrix. Deterministic: same list, same order, every
    /// call.
    pub fn scenarios(&self) -> Vec<Scenario> {
        const SEED: u64 = 42;
        const EPS: f64 = 0.03;
        let mut out = Vec::new();
        match self {
            MatrixKind::Smoke => {
                let graphs = [(Family::Tri2d, 900usize), (Family::Rdg2d, 800)];
                let presets = [TopoPreset::Uniform, TopoPreset::TwoSpeed];
                let algos = ["geoKM", "zSFC", "pmGraph"];
                for (family, n) in graphs {
                    for topo in presets {
                        for algo in algos {
                            out.push(Scenario {
                                family,
                                n,
                                k: 8,
                                topo,
                                algo: algo.to_string(),
                                epsilon: EPS,
                                seed: SEED,
                                solve_iters: 10,
                                dynamic: DynamicKind::None,
                                epochs: 0,
                                overlap: false,
                                part_backend: None,
                                part_ranks: 0,
                                layout: SpmvLayout::Ell,
                                serve: None,
                                app: None,
                                net: NetKind::Flat,
                                scale: None,
                            });
                        }
                    }
                }
            }
            MatrixKind::Dynamic => {
                for dynamic in [DynamicKind::RefineFront, DynamicKind::SpeedDrift] {
                    for algo in REPART_NAMES {
                        out.push(Scenario {
                            family: Family::Refined2d,
                            n: 1500,
                            k: 8,
                            topo: TopoPreset::TwoSpeed,
                            algo: algo.to_string(),
                            epsilon: EPS,
                            seed: SEED,
                            solve_iters: 0,
                            dynamic,
                            epochs: 5,
                            overlap: false,
                            part_backend: None,
                            part_ranks: 0,
                            layout: SpmvLayout::Ell,
                            serve: None,
                            app: None,
                            net: NetKind::Flat,
                            scale: None,
                        });
                    }
                }
            }
            MatrixKind::PaperSmall => {
                let graphs = [
                    (Family::Tri2d, 2500usize),
                    (Family::Rdg2d, 2500),
                    (Family::Refined2d, 2500),
                    (Family::Tet3d, 2000),
                ];
                push_paper_grid(&mut out, &graphs, 24, EPS, SEED, 0, false);
            }
            MatrixKind::PaperFull => {
                let graphs = [
                    (Family::Tri2d, 12_000usize),
                    (Family::Rdg2d, 12_000),
                    (Family::Refined2d, 12_000),
                    (Family::Tet3d, 8_000),
                ];
                push_paper_grid(&mut out, &graphs, 48, EPS, SEED, 40, true);
            }
            MatrixKind::PartDist => {
                // Per (graph, algo) cell: the sequential baseline, the
                // priced scaling sweep (sim at 1/2/4 ranks), and one
                // measured point (threads at 4 ranks).
                let graphs = [(Family::Tri2d, 2500usize), (Family::Rdg2d, 2500)];
                let axes: [(Option<ExecBackend>, usize); 5] = [
                    (None, 0),
                    (Some(ExecBackend::Sim), 1),
                    (Some(ExecBackend::Sim), 2),
                    (Some(ExecBackend::Sim), 4),
                    (Some(ExecBackend::Threads), 4),
                ];
                for (family, n) in graphs {
                    for algo in DIST_NAMES {
                        for (part_backend, part_ranks) in axes {
                            out.push(Scenario {
                                family,
                                n,
                                k: 8,
                                topo: TopoPreset::Uniform,
                                algo: algo.to_string(),
                                epsilon: EPS,
                                seed: SEED,
                                solve_iters: 0,
                                dynamic: DynamicKind::None,
                                epochs: 0,
                                overlap: false,
                                part_backend,
                                part_ranks,
                                layout: SpmvLayout::Ell,
                                serve: None,
                                app: None,
                                net: NetKind::Flat,
                                scale: None,
                            });
                        }
                    }
                }
            }
            MatrixKind::Serve => {
                // Serving runs reuse the virtual-time backend, so the
                // matrix is deterministic end to end: the same trace and
                // the same summary bits every run.
                let graphs = [(Family::Tri2d, 800usize), (Family::Rdg2d, 800)];
                for (family, n) in graphs {
                    for rate in [40.0f64, 80.0] {
                        out.push(Scenario {
                            family,
                            n,
                            k: 8,
                            topo: TopoPreset::Uniform,
                            algo: "geoKM".to_string(),
                            epsilon: EPS,
                            seed: SEED,
                            solve_iters: 0,
                            dynamic: DynamicKind::None,
                            epochs: 0,
                            overlap: false,
                            part_backend: None,
                            part_ranks: 0,
                            layout: SpmvLayout::Ell,
                            serve: Some(ServeSpec {
                                duration_secs: 2.0,
                                arrival_rate: rate,
                                queue_cap: 32,
                                servers: 2,
                            }),
                            app: None,
                            net: NetKind::Flat,
                            scale: None,
                        });
                    }
                }
            }
            MatrixKind::Sweep => {
                // One serving cell, offered load stepped across the
                // saturation knee on a single virtual server: early rates
                // are far below capacity (goodput tracks offeredRate),
                // the top rates are past it (goodput flattens at
                // capacity, latP99 and rejections grow). Deterministic
                // like the serve matrix — same bits every run.
                for rate in [250.0f64, 500.0, 1000.0, 2000.0, 4000.0, 8000.0] {
                    out.push(Scenario {
                        family: Family::Tri2d,
                        n: 800,
                        k: 8,
                        topo: TopoPreset::Uniform,
                        algo: "geoKM".to_string(),
                        epsilon: EPS,
                        seed: SEED,
                        solve_iters: 0,
                        dynamic: DynamicKind::None,
                        epochs: 0,
                        overlap: false,
                        part_backend: None,
                        part_ranks: 0,
                        layout: SpmvLayout::Ell,
                        serve: Some(ServeSpec {
                            duration_secs: 2.0,
                            arrival_rate: rate,
                            queue_cap: 32,
                            servers: 1,
                        }),
                        app: None,
                        net: NetKind::Flat,
                        scale: None,
                    });
                }
            }
            MatrixKind::Apps => {
                // App × aggregation × backend at a fixed rank count: the
                // sim rows carry the priced aggregation win, the threads
                // rows confirm it (and bit-identity) on real threads.
                let graphs = [(Family::Tri2d, 900usize), (Family::Rdg2d, 800)];
                for (family, n) in graphs {
                    for kernel in crate::apps::APP_NAMES {
                        for agg in [AggMode::Agg, AggMode::Direct] {
                            for backend in [ExecBackend::Sim, ExecBackend::Threads] {
                                out.push(Scenario {
                                    family,
                                    n,
                                    k: 8,
                                    topo: TopoPreset::Uniform,
                                    algo: "geoKM".to_string(),
                                    epsilon: EPS,
                                    seed: SEED,
                                    solve_iters: 0,
                                    dynamic: DynamicKind::None,
                                    epochs: 0,
                                    overlap: false,
                                    part_backend: None,
                                    part_ranks: 0,
                                    layout: SpmvLayout::Ell,
                                    serve: None,
                                    app: Some(AppSpec {
                                        kernel: kernel.to_string(),
                                        agg,
                                        backend,
                                        ranks: 4,
                                    }),
                                    net: NetKind::Flat,
                                    scale: None,
                                });
                            }
                        }
                    }
                }
            }
            MatrixKind::Scale => {
                // Virtual-scale pricing: the partition still runs at
                // k = 8 on the real instance (quality metrics stay
                // meaningful), while the communication is priced at
                // `ranks` virtual ranks through the closed-form model —
                // flat vs hierarchical schedule under two non-flat
                // fabrics. Rank counts are powers of two so the
                // hier-strictly-cheaper property holds exactly (tree
                // depths add: ceil(log2 g) + ceil(log2 nodes) =
                // ceil(log2 k)).
                let graphs = [(Family::Tri2d, 900usize), (Family::Rdg2d, 800)];
                let ranks_axis = [64usize, 256, 1024, 4096, 16384];
                for (family, n) in graphs {
                    for algo in ["geoKM", "zSFC"] {
                        for ranks in ranks_axis {
                            for hier in [false, true] {
                                for net in [NetKind::FatTree, NetKind::Torus] {
                                    out.push(Scenario {
                                        family,
                                        n,
                                        k: 8,
                                        topo: TopoPreset::Uniform,
                                        algo: algo.to_string(),
                                        epsilon: EPS,
                                        seed: SEED,
                                        solve_iters: 0,
                                        dynamic: DynamicKind::None,
                                        epochs: 0,
                                        overlap: false,
                                        part_backend: None,
                                        part_ranks: 0,
                                        layout: SpmvLayout::Ell,
                                        serve: None,
                                        app: None,
                                        net,
                                        scale: Some(ScaleSpec { ranks, hier }),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Shared shape of the paper-small/paper-full grids: every preset × the
/// eight study algorithms, hierKM added on the hierarchical preset, and
/// (optionally) the paper-excluded tools on the uniform preset.
fn push_paper_grid(
    out: &mut Vec<Scenario>,
    graphs: &[(Family, usize)],
    k: usize,
    epsilon: f64,
    seed: u64,
    solve_iters: usize,
    include_excluded: bool,
) {
    for &(family, n) in graphs {
        for topo in ALL_PRESETS {
            let mut algos: Vec<&str> = ALL_NAMES.to_vec();
            if topo == TopoPreset::Hier {
                algos.push("hierKM");
            }
            if include_excluded && topo == TopoPreset::Uniform {
                algos.extend(crate::partitioners::EXT_NAMES);
            }
            for algo in algos {
                out.push(Scenario {
                    family,
                    n,
                    k,
                    topo,
                    algo: algo.to_string(),
                    epsilon,
                    seed,
                    solve_iters,
                    dynamic: DynamicKind::None,
                    epochs: 0,
                    overlap: false,
                    part_backend: None,
                    part_ranks: 0,
                    layout: SpmvLayout::Ell,
                    serve: None,
                    app: None,
                    net: NetKind::Flat,
                    scale: None,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_names_round_trip() {
        for p in ALL_PRESETS {
            assert_eq!(TopoPreset::parse(p.name()), Some(p), "{}", p.name());
        }
        assert!(TopoPreset::parse("bogus").is_none());
    }

    #[test]
    fn presets_build_k_pus() {
        for p in ALL_PRESETS {
            let t = p.build(8);
            assert_eq!(t.k(), 8, "{}", p.name());
            assert!(t.pus.iter().all(|pu| pu.speed > 0.0 && pu.memory > 0.0));
        }
    }

    #[test]
    fn hier_preset_is_three_level() {
        let t = TopoPreset::Hier.build(16);
        assert_eq!(t.k(), 16);
        assert_eq!(t.root_children().len(), 2);
    }

    #[test]
    fn memsat_preset_saturates_fast_pus() {
        let t = TopoPreset::MemSaturated.build(12);
        let load = 100.0;
        let scaled = t.scaled_for_load(load, TABLE3_FILL);
        let bs = block_sizes(load, &scaled).unwrap();
        // The fast PUs (index 0..num_fast) must end saturated.
        assert!(bs.saturated[0], "fast PU not saturated: {:?}", bs.saturated);
        assert!(!bs.saturated[11], "slow PU saturated");
    }

    #[test]
    fn matrix_names_round_trip() {
        for m in [
            MatrixKind::Smoke,
            MatrixKind::PaperSmall,
            MatrixKind::PaperFull,
            MatrixKind::Dynamic,
            MatrixKind::PartDist,
            MatrixKind::Serve,
            MatrixKind::Apps,
            MatrixKind::Scale,
            MatrixKind::Sweep,
        ] {
            assert_eq!(MatrixKind::parse(m.name()), Some(m));
        }
        assert_eq!(MatrixKind::parse("saturation"), Some(MatrixKind::Sweep));
        assert!(MatrixKind::parse("nope").is_none());
    }

    #[test]
    fn sweep_matrix_shape() {
        let s = MatrixKind::Sweep.scenarios();
        assert_eq!(s.len(), 6);
        let rates: Vec<f64> =
            s.iter().map(|x| x.serve.expect("sweep rows carry a ServeSpec").arrival_rate).collect();
        // The offered-load axis must be strictly monotone so the knee is
        // readable straight down the CSV.
        for w in rates.windows(2) {
            assert!(w[0] < w[1], "offered load not monotone: {rates:?}");
        }
        // The top rate must sit well past a single server's capacity so
        // the sweep actually saturates.
        assert!(rates[rates.len() - 1] >= 16.0 * rates[0]);
        for x in &s {
            let spec = x.serve.unwrap();
            assert_eq!(spec.servers, 1, "saturation is measured against one server");
            assert!(spec.duration_secs > 0.0);
        }
        // IDs unique (the -serveD…R… suffix disambiguates rates).
        let mut ids: Vec<String> = s.iter().map(|x| x.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), s.len());
    }

    #[test]
    fn partdist_matrix_shape() {
        let s = MatrixKind::PartDist.scenarios();
        // 2 graphs × 3 dist algos × (1 seq + 3 sim + 1 threads) axes.
        assert_eq!(s.len(), 2 * DIST_NAMES.len() * 5);
        for x in &s {
            assert!(DIST_NAMES.contains(&x.algo.as_str()), "{} not dist-capable", x.algo);
            if let Some(b) = x.part_backend {
                assert!(x.part_ranks >= 1);
                assert!(matches!(b, ExecBackend::Sim | ExecBackend::Threads));
            } else {
                assert_eq!(x.part_ranks, 0);
            }
        }
        // The sim sweep covers ranks 1, 2, 4 for the scatter's time axis.
        for ranks in [1usize, 2, 4] {
            assert!(s
                .iter()
                .any(|x| x.part_backend == Some(ExecBackend::Sim) && x.part_ranks == ranks));
        }
        // IDs unique (the -pb suffix disambiguates the axes).
        let mut ids: Vec<String> = s.iter().map(|x| x.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), s.len());
    }

    #[test]
    fn dynamic_matrix_shape() {
        let s = MatrixKind::Dynamic.scenarios();
        // 2 dynamics × 3 repartitioners.
        assert_eq!(s.len(), 6);
        for x in &s {
            assert_ne!(x.dynamic, DynamicKind::None);
            assert!(x.epochs >= 2);
            assert!(
                crate::repart::repartitioner_by_name(&x.algo).is_some(),
                "{} not a repartitioner",
                x.algo
            );
        }
        // IDs unique.
        let mut ids: Vec<String> = s.iter().map(|x| x.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), s.len());
    }

    #[test]
    fn smoke_matrix_shape() {
        let s = MatrixKind::Smoke.scenarios();
        assert_eq!(s.len(), 12);
        // IDs unique and stable across calls.
        let ids: Vec<String> = s.iter().map(|x| x.id()).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate scenario ids");
        let again: Vec<String> =
            MatrixKind::Smoke.scenarios().iter().map(|x| x.id()).collect();
        assert_eq!(ids, again);
    }

    #[test]
    fn paper_small_covers_all_presets_and_algos() {
        let s = MatrixKind::PaperSmall.scenarios();
        // 4 graphs × (4 presets × 8 algos + hierKM once) = 4 × 33.
        assert_eq!(s.len(), 4 * (4 * ALL_NAMES.len() + 1));
        for p in ALL_PRESETS {
            assert!(s.iter().any(|x| x.topo == p), "preset {} missing", p.name());
        }
        for a in ALL_NAMES {
            assert!(s.iter().any(|x| x.algo == *a), "algo {a} missing");
        }
        assert!(s.iter().any(|x| x.algo == "hierKM"));
    }

    #[test]
    fn scenario_id_format() {
        let mut s = Scenario {
            family: Family::Tri2d,
            n: 900,
            k: 8,
            topo: TopoPreset::Uniform,
            algo: "geoKM".into(),
            epsilon: 0.03,
            seed: 42,
            solve_iters: 0,
            dynamic: DynamicKind::None,
            epochs: 0,
            overlap: false,
            part_backend: None,
            part_ranks: 0,
            layout: SpmvLayout::Ell,
            serve: None,
            app: None,
            net: NetKind::Flat,
            scale: None,
        };
        // Static ids keep the historical shape (golden-baseline keys).
        assert_eq!(s.id(), "tri_2d-n900-k8-uniform-geoKM-e0.03-s42");
        // The serving axis gets its own suffix.
        s.serve = Some(ServeSpec {
            duration_secs: 2.0,
            arrival_rate: 40.0,
            queue_cap: 32,
            servers: 2,
        });
        assert_eq!(s.id(), "tri_2d-n900-k8-uniform-geoKM-e0.03-s42-serveD2R40");
        s.serve = None;
        // The non-default layout gets its own suffix; the default never
        // perturbs golden keys.
        s.layout = SpmvLayout::SellCs;
        assert_eq!(s.id(), "tri_2d-n900-k8-uniform-geoKM-e0.03-s42-lsellcs");
        s.layout = SpmvLayout::Ell;
        s.part_backend = Some(ExecBackend::Sim);
        s.part_ranks = 4;
        assert_eq!(s.id(), "tri_2d-n900-k8-uniform-geoKM-e0.03-s42-pbsimR4");
        s.part_backend = None;
        s.dynamic = DynamicKind::RefineFront;
        s.epochs = 5;
        s.algo = "diffusion".into();
        assert_eq!(
            s.id(),
            "tri_2d-n900-k8-uniform-diffusion-e0.03-s42-dynrefine-front-E5"
        );
    }

    #[test]
    fn serve_matrix_shape() {
        let s = MatrixKind::Serve.scenarios();
        // 2 graphs × 2 arrival rates.
        assert_eq!(s.len(), 4);
        for x in &s {
            let spec = x.serve.expect("serve scenario without a spec");
            assert!(spec.duration_secs > 0.0);
            assert!(spec.arrival_rate > 0.0);
            assert!(spec.queue_cap >= 1);
            assert!(spec.servers >= 1);
            assert_eq!(x.dynamic, DynamicKind::None);
            assert_eq!(x.part_backend, None);
        }
        // IDs unique (the -serve suffix carries the rate axis).
        let mut ids: Vec<String> = s.iter().map(|x| x.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), s.len());
    }

    #[test]
    fn apps_matrix_shape() {
        let s = MatrixKind::Apps.scenarios();
        // 2 graphs × 3 kernels × 2 agg modes × 2 backends.
        assert_eq!(s.len(), 2 * crate::apps::APP_NAMES.len() * 2 * 2);
        for x in &s {
            let spec = x.app.as_ref().expect("apps scenario without a spec");
            assert!(crate::apps::APP_NAMES.contains(&spec.kernel.as_str()));
            assert_eq!(spec.ranks, 4);
            assert_eq!(x.solve_iters, 0);
            assert_eq!(x.dynamic, DynamicKind::None);
            assert!(x.serve.is_none());
        }
        // Both modes and both backends present for every kernel.
        for kernel in crate::apps::APP_NAMES {
            for agg in [AggMode::Agg, AggMode::Direct] {
                for backend in [ExecBackend::Sim, ExecBackend::Threads] {
                    assert!(
                        s.iter().any(|x| {
                            let a = x.app.as_ref().unwrap();
                            a.kernel == kernel && a.agg == agg && a.backend == backend
                        }),
                        "missing {kernel} cell"
                    );
                }
            }
        }
        // IDs unique (the -app suffix carries all three sub-axes).
        let mut ids: Vec<String> = s.iter().map(|x| x.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), s.len());
    }

    #[test]
    fn app_axis_id_suffix() {
        let mut s = MatrixKind::Smoke.scenarios().remove(0);
        let base = s.id();
        s.app = Some(AppSpec {
            kernel: "sssp".into(),
            agg: AggMode::Agg,
            backend: ExecBackend::Sim,
            ranks: 4,
        });
        assert_eq!(s.id(), format!("{base}-appsssp-aggsimR4"));
        s.app = Some(AppSpec {
            kernel: "bfs".into(),
            agg: AggMode::Direct,
            backend: ExecBackend::Threads,
            ranks: 2,
        });
        assert_eq!(s.id(), format!("{base}-appbfs-directthreadsR2"));
        // The default (None) never perturbs the historical golden key.
        s.app = None;
        assert_eq!(s.id(), base);
    }

    #[test]
    fn scale_matrix_shape_and_determinism() {
        let s = MatrixKind::Scale.scenarios();
        // 2 graphs × 2 algos × 5 rank counts × 2 schedules × 2 nets.
        assert_eq!(s.len(), 2 * 2 * 5 * 2 * 2);
        for x in &s {
            let spec = x.scale.expect("scale scenario without a spec");
            assert!(spec.ranks.is_power_of_two(), "ranks {} not a power of two", spec.ranks);
            assert!(spec.ranks >= 64 && spec.ranks <= 16384);
            assert_ne!(x.net, NetKind::Flat, "scale matrix sweeps non-flat fabrics");
            assert_eq!(x.solve_iters, 0);
            assert!(x.app.is_none() && x.serve.is_none());
        }
        assert!(s.iter().any(|x| x.scale.unwrap().ranks == 16384 && x.scale.unwrap().hier));
        // IDs unique and deterministic call to call (seed-determinism of
        // the scenario ids — the golden gate depends on it).
        let ids: Vec<String> = s.iter().map(|x| x.id()).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate scale scenario ids");
        let again: Vec<String> =
            MatrixKind::Scale.scenarios().iter().map(|x| x.id()).collect();
        assert_eq!(ids, again);
    }

    #[test]
    fn net_and_scale_id_suffixes() {
        let mut s = MatrixKind::Smoke.scenarios().remove(0);
        let base = s.id();
        s.net = NetKind::FatTree;
        assert_eq!(s.id(), format!("{base}-netfattree"));
        s.scale = Some(ScaleSpec { ranks: 1024, hier: true });
        assert_eq!(s.id(), format!("{base}-netfattree-scaleR1024-hier"));
        s.scale = Some(ScaleSpec { ranks: 64, hier: false });
        s.net = NetKind::Torus;
        assert_eq!(s.id(), format!("{base}-nettorus-scaleR64"));
        // The defaults never perturb the historical golden keys.
        s.net = NetKind::Flat;
        s.scale = None;
        assert_eq!(s.id(), base);
    }

    #[test]
    fn alg1_targets_sum_to_load() {
        let g = Family::Tri2d.generate(400, 1);
        let t = TopoPreset::TwoSpeed.build(6);
        let (tw, opt) = alg1_targets(&g, &t).unwrap();
        assert_eq!(tw.len(), 6);
        let total: f64 = tw.iter().sum();
        assert!((total - g.total_vertex_weight()).abs() < 1e-6 * total);
        assert!(opt > 0.0);
    }
}
