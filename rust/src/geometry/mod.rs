//! Geometric primitives: points (2-D/3-D), bounding boxes, and Hilbert
//! space-filling curves (the backbone of the `zSFC` partitioner, k-means
//! seeding, and Delaunay insertion ordering).

pub mod hilbert;
pub mod point;

pub use hilbert::{hilbert2d, hilbert3d, hilbert_index};
pub use point::{Aabb, Point};
