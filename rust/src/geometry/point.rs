//! Points in 2 or 3 dimensions and axis-aligned bounding boxes.
//!
//! A single `Point` type with a `dim` field (and a zeroed third coordinate
//! in 2-D) keeps the partitioners generic over dimension without trait
//! gymnastics; all mesh/geometric code paths check `dim` where it matters.

/// A point in R^2 or R^3. For 2-D points, `z == 0.0` and `dim == 2`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// x coordinate.
    pub x: f64,
    /// y coordinate.
    pub y: f64,
    /// z coordinate (0 for 2-D points).
    pub z: f64,
    /// Dimensionality tag (2 or 3).
    pub dim: u8,
}

impl Point {
    /// 2-D point.
    pub fn new2(x: f64, y: f64) -> Point {
        Point { x, y, z: 0.0, dim: 2 }
    }

    /// 3-D point.
    pub fn new3(x: f64, y: f64, z: f64) -> Point {
        Point { x, y, z, dim: 3 }
    }

    /// Coordinate by axis index (0=x, 1=y, 2=z).
    #[inline]
    pub fn coord(&self, axis: usize) -> f64 {
        match axis {
            0 => self.x,
            1 => self.y,
            _ => self.z,
        }
    }

    #[inline]
    /// Set coordinate `axis` (0 = x, 1 = y, 2 = z).
    pub fn set_coord(&mut self, axis: usize, v: f64) {
        match axis {
            0 => self.x = v,
            1 => self.y = v,
            _ => self.z = v,
        }
    }

    /// Squared Euclidean distance.
    #[inline]
    pub fn dist2(&self, o: &Point) -> f64 {
        let dx = self.x - o.x;
        let dy = self.y - o.y;
        let dz = self.z - o.z;
        dx * dx + dy * dy + dz * dz
    }

    #[inline]
    /// Euclidean distance to `o`.
    pub fn dist(&self, o: &Point) -> f64 {
        self.dist2(o).sqrt()
    }

    #[inline]
    /// Componentwise sum.
    pub fn add(&self, o: &Point) -> Point {
        Point {
            x: self.x + o.x,
            y: self.y + o.y,
            z: self.z + o.z,
            dim: self.dim,
        }
    }

    #[inline]
    /// Scale every coordinate by `s`.
    pub fn scale(&self, s: f64) -> Point {
        Point {
            x: self.x * s,
            y: self.y * s,
            z: self.z * s,
            dim: self.dim,
        }
    }

    /// Origin of the given dimensionality.
    pub fn zero(dim: u8) -> Point {
        Point { x: 0.0, y: 0.0, z: 0.0, dim }
    }
}

/// Axis-aligned bounding box.
#[derive(Debug, Clone, Copy)]
pub struct Aabb {
    /// Componentwise minimum corner.
    pub min: Point,
    /// Componentwise maximum corner.
    pub max: Point,
}

impl Aabb {
    /// Bounding box of a non-empty point set.
    pub fn of(points: &[Point]) -> Aabb {
        assert!(!points.is_empty());
        let dim = points[0].dim;
        let mut min = Point::new3(f64::INFINITY, f64::INFINITY, f64::INFINITY);
        let mut max = Point::new3(f64::NEG_INFINITY, f64::NEG_INFINITY, f64::NEG_INFINITY);
        for p in points {
            min.x = min.x.min(p.x);
            min.y = min.y.min(p.y);
            min.z = min.z.min(p.z);
            max.x = max.x.max(p.x);
            max.y = max.y.max(p.y);
            max.z = max.z.max(p.z);
        }
        min.dim = dim;
        max.dim = dim;
        Aabb { min, max }
    }

    /// Extent along an axis.
    pub fn extent(&self, axis: usize) -> f64 {
        self.max.coord(axis) - self.min.coord(axis)
    }

    /// Axis with the largest extent, restricted to the point dimension.
    pub fn longest_axis(&self) -> usize {
        let d = self.min.dim as usize;
        (0..d)
            .max_by(|&a, &b| self.extent(a).partial_cmp(&self.extent(b)).unwrap())
            .unwrap_or(0)
    }

    /// Normalize `p` into [0,1]^d relative to this box (degenerate axes → 0.5).
    pub fn normalize(&self, p: &Point) -> Point {
        let mut q = *p;
        for a in 0..(p.dim as usize) {
            let e = self.extent(a);
            let v = if e > 0.0 {
                (p.coord(a) - self.min.coord(a)) / e
            } else {
                0.5
            };
            q.set_coord(a, v.clamp(0.0, 1.0));
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_and_coords() {
        let a = Point::new2(0.0, 0.0);
        let b = Point::new2(3.0, 4.0);
        assert_eq!(a.dist(&b), 5.0);
        assert_eq!(b.coord(0), 3.0);
        assert_eq!(b.coord(1), 4.0);
    }

    #[test]
    fn point3_dist() {
        let a = Point::new3(1.0, 2.0, 3.0);
        let b = Point::new3(1.0, 2.0, 5.0);
        assert_eq!(a.dist(&b), 2.0);
    }

    #[test]
    fn aabb_of_points() {
        let pts = vec![
            Point::new2(0.0, 5.0),
            Point::new2(2.0, 1.0),
            Point::new2(-1.0, 3.0),
        ];
        let bb = Aabb::of(&pts);
        assert_eq!(bb.min.x, -1.0);
        assert_eq!(bb.max.y, 5.0);
        assert_eq!(bb.longest_axis(), 1); // y extent 4 > x extent 3
    }

    #[test]
    fn normalize_unit() {
        let pts = vec![Point::new2(0.0, 0.0), Point::new2(10.0, 20.0)];
        let bb = Aabb::of(&pts);
        let q = bb.normalize(&Point::new2(5.0, 10.0));
        assert!((q.x - 0.5).abs() < 1e-12);
        assert!((q.y - 0.5).abs() < 1e-12);
    }

    #[test]
    fn normalize_degenerate_axis() {
        let pts = vec![Point::new2(1.0, 0.0), Point::new2(1.0, 2.0)];
        let bb = Aabb::of(&pts);
        let q = bb.normalize(&Point::new2(1.0, 1.0));
        assert_eq!(q.x, 0.5); // degenerate x → 0.5
    }

    #[test]
    fn add_scale() {
        let p = Point::new2(1.0, 2.0).add(&Point::new2(3.0, 4.0)).scale(0.5);
        assert_eq!((p.x, p.y), (2.0, 3.0));
    }
}
