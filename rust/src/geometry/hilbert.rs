//! Hilbert space-filling curves in 2-D and 3-D.
//!
//! The Hilbert curve maps the unit square/cube onto a 1-D index while
//! preserving locality: points close on the curve are close in space.
//! Used by the `zSFC` partitioner (paper §III-a, Zoltan's SFC method),
//! by `pmGeom`'s initial partition, by balanced-k-means seeding, and to
//! order Delaunay insertions for fast walking point location.
//!
//! 2-D: the classic rotate/reflect iteration (Wikipedia `xy2d`).
//! 3-D: Skilling's transpose algorithm (AIP Conf. Proc. 707, 2004), which
//! converts between a Gray-code-like "transposed" Hilbert index and axis
//! coordinates for any dimension; we instantiate it for d = 3.

/// Bits of resolution per axis used when hashing f64 coordinates.
pub const HILBERT_ORDER: u32 = 16;

/// 2-D Hilbert index of integer cell (x, y) on a 2^order × 2^order grid.
pub fn hilbert2d(order: u32, mut x: u32, mut y: u32) -> u64 {
    let n = 1u32 << order;
    debug_assert!(x < n && y < n);
    let mut rx: u32;
    let mut ry: u32;
    let mut d: u64 = 0;
    let mut s = n / 2;
    while s > 0 {
        rx = u32::from((x & s) > 0);
        ry = u32::from((y & s) > 0);
        d += (s as u64) * (s as u64) * ((3 * rx) ^ ry) as u64;
        // Rotate quadrant.
        if ry == 0 {
            if rx == 1 {
                x = s.wrapping_sub(1).wrapping_sub(x) & (n - 1);
                y = s.wrapping_sub(1).wrapping_sub(y) & (n - 1);
            }
            std::mem::swap(&mut x, &mut y);
        }
        s /= 2;
    }
    d
}

/// Inverse of [`hilbert2d`]: cell (x, y) for index `d`.
pub fn hilbert2d_inv(order: u32, mut d: u64) -> (u32, u32) {
    let n = 1u64 << order;
    let (mut x, mut y) = (0u64, 0u64);
    let mut s = 1u64;
    while s < n {
        let rx = 1 & (d / 2);
        let ry = 1 & (d ^ rx);
        // Rotate.
        if ry == 0 {
            if rx == 1 {
                x = s - 1 - x;
                y = s - 1 - y;
            }
            std::mem::swap(&mut x, &mut y);
        }
        x += s * rx;
        y += s * ry;
        d /= 4;
        s *= 2;
    }
    (x as u32, y as u32)
}

/// 3-D Hilbert index via Skilling's transpose algorithm.
///
/// Coordinates are `order`-bit integers; the result packs the transposed
/// Hilbert code into a single u64 with x's bits most significant per level.
pub fn hilbert3d(order: u32, x: u32, y: u32, z: u32) -> u64 {
    debug_assert!(order <= 21, "3*order must fit in u64");
    let mut c = [x, y, z];
    axes_to_transpose(&mut c, order);
    // Interleave: bit (order-1-b) of each axis, x first.
    let mut h: u64 = 0;
    for b in (0..order).rev() {
        for v in &c {
            h = (h << 1) | ((*v >> b) & 1) as u64;
        }
    }
    h
}

/// Inverse of [`hilbert3d`].
pub fn hilbert3d_inv(order: u32, h: u64) -> (u32, u32, u32) {
    let mut c = [0u32; 3];
    // De-interleave.
    let mut shift = (3 * order) as i64;
    for b in (0..order).rev() {
        for v in c.iter_mut() {
            shift -= 1;
            *v |= (((h >> shift) & 1) as u32) << b;
        }
    }
    transpose_to_axes(&mut c, order);
    (c[0], c[1], c[2])
}

/// Skilling: axis coordinates -> transposed Hilbert code (in place).
fn axes_to_transpose(x: &mut [u32; 3], bits: u32) {
    let n = 3;
    let mut m = 1u32 << (bits - 1);
    // Inverse undo.
    while m > 1 {
        let p = m - 1;
        for i in 0..n {
            if x[i] & m != 0 {
                x[0] ^= p; // invert
            } else {
                let t = (x[0] ^ x[i]) & p;
                x[0] ^= t;
                x[i] ^= t;
            }
        }
        m >>= 1;
    }
    // Gray encode.
    for i in 1..n {
        x[i] ^= x[i - 1];
    }
    let mut t = 0u32;
    let mut q = 1u32 << (bits - 1);
    while q > 1 {
        if x[n - 1] & q != 0 {
            t ^= q - 1;
        }
        q >>= 1;
    }
    for xi in x.iter_mut() {
        *xi ^= t;
    }
}

/// Skilling: transposed Hilbert code -> axis coordinates (in place).
fn transpose_to_axes(x: &mut [u32; 3], bits: u32) {
    let n = 3;
    // Gray decode by H ^ (H/2).
    let mut t = x[n - 1] >> 1;
    for i in (1..n).rev() {
        x[i] ^= x[i - 1];
    }
    x[0] ^= t;
    // Undo excess work.
    let mut q = 2u32;
    while q != (1u32 << bits) {
        let p = q - 1;
        for i in (0..n).rev() {
            if x[i] & q != 0 {
                x[0] ^= p;
            } else {
                t = (x[0] ^ x[i]) & p;
                x[0] ^= t;
                x[i] ^= t;
            }
        }
        q <<= 1;
    }
}

use super::point::{Aabb, Point};

/// Hilbert index of a point normalized into the bounding box, dispatching
/// on dimension. This is the single entry point partitioners use.
pub fn hilbert_index(p: &Point, bb: &Aabb) -> u64 {
    let q = bb.normalize(p);
    let n = (1u64 << HILBERT_ORDER) as f64;
    let to_cell = |v: f64| -> u32 { ((v * n) as u64).min((1u64 << HILBERT_ORDER) - 1) as u32 };
    if p.dim == 2 {
        hilbert2d(HILBERT_ORDER, to_cell(q.x), to_cell(q.y))
    } else {
        hilbert3d(HILBERT_ORDER, to_cell(q.x), to_cell(q.y), to_cell(q.z))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h2d_order1_is_u_shape() {
        // Order-1 curve visits (0,0),(0,1),(1,1),(1,0).
        assert_eq!(hilbert2d(1, 0, 0), 0);
        assert_eq!(hilbert2d(1, 0, 1), 1);
        assert_eq!(hilbert2d(1, 1, 1), 2);
        assert_eq!(hilbert2d(1, 1, 0), 3);
    }

    #[test]
    fn h2d_bijective_order4() {
        let order = 4;
        let n = 1u32 << order;
        let mut seen = vec![false; (n * n) as usize];
        for x in 0..n {
            for y in 0..n {
                let d = hilbert2d(order, x, y) as usize;
                assert!(d < seen.len());
                assert!(!seen[d], "duplicate index {d}");
                seen[d] = true;
                let (xi, yi) = hilbert2d_inv(order, d as u64);
                assert_eq!((xi, yi), (x, y));
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn h2d_adjacent_indices_are_adjacent_cells() {
        // Consecutive Hilbert indices differ by exactly one unit step.
        let order = 5;
        let n = 1u64 << (2 * order);
        let mut prev = hilbert2d_inv(order, 0);
        for d in 1..n {
            let cur = hilbert2d_inv(order, d);
            let dist = (cur.0 as i64 - prev.0 as i64).abs() + (cur.1 as i64 - prev.1 as i64).abs();
            assert_eq!(dist, 1, "index {d}: {prev:?} -> {cur:?}");
            prev = cur;
        }
    }

    #[test]
    fn h3d_bijective_order3() {
        let order = 3;
        let n = 1u32 << order;
        let mut seen = vec![false; (n * n * n) as usize];
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    let d = hilbert3d(order, x, y, z) as usize;
                    assert!(d < seen.len(), "index {d} out of range");
                    assert!(!seen[d], "duplicate index {d}");
                    seen[d] = true;
                    let (xi, yi, zi) = hilbert3d_inv(order, d as u64);
                    assert_eq!((xi, yi, zi), (x, y, z));
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn h3d_adjacent_indices_are_adjacent_cells() {
        let order = 3;
        let n = 1u64 << (3 * order);
        let mut prev = hilbert3d_inv(order, 0);
        for d in 1..n {
            let cur = hilbert3d_inv(order, d);
            let dist = (cur.0 as i64 - prev.0 as i64).abs()
                + (cur.1 as i64 - prev.1 as i64).abs()
                + (cur.2 as i64 - prev.2 as i64).abs();
            assert_eq!(dist, 1, "index {d}: {prev:?} -> {cur:?}");
            prev = cur;
        }
    }

    #[test]
    fn hilbert_index_locality() {
        // Nearby points should have closer Hilbert indices than far points,
        // statistically.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let pts: Vec<Point> = (0..200)
            .map(|_| Point::new2(rng.f64(), rng.f64()))
            .collect();
        let bb = Aabb::of(&pts);
        let mut near = Vec::new();
        let mut far = Vec::new();
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                let sd = pts[i].dist(&pts[j]);
                let hd = (hilbert_index(&pts[i], &bb) as i128
                    - hilbert_index(&pts[j], &bb) as i128)
                    .unsigned_abs() as f64;
                if sd < 0.05 {
                    near.push(hd);
                } else if sd > 0.5 {
                    far.push(hd);
                }
            }
        }
        let m_near = crate::util::stats::mean(&near);
        let m_far = crate::util::stats::mean(&far);
        assert!(
            m_near < m_far * 0.5,
            "near mean {m_near} should be well below far mean {m_far}"
        );
    }
}
