//! Application layer: the SpMV/CG kernels the paper benchmarks (§VI-a)
//! and the heterogeneous-cluster execution simulator (§VI-C).
//!
//! The matrix is the graph's shifted Laplacian (`L + σI`, positive
//! definite). Storage is padded ELL (`solver::ell`) matching the L1
//! Pallas kernel's layout, so the same data feeds the native rust path
//! and the PJRT artifacts. `distsim` models a heterogeneous cluster:
//! per-PU compute scaled by `1/c_s`, α-β communication priced by the
//! partition's measured communication volumes.

pub mod cg;
pub mod distcg;
pub mod distsim;
pub mod ell;
pub mod halo;
pub mod precond;
pub mod sell;
pub mod spmv;

pub use cg::{cg_solve, CgResult};
pub use distcg::{pipelined_cg_solve, DistributedMatrix};
pub use halo::{HaloMatrix, HaloSolver};
pub use precond::pcg_solve;
pub use distsim::{ClusterSim, SimReport};
pub use ell::EllMatrix;
pub use sell::{SellMatrix, SpmvLayout};
