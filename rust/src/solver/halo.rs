//! Halo (ghost-cell) exchange structures for distributed SpMV.
//!
//! `DistributedMatrix` gathers the full global vector per SpMV — simple,
//! but its data movement is not what an MPI code does. [`HaloMatrix`]
//! builds the real structure: each PU stores its rows with columns
//! *renumbered into a local space* `[own rows | ghost entries]`, plus the
//! exchange lists (which owned values go to which neighbor PU). Per
//! iteration each PU receives exactly its ghost values — the paper's
//! communication-volume metric *is* the size of these lists, which is
//! asserted by a test and exercised by the `micro` bench.

use super::ell::EllMatrix;
use super::sell::{SellMatrix, SpmvLayout, DEFAULT_CHUNK, DEFAULT_SIGMA};
use crate::partition::Partition;

/// One PU's share of the matrix plus its halo metadata.
#[derive(Debug, Clone)]
pub struct HaloBlock {
    /// Rows in local indexing: columns < own.len() are owned, columns ≥
    /// own.len() index into the ghost segment.
    pub ell: EllMatrix,
    /// Global ids of owned rows (local 0..own.len() ↔ global).
    pub own: Vec<u32>,
    /// Global ids of ghost entries (local own.len()+i ↔ global ghosts[i]).
    pub ghosts: Vec<u32>,
    /// For each neighbor PU: (neighbor, owned-local-indices to send).
    pub send_lists: Vec<(u32, Vec<u32>)>,
    /// Local rows that reference no ghost column — computable before the
    /// halo exchange completes, i.e. the compute a nonblocking exchange
    /// can hide behind (ascending local indices).
    pub interior: Vec<u32>,
    /// Local rows that touch at least one ghost column — they must wait
    /// for the exchange (ascending; `interior ∪ boundary` = all rows).
    pub boundary: Vec<u32>,
}

impl HaloBlock {
    /// Local vector `[owned x | ghost x]` gathered from the global `x`.
    pub fn gather_local(&self, x: &[f32]) -> Vec<f32> {
        let mut xl = vec![0.0f32; self.own.len() + self.ghosts.len()];
        self.gather_local_into(x, &mut xl);
        xl
    }

    /// [`HaloBlock::gather_local`] into a caller buffer of length
    /// `own.len() + ghosts.len()` — the allocation-free form the
    /// [`HaloSolver`] workspaces use every iteration.
    pub fn gather_local_into(&self, x: &[f32], xl: &mut [f32]) {
        debug_assert_eq!(xl.len(), self.own.len() + self.ghosts.len());
        for (i, &g) in self.own.iter().enumerate() {
            xl[i] = x[g as usize];
        }
        let nb = self.own.len();
        for (i, &g) in self.ghosts.iter().enumerate() {
            xl[nb + i] = x[g as usize];
        }
    }

    /// One row of the block ELL kernel (diagonal + slots) — the single
    /// definition every distributed path shares; the exec engine's
    /// exact-trajectory guarantee depends on there being one copy of
    /// this loop body ([`HaloBlock::spmv_local`] and
    /// [`HaloBlock::spmv_rows`] both delegate here).
    #[inline]
    fn spmv_row(&self, xl: &[f32], li: usize) -> f32 {
        let w = self.ell.w;
        let mut acc = self.ell.diag[li] * xl[li];
        let base = li * w;
        for s in 0..w {
            acc += self.ell.values[base + s] * xl[self.ell.cols[base + s] as usize];
        }
        acc
    }

    /// The block ELL kernel over a local vector: every owned row through
    /// the shared [`HaloBlock::spmv_row`] body.
    pub fn spmv_local(&self, xl: &[f32], y_local: &mut [f32]) {
        for li in 0..self.own.len() {
            y_local[li] = self.spmv_row(xl, li);
        }
    }

    /// The same kernel over a subset of local rows. Running it on
    /// [`HaloBlock::interior`] and then [`HaloBlock::boundary`] produces a
    /// `y_local` bit-identical to [`HaloBlock::spmv_local`] (same row
    /// body, rows written independently) — the property that makes
    /// compute/communication overlap numerics-free.
    pub fn spmv_rows(&self, xl: &[f32], y_local: &mut [f32], rows: &[u32]) {
        for &li in rows {
            y_local[li as usize] = self.spmv_row(xl, li as usize);
        }
    }
}

/// Halo-exchange distributed matrix.
pub struct HaloMatrix {
    /// One block per PU, in rank order.
    pub blocks: Vec<HaloBlock>,
    /// Global number of rows.
    pub n: usize,
}

impl HaloMatrix {
    /// Decompose `ell` into per-block halo structures under `part`.
    pub fn new(ell: &EllMatrix, part: &Partition) -> HaloMatrix {
        let k = part.k;
        let n = ell.n;
        // Local index of every global vertex within its own block.
        let mut local_of = vec![0u32; n];
        let mut owners: Vec<Vec<u32>> = vec![Vec::new(); k];
        for u in 0..n {
            let b = part.assignment[u] as usize;
            local_of[u] = owners[b].len() as u32;
            owners[b].push(u as u32);
        }
        let mut blocks = Vec::with_capacity(k);
        for b in 0..k {
            let own = owners[b].clone();
            let nb = own.len();
            // Discover ghosts: foreign columns referenced by my rows.
            let mut ghost_local: std::collections::HashMap<u32, u32> =
                std::collections::HashMap::new();
            let mut ghosts: Vec<u32> = Vec::new();
            let w = ell.w;
            let mut values = vec![0.0f32; nb * w];
            let mut cols = vec![0i32; nb * w];
            let mut diag = vec![0.0f32; nb];
            for (li, &gu) in own.iter().enumerate() {
                let gu = gu as usize;
                diag[li] = ell.diag[gu];
                for s in 0..w {
                    let v = ell.values[gu * w + s];
                    let c = ell.cols[gu * w + s] as usize;
                    values[li * w + s] = v;
                    if v == 0.0 {
                        // Self-referential padding in *local* indexing
                        // (mirrors the EllMatrix fix): the pad's x-load
                        // stays on this row's own entry and can never
                        // alias a ghost column.
                        cols[li * w + s] = li as i32;
                        continue;
                    }
                    let cb = part.assignment[c] as usize;
                    cols[li * w + s] = if cb == b {
                        local_of[c] as i32
                    } else {
                        let next = nb as u32 + ghosts.len() as u32;
                        let gl = *ghost_local.entry(c as u32).or_insert_with(|| {
                            ghosts.push(c as u32);
                            next
                        });
                        gl as i32
                    };
                }
            }
            // Split rows by whether they reference a ghost column: the
            // interior rows are exactly the work a nonblocking halo
            // exchange can hide.
            let mut interior = Vec::new();
            let mut boundary = Vec::new();
            for li in 0..nb {
                let touches_ghost = (0..w).any(|s| {
                    values[li * w + s] != 0.0 && cols[li * w + s] as usize >= nb
                });
                if touches_ghost {
                    boundary.push(li as u32);
                } else {
                    interior.push(li as u32);
                }
            }
            let ell_local = EllMatrix {
                n: nb,
                w,
                values,
                cols,
                diag,
            };
            blocks.push(HaloBlock {
                ell: ell_local,
                own,
                ghosts,
                send_lists: Vec::new(), // filled below
                interior,
                boundary,
            });
        }
        // Send lists: for each block's ghosts, tell the owner to send.
        let mut sends: Vec<std::collections::HashMap<u32, Vec<u32>>> =
            vec![std::collections::HashMap::new(); k];
        for (b, blk) in blocks.iter().enumerate() {
            for &g in &blk.ghosts {
                let owner = part.assignment[g as usize] as usize;
                sends[owner]
                    .entry(b as u32)
                    .or_default()
                    .push(local_of[g as usize]);
            }
        }
        for (b, blk) in blocks.iter_mut().enumerate() {
            let mut lists: Vec<(u32, Vec<u32>)> = sends[b]
                .iter()
                .map(|(nb, l)| (*nb, l.clone()))
                .collect();
            lists.sort_unstable_by_key(|(nb, _)| *nb);
            blk.send_lists = lists;
        }
        HaloMatrix { blocks, n }
    }

    /// Words sent by block `b` per SpMV (= Σ send list lengths). Matches
    /// `partition::metrics` communication volume by construction.
    pub fn send_volume(&self, b: usize) -> usize {
        self.blocks[b].send_lists.iter().map(|(_, l)| l.len()).sum()
    }

    /// The static exchange pattern for the virtual-cluster engine — the
    /// seam `exec::Comm` transports execute.
    pub fn exchange_plan(&self, part: &Partition) -> crate::exec::ExchangePlan {
        crate::exec::ExchangePlan::new(self, part)
    }

    /// One distributed SpMV with the per-block work chunked across the
    /// job queue. Identical numerics to [`HaloMatrix::spmv`] (which is
    /// this with one worker); block rows are disjoint so blocks compute
    /// independently and the leader scatters.
    pub fn par_spmv(&self, x: &[f32], y: &mut [f32], workers: usize) {
        let parts = crate::coordinator::jobqueue::run_jobs(
            (0..self.blocks.len()).collect(),
            workers.max(1),
            |&b| {
                let blk = &self.blocks[b];
                let xl = blk.gather_local(x);
                let mut y_local = vec![0.0f32; blk.own.len()];
                blk.spmv_local(&xl, &mut y_local);
                (b, y_local)
            },
        );
        for (b, y_local) in parts {
            for (li, &g) in self.blocks[b].own.iter().enumerate() {
                y[g as usize] = y_local[li];
            }
        }
    }

    /// One full distributed SpMV: exchange halos, then compute locally.
    /// `x` and `y` are global vectors (the "MPI" is in-process). Local x
    /// is `[owned | ghosts]` — the receive side of the halo exchange;
    /// senders' lists are the mirror image.
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        self.par_spmv(x, y, 1);
    }
}

impl super::cg::SpmvBackend for HaloMatrix {
    fn n(&self) -> usize {
        self.n
    }
    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> anyhow::Result<()> {
        HaloMatrix::spmv(self, x, y);
        Ok(())
    }
}

/// Zero-allocation CG backend over a [`HaloMatrix`]: all workspaces —
/// per-block local vectors and (for the SELL layout) the kernel
/// structures — are built once up front, so the solve loop performs
/// **zero heap allocations per iteration** (`cg_solve` preallocates its
/// side too; pinned by `tests/alloc_counter.rs`).
///
/// The SpMV is the *fused* interior/boundary path: each block gathers its
/// `[own | ghost]` x into a reused workspace (the in-process halo
/// exchange), runs the interior rows, then the boundary rows — the same
/// split the nonblocking engine overlaps, here exploited purely for the
/// allocation-free fast path. Results are bit-identical to
/// [`HaloMatrix::spmv`] on the ELL layout (same `spmv_row` body, disjoint
/// row sets) and `==`-equal on SELL-C-σ (see `solver::sell`).
pub struct HaloSolver<'a> {
    h: &'a HaloMatrix,
    layout: SpmvLayout,
    /// Per-block (interior, boundary) SELL kernels; empty on the ELL path.
    sell: Vec<(SellMatrix, SellMatrix)>,
    /// Per-block reused `[own | ghosts]` gather buffers.
    xl: Vec<Vec<f32>>,
    /// Per-block reused local results (SELL path scatters through these).
    yl: Vec<Vec<f32>>,
}

impl<'a> HaloSolver<'a> {
    /// Preallocate every workspace (and build the SELL kernels when
    /// `layout` asks for them).
    pub fn new(h: &'a HaloMatrix, layout: SpmvLayout) -> HaloSolver<'a> {
        let sell = match layout {
            SpmvLayout::Ell => Vec::new(),
            SpmvLayout::SellCs => h
                .blocks
                .iter()
                .map(|blk| {
                    (
                        SellMatrix::from_ell_rows(&blk.ell, &blk.interior, DEFAULT_CHUNK, DEFAULT_SIGMA),
                        SellMatrix::from_ell_rows(&blk.ell, &blk.boundary, DEFAULT_CHUNK, DEFAULT_SIGMA),
                    )
                })
                .collect(),
        };
        let xl = h.blocks.iter().map(|b| vec![0.0f32; b.own.len() + b.ghosts.len()]).collect();
        let yl = h.blocks.iter().map(|b| vec![0.0f32; b.own.len()]).collect();
        HaloSolver { h, layout, sell, xl, yl }
    }

    /// Which layout the kernels run on.
    pub fn layout(&self) -> SpmvLayout {
        self.layout
    }
}

impl super::cg::SpmvBackend for HaloSolver<'_> {
    fn n(&self) -> usize {
        self.h.n
    }

    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> anyhow::Result<()> {
        let h = self.h;
        // Halo exchange: every block's gather is the in-process receive.
        for (b, blk) in h.blocks.iter().enumerate() {
            blk.gather_local_into(x, &mut self.xl[b]);
        }
        // Fused interior-then-boundary compute per block.
        for (b, blk) in h.blocks.iter().enumerate() {
            let xl = &self.xl[b];
            match self.layout {
                SpmvLayout::Ell => {
                    for &li in &blk.interior {
                        y[blk.own[li as usize] as usize] = blk.spmv_row(xl, li as usize);
                    }
                    for &li in &blk.boundary {
                        y[blk.own[li as usize] as usize] = blk.spmv_row(xl, li as usize);
                    }
                }
                SpmvLayout::SellCs => {
                    let yl = &mut self.yl[b];
                    let (interior, boundary) = &self.sell[b];
                    interior.spmv_into(xl, yl);
                    boundary.spmv_into(xl, yl);
                    for (li, &g) in blk.own.iter().enumerate() {
                        y[g as usize] = yl[li];
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::partition::{metrics, Partition};
    use crate::solver::spmv::spmv_ell_native;

    fn setup() -> (crate::graph::Csr, EllMatrix, Partition) {
        let g = mesh_2d_tri(16, 16, 3);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let part = Partition::new(
            (0..g.n())
                .map(|u| u32::from(g.coords[u].x > 7.5) + 2 * u32::from(g.coords[u].y > 7.5))
                .collect(),
            4,
        );
        (g, ell, part)
    }

    #[test]
    fn halo_spmv_equals_whole() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.17).cos()).collect();
        let whole = spmv_ell_native(&ell, &x);
        let mut y = vec![0.0f32; ell.n];
        h.spmv(&x, &mut y);
        for i in 0..ell.n {
            assert!((y[i] - whole[i]).abs() < 1e-5, "row {i}");
        }
    }

    #[test]
    fn ghost_lists_match_comm_volume_metric() {
        let (g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        let m = metrics(&g, &part, &[]);
        let total_send: usize = (0..part.k).map(|b| h.send_volume(b)).sum();
        assert_eq!(
            total_send as f64, m.total_comm_volume,
            "halo send lists must equal the metric's comm volume"
        );
        let max_send = (0..part.k).map(|b| h.send_volume(b)).max().unwrap();
        assert_eq!(max_send as f64, m.max_comm_volume);
    }

    #[test]
    fn ghosts_are_owned_elsewhere_and_unique() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        for (b, blk) in h.blocks.iter().enumerate() {
            let mut seen = std::collections::HashSet::new();
            for &g in &blk.ghosts {
                assert_ne!(part.assignment[g as usize] as usize, b);
                assert!(seen.insert(g), "duplicate ghost {g}");
            }
        }
    }

    #[test]
    fn par_spmv_matches_sequential_spmv() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.29).sin()).collect();
        let mut seq = vec![0.0f32; ell.n];
        h.spmv(&x, &mut seq);
        for workers in [1, 3] {
            let mut par = vec![0.0f32; ell.n];
            h.par_spmv(&x, &mut par, workers);
            assert_eq!(seq, par, "workers={workers}");
        }
    }

    #[test]
    fn exchange_plan_mirrors_send_volume() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        let plan = h.exchange_plan(&part);
        for b in 0..part.k {
            assert_eq!(plan.send_volume(b), h.send_volume(b));
        }
    }

    #[test]
    fn halo_cg_converges() {
        use crate::solver::cg::cg_solve;
        let (_g, ell, part) = setup();
        let mut h = HaloMatrix::new(&ell, &part);
        let b: Vec<f32> = (0..ell.n).map(|i| (i % 5) as f32 - 2.0).collect();
        let res = cg_solve(&mut h, &b, 200, 1e-5).unwrap();
        let whole = spmv_ell_native(&ell, &res.x);
        let err = whole
            .iter()
            .zip(&b)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-2, "max |Ax-b| {err}");
    }

    #[test]
    fn interior_boundary_split_covers_all_rows_and_matches_full_spmv() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.23).cos()).collect();
        for blk in &h.blocks {
            let nb = blk.own.len();
            // Disjoint cover of all local rows.
            let mut seen = vec![false; nb];
            for &li in blk.interior.iter().chain(&blk.boundary) {
                assert!(!seen[li as usize], "row {li} in both splits");
                seen[li as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "split misses rows");
            // Boundary rows are exactly those touching ghost columns.
            for &li in &blk.boundary {
                let li = li as usize;
                let touches = (0..blk.ell.w).any(|s| {
                    blk.ell.values[li * blk.ell.w + s] != 0.0
                        && blk.ell.cols[li * blk.ell.w + s] as usize >= nb
                });
                assert!(touches, "boundary row {li} has no ghost column");
            }
            // interior-then-boundary ≡ the full kernel, bit for bit.
            let xl = blk.gather_local(&x);
            let mut full = vec![0.0f32; nb];
            blk.spmv_local(&xl, &mut full);
            let mut split = vec![0.0f32; nb];
            blk.spmv_rows(&xl, &mut split, &blk.interior);
            blk.spmv_rows(&xl, &mut split, &blk.boundary);
            assert_eq!(full, split);
        }
        // A nontrivial partition must actually have both kinds of rows.
        assert!(h.blocks.iter().any(|b| !b.interior.is_empty()));
        assert!(h.blocks.iter().any(|b| !b.boundary.is_empty()));
    }

    #[test]
    fn local_padding_is_self_referential() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        for blk in &h.blocks {
            let w = blk.ell.w;
            for li in 0..blk.own.len() {
                for s in 0..w {
                    if blk.ell.values[li * w + s] == 0.0 {
                        assert_eq!(blk.ell.cols[li * w + s], li as i32, "row {li} slot {s}");
                    }
                }
            }
        }
    }

    #[test]
    fn halo_solver_matches_halo_spmv_on_both_layouts() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.41).sin()).collect();
        let mut reference = vec![0.0f32; ell.n];
        h.spmv(&x, &mut reference);
        for layout in [SpmvLayout::Ell, SpmvLayout::SellCs] {
            use crate::solver::cg::SpmvBackend;
            let mut solver = HaloSolver::new(&h, layout);
            assert_eq!(solver.layout(), layout);
            let mut y = vec![0.0f32; ell.n];
            solver.spmv(&x, &mut y).unwrap();
            assert_eq!(y, reference, "layout {}", layout.name());
            // Workspaces are reused, not regrown: a second call agrees.
            let mut y2 = vec![0.0f32; ell.n];
            solver.spmv(&x, &mut y2).unwrap();
            assert_eq!(y2, reference);
        }
    }

    #[test]
    fn halo_solver_cg_trajectory_matches_reference_backend() {
        use crate::solver::cg::cg_solve;
        let (_g, ell, part) = setup();
        let mut h = HaloMatrix::new(&ell, &part);
        let b: Vec<f32> = (0..ell.n).map(|i| (i % 5) as f32 - 2.0).collect();
        let reference = cg_solve(&mut h, &b, 120, 1e-5).unwrap();
        for layout in [SpmvLayout::Ell, SpmvLayout::SellCs] {
            let mut solver = HaloSolver::new(&h, layout);
            let res = cg_solve(&mut solver, &b, 120, 1e-5).unwrap();
            assert_eq!(res.iterations, reference.iterations, "layout {}", layout.name());
            assert_eq!(res.x, reference.x, "layout {}", layout.name());
            assert_eq!(res.residual_norms, reference.residual_norms);
        }
    }

    #[test]
    fn send_lists_are_mirror_of_ghosts() {
        let (_g, ell, part) = setup();
        let h = HaloMatrix::new(&ell, &part);
        // Sum over blocks of (ghosts from owner o) == o's send list to b.
        for (b, blk) in h.blocks.iter().enumerate() {
            let mut from_owner: std::collections::HashMap<u32, usize> =
                std::collections::HashMap::new();
            for &g in &blk.ghosts {
                *from_owner.entry(part.assignment[g as usize]).or_default() += 1;
            }
            for (o, count) in from_owner {
                let send = h.blocks[o as usize]
                    .send_lists
                    .iter()
                    .find(|(nb, _)| *nb == b as u32)
                    .map(|(_, l)| l.len())
                    .unwrap_or(0);
                assert_eq!(send, count, "owner {o} -> block {b}");
            }
        }
    }
}
