//! Distributed SpMV/CG: the matrix is split into per-PU row blocks
//! according to a partition (exactly how the paper's LAMA runs distribute
//! the Laplacian, §VI-a); each "PU" computes its rows, the leader
//! assembles. Single-process here, but the data movement mirrors the
//! MPI version: per-PU row blocks with global-indexed columns + a halo
//! of the global vector — and the per-PU compute times feed the
//! heterogeneous simulator.
//!
//! Also home of [`pipelined_cg_solve`], the sequential reference for the
//! Saad/Eller-style single-reduction CG the virtual-cluster engine runs
//! as `exec::CgVariant::Pipelined` (see DESIGN.md §5 for the
//! derivation): both dot products a CG iteration needs, p·Ap and Ap·Ap,
//! are available right after the SpMV, so they ride **one** allreduce
//! and ‖r‖² follows from the recurrence `rs' = α²·(Ap·Ap) − rs` instead
//! of a second reduction — halving the per-iteration synchronization
//! count at the price of a slightly different round-off trajectory.

use super::cg::{CgResult, SpmvBackend};
use super::ell::EllMatrix;
use super::spmv::spmv_block_rows_full;
use crate::partition::Partition;
use anyhow::Result;

/// Single-reduction (pipelined) CG from x₀ = 0: one combined reduction
/// per iteration instead of two. Same solution as [`super::cg_solve`]
/// within CG round-off; the reported residual norms come from the
/// recurrence, not an explicit r·r.
///
/// Dot products accumulate in f64 (like the distributed engine's
/// rank-order reductions), so this function is the sequential
/// cross-check for `VirtualCluster::solve_cg_opts` with
/// `CgVariant::Pipelined`.
pub fn pipelined_cg_solve<B: SpmvBackend>(
    backend: &mut B,
    b: &[f32],
    max_iters: usize,
    tol: f32,
) -> Result<CgResult> {
    let n = backend.n();
    assert_eq!(b.len(), n);
    const TINY: f64 = 1e-30;
    let mut x = vec![0.0f32; n];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut ap = vec![0.0f32; n];
    let dot = |a: &[f32], b: &[f32]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x * *y) as f64).sum()
    };
    let mut rs = dot(&r, &r);
    let b_norm = rs.sqrt().max(TINY);
    let mut norms = Vec::with_capacity(max_iters);
    let mut iters = 0;
    for _ in 0..max_iters {
        backend.spmv(&p, &mut ap)?;
        // The single combined "allreduce": both scalars in one message.
        let p_ap = dot(&p, &ap).max(TINY);
        let ap_ap = dot(&ap, &ap);
        let alpha = rs / p_ap;
        // rs' = rs − 2α(p·Ap) + α²(Ap·Ap) with α = rs/(p·Ap) collapses
        // to α²(Ap·Ap) − rs; clamp against late-stage cancellation.
        let rs_new = (alpha * alpha * ap_ap - rs).max(0.0);
        let beta = (rs_new / rs.max(TINY)) as f32;
        let alpha = alpha as f32;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
        iters += 1;
        norms.push(rs.sqrt() as f32);
        if rs.sqrt() <= tol as f64 * b_norm {
            break;
        }
    }
    Ok(CgResult { x, residual_norms: norms, iterations: iters })
}

/// Row-distributed ELL matrix.
pub struct DistributedMatrix {
    /// Per block: (row-block with global columns, owned global rows).
    pub blocks: Vec<(EllMatrix, Vec<u32>)>,
    /// Global number of rows.
    pub n: usize,
    /// Wall-clock seconds spent in each block's SpMV since the last
    /// `take_times` (drives the simulator's per-PU compute observation).
    per_block_secs: Vec<f64>,
}

impl DistributedMatrix {
    /// Split `ell` into per-PU row blocks according to `part`.
    pub fn new(ell: &EllMatrix, part: &Partition) -> DistributedMatrix {
        let blocks: Vec<(EllMatrix, Vec<u32>)> = (0..part.k as u32)
            .map(|b| ell.block_rows(&part.assignment, b))
            .collect();
        DistributedMatrix {
            n: ell.n,
            per_block_secs: vec![0.0; blocks.len()],
            blocks,
        }
    }

    /// Reset and return the accumulated per-block SpMV seconds.
    pub fn take_times(&mut self) -> Vec<f64> {
        std::mem::replace(&mut self.per_block_secs, vec![0.0; self.blocks.len()])
    }
}

impl SpmvBackend for DistributedMatrix {
    fn n(&self) -> usize {
        self.n
    }

    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> Result<()> {
        for (b, (ell_b, rows)) in self.blocks.iter().enumerate() {
            let t = crate::util::timer::Timer::start();
            let mut y_local = vec![0.0f32; rows.len()];
            spmv_block_rows_full(ell_b, rows, x, &mut y_local);
            for (i, &r) in rows.iter().enumerate() {
                y[r as usize] = y_local[i];
            }
            self.per_block_secs[b] += t.secs();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::solver::cg::{cg_solve, NativeBackend};
    use crate::solver::spmv::spmv_ell_native;

    fn setup() -> (crate::graph::Csr, EllMatrix, Partition) {
        let g = mesh_2d_tri(20, 20, 1);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let part = Partition::new(
            (0..g.n()).map(|u| ((g.coords[u].x > 9.5) as u32) + 2 * ((g.coords[u].y > 9.5) as u32)).collect(),
            4,
        );
        (g, ell, part)
    }

    #[test]
    fn distributed_spmv_equals_whole() {
        let (_g, ell, part) = setup();
        let mut dist = DistributedMatrix::new(&ell, &part);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.31).sin()).collect();
        let whole = spmv_ell_native(&ell, &x);
        let mut y = vec![0.0f32; ell.n];
        dist.spmv(&x, &mut y).unwrap();
        for i in 0..ell.n {
            assert!((y[i] - whole[i]).abs() < 1e-5, "row {i}");
        }
    }

    #[test]
    fn distributed_cg_equals_sequential() {
        let (_g, ell, part) = setup();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
        let mut whole = NativeBackend { a: &ell };
        let seq = cg_solve(&mut whole, &b, 80, 0.0).unwrap();
        let mut dist = DistributedMatrix::new(&ell, &part);
        let par = cg_solve(&mut dist, &b, 80, 0.0).unwrap();
        let max_diff = seq
            .x
            .iter()
            .zip(&par.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "distributed CG diverged: {max_diff}");
    }

    #[test]
    fn per_block_times_accumulate() {
        let (_g, ell, part) = setup();
        let mut dist = DistributedMatrix::new(&ell, &part);
        let x = vec![1.0f32; ell.n];
        let mut y = vec![0.0f32; ell.n];
        dist.spmv(&x, &mut y).unwrap();
        let times = dist.take_times();
        assert_eq!(times.len(), 4);
        assert!(times.iter().all(|&t| t >= 0.0));
        // Second take is reset.
        assert!(dist.take_times().iter().all(|&t| t == 0.0));
    }

    #[test]
    fn pipelined_cg_matches_classic_solution() {
        let (_g, ell, _part) = setup();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
        // 40 iterations keeps both solvers well above the f32 convergence
        // floor, where the ‖r‖² recurrence is a faithful tracker; at the
        // floor it deviates by design (the pipelined-CG trade-off).
        let mut whole = NativeBackend { a: &ell };
        let seq = cg_solve(&mut whole, &b, 40, 0.0).unwrap();
        let mut whole = NativeBackend { a: &ell };
        let pipe = pipelined_cg_solve(&mut whole, &b, 40, 0.0).unwrap();
        assert_eq!(pipe.iterations, seq.iterations);
        let max_diff = seq
            .x
            .iter()
            .zip(&pipe.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "pipelined diverged from classic: {max_diff}");
        // The recurrence residual tracks the explicit one away from the
        // floor.
        let (a, b) = (
            *seq.residual_norms.last().unwrap(),
            *pipe.residual_norms.last().unwrap(),
        );
        assert!((a - b).abs() <= 0.25 * a.abs().max(1e-6), "residuals {a} vs {b}");
    }

    #[test]
    fn pipelined_cg_works_on_the_distributed_backend() {
        let (_g, ell, part) = setup();
        let b: Vec<f32> = (0..ell.n).map(|i| (i % 5) as f32 - 2.0).collect();
        let mut dist = DistributedMatrix::new(&ell, &part);
        let par = pipelined_cg_solve(&mut dist, &b, 120, 1e-5).unwrap();
        let whole = spmv_ell_native(&ell, &par.x);
        let err = whole
            .iter()
            .zip(&b)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-2, "max |Ax-b| {err}");
    }

    #[test]
    fn pipelined_cg_handles_zero_rhs() {
        let (_g, ell, _part) = setup();
        let b = vec![0.0f32; ell.n];
        let mut whole = NativeBackend { a: &ell };
        let res = pipelined_cg_solve(&mut whole, &b, 10, 1e-6).unwrap();
        assert!(res.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn all_rows_covered_once() {
        let (_g, ell, part) = setup();
        let dist = DistributedMatrix::new(&ell, &part);
        let mut seen = vec![false; ell.n];
        for (_, rows) in &dist.blocks {
            for &r in rows {
                assert!(!seen[r as usize], "row {r} in two blocks");
                seen[r as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
