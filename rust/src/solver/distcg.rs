//! Distributed SpMV/CG: the matrix is split into per-PU row blocks
//! according to a partition (exactly how the paper's LAMA runs distribute
//! the Laplacian, §VI-a); each "PU" computes its rows, the leader
//! assembles. Single-process here, but the data movement mirrors the
//! MPI version: per-PU row blocks with global-indexed columns + a halo
//! of the global vector — and the per-PU compute times feed the
//! heterogeneous simulator.

use super::cg::SpmvBackend;
use super::ell::EllMatrix;
use super::spmv::spmv_block_rows_full;
use crate::partition::Partition;
use anyhow::Result;

/// Row-distributed ELL matrix.
pub struct DistributedMatrix {
    /// Per block: (row-block with global columns, owned global rows).
    pub blocks: Vec<(EllMatrix, Vec<u32>)>,
    pub n: usize,
    /// Wall-clock seconds spent in each block's SpMV since the last
    /// `take_times` (drives the simulator's per-PU compute observation).
    per_block_secs: Vec<f64>,
}

impl DistributedMatrix {
    pub fn new(ell: &EllMatrix, part: &Partition) -> DistributedMatrix {
        let blocks: Vec<(EllMatrix, Vec<u32>)> = (0..part.k as u32)
            .map(|b| ell.block_rows(&part.assignment, b))
            .collect();
        DistributedMatrix {
            n: ell.n,
            per_block_secs: vec![0.0; blocks.len()],
            blocks,
        }
    }

    /// Reset and return the accumulated per-block SpMV seconds.
    pub fn take_times(&mut self) -> Vec<f64> {
        std::mem::replace(&mut self.per_block_secs, vec![0.0; self.blocks.len()])
    }
}

impl SpmvBackend for DistributedMatrix {
    fn n(&self) -> usize {
        self.n
    }

    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> Result<()> {
        for (b, (ell_b, rows)) in self.blocks.iter().enumerate() {
            let t = crate::util::timer::Timer::start();
            let mut y_local = vec![0.0f32; rows.len()];
            spmv_block_rows_full(ell_b, rows, x, &mut y_local);
            for (i, &r) in rows.iter().enumerate() {
                y[r as usize] = y_local[i];
            }
            self.per_block_secs[b] += t.secs();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::solver::cg::{cg_solve, NativeBackend};
    use crate::solver::spmv::spmv_ell_native;

    fn setup() -> (crate::graph::Csr, EllMatrix, Partition) {
        let g = mesh_2d_tri(20, 20, 1);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let part = Partition::new(
            (0..g.n()).map(|u| ((g.coords[u].x > 9.5) as u32) + 2 * ((g.coords[u].y > 9.5) as u32)).collect(),
            4,
        );
        (g, ell, part)
    }

    #[test]
    fn distributed_spmv_equals_whole() {
        let (_g, ell, part) = setup();
        let mut dist = DistributedMatrix::new(&ell, &part);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.31).sin()).collect();
        let whole = spmv_ell_native(&ell, &x);
        let mut y = vec![0.0f32; ell.n];
        dist.spmv(&x, &mut y).unwrap();
        for i in 0..ell.n {
            assert!((y[i] - whole[i]).abs() < 1e-5, "row {i}");
        }
    }

    #[test]
    fn distributed_cg_equals_sequential() {
        let (_g, ell, part) = setup();
        let b: Vec<f32> = (0..ell.n).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
        let mut whole = NativeBackend { a: &ell };
        let seq = cg_solve(&mut whole, &b, 80, 0.0).unwrap();
        let mut dist = DistributedMatrix::new(&ell, &part);
        let par = cg_solve(&mut dist, &b, 80, 0.0).unwrap();
        let max_diff = seq
            .x
            .iter()
            .zip(&par.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "distributed CG diverged: {max_diff}");
    }

    #[test]
    fn per_block_times_accumulate() {
        let (_g, ell, part) = setup();
        let mut dist = DistributedMatrix::new(&ell, &part);
        let x = vec![1.0f32; ell.n];
        let mut y = vec![0.0f32; ell.n];
        dist.spmv(&x, &mut y).unwrap();
        let times = dist.take_times();
        assert_eq!(times.len(), 4);
        assert!(times.iter().all(|&t| t >= 0.0));
        // Second take is reset.
        assert!(dist.take_times().iter().all(|&t| t == 0.0));
    }

    #[test]
    fn all_rows_covered_once() {
        let (_g, ell, part) = setup();
        let dist = DistributedMatrix::new(&ell, &part);
        let mut seen = vec![false; ell.n];
        for (_, rows) in &dist.blocks {
            for &r in rows {
                assert!(!seen[r as usize], "row {r} in two blocks");
                seen[r as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
