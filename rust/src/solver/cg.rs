//! Conjugate gradients in rust (f32), over any SpMV backend.
//!
//! The backend abstraction lets the same driver run on:
//! - the native ELL SpMV (always available), sequential or chunked
//!   across the job queue ([`NativeParBackend`]),
//! - the virtual-cluster execution engine (`exec::ClusterBackend`
//!   routes each SpMV through a halo exchange over a `Comm` transport),
//! - a PJRT executable compiled from the L2/L1 artifact (the production
//!   path of the three-layer architecture).

use super::ell::EllMatrix;
use super::spmv::{par_spmv_ell_into, spmv_ell_into};
use anyhow::Result;

/// SpMV provider for the CG driver.
pub trait SpmvBackend {
    /// Problem size (rows of the operator).
    fn n(&self) -> usize;
    /// y = A·x.
    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> Result<()>;
}

/// Native backend over an [`EllMatrix`].
pub struct NativeBackend<'a> {
    /// The matrix applied on every `spmv` call.
    pub a: &'a EllMatrix,
}

impl<'a> SpmvBackend for NativeBackend<'a> {
    fn n(&self) -> usize {
        self.a.n
    }
    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> Result<()> {
        spmv_ell_into(self.a, x, y);
        Ok(())
    }
}

/// Native backend with the SpMV rows chunked across the job queue.
/// Bit-identical numerics to [`NativeBackend`] (the parallel SpMV
/// computes each row independently with the same code).
pub struct NativeParBackend<'a> {
    /// The matrix applied on every `spmv` call.
    pub a: &'a EllMatrix,
    /// Worker threads for the row chunks (see `coordinator::jobqueue`).
    pub workers: usize,
}

impl<'a> SpmvBackend for NativeParBackend<'a> {
    fn n(&self) -> usize {
        self.a.n
    }
    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> Result<()> {
        par_spmv_ell_into(self.a, x, y, self.workers);
        Ok(())
    }
}

/// PJRT backend over a compiled spmv artifact (matrix captured padded).
/// The matrix is device-resident (bound once); only x moves per call —
/// see EXPERIMENTS.md §Perf for the before/after.
pub struct PjrtBackend<'a> {
    bound: crate::runtime::BoundSpmv<'a>,
    n: usize,
}

impl<'a> PjrtBackend<'a> {
    /// Bind the padded matrix device-resident on `exec`.
    pub fn new(exec: &'a crate::runtime::SpmvExec, a: &EllMatrix) -> Result<PjrtBackend<'a>> {
        anyhow::ensure!(a.n == exec.n && a.w == exec.w, "matrix/artifact shape mismatch");
        Ok(PjrtBackend { bound: exec.bind(&a.values, &a.cols, &a.diag)?, n: a.n })
    }
}

impl<'a> SpmvBackend for PjrtBackend<'a> {
    fn n(&self) -> usize {
        self.n
    }
    fn spmv(&mut self, x: &[f32], y: &mut [f32]) -> Result<()> {
        let out = self.bound.run(x)?;
        y.copy_from_slice(&out);
        Ok(())
    }
}

/// CG outcome.
#[derive(Debug, Clone)]
pub struct CgResult {
    /// Final iterate.
    pub x: Vec<f32>,
    /// ‖r‖ after every iteration.
    pub residual_norms: Vec<f32>,
    /// Iterations executed.
    pub iterations: usize,
}

/// Run CG from x₀ = 0 for at most `max_iters`, stopping early at
/// ‖r‖ ≤ `tol`·‖b‖. Guarded divisions as in the L2 model.
pub fn cg_solve<B: SpmvBackend>(
    backend: &mut B,
    b: &[f32],
    max_iters: usize,
    tol: f32,
) -> Result<CgResult> {
    let n = backend.n();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut ap = vec![0.0f32; n];
    let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
    let mut rs = dot(&r, &r);
    let b_norm = rs.sqrt().max(1e-30);
    let mut norms = Vec::with_capacity(max_iters);
    let tiny = 1e-30f32;
    let mut iters = 0;
    for _ in 0..max_iters {
        backend.spmv(&p, &mut ap)?;
        let p_ap = dot(&p, &ap).max(tiny);
        let alpha = rs / p_ap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs.max(tiny);
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
        iters += 1;
        norms.push(rs.sqrt());
        if rs.sqrt() <= tol * b_norm {
            break;
        }
    }
    Ok(CgResult { x, residual_norms: norms, iterations: iters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{mesh_2d_tri, rgg_2d};
    use crate::solver::ell::EllMatrix;
    use crate::solver::spmv::spmv_ell_native;

    #[test]
    fn converges_on_mesh_laplacian() {
        let g = mesh_2d_tri(16, 16, 1);
        let a = EllMatrix::from_graph(&g, 0.05);
        let b: Vec<f32> = (0..g.n()).map(|i| ((i * 7) % 13) as f32 - 6.0).collect();
        let mut backend = NativeBackend { a: &a };
        let res = cg_solve(&mut backend, &b, 500, 1e-5).unwrap();
        // Residual dropped 5 orders of magnitude.
        let r0 = res.residual_norms[0];
        let rl = *res.residual_norms.last().unwrap();
        assert!(rl <= 1e-4 * r0.max(1.0), "residual {rl} (start {r0})");
        // Verify Ax ≈ b independently.
        let ax = spmv_ell_native(&a, &res.x);
        let err: f32 = ax.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0, f32::max);
        assert!(err < 1e-2, "max |Ax-b| = {err}");
    }

    #[test]
    fn parallel_backend_matches_native() {
        let g = mesh_2d_tri(96, 96, 4); // big enough for the chunked path
        let a = EllMatrix::from_graph(&g, 0.05);
        let b: Vec<f32> = (0..g.n()).map(|i| ((i * 3) % 11) as f32 - 5.0).collect();
        let mut seq = NativeBackend { a: &a };
        let r_seq = cg_solve(&mut seq, &b, 60, 0.0).unwrap();
        let mut par = NativeParBackend { a: &a, workers: 4 };
        let r_par = cg_solve(&mut par, &b, 60, 0.0).unwrap();
        assert_eq!(r_seq.residual_norms, r_par.residual_norms);
        assert_eq!(r_seq.x, r_par.x);
    }

    #[test]
    fn early_stopping_respects_tol() {
        let g = mesh_2d_tri(12, 12, 2);
        let a = EllMatrix::from_graph(&g, 0.1);
        let b = vec![1.0f32; g.n()];
        let mut backend = NativeBackend { a: &a };
        let loose = cg_solve(&mut backend, &b, 500, 1e-2).unwrap();
        let tight = cg_solve(&mut backend, &b, 500, 1e-6).unwrap();
        assert!(loose.iterations <= tight.iterations);
        assert!(loose.iterations < 500);
    }

    #[test]
    fn handles_converged_start_gracefully() {
        // b = 0 → rs = 0 immediately; guarded divisions must not NaN.
        let g = mesh_2d_tri(8, 8, 3);
        let a = EllMatrix::from_graph(&g, 0.1);
        let b = vec![0.0f32; g.n()];
        let mut backend = NativeBackend { a: &a };
        let res = cg_solve(&mut backend, &b, 10, 1e-6).unwrap();
        assert!(res.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn residuals_mostly_decrease() {
        let g = rgg_2d(800, 4);
        let a = EllMatrix::from_graph(&g, 0.2);
        let b: Vec<f32> = (0..g.n()).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut backend = NativeBackend { a: &a };
        let res = cg_solve(&mut backend, &b, 100, 0.0).unwrap();
        let ns = &res.residual_norms;
        let drops = ns.windows(2).filter(|w| w[1] <= w[0] * 1.2).count();
        assert!(
            drops as f64 > 0.8 * (ns.len() - 1) as f64,
            "residuals too noisy"
        );
    }
}
