//! Jacobi-preconditioned CG.
//!
//! The paper's LAMA solves use CG on the shifted Laplacian; diagonal
//! (Jacobi) preconditioning is the standard upgrade and is cheap to
//! distribute (the preconditioner is block-local by construction), so we
//! provide it as a solver option and compare iteration counts in the
//! ablation bench.

use super::cg::SpmvBackend;
use super::CgResult;
use anyhow::Result;

/// Preconditioned CG with M = diag(A): solve M z = r exactly per
/// iteration. Falls back to plain CG behaviour when all diagonal entries
/// are 1.
pub fn pcg_solve<B: SpmvBackend>(
    backend: &mut B,
    diag: &[f32],
    b: &[f32],
    max_iters: usize,
    tol: f32,
) -> Result<CgResult> {
    let n = backend.n();
    assert_eq!(b.len(), n);
    assert_eq!(diag.len(), n);
    let inv_d: Vec<f32> = diag.iter().map(|&d| if d.abs() > 1e-30 { 1.0 / d } else { 1.0 }).collect();
    let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
    let mut x = vec![0.0f32; n];
    let mut r = b.to_vec();
    let mut z: Vec<f32> = r.iter().zip(&inv_d).map(|(ri, di)| ri * di).collect();
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let b_norm = dot(b, b).sqrt().max(1e-30);
    let tiny = 1e-30f32;
    let mut ap = vec![0.0f32; n];
    let mut norms = Vec::with_capacity(max_iters);
    let mut iters = 0;
    for _ in 0..max_iters {
        backend.spmv(&p, &mut ap)?;
        let alpha = rz / dot(&p, &ap).max(tiny);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        for i in 0..n {
            z[i] = r[i] * inv_d[i];
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz.max(tiny);
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz = rz_new;
        iters += 1;
        let rn = dot(&r, &r).sqrt();
        norms.push(rn);
        if rn <= tol * b_norm {
            break;
        }
    }
    Ok(CgResult { x, residual_norms: norms, iterations: iters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::solver::cg::{cg_solve, NativeBackend};
    use crate::solver::spmv::spmv_ell_native;
    use crate::solver::EllMatrix;

    /// Weighted mesh: spread edge weights so the diagonal varies and
    /// Jacobi actually helps.
    fn weighted_system() -> EllMatrix {
        let g0 = mesh_2d_tri(20, 20, 4);
        let mut b = crate::graph::GraphBuilder::new(g0.n());
        for u in 0..g0.n() {
            for &v in g0.neighbors(u) {
                if (v as usize) > u {
                    let w = 1.0 + ((u * 31 + v as usize * 17) % 19) as f64;
                    b.add_weighted_edge(u, v as usize, w);
                }
            }
        }
        b.set_coords(g0.coords.clone());
        EllMatrix::from_graph(&b.build(), 0.5)
    }

    #[test]
    fn pcg_solves_the_system() {
        let a = weighted_system();
        let b: Vec<f32> = (0..a.n).map(|i| ((i % 11) as f32 - 5.0) / 3.0).collect();
        let diag = a.diag.clone();
        let mut backend = NativeBackend { a: &a };
        let res = pcg_solve(&mut backend, &diag, &b, 500, 1e-6).unwrap();
        let ax = spmv_ell_native(&a, &res.x);
        let err = ax.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
        assert!(err < 2e-2, "max |Ax-b| {err}");
    }

    #[test]
    fn jacobi_reduces_iterations_on_scaled_system() {
        let a = weighted_system();
        let b: Vec<f32> = (0..a.n).map(|i| (i as f32 * 0.05).sin()).collect();
        let diag = a.diag.clone();
        let tol = 1e-5;
        let mut backend = NativeBackend { a: &a };
        let plain = cg_solve(&mut backend, &b, 2000, tol).unwrap();
        let mut backend = NativeBackend { a: &a };
        let pre = pcg_solve(&mut backend, &diag, &b, 2000, tol).unwrap();
        assert!(
            pre.iterations <= plain.iterations,
            "jacobi {} vs plain {}",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn identity_preconditioner_matches_cg() {
        let g = mesh_2d_tri(12, 12, 5);
        let a = EllMatrix::from_graph(&g, 0.1);
        let b: Vec<f32> = (0..a.n).map(|i| (i % 7) as f32 - 3.0).collect();
        let ones = vec![1.0f32; a.n];
        let mut back1 = NativeBackend { a: &a };
        let plain = cg_solve(&mut back1, &b, 60, 0.0).unwrap();
        let mut back2 = NativeBackend { a: &a };
        let pre = pcg_solve(&mut back2, &ones, &b, 60, 0.0).unwrap();
        let diff = plain
            .x
            .iter()
            .zip(&pre.x)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-4, "identity-M PCG must equal CG, diff {diff}");
    }
}
