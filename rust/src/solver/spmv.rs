//! Native (rust) SpMV over the ELL layout — the fallback backend and the
//! oracle the PJRT path is validated against. The hot loop is kept
//! allocation-free; see EXPERIMENTS.md §Perf for the optimization log.

use super::ell::EllMatrix;

/// y = diag·x + ELL·x, allocating the output.
pub fn spmv_ell_native(a: &EllMatrix, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; a.n];
    spmv_ell_into(a, x, &mut y);
    y
}

/// y = diag·x + ELL·x into a caller buffer (no allocation).
pub fn spmv_ell_into(a: &EllMatrix, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    let w = a.w;
    for u in 0..a.n {
        let mut acc = a.diag[u] * x[u];
        let base = u * w;
        for s in 0..w {
            // Padding entries are (0.0, col 0): they multiply to 0 and
            // cost one fused multiply-add — branch-free by design.
            acc += a.values[base + s] * x[a.cols[base + s] as usize];
        }
        y[u] = acc;
    }
}

/// Block-row SpMV: `a` holds a subset of rows with *global* column
/// indexing (see `EllMatrix::block_rows`); `x` is the full global vector.
pub fn spmv_block_rows(a: &EllMatrix, x_global: &[f32], y_local: &mut [f32]) {
    debug_assert_eq!(y_local.len(), a.n);
    let w = a.w;
    for r in 0..a.n {
        let base = r * w;
        let mut acc = 0.0f32;
        for s in 0..w {
            acc += a.values[base + s] * x_global[a.cols[base + s] as usize];
        }
        y_local[r] = acc;
    }
    // diag indexes the *local* row; its x entry is the owning global row,
    // which callers fold in because they know the row ids. To keep this
    // function self-contained we leave the diagonal to the caller.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::graph::Laplacian;
    use crate::solver::ell::EllMatrix;

    #[test]
    fn matches_f64_laplacian_spmv() {
        let g = mesh_2d_tri(15, 15, 1);
        let lap = Laplacian::from_graph(&g, 0.2);
        let ell = EllMatrix::from_laplacian(&lap);
        let x64: Vec<f64> = (0..g.n()).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let mut y64 = vec![0.0f64; g.n()];
        lap.spmv(&x64, &mut y64);
        let y32 = spmv_ell_native(&ell, &x32);
        for i in 0..g.n() {
            assert!(
                (y64[i] as f32 - y32[i]).abs() < 1e-3,
                "row {i}: {} vs {}",
                y64[i],
                y32[i]
            );
        }
    }

    #[test]
    fn laplacian_times_ones_is_shift() {
        let g = mesh_2d_tri(10, 10, 2);
        let ell = EllMatrix::from_graph(&g, 0.5);
        let x = vec![1.0f32; g.n()];
        let y = spmv_ell_native(&ell, &x);
        for (i, &v) in y.iter().enumerate() {
            assert!((v - 0.5).abs() < 1e-5, "row {i}: {v}");
        }
    }

    #[test]
    fn block_rows_sum_to_whole() {
        let g = mesh_2d_tri(12, 12, 3);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let assignment: Vec<u32> = (0..g.n()).map(|u| (u % 3) as u32).collect();
        let x: Vec<f32> = (0..g.n()).map(|i| (i as f32 * 0.13).cos()).collect();
        let whole = spmv_ell_native(&ell, &x);
        for b in 0..3u32 {
            let (rows_ell, rows) = ell.block_rows(&assignment, b);
            let mut y_local = vec![0.0f32; rows.len()];
            spmv_block_rows(&rows_ell, &x, &mut y_local);
            for (i, &r) in rows.iter().enumerate() {
                let with_diag = y_local[i] + rows_ell.diag[i] * x[r as usize];
                assert!(
                    (with_diag - whole[r as usize]).abs() < 1e-4,
                    "block {b} row {r}: {} vs {}",
                    with_diag,
                    whole[r as usize]
                );
            }
        }
    }
}
