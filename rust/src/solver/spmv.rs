//! Native (rust) SpMV over the ELL layout — the fallback backend and the
//! oracle the PJRT path is validated against. The hot loop is kept
//! allocation-free; see EXPERIMENTS.md §Perf for the optimization log.

use super::ell::EllMatrix;

/// y = diag·x + ELL·x, allocating the output.
pub fn spmv_ell_native(a: &EllMatrix, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; a.n];
    spmv_ell_into(a, x, &mut y);
    y
}

/// y = diag·x + ELL·x into a caller buffer (no allocation).
pub fn spmv_ell_into(a: &EllMatrix, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    spmv_rows_range(a, x, 0, a.n, y);
}

/// Rows `lo..hi` of diag·x + ELL·x into `out` (length `hi - lo`).
fn spmv_rows_range(a: &EllMatrix, x: &[f32], lo: usize, hi: usize, out: &mut [f32]) {
    let w = a.w;
    for (j, u) in (lo..hi).enumerate() {
        let mut acc = a.diag[u] * x[u];
        let base = u * w;
        for s in 0..w {
            // Padding entries are (0.0, self-referential col): they
            // multiply to 0, cost one fused multiply-add, and their
            // x-load stays on the row's own cache line — branch-free by
            // design.
            acc += a.values[base + s] * x[a.cols[base + s] as usize];
        }
        out[j] = acc;
    }
}

/// Rows below which chunking over the job queue costs more than it buys.
const PAR_MIN_ROWS: usize = 4096;

/// y = diag·x + ELL·x with the rows chunked across
/// `coordinator::jobqueue::run_jobs` workers. Bit-identical to
/// [`spmv_ell_into`] (each row is computed independently by the same
/// code), falls back to the sequential path on small inputs.
pub fn par_spmv_ell_into(a: &EllMatrix, x: &[f32], y: &mut [f32], workers: usize) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    let workers = workers.max(1);
    if workers == 1 || a.n < 2 * PAR_MIN_ROWS {
        spmv_ell_into(a, x, y);
        return;
    }
    let chunk = a.n.div_ceil(workers).max(PAR_MIN_ROWS);
    let jobs: Vec<(usize, usize)> = (0..a.n)
        .step_by(chunk)
        .map(|lo| (lo, (lo + chunk).min(a.n)))
        .collect();
    let parts = crate::coordinator::jobqueue::run_jobs(jobs.clone(), workers, |&(lo, hi)| {
        let mut out = vec![0.0f32; hi - lo];
        spmv_rows_range(a, x, lo, hi, &mut out);
        out
    });
    for ((lo, hi), part) in jobs.into_iter().zip(parts) {
        y[lo..hi].copy_from_slice(&part);
    }
}

/// Block-row SpMV **without the diagonal**: `a` holds a subset of rows
/// with *global* column indexing (see `EllMatrix::block_rows`); `x` is
/// the full global vector.
///
/// `diag[r]` pairs with `x[rows[r]]`, which this function cannot know —
/// prefer [`spmv_block_rows_full`], which takes the owned global row ids
/// and folds the diagonal in, so callers cannot silently drop it.
pub fn spmv_block_rows(a: &EllMatrix, x_global: &[f32], y_local: &mut [f32]) {
    debug_assert_eq!(y_local.len(), a.n);
    let w = a.w;
    for r in 0..a.n {
        let base = r * w;
        let mut acc = 0.0f32;
        for s in 0..w {
            acc += a.values[base + s] * x_global[a.cols[base + s] as usize];
        }
        y_local[r] = acc;
    }
}

/// Block-row SpMV *including* the diagonal: `rows` are the owned global
/// row ids (local row r ↔ global `rows[r]`), so
/// `y_local[r] = diag[r]·x[rows[r]] + Σ values[r,s]·x[cols[r,s]]`.
pub fn spmv_block_rows_full(a: &EllMatrix, rows: &[u32], x_global: &[f32], y_local: &mut [f32]) {
    debug_assert_eq!(rows.len(), a.n);
    debug_assert_eq!(y_local.len(), a.n);
    let w = a.w;
    for r in 0..a.n {
        let base = r * w;
        let mut acc = a.diag[r] * x_global[rows[r] as usize];
        for s in 0..w {
            acc += a.values[base + s] * x_global[a.cols[base + s] as usize];
        }
        y_local[r] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::graph::Laplacian;
    use crate::solver::ell::EllMatrix;

    #[test]
    fn matches_f64_laplacian_spmv() {
        let g = mesh_2d_tri(15, 15, 1);
        let lap = Laplacian::from_graph(&g, 0.2);
        let ell = EllMatrix::from_laplacian(&lap);
        let x64: Vec<f64> = (0..g.n()).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let mut y64 = vec![0.0f64; g.n()];
        lap.spmv(&x64, &mut y64);
        let y32 = spmv_ell_native(&ell, &x32);
        for i in 0..g.n() {
            assert!(
                (y64[i] as f32 - y32[i]).abs() < 1e-3,
                "row {i}: {} vs {}",
                y64[i],
                y32[i]
            );
        }
    }

    #[test]
    fn laplacian_times_ones_is_shift() {
        let g = mesh_2d_tri(10, 10, 2);
        let ell = EllMatrix::from_graph(&g, 0.5);
        let x = vec![1.0f32; g.n()];
        let y = spmv_ell_native(&ell, &x);
        for (i, &v) in y.iter().enumerate() {
            assert!((v - 0.5).abs() < 1e-5, "row {i}: {v}");
        }
    }

    #[test]
    fn par_spmv_matches_sequential() {
        // Big enough to take the chunked path with >1 worker.
        let g = mesh_2d_tri(100, 100, 4);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.11).sin()).collect();
        let mut seq = vec![0.0f32; ell.n];
        spmv_ell_into(&ell, &x, &mut seq);
        for workers in [1, 2, 5] {
            let mut par = vec![0.0f32; ell.n];
            par_spmv_ell_into(&ell, &x, &mut par, workers);
            assert_eq!(seq, par, "workers={workers} must be bit-identical");
        }
    }

    #[test]
    fn block_rows_full_includes_diagonal() {
        let g = mesh_2d_tri(12, 12, 3);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let assignment: Vec<u32> = (0..g.n()).map(|u| (u % 3) as u32).collect();
        let x: Vec<f32> = (0..g.n()).map(|i| (i as f32 * 0.23).sin()).collect();
        let whole = spmv_ell_native(&ell, &x);
        for b in 0..3u32 {
            let (rows_ell, rows) = ell.block_rows(&assignment, b);
            let mut y_local = vec![0.0f32; rows.len()];
            spmv_block_rows_full(&rows_ell, &rows, &x, &mut y_local);
            for (i, &r) in rows.iter().enumerate() {
                assert!(
                    (y_local[i] - whole[r as usize]).abs() < 1e-4,
                    "block {b} row {r}: {} vs {}",
                    y_local[i],
                    whole[r as usize]
                );
            }
        }
    }

    #[test]
    fn block_rows_sum_to_whole() {
        let g = mesh_2d_tri(12, 12, 3);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let assignment: Vec<u32> = (0..g.n()).map(|u| (u % 3) as u32).collect();
        let x: Vec<f32> = (0..g.n()).map(|i| (i as f32 * 0.13).cos()).collect();
        let whole = spmv_ell_native(&ell, &x);
        for b in 0..3u32 {
            let (rows_ell, rows) = ell.block_rows(&assignment, b);
            let mut y_local = vec![0.0f32; rows.len()];
            spmv_block_rows(&rows_ell, &x, &mut y_local);
            for (i, &r) in rows.iter().enumerate() {
                let with_diag = y_local[i] + rows_ell.diag[i] * x[r as usize];
                assert!(
                    (with_diag - whole[r as usize]).abs() < 1e-4,
                    "block {b} row {r}: {} vs {}",
                    with_diag,
                    whole[r as usize]
                );
            }
        }
    }
}
