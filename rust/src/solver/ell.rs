//! Padded ELL storage for the shifted Laplacian — the exact layout the
//! L1 Pallas kernel consumes (`values[n, w]`, `cols[n, w]`, `diag[n]`).
//!
//! Padding slots carry value 0 and a *self-referential* column
//! (`cols[pad of row u] = u`): the product is still exactly 0, but the
//! x-load hits the row's own entry — already in cache for the diagonal —
//! instead of hammering `x[0]` from every row (cache-hostile at large w,
//! and wrong if `x[0]` ever goes non-finite, since `0·NaN = NaN`).

use crate::graph::{Csr, Laplacian};
use anyhow::{ensure, Result};

/// ELL matrix (f32, matching the AOT artifacts).
#[derive(Debug, Clone)]
pub struct EllMatrix {
    /// Number of rows.
    pub n: usize,
    /// Slots per row (the padded ELL width).
    pub w: usize,
    /// Row-major (n, w).
    pub values: Vec<f32>,
    /// Row-major (n, w).
    pub cols: Vec<i32>,
    /// Diagonal entries, stored separately from the slots.
    pub diag: Vec<f32>,
}

impl EllMatrix {
    /// Build from a graph's shifted Laplacian. Width = max row degree.
    pub fn from_graph(g: &Csr, shift: f64) -> EllMatrix {
        let lap = Laplacian::from_graph(g, shift);
        EllMatrix::from_laplacian(&lap)
    }

    /// Build from an assembled Laplacian (diagonal split out).
    pub fn from_laplacian(lap: &Laplacian) -> EllMatrix {
        let n = lap.n();
        let w = lap.max_row_nnz().max(1);
        let mut values = vec![0.0f32; n * w];
        let mut cols = vec![0i32; n * w];
        for u in 0..n {
            // Self-referential padding (see module doc); real slots
            // overwrite the prefix below.
            for s in 0..w {
                cols[u * w + s] = u as i32;
            }
            for (slot, e) in (lap.xadj[u]..lap.xadj[u + 1]).enumerate() {
                values[u * w + slot] = lap.vals[e] as f32;
                cols[u * w + slot] = lap.cols[e] as i32;
            }
        }
        EllMatrix {
            n,
            w,
            values,
            cols,
            diag: lap.diag.iter().map(|&d| d as f32).collect(),
        }
    }

    /// Non-padding entries.
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Pad to the artifact shape (n2 ≥ n, w2 ≥ w). Padding rows have
    /// diag = 1 so the padded system stays positive definite (the padded
    /// subspace solves x = b = 0 and never couples back).
    pub fn pad_to(&self, n2: usize, w2: usize) -> Result<EllMatrix> {
        ensure!(n2 >= self.n && w2 >= self.w, "pad_to must not shrink");
        let mut values = vec![0.0f32; n2 * w2];
        let mut cols: Vec<i32> = (0..n2 * w2).map(|i| (i / w2) as i32).collect();
        for u in 0..self.n {
            for s in 0..self.w {
                values[u * w2 + s] = self.values[u * self.w + s];
                cols[u * w2 + s] = self.cols[u * self.w + s];
            }
        }
        let mut diag = vec![1.0f32; n2];
        diag[..self.n].copy_from_slice(&self.diag);
        Ok(EllMatrix { n: n2, w: w2, values, cols, diag })
    }

    /// Extract the rows of one partition block, with columns still in
    /// *global* indexing (the distributed driver gathers the global x).
    /// Returns (row-subset ELL over n_global columns, owned global rows).
    pub fn block_rows(&self, assignment: &[u32], block: u32) -> (EllMatrix, Vec<u32>) {
        let rows: Vec<u32> = (0..self.n as u32)
            .filter(|&u| assignment[u as usize] == block)
            .collect();
        let mut values = vec![0.0f32; rows.len() * self.w];
        let mut cols = vec![0i32; rows.len() * self.w];
        let mut diag = vec![0.0f32; rows.len()];
        for (i, &u) in rows.iter().enumerate() {
            let u = u as usize;
            values[i * self.w..(i + 1) * self.w]
                .copy_from_slice(&self.values[u * self.w..(u + 1) * self.w]);
            cols[i * self.w..(i + 1) * self.w]
                .copy_from_slice(&self.cols[u * self.w..(u + 1) * self.w]);
            diag[i] = self.diag[u];
        }
        (
            EllMatrix { n: rows.len(), w: self.w, values, cols, diag },
            rows,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::graph::GraphBuilder;

    fn path3_ell() -> EllMatrix {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        EllMatrix::from_graph(&b.build(), 0.5)
    }

    #[test]
    fn from_graph_layout() {
        let e = path3_ell();
        assert_eq!(e.n, 3);
        assert_eq!(e.w, 2); // middle vertex has 2 neighbors
        assert_eq!(e.diag, vec![1.5, 2.5, 1.5]);
        // Row 0: one entry (-1 at col 1), one padding slot.
        assert_eq!(e.values[0..2], [-1.0, 0.0]);
        assert_eq!(e.cols[0..2], [1, 0]);
        // Row 2's padding slot points at row 2 itself, not column 0.
        assert_eq!(e.values[4..6], [-1.0, 0.0]);
        assert_eq!(e.cols[4..6], [1, 2]);
        assert_eq!(e.nnz(), 4);
    }

    #[test]
    fn padding_columns_are_self_referential() {
        let e = path3_ell();
        for u in 0..e.n {
            for s in 0..e.w {
                if e.values[u * e.w + s] == 0.0 {
                    assert_eq!(e.cols[u * e.w + s], u as i32, "row {u} slot {s}");
                }
            }
        }
        let p = e.pad_to(8, 4).unwrap();
        for u in 0..p.n {
            for s in 0..p.w {
                if p.values[u * p.w + s] == 0.0 {
                    assert_eq!(p.cols[u * p.w + s], u as i32, "padded row {u} slot {s}");
                }
            }
        }
    }

    #[test]
    fn pads_never_read_row_zero() {
        // With column-0 pads, a non-finite x[0] would poison every padded
        // row (`0 · NaN = NaN`). Self-referential pads keep the damage
        // confined to row 0 itself.
        use crate::solver::spmv::spmv_ell_native;
        let e = path3_ell();
        let x = [f32::NAN, 1.0, 2.0];
        let y = spmv_ell_native(&e, &x);
        assert!(y[0].is_nan()); // row 0 genuinely reads x[0]
        assert!(y[2].is_finite(), "row 2's pad slot read x[0]: {}", y[2]);
    }

    #[test]
    fn pad_preserves_and_extends() {
        let e = path3_ell();
        let p = e.pad_to(8, 4).unwrap();
        assert_eq!(p.n, 8);
        assert_eq!(p.w, 4);
        assert_eq!(p.diag[0..3], [1.5, 2.5, 1.5]);
        assert_eq!(p.diag[3..], [1.0, 1.0, 1.0, 1.0, 1.0]);
        // Row 1 entries preserved at the right offsets.
        assert_eq!(p.values[4..6], [-1.0, -1.0]);
        assert_eq!(p.cols[4..6], [0, 2]);
        // Shrinking is rejected.
        assert!(e.pad_to(2, 2).is_err());
    }

    #[test]
    fn padded_spmv_agrees_on_prefix() {
        use crate::solver::spmv::spmv_ell_native;
        let g = mesh_2d_tri(12, 12, 1);
        let e = EllMatrix::from_graph(&g, 0.1);
        let p = e.pad_to(256, e.w + 2).unwrap();
        let x: Vec<f32> = (0..e.n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut xp = x.clone();
        xp.resize(256, 0.0);
        let y = spmv_ell_native(&e, &x);
        let yp = spmv_ell_native(&p, &xp);
        for i in 0..e.n {
            assert!((y[i] - yp[i]).abs() < 1e-5, "row {i}: {} vs {}", y[i], yp[i]);
        }
        for i in e.n..256 {
            assert_eq!(yp[i], 0.0);
        }
    }

    #[test]
    fn block_rows_extraction() {
        let e = path3_ell();
        let (b0, rows) = e.block_rows(&[0, 0, 1], 0);
        assert_eq!(rows, vec![0, 1]);
        assert_eq!(b0.n, 2);
        assert_eq!(b0.diag, vec![1.5, 2.5]);
        // Columns stay global: row 1 references columns 0 and 2.
        assert_eq!(b0.cols[2..4], [0, 2]);
    }
}
