//! Heterogeneous-cluster execution simulator (stands in for the paper's
//! TOPO3 testbed, where the authors tune down real compute nodes).
//!
//! Model, per CG/SpMV iteration:
//!
//! ```text
//! T_iter = max_i ( flops_i · t_flop / c_s(p_i)            compute
//!                  + α · n_neighbors_i + β · sendvol_i )  halo exchange
//!          + t_allreduce(k)                               CG dot products
//! ```
//!
//! `t_flop` is *calibrated* on this machine by timing the native ELL
//! SpMV once, so simulated times are anchored to real measured kernel
//! speed (the paper's relative comparisons survive the calibration
//! constant). The numeric solution itself is computed for real — either
//! through the native backend or the PJRT artifact — so reported
//! residuals are genuine.

use crate::exec::{CostModel, ExecBackend, ExecReport, SolveOpts, VirtualCluster};
use crate::graph::{Csr, QuotientGraph};
use crate::partition::Partition;
use crate::solver::cg::{cg_solve, CgResult, SpmvBackend};
use crate::solver::ell::EllMatrix;
use crate::solver::spmv::spmv_ell_native;
use crate::topology::Topology;
use crate::util::timer::Timer;
use anyhow::Result;

/// α-β communication parameters (seconds, seconds/word) plus the
/// calibrated per-flop time.
#[derive(Debug, Clone)]
pub struct ClusterSim {
    /// Per-message latency (s). HLRN-class interconnect ≈ 2 µs.
    pub alpha: f64,
    /// Per-word transfer time (s). ≈ 1e-9 (8 B / 10 GB/s).
    pub beta: f64,
    /// Per-nonzero SpMV time on a speed-1 PU (s); calibrated.
    pub t_flop: f64,
    /// Allreduce latency per CG iteration as a function of k.
    pub allreduce_base: f64,
}

impl Default for ClusterSim {
    fn default() -> Self {
        ClusterSim {
            alpha: 2e-6,
            beta: 1e-9,
            t_flop: 2e-9, // overwritten by calibrate()
            allreduce_base: 1e-6,
        }
    }
}

/// Per-iteration time report for one (partition, topology) pair.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Simulated seconds per iteration (the paper's Fig. 5 y-axis).
    pub time_per_iter: f64,
    /// Compute component of the bottleneck PU.
    pub bottleneck_compute: f64,
    /// Communication component of the bottleneck PU.
    pub bottleneck_comm: f64,
    /// Which PU bounds the iteration.
    pub bottleneck_pu: usize,
    /// Per-PU (compute, comm) breakdown.
    pub per_pu: Vec<(f64, f64)>,
}

impl ClusterSim {
    /// Calibrate `t_flop` by timing the native SpMV on this machine.
    pub fn calibrate(&mut self, a: &EllMatrix) {
        let x = vec![1.0f32; a.n];
        // Warmup + measure.
        let _ = spmv_ell_native(a, &x);
        let reps = 5;
        let t = Timer::start();
        for _ in 0..reps {
            std::hint::black_box(spmv_ell_native(a, std::hint::black_box(&x)));
        }
        let secs = t.secs() / reps as f64;
        let ops = (a.n * (a.w + 1)) as f64; // fused mul-add per slot + diag
        self.t_flop = (secs / ops).max(1e-12);
    }

    /// Simulate one SpMV/CG iteration for a partition on a topology.
    pub fn iteration(
        &self,
        g: &Csr,
        part: &Partition,
        topo: &Topology,
        ell_width: usize,
    ) -> SimReport {
        assert_eq!(part.k, topo.k());
        let q = QuotientGraph::build(g, &part.assignment, part.k);
        // Per-PU flops: rows × (width + diagonal).
        let sizes = part.block_sizes();
        let mut per_pu = Vec::with_capacity(part.k);
        let mut worst = (0usize, 0.0f64, 0.0f64);
        for i in 0..part.k {
            let flops = sizes[i] as f64 * (ell_width + 1) as f64;
            let compute = flops * self.t_flop / topo.pus[i].speed;
            let neighbors = q.adj[i].len() as f64;
            let sendvol: f64 = q.adj[i].iter().map(|&(_, v)| v).sum();
            let comm = self.alpha * neighbors + self.beta * sendvol * 4.0; // f32 words
            per_pu.push((compute, comm));
            if compute + comm > worst.1 + worst.2 {
                worst = (i, compute, comm);
            }
        }
        let allreduce = self.allreduce_base * (part.k as f64).log2().max(1.0);
        SimReport {
            time_per_iter: worst.1 + worst.2 + allreduce,
            bottleneck_compute: worst.1,
            bottleneck_comm: worst.2,
            bottleneck_pu: worst.0,
            per_pu,
        }
    }

    /// The α-β constants as the exec-engine cost model (the simulated
    /// transport of the virtual cluster prices with exactly these).
    pub fn cost_model(&self) -> CostModel {
        CostModel {
            alpha: self.alpha,
            beta: self.beta,
            t_flop: self.t_flop,
            allreduce_base: self.allreduce_base,
        }
    }

    /// Distributed CG through the virtual-cluster engine: the matrix is
    /// decomposed into per-PU halo blocks and solved through the chosen
    /// backend — `sim` reproduces this simulator's α-β accounting by
    /// executing the distributed algorithm sequentially, `threads` runs
    /// one OS thread per PU with speed throttling and measures for real.
    #[allow(clippy::too_many_arguments)]
    pub fn run_cg_virtual(
        &self,
        ell: &EllMatrix,
        part: &Partition,
        topo: &Topology,
        backend: ExecBackend,
        b: &[f32],
        max_iters: usize,
        tol: f32,
    ) -> Result<(CgResult, ExecReport)> {
        self.run_cg_virtual_opts(ell, part, topo, backend, b, max_iters, tol, SolveOpts::default())
    }

    /// [`ClusterSim::run_cg_virtual`] with explicit execution options —
    /// nonblocking compute/communication overlap and/or the pipelined
    /// single-reduction CG variant (see `exec::SolveOpts`).
    #[allow(clippy::too_many_arguments)]
    pub fn run_cg_virtual_opts(
        &self,
        ell: &EllMatrix,
        part: &Partition,
        topo: &Topology,
        backend: ExecBackend,
        b: &[f32],
        max_iters: usize,
        tol: f32,
        opts: SolveOpts,
    ) -> Result<(CgResult, ExecReport)> {
        let vc = VirtualCluster::new(ell, part, topo, self.cost_model())?;
        vc.solve_cg_opts(backend, b, max_iters, tol, opts)
    }

    /// Full simulated CG: run the numerics for real through `backend`
    /// while pricing each iteration with the cluster model.
    pub fn run_cg<B: SpmvBackend>(
        &self,
        g: &Csr,
        part: &Partition,
        topo: &Topology,
        ell_width: usize,
        backend: &mut B,
        b: &[f32],
        max_iters: usize,
        tol: f32,
    ) -> Result<(CgResult, SimReport)> {
        let report = self.iteration(g, part, topo, ell_width);
        let result = cg_solve(backend, b, max_iters, tol)?;
        Ok((result, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocksizes::block_sizes;
    use crate::gen::mesh_2d_tri;
    use crate::partitioners::{by_name, Ctx};
    use crate::topology::{topo1, Pu, Topo1Spec, Topology};

    fn sim() -> ClusterSim {
        ClusterSim { t_flop: 1e-9, ..Default::default() }
    }

    fn partition_with(name: &str, g: &Csr, targets: &[f64], topo: &Topology) -> Partition {
        let ctx = Ctx { graph: g, targets, topo, epsilon: 0.05, seed: 1 };
        by_name(name).unwrap().partition(&ctx).unwrap()
    }

    use crate::graph::Csr;

    #[test]
    fn balanced_beats_imbalanced_homogeneous() {
        let g = mesh_2d_tri(30, 30, 1);
        let topo = Topology::homogeneous(4, 1.0, 1e9);
        let targets = vec![g.n() as f64 / 4.0; 4];
        let good = partition_with("geoKM", &g, &targets, &topo);
        // Degenerate: one block holds nearly everything.
        let mut bad_assign = vec![0u32; g.n()];
        for u in 0..3 {
            bad_assign[u] = (u + 1) as u32;
        }
        let bad = Partition::new(bad_assign, 4);
        let s = sim();
        let tg = s.iteration(&g, &good, &topo, 8).time_per_iter;
        let tb = s.iteration(&g, &bad, &topo, 8).time_per_iter;
        assert!(tg < tb, "balanced {tg} vs degenerate {tb}");
    }

    #[test]
    fn heterogeneity_aware_targets_beat_uniform() {
        // On TOPO1 with fast PUs, Algorithm-1 targets must beat uniform
        // targets (the whole point of the paper).
        let g = mesh_2d_tri(40, 40, 2);
        let topo = topo1(Topo1Spec {
            k: 8,
            num_fast: 2,
            fast: Pu { speed: 8.0, memory: 1e9 },
        });
        let bs = block_sizes(g.n() as f64, &topo).unwrap();
        let ldht = partition_with("geoKM", &g, &bs.tw, &topo);
        let uniform_targets = vec![g.n() as f64 / 8.0; 8];
        let uniform = partition_with("geoKM", &g, &uniform_targets, &topo);
        // Isolate the compute term: on this miniature instance the α
        // latency otherwise dominates and hides the balance effect the
        // test is about.
        let mut s = sim();
        s.alpha = 0.0;
        s.beta = 0.0;
        let t_ldht = s.iteration(&g, &ldht, &topo, 8).time_per_iter;
        let t_uni = s.iteration(&g, &uniform, &topo, 8).time_per_iter;
        assert!(
            t_ldht < t_uni,
            "LDHT targets {t_ldht} must beat uniform {t_uni}"
        );
    }

    #[test]
    fn comm_component_scales_with_cut() {
        let g = mesh_2d_tri(30, 30, 3);
        let topo = Topology::homogeneous(4, 1.0, 1e9);
        let targets = vec![g.n() as f64 / 4.0; 4];
        let good = partition_with("geoKM", &g, &targets, &topo);
        // Round-robin partition: same balance, horrible cut.
        let rr = Partition::new(
            (0..g.n()).map(|u| (u % 4) as u32).collect(),
            4,
        );
        let mut s = sim();
        s.alpha = 0.0; // isolate the volume term
        let good_comm = s.iteration(&g, &good, &topo, 8).bottleneck_comm;
        let rr_comm = s.iteration(&g, &rr, &topo, 8).bottleneck_comm;
        assert!(rr_comm > 5.0 * good_comm, "rr {rr_comm} vs good {good_comm}");
    }

    #[test]
    fn calibration_produces_sane_t_flop() {
        let g = mesh_2d_tri(50, 50, 4);
        let a = crate::solver::ell::EllMatrix::from_graph(&g, 0.1);
        let mut s = ClusterSim::default();
        s.calibrate(&a);
        // On any plausible CPU: 0.01ns .. 100ns per fused op.
        assert!(s.t_flop > 1e-12 && s.t_flop < 1e-7, "t_flop {}", s.t_flop);
    }

    #[test]
    fn run_cg_virtual_matches_backend_pair() {
        use crate::exec::ExecBackend;
        let g = mesh_2d_tri(16, 16, 5);
        let topo = Topology::homogeneous(4, 1.0, 1e9);
        let targets = vec![g.n() as f64 / 4.0; 4];
        let p = partition_with("geoKM", &g, &targets, &topo);
        let a = EllMatrix::from_graph(&g, 0.1);
        let b = vec![1.0f32; g.n()];
        let s = sim();
        let (res_sim, rep_sim) = s
            .run_cg_virtual(&a, &p, &topo, ExecBackend::Sim, &b, 100, 1e-5)
            .unwrap();
        let (res_thr, _) = s
            .run_cg_virtual(&a, &p, &topo, ExecBackend::Threads, &b, 100, 1e-5)
            .unwrap();
        assert_eq!(res_sim.residual_norms, res_thr.residual_norms);
        assert!(res_sim.residual_norms.last().unwrap() < &1e-3);
        assert_eq!(rep_sim.backend, "sim");
        assert_eq!(rep_sim.compute_secs.len(), 4);
    }

    #[test]
    fn run_cg_returns_real_numerics() {
        use crate::solver::cg::NativeBackend;
        let g = mesh_2d_tri(16, 16, 5);
        let topo = Topology::homogeneous(4, 1.0, 1e9);
        let targets = vec![g.n() as f64 / 4.0; 4];
        let p = partition_with("geoKM", &g, &targets, &topo);
        let a = EllMatrix::from_graph(&g, 0.1);
        let b = vec![1.0f32; g.n()];
        let mut backend = NativeBackend { a: &a };
        let s = sim();
        let (res, rep) = s
            .run_cg(&g, &p, &topo, a.w, &mut backend, &b, 200, 1e-5)
            .unwrap();
        assert!(res.residual_norms.last().unwrap() < &1e-3);
        assert!(rep.time_per_iter > 0.0);
        assert_eq!(rep.per_pu.len(), 4);
    }
}
