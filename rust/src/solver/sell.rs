//! SELL-C-σ (sliced ELLPACK) storage — the raw-speed SpMV fast path.
//!
//! Padded ELL spends `n·w` slots even when row sizes vary widely. SELL-C-σ
//! (Kreutzer et al.) sorts rows by descending entry count within windows
//! of σ rows, groups them into chunks of C rows, and pads each chunk only
//! to its *own* widest row. Storage inside a chunk is column-major
//! (slot s of all C rows, then slot s+1), the unit-stride access pattern
//! a vectorizing compiler wants. The row permutation stays explicit
//! ([`SellMatrix::perm`]) and results are scattered back through it, so
//! callers always see original row order.
//!
//! Agreement with ELL is exact, not approximate: a stored row adds its
//! real entries in the same slot order as the ELL kernel, and padding
//! slots contribute a literal `0.0 · x[row]` in both layouts (pad columns
//! are self-referential, see `solver::ell`), so per-row partial sums are
//! identical and results compare `==` (pinned by `tests/sell_layout.rs`).

use super::ell::EllMatrix;

/// Which SpMV storage layout a solve runs on — the seam threaded through
/// `exec::SolveOpts`, the harness scenario axis, and the CLI `--layout`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpmvLayout {
    /// Padded ELL — the reference layout every other path is pinned to.
    #[default]
    Ell,
    /// SELL-C-σ chunks at the default C/σ (see [`SellMatrix`]).
    SellCs,
}

impl SpmvLayout {
    /// Parse a CLI layout name (`ell` / `sellcs`), case-insensitive.
    pub fn parse(s: &str) -> Option<SpmvLayout> {
        match s.to_ascii_lowercase().as_str() {
            "ell" => Some(SpmvLayout::Ell),
            "sellcs" | "sell" | "sell-c-s" | "sell-c-sigma" => Some(SpmvLayout::SellCs),
            _ => None,
        }
    }

    /// Canonical layout name (`"ell"` / `"sellcs"`).
    pub fn name(&self) -> &'static str {
        match self {
            SpmvLayout::Ell => "ell",
            SpmvLayout::SellCs => "sellcs",
        }
    }
}

/// Chunk height used when no explicit C is requested: 8 rows fill a
/// 256-bit f32 lane exactly and keep one long row's padding blast radius
/// to 7 neighbors.
pub const DEFAULT_CHUNK: usize = 8;

/// Sort window used when no explicit σ is requested. Local sorting keeps
/// rows near their neighbors (cache-friendly x access on mesh orderings)
/// while still grouping similar-degree rows into the same chunk.
pub const DEFAULT_SIGMA: usize = 64;

/// Hard cap on C — the kernel accumulates one chunk in a stack buffer.
pub const MAX_CHUNK: usize = 64;

/// Rows below which chunking the kernel over the job queue costs more
/// than it buys (mirrors `solver::spmv::PAR_MIN_ROWS`).
const PAR_MIN_ROWS: usize = 4096;

/// SELL-C-σ matrix over the same entry set as an [`EllMatrix`] (or a row
/// subset of one). The diagonal stays split out, exactly as in ELL.
#[derive(Debug, Clone)]
pub struct SellMatrix {
    /// Number of stored rows.
    pub n: usize,
    /// Chunk height C (1 ≤ C ≤ [`MAX_CHUNK`]).
    pub c: usize,
    /// Sort window σ (1 = keep input order, ≥ n = one global sort).
    pub sigma: usize,
    /// Slot-data offset of each chunk; `chunk_ptr[ch+1] - chunk_ptr[ch]
    /// = chunk_w[ch] · rows_in_chunk`.
    pub chunk_ptr: Vec<usize>,
    /// Per-chunk width = max entry count over the chunk's rows.
    pub chunk_w: Vec<usize>,
    /// Chunk-local column-major slot values; padding slots are 0.0.
    pub values: Vec<f32>,
    /// Chunk-local column-major slot columns; padding slots are
    /// self-referential (`perm` of their row), matching the ELL fix.
    pub cols: Vec<i32>,
    /// Diagonal in *stored* order: `diag[p]` pairs with `x[perm[p]]`.
    pub diag: Vec<f32>,
    /// Stored row `p` computes source row `perm[p]` — the index of that
    /// row in the x/y vectors the kernel reads and writes.
    pub perm: Vec<u32>,
}

impl SellMatrix {
    /// Build over all rows of `ell` with explicit C and σ.
    pub fn from_ell(ell: &EllMatrix, c: usize, sigma: usize) -> SellMatrix {
        let all: Vec<u32> = (0..ell.n as u32).collect();
        SellMatrix::from_ell_rows(ell, &all, c, sigma)
    }

    /// Build over all rows of `ell` at the default C/σ.
    pub fn from_ell_default(ell: &EllMatrix) -> SellMatrix {
        SellMatrix::from_ell(ell, DEFAULT_CHUNK, DEFAULT_SIGMA)
    }

    /// Build over a subset of `ell`'s rows (e.g. a halo block's interior
    /// or boundary split). `rows` are row indices into `ell`, which are
    /// also the x/y indices the kernel will use; the subset rows must be
    /// distinct. σ windows are applied over the order of `rows`.
    pub fn from_ell_rows(ell: &EllMatrix, rows: &[u32], c: usize, sigma: usize) -> SellMatrix {
        assert!(c >= 1 && c <= MAX_CHUNK, "chunk height {c} outside 1..={MAX_CHUNK}");
        let sigma = sigma.max(1);
        let w = ell.w;
        let entries_of = |u: usize| (0..w).filter(|&s| ell.values[u * w + s] != 0.0).count();
        // Stable descending-entry-count sort within σ windows: stability
        // keeps equal-degree rows in input order, so construction is
        // deterministic and σ=1 is exactly the identity permutation.
        let mut keyed: Vec<(u32, usize)> =
            rows.iter().map(|&u| (u, entries_of(u as usize))).collect();
        for window in keyed.chunks_mut(sigma) {
            window.sort_by_key(|&(_, cnt)| std::cmp::Reverse(cnt));
        }
        let n = keyed.len();
        let perm: Vec<u32> = keyed.iter().map(|&(u, _)| u).collect();
        let nchunks = n.div_ceil(c);
        let mut chunk_ptr = Vec::with_capacity(nchunks + 1);
        let mut chunk_w = Vec::with_capacity(nchunks);
        chunk_ptr.push(0usize);
        let mut values = Vec::new();
        let mut cols = Vec::new();
        for ch in 0..nchunks {
            let r0 = ch * c;
            let rows_in = (n - r0).min(c);
            let wc = keyed[r0..r0 + rows_in].iter().map(|&(_, cnt)| cnt).max().unwrap_or(0);
            let base = values.len();
            values.resize(base + wc * rows_in, 0.0f32);
            cols.resize(base + wc * rows_in, 0i32);
            for r in 0..rows_in {
                let u = perm[r0 + r] as usize;
                let mut slot = 0usize;
                for s in 0..w {
                    let v = ell.values[u * w + s];
                    if v != 0.0 {
                        values[base + slot * rows_in + r] = v;
                        cols[base + slot * rows_in + r] = ell.cols[u * w + s];
                        slot += 1;
                    }
                }
                // Self-referential padding: x[u] is already hot for the
                // diagonal, so pads never pull a foreign cache line.
                for s in slot..wc {
                    cols[base + s * rows_in + r] = u as i32;
                }
            }
            chunk_w.push(wc);
            chunk_ptr.push(values.len());
        }
        let diag: Vec<f32> = perm.iter().map(|&u| ell.diag[u as usize]).collect();
        SellMatrix { n, c, sigma, chunk_ptr, chunk_w, values, cols, diag, perm }
    }

    /// Number of chunks.
    pub fn chunks(&self) -> usize {
        self.chunk_w.len()
    }

    /// Non-padding slots.
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Stored slots / non-padding slots — the padding overhead SELL-C-σ
    /// exists to shrink (padded ELL's ratio is `n·w / nnz`). 1.0 when the
    /// matrix has no entries at all.
    pub fn fill_ratio(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            1.0
        } else {
            self.values.len() as f64 / nnz as f64
        }
    }

    /// `y[perm[p]] = diag·x + entries·x` for every stored row, sequential.
    /// Rows *not* covered by `perm` are left untouched, which is what the
    /// fused interior/boundary halo path relies on.
    pub fn spmv_into(&self, x: &[f32], y: &mut [f32]) {
        let mut acc = [0.0f32; MAX_CHUNK];
        for ch in 0..self.chunks() {
            self.chunk_kernel(ch, x, &mut acc);
            let r0 = ch * self.c;
            let rows_in = (self.n - r0).min(self.c);
            for r in 0..rows_in {
                y[self.perm[r0 + r] as usize] = acc[r];
            }
        }
    }

    /// One chunk's rows into `acc[0..rows_in]` (stored order, no scatter).
    #[inline]
    fn chunk_kernel(&self, ch: usize, x: &[f32], acc: &mut [f32; MAX_CHUNK]) {
        let r0 = ch * self.c;
        let rows_in = (self.n - r0).min(self.c);
        let wc = self.chunk_w[ch];
        let base = self.chunk_ptr[ch];
        for r in 0..rows_in {
            acc[r] = self.diag[r0 + r] * x[self.perm[r0 + r] as usize];
        }
        for s in 0..wc {
            let off = base + s * rows_in;
            for r in 0..rows_in {
                acc[r] += self.values[off + r] * x[self.cols[off + r] as usize];
            }
        }
    }

    /// Chunks `ch_lo..ch_hi` into `out`, stored-row order (`out[0]` is
    /// stored row `ch_lo·C`). Used by the parallel kernel's workers.
    fn spmv_chunks_stored(&self, x: &[f32], ch_lo: usize, ch_hi: usize, out: &mut [f32]) {
        let mut acc = [0.0f32; MAX_CHUNK];
        let p0 = ch_lo * self.c;
        for ch in ch_lo..ch_hi {
            self.chunk_kernel(ch, x, &mut acc);
            let r0 = ch * self.c;
            let rows_in = (self.n - r0).min(self.c);
            out[r0 - p0..r0 - p0 + rows_in].copy_from_slice(&acc[..rows_in]);
        }
    }

    /// The kernel with chunk ranges spread across
    /// `coordinator::jobqueue::run_jobs` workers. Bit-identical to
    /// [`SellMatrix::spmv_into`] (each chunk is computed independently by
    /// the same code); falls back to sequential on small inputs.
    pub fn par_spmv_into(&self, x: &[f32], y: &mut [f32], workers: usize) {
        let workers = workers.max(1);
        if workers == 1 || self.n < 2 * PAR_MIN_ROWS {
            self.spmv_into(x, y);
            return;
        }
        let nchunks = self.chunks();
        let per_job = self.n.div_ceil(workers).max(PAR_MIN_ROWS).div_ceil(self.c);
        let jobs: Vec<(usize, usize)> = (0..nchunks)
            .step_by(per_job)
            .map(|lo| (lo, (lo + per_job).min(nchunks)))
            .collect();
        let parts = crate::coordinator::jobqueue::run_jobs(jobs.clone(), workers, |&(lo, hi)| {
            let p0 = lo * self.c;
            let p1 = (hi * self.c).min(self.n);
            let mut out = vec![0.0f32; p1 - p0];
            self.spmv_chunks_stored(x, lo, hi, &mut out);
            out
        });
        for ((lo, _), part) in jobs.into_iter().zip(parts) {
            let p0 = lo * self.c;
            for (i, &v) in part.iter().enumerate() {
                y[self.perm[p0 + i] as usize] = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh_2d_tri;
    use crate::graph::GraphBuilder;
    use crate::solver::spmv::spmv_ell_native;

    fn star_ell() -> EllMatrix {
        // Vertex 0 has degree 4, leaves degree 1 — wide degree variance.
        let mut b = GraphBuilder::new(5);
        for v in 1..5 {
            b.add_edge(0, v);
        }
        EllMatrix::from_graph(&b.build(), 0.5)
    }

    #[test]
    fn layout_parse_round_trip() {
        assert_eq!(SpmvLayout::parse("ell"), Some(SpmvLayout::Ell));
        assert_eq!(SpmvLayout::parse("SELLCS"), Some(SpmvLayout::SellCs));
        assert_eq!(SpmvLayout::parse("sell-c-sigma"), Some(SpmvLayout::SellCs));
        assert_eq!(SpmvLayout::parse("csr"), None);
        assert_eq!(SpmvLayout::default(), SpmvLayout::Ell);
        assert_eq!(SpmvLayout::SellCs.name(), "sellcs");
    }

    #[test]
    fn construction_sorts_within_sigma_and_keeps_perm() {
        let ell = star_ell();
        // Global sort: the hub (4 entries) must come first.
        let s = SellMatrix::from_ell(&ell, 2, ell.n);
        assert_eq!(s.perm[0], 0);
        assert_eq!(s.n, 5);
        assert_eq!(s.chunks(), 3);
        // Chunk 0 holds the hub → width 4; the leaf-only chunks need 1.
        assert_eq!(s.chunk_w[0], 4);
        assert!(s.chunk_w[1] <= 1 && s.chunk_w[2] <= 1);
        // σ=1 keeps input order.
        let id = SellMatrix::from_ell(&ell, 2, 1);
        assert_eq!(id.perm, vec![0, 1, 2, 3, 4]);
        // A permutation either way.
        let mut sorted: Vec<u32> = s.perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sell_pads_less_than_ell() {
        let ell = star_ell();
        let s = SellMatrix::from_ell(&ell, 2, ell.n);
        assert_eq!(s.nnz(), ell.nnz());
        // ELL stores 5·4 = 20 slots for 8 entries; sorted SELL-2 stores
        // 2·4 + 2·1 + 1·1 = 11.
        assert!(s.values.len() < ell.n * ell.w, "{} slots", s.values.len());
        assert!(s.fill_ratio() < (ell.n * ell.w) as f64 / ell.nnz() as f64);
    }

    #[test]
    fn sell_pad_columns_are_self_referential() {
        let ell = star_ell();
        let s = SellMatrix::from_ell(&ell, 2, ell.n);
        for ch in 0..s.chunks() {
            let r0 = ch * s.c;
            let rows_in = (s.n - r0).min(s.c);
            let base = s.chunk_ptr[ch];
            for sl in 0..s.chunk_w[ch] {
                for r in 0..rows_in {
                    let i = base + sl * rows_in + r;
                    if s.values[i] == 0.0 {
                        assert_eq!(s.cols[i], s.perm[r0 + r] as i32, "chunk {ch} slot {sl} row {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn sell_spmv_matches_ell_exactly() {
        let g = mesh_2d_tri(17, 13, 2);
        let ell = EllMatrix::from_graph(&g, 0.2);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.19).sin()).collect();
        let reference = spmv_ell_native(&ell, &x);
        for (c, sigma) in [(4, 1), (8, 64), (8, ell.n), (32, 32)] {
            let s = SellMatrix::from_ell(&ell, c, sigma);
            let mut y = vec![0.0f32; ell.n];
            s.spmv_into(&x, &mut y);
            assert_eq!(y, reference, "C={c} σ={sigma}");
        }
    }

    #[test]
    fn par_spmv_matches_sequential() {
        let g = mesh_2d_tri(100, 100, 4);
        let ell = EllMatrix::from_graph(&g, 0.1);
        let s = SellMatrix::from_ell_default(&ell);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut seq = vec![0.0f32; ell.n];
        s.spmv_into(&x, &mut seq);
        for workers in [1, 2, 5] {
            let mut par = vec![0.0f32; ell.n];
            s.par_spmv_into(&x, &mut par, workers);
            assert_eq!(seq, par, "workers={workers}");
        }
    }

    #[test]
    fn row_subset_touches_only_its_rows() {
        let g = mesh_2d_tri(10, 10, 1);
        let ell = EllMatrix::from_graph(&g, 0.3);
        let x: Vec<f32> = (0..ell.n).map(|i| (i as f32 * 0.07).sin()).collect();
        let reference = spmv_ell_native(&ell, &x);
        let evens: Vec<u32> = (0..ell.n as u32).filter(|u| u % 2 == 0).collect();
        let s = SellMatrix::from_ell_rows(&ell, &evens, 4, 16);
        let mut y = vec![f32::NAN; ell.n];
        s.spmv_into(&x, &mut y);
        for u in 0..ell.n {
            if u % 2 == 0 {
                assert_eq!(y[u], reference[u], "row {u}");
            } else {
                assert!(y[u].is_nan(), "row {u} written by a subset kernel");
            }
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let ell = star_ell();
        let empty = SellMatrix::from_ell_rows(&ell, &[], 8, 64);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.chunks(), 0);
        let mut y = vec![0.0f32; ell.n];
        empty.spmv_into(&[0.0; 5], &mut y); // must not panic or write
        assert_eq!(y, vec![0.0; 5]);
        let single = SellMatrix::from_ell_rows(&ell, &[3], 8, 64);
        assert_eq!(single.n, 1);
        let x = vec![1.0f32; ell.n];
        single.spmv_into(&x, &mut y);
        let reference = spmv_ell_native(&ell, &x);
        assert_eq!(y[3], reference[3]);
    }
}
