//! Quotient (communication) graph of a partition.
//!
//! Each vertex of the quotient graph corresponds to a block of the
//! application graph; a weighted edge {i, j} carries the communication
//! volume exchanged between blocks i and j (paper §V). Used by
//! Geographer-R to schedule pairwise refinement rounds via edge coloring,
//! and by the cluster simulator's communication model.

use super::Csr;

/// Quotient graph over `k` blocks.
#[derive(Debug, Clone)]
pub struct QuotientGraph {
    /// Number of blocks (quotient vertices).
    pub k: usize,
    /// Adjacency: for each block, sorted (neighbor block, comm volume).
    pub adj: Vec<Vec<(u32, f64)>>,
    /// Edge cut contributed by each block pair, parallel structure to adj.
    pub cut: Vec<Vec<(u32, f64)>>,
}

impl QuotientGraph {
    /// Build from a graph and a block assignment (`part[u] < k`).
    ///
    /// Communication volume of the pair {i, j}: the number of vertices of
    /// block i with ≥1 neighbor in block j, plus vice versa (each boundary
    /// vertex's value must be sent once to each neighboring block).
    pub fn build(g: &Csr, part: &[u32], k: usize) -> QuotientGraph {
        assert_eq!(part.len(), g.n());
        use std::collections::HashMap;
        let mut vol: HashMap<(u32, u32), f64> = HashMap::new();
        let mut cutw: HashMap<(u32, u32), f64> = HashMap::new();
        let mut seen: Vec<u32> = Vec::new();
        for u in 0..g.n() {
            let bu = part[u];
            debug_assert!((bu as usize) < k);
            seen.clear();
            for e in g.arc_range(u) {
                let v = g.adjncy[e] as usize;
                let bv = part[v];
                if bv == bu {
                    continue;
                }
                let key = if bu < bv { (bu, bv) } else { (bv, bu) };
                // Cut counts each undirected edge once (u < v guard).
                if u < v {
                    *cutw.entry(key).or_insert(0.0) += g.arc_weight(e);
                }
                // Volume: u's value crosses to block bv once.
                if !seen.contains(&bv) {
                    seen.push(bv);
                    *vol.entry(key).or_insert(0.0) += g.vertex_weight(u);
                }
            }
        }
        let mut adj = vec![Vec::new(); k];
        for (&(i, j), &w) in &vol {
            adj[i as usize].push((j, w));
            adj[j as usize].push((i, w));
        }
        let mut cut = vec![Vec::new(); k];
        for (&(i, j), &w) in &cutw {
            cut[i as usize].push((j, w));
            cut[j as usize].push((i, w));
        }
        for l in adj.iter_mut().chain(cut.iter_mut()) {
            l.sort_unstable_by_key(|&(b, _)| b);
        }
        QuotientGraph { k, adj, cut }
    }

    /// Number of quotient edges (communicating block pairs).
    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|l| l.len()).sum::<usize>() / 2
    }

    /// All quotient edges as (i, j, volume) with i < j.
    pub fn edges(&self) -> Vec<(u32, u32, f64)> {
        let mut out = Vec::new();
        for (i, l) in self.adj.iter().enumerate() {
            for &(j, w) in l {
                if (i as u32) < j {
                    out.push((i as u32, j, w));
                }
            }
        }
        out
    }

    /// Maximum quotient degree (how many blocks one block talks to).
    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|l| l.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// 2x2 grid: 0-1 / 2-3 with vertical edges 0-2, 1-3.
    fn grid2x2() -> Csr {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(2, 3);
        b.add_edge(0, 2);
        b.add_edge(1, 3);
        b.build()
    }

    #[test]
    fn two_blocks_horizontal_split() {
        let g = grid2x2();
        // blocks: {0,1} and {2,3} — cut = 2 (edges 0-2, 1-3).
        let q = QuotientGraph::build(&g, &[0, 0, 1, 1], 2);
        assert_eq!(q.num_edges(), 1);
        let e = q.edges();
        assert_eq!(e.len(), 1);
        let (i, j, vol) = e[0];
        assert_eq!((i, j), (0, 1));
        // All 4 vertices are boundary: each sends once → volume 4.
        assert_eq!(vol, 4.0);
        assert_eq!(q.cut[0], vec![(1, 2.0)]);
    }

    #[test]
    fn four_singleton_blocks() {
        let g = grid2x2();
        let q = QuotientGraph::build(&g, &[0, 1, 2, 3], 4);
        assert_eq!(q.num_edges(), 4); // one per graph edge
        assert_eq!(q.max_degree(), 2);
    }

    #[test]
    fn no_cut_single_block() {
        let g = grid2x2();
        let q = QuotientGraph::build(&g, &[0, 0, 0, 0], 1);
        assert_eq!(q.num_edges(), 0);
    }

    #[test]
    fn volume_counts_distinct_targets_once() {
        // Star: center 0 connected to 1,2,3; blocks {0}, {1,2}, {3}.
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(0, 2);
        b.add_edge(0, 3);
        let g = b.build();
        let q = QuotientGraph::build(&g, &[0, 1, 1, 2], 3);
        // Pair (0,1): center sends once (vol 1), vertices 1 and 2 each send
        // once back (vol 2) → total 3.
        let e01 = q.adj[0].iter().find(|&&(b, _)| b == 1).unwrap();
        assert_eq!(e01.1, 3.0);
        // Pair (0,2): center + vertex 3 → 2.
        let e02 = q.adj[0].iter().find(|&&(b, _)| b == 2).unwrap();
        assert_eq!(e02.1, 2.0);
    }
}
